#!/usr/bin/env python
"""Fail on broken relative links in markdown files.

    python tools/check_links.py README.md docs

Each argument is a markdown file or a directory scanned for ``*.md``.
Checks every inline ``[text](target)`` whose target is not an absolute
URL (``http(s)://``, ``mailto:``) or a pure in-page anchor (``#...``):
the referenced path must exist relative to the file's directory.
Fragments are checked when the target file is markdown: ``page.md#some
-heading`` must match a heading slug (GitHub-style: lowercase, spaces
to dashes, punctuation dropped) in the target file.  Exits non-zero
listing every broken link.
"""
from __future__ import annotations

import functools
import re
import sys
from pathlib import Path

_LINK = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
_CODE_FENCE = re.compile(r"^(```|~~~)")


def _slug(heading: str) -> str:
    h = heading.strip().lower()
    h = re.sub(r"[`*_]", "", h)
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


@functools.lru_cache(maxsize=None)
def _anchors(md: Path) -> set:
    out = set()
    in_fence = False
    for line in md.read_text(encoding="utf-8").splitlines():
        if _CODE_FENCE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence and line.startswith("#"):
            out.add(_slug(line.lstrip("#")))
    return out


def check_file(md: Path) -> list:
    errors = []
    text = md.read_text(encoding="utf-8")
    # strip fenced code blocks: example links in code aren't contracts
    lines, in_fence, kept = text.splitlines(), False, []
    for line in lines:
        if _CODE_FENCE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence:
            kept.append(line)
    for target in _LINK.findall("\n".join(kept)):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, frag = target.partition("#")
        if not path_part:        # in-page anchor: check against self
            if _slug(frag) not in _anchors(md):
                errors.append(f"{md}: broken anchor ({target})")
            continue
        dest = (md.parent / path_part).resolve()
        if not dest.exists():
            errors.append(f"{md}: broken link ({target})")
        elif frag and dest.suffix == ".md":
            if _slug(frag) not in _anchors(dest):
                errors.append(f"{md}: broken fragment ({target})")
    return errors


def main(argv: list) -> int:
    files: list = []
    for a in argv:
        p = Path(a)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            files.append(p)
        else:
            print(f"check_links: no such path {a}", file=sys.stderr)
            return 2
    errors = []
    for f in files:
        errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} files, {len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or ["README.md", "docs"]))
