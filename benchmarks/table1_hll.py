"""Paper Table 1: cost and relative error of the per-bucket HLLs.

For each (synthetic analogue of the paper's four) dataset:
  %Cost  = time(bucket-count + HLL merge + estimate) / time(full hybrid
           query path), averaged over the radius where LSH search wins
           (the paper's setting);
  %Error = |candSize_hll - candSize_exact| / candSize_exact averaged
           over the 100-query set (exact candSize = distinct union of
           the L probed buckets, computed offline in numpy).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import DATASETS, build_index, pick_radii, prep, timed


def exact_cand_sizes(idx, queries) -> np.ndarray:
    """Distinct union of the query's buckets across tables (ground truth)."""
    qb = np.asarray(idx._bucket_fn(idx.params, jnp.asarray(queries)))
    perm = np.asarray(idx.tables.perm)
    starts = np.asarray(idx.tables.starts)
    out = np.zeros(len(qb), np.int64)
    for i, row in enumerate(qb):
        seen = set()
        for j, b in enumerate(row):
            lo, hi = starts[j, b], starts[j, b + 1]
            seen.update(perm[j, lo:hi].tolist())
        out[i] = len(seen)
    return out


def run(scale: float = 0.2, seed: int = 0) -> List[Dict]:
    rows = []
    for name in DATASETS:
        x, q, metric = prep(name, scale, seed=seed)
        radii = pick_radii(x, metric)
        r = radii[1]  # small radius: LSH clearly beats linear (paper)
        m = 128
        idx = build_index(name, x, metric, r, m=m, seed=seed)
        qj = jnp.asarray(q)

        est = idx.estimate(qj)
        exact = exact_cand_sizes(idx, q)
        errs = np.abs(np.asarray(est.cand_est) - exact) / np.maximum(exact, 1)

        def estimate_only(queries):
            return idx.estimate(queries).cand_est

        t_est = timed(estimate_only, qj)
        t_query = timed(lambda qq: idx.query(qq, r).route.cand_est, qj)
        rows.append({
            "dataset": name, "n": x.shape[0], "metric": metric, "r": r,
            "m": m, "L": idx.family.L, "k": idx.family.k,
            "pct_cost": 100.0 * t_est / max(t_query, 1e-9),
            "pct_error": 100.0 * float(np.mean(errs)),
            "pct_error_std": 100.0 * float(np.std(errs)),
            "us_per_call": 1e6 * t_est,
        })
    return rows


def main(scale: float = 0.2):
    rows = run(scale)
    print("table1,dataset,n,pct_cost,pct_error,pct_error_std,us_per_call")
    for r in rows:
        print(f"table1,{r['dataset']},{r['n']},{r['pct_cost']:.2f},"
              f"{r['pct_error']:.2f},{r['pct_error_std']:.2f},"
              f"{r['us_per_call']:.1f}")
    return rows


if __name__ == "__main__":
    main()
