"""Benchmark orchestrator — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--scale 0.2] [--quick]

Prints ``name,us_per_call,derived`` CSV rows per the harness contract,
plus the per-table CSV blocks.  The roofline report (dry-run derived)
is appended when results/dryrun JSONs exist.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.12,
                    help="dataset size fraction of the paper's sizes")
    ap.add_argument("--quick", action="store_true",
                    help="tiny scale for CI (0.03)")
    ap.add_argument("--emit", metavar="PATH", default=None,
                    help="run a streaming benchmark and write its JSON; "
                         "--emit BENCH_streaming.json runs the single-host "
                         "bench, --emit BENCH_sharded.json the mesh-sharded "
                         "one (>= 2 host devices forced), --emit "
                         "BENCH_lsm.json the LSM compaction-stall bench, "
                         "--emit BENCH_async.json the serving-thread stall "
                         "comparison (tick-based vs async CompactionDriver), "
                         "--emit BENCH_rebalance.json the skewed-stream "
                         "placement comparison (>= 2 host devices forced), "
                         "--emit BENCH_obs.json the observability overhead "
                         "+ misroute-rate bench, --emit BENCH_kernels.json "
                         "the fused-vs-composed kernel comparison, --emit "
                         "BENCH_serve.json the closed-loop serving "
                         "throughput bench (coalescing + result cache vs "
                         "naive), --emit BENCH_serve_mt.json the multi-"
                         "tenant flood-isolation bench (per-tenant token "
                         "buckets under a noisy neighbor), --emit "
                         "BENCH_recovery.json the checkpoint-stall + "
                         "warm-standby recovery bench (>= 2 host devices "
                         "forced for the elastic restore). Skips the "
                         "paper tables")
    args = ap.parse_args()
    scale = 0.03 if args.quick else args.scale

    def force_two_host_devices():
        # must precede the first jax import in this process
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count=2").strip()

    if args.emit and "rebalance" in os.path.basename(args.emit):
        force_two_host_devices()
        from benchmarks import sharded_bench
        print("name,us_per_call,derived")
        t0 = time.time()
        rows = sharded_bench.skew_main(scale, emit=args.emit)
        print(f"rebalance_p99_keep_local,"
              f"{1e6 * rows['p99_keep_local_s']:.1f},"
              f"linear route; max-shard frac "
              f"{rows['max_shard_frac_keep_local']:.2f}, "
              f"padded rows {rows['sum_n_pad_keep_local']}")
        print(f"rebalance_p99_load_balance,"
              f"{1e6 * rows['p99_load_balance_s']:.1f},"
              f"linear route; max-shard frac "
              f"{rows['max_shard_frac_load_balance']:.2f}, "
              f"padded rows {rows['sum_n_pad_load_balance']} "
              f"({rows['rows_moved_load_balance']} rows moved)")
        print(f"rebalance_skew_latency_delta,"
              f"{1e6 * rows['skew_latency_delta_s']:.1f},"
              f"linear-route p99 cut {rows['p99_keep_local_s'] / max(rows['p99_load_balance_s'], 1e-12):.2f}x; "
              f"padded-rows cut {rows['padded_rows_cut']:.2f}x")
        print(f"total_bench_seconds,{1e6*(time.time()-t0):.0f},"
              f"scale={scale} -> {args.emit}")
        return

    if args.emit and "recovery" in os.path.basename(args.emit):
        force_two_host_devices()
        from benchmarks import recovery_bench
        print("name,us_per_call,derived")
        t0 = time.time()
        rows = recovery_bench.main(scale, emit=args.emit)
        print(f"recovery_cut_stall,"
              f"{1e6 * rows['cut_checkpoint_stall_s']:.1f},"
              f"consistent-cut incremental snapshot with "
              f"{rows['pending_merges_at_cut']} merges queued "
              f"(flush barrier: "
              f"{1e3 * rows['flush_checkpoint_stall_s']:.1f}ms; "
              f"stall cut {rows['snapshot_stall_cut']:.1f}x)")
        print(f"recovery_incremental_bytes,{0:.1f},"
              f"{rows['incremental_save_bytes']} of "
              f"{rows['full_state_bytes']} state bytes rewritten "
              f"({100 * rows['incremental_bytes_frac']:.1f}%; "
              f"{rows['chunks_reused']} chunks reused)")
        print(f"recovery_restore,"
              f"{1e6 * rows['restore_s']:.1f},"
              f"warm-standby restore, identical="
              f"{rows['restore_identical']}; elastic 2->1 "
              f"{rows['elastic_restore_s']}s, identical="
              f"{rows['elastic_identical']}")
        print(f"total_bench_seconds,{1e6*(time.time()-t0):.0f},"
              f"scale={scale} -> {args.emit}")
        return

    if args.emit and "kernel" in os.path.basename(args.emit):
        from benchmarks import kernel_bench
        t0 = time.time()
        out = kernel_bench.main(scale, emit=args.emit)
        worst = min(r["fused_speedup_composed"]
                    for k, r in out["routes"].items())
        print(f"kernel_fused_min_speedup,{0:.1f},"
              f"{worst:.2f}x composed (impl={out['impl']}, "
              f"tpu={out['on_tpu']})")
        print(f"total_bench_seconds,{1e6*(time.time()-t0):.0f},"
              f"scale={scale} -> {args.emit}")
        return

    # "serve_mt" must dispatch before the "serve" substring check below
    if args.emit and "serve_mt" in os.path.basename(args.emit):
        from benchmarks import serve_bench
        print("name,us_per_call,derived")
        t0 = time.time()
        rows = serve_bench.multi_tenant_main(scale, emit=args.emit)
        print(f"serve_mt_quiet_p99_solo,"
              f"{1e6 * rows['quiet_p99_solo_s']:.0f},"
              f"quiet-tenant p99 with no flood")
        print(f"serve_mt_quiet_p99_flood,"
              f"{1e6 * rows['quiet_p99_flood_s']:.0f},"
              f"isolation ratio {rows['isolation_ratio_p99']:.2f}x "
              f"(no-quota counterfactual "
              f"{rows['noquota_ratio_p99']:.2f}x); flood "
              f"{rows['noisy_rejected']} rejected / "
              f"{rows['noisy_admitted']} admitted at the token bucket")
        print(f"total_bench_seconds,{1e6*(time.time()-t0):.0f},"
              f"scale={scale} -> {args.emit}")
        return

    if args.emit and "serve" in os.path.basename(args.emit):
        from benchmarks import serve_bench
        print("name,us_per_call,derived")
        t0 = time.time()
        rows = serve_bench.main(scale, emit=args.emit)
        for mode in ("naive", "coalesced", "coalesced_cache"):
            m = rows["modes"][mode]
            print(f"serve_sustained_qps_{mode},"
                  f"{1e6 / max(m['sustained_qps'], 1e-9):.1f},"
                  f"{m['sustained_qps']:.0f} qps sustained "
                  f"(p99 {1e3 * m['p99_s_at_sustained']:.1f}ms vs SLO "
                  f"{1e3 * rows['slo_s']:.0f}ms; capacity "
                  f"{m['capacity_qps']:.0f} qps)")
        print(f"serve_speedup_vs_naive,{0:.1f},"
              f"coalesced {rows['speedup_coalesced_vs_naive']:.1f}x, "
              f"+cache {rows['speedup_cache_vs_naive']:.1f}x at "
              f"cold hit rate {rows['cache_hit_rate']:.2f} "
              f"({rows['n_distinct']}/{rows['n_requests']} distinct)")
        print(f"total_bench_seconds,{1e6*(time.time()-t0):.0f},"
              f"scale={scale} -> {args.emit}")
        return

    if args.emit and "obs" in os.path.basename(args.emit):
        from benchmarks import obs_bench
        print("name,us_per_call,derived")
        t0 = time.time()
        rows = obs_bench.main(scale, emit=args.emit)
        print(f"obs_query_disabled,"
              f"{1e6 * rows['query_s_disabled']:.1f},"
              f"per {rows['n_queries']}-query batch, n={rows['n']}")
        print(f"obs_query_enabled,"
              f"{1e6 * rows['query_s_enabled']:.1f},"
              f"overhead {100 * rows['obs_overhead_frac']:.2f}% at "
              f"sample_every={rows['trace_sample_every']} "
              f"(every-batch tracing: "
              f"{100 * rows['trace_overhead_frac']:.1f}%)")
        print(f"obs_misroute_rate,{0:.1f},"
              f"{rows['misroute_rate']:.4f} over {rows['queries_traced']} "
              f"traced queries; frac_lsh {rows['frac_lsh']:.2f}")
        print(f"total_bench_seconds,{1e6*(time.time()-t0):.0f},"
              f"scale={scale} -> {args.emit}")
        return

    if args.emit and "sharded" in os.path.basename(args.emit):
        force_two_host_devices()
        from benchmarks import sharded_bench
        print("name,us_per_call,derived")
        t0 = time.time()
        rows = sharded_bench.main(scale, emit=args.emit)
        print(f"sharded_churn_throughput,"
              f"{1e6 / max(rows['churn_docs_per_s'], 1e-9):.1f},"
              f"{rows['churn_docs_per_s']:.0f} docs/s over "
              f"{rows['shards']} shards")
        print(f"sharded_query_per_shard,"
              f"{1e6 * rows['query_batch_s_per_shard']:.1f},"
              f"{rows['query_batch_s_per_shard'] / max(rows['query_batch_s_global'], 1e-12):.2f}x global "
              f"(after compact: "
              f"{rows['query_batch_s_after_compact'] / max(rows['query_batch_s_global'], 1e-12):.2f}x)")
        print(f"total_bench_seconds,{1e6*(time.time()-t0):.0f},"
              f"scale={scale} -> {args.emit}")
        return

    if args.emit and "async" in os.path.basename(args.emit):
        from benchmarks import lsm_bench
        print("name,us_per_call,derived")
        t0 = time.time()
        rows = lsm_bench.async_main(scale, emit=args.emit)
        print(f"async_serving_maint_tick,"
              f"{1e6 * rows['serving_maint_s_tick']:.1f},"
              f"serving-thread compaction s over {rows['rounds']} rounds "
              f"(budgeted ticks)")
        print(f"async_serving_maint_driver,"
              f"{1e6 * rows['serving_maint_s_driver']:.1f},"
              f"driver drain() only; {rows['driver_stage_calls']} gathers "
              f"on the worker, {rows['driver_applied']} swaps applied")
        print(f"async_serving_stall_cut,{0:.1f},"
              f"{rows['serving_stall_cut']:.1f}x less serving-thread "
              f"compaction time; round p99 "
              f"{1e3 * rows['driver_round_p99_s']:.1f}ms vs "
              f"{1e3 * rows['tick_round_p99_s']:.1f}ms tick")
        print(f"total_bench_seconds,{1e6*(time.time()-t0):.0f},"
              f"scale={scale} -> {args.emit}")
        return

    if args.emit and "lsm" in os.path.basename(args.emit):
        from benchmarks import lsm_bench
        print("name,us_per_call,derived")
        t0 = time.time()
        rows = lsm_bench.main(scale, emit=args.emit)
        print(f"lsm_round_p99_budgeted,"
              f"{1e6 * rows['budgeted_round_p99_s']:.1f},"
              f"max {1e3 * rows['budgeted_round_max_s']:.1f}ms over "
              f"{rows['n_churn']} churned docs")
        print(f"lsm_stall_cut_vs_monolithic,{0:.1f},"
              f"{rows['stall_cut_vs_monolithic']:.1f}x lower worst-case "
              f"query-batch stall (vs sync tiered: "
              f"{rows['stall_cut_vs_sync']:.1f}x)")
        print(f"lsm_insert_throughput,"
              f"{1e6 / max(rows['insert_docs_per_s'], 1e-9):.1f},"
              f"{rows['insert_docs_per_s']:.0f} docs/s; merges/level "
              f"{rows['budgeted_merges_per_level']}")
        print(f"total_bench_seconds,{1e6*(time.time()-t0):.0f},"
              f"scale={scale} -> {args.emit}")
        return

    if args.emit:
        from benchmarks import streaming_bench
        print("name,us_per_call,derived")
        t0 = time.time()
        rows = streaming_bench.main(scale, emit=args.emit)
        print(f"streaming_insert_throughput,"
              f"{1e6 / max(rows['insert_docs_per_s'], 1e-9):.1f},"
              f"{rows['insert_docs_per_s']:.0f} docs/s")
        print(f"streaming_insert_vs_rebuild,{0:.1f},"
              f"{rows['speedup_insert_vs_rebuild']:.1f}x faster than "
              f"full rebuild of n={rows['n']}+{rows['n_insert']}")
        print(f"streaming_query_overhead,"
              f"{1e6 * rows['query_batch_s_dynamic']:.1f},"
              f"{rows['query_batch_s_dynamic'] / max(rows['query_batch_s_static'], 1e-12):.2f}x static "
              f"(after compact: "
              f"{rows['query_batch_s_after_compact'] / max(rows['query_batch_s_static'], 1e-12):.2f}x)")
        print(f"total_bench_seconds,{1e6*(time.time()-t0):.0f},"
              f"scale={scale} -> {args.emit}")
        return

    from benchmarks import fig2_hybrid, fig3_output, kernel_bench, table1_hll
    from benchmarks import roofline_report

    print("name,us_per_call,derived")
    t0 = time.time()
    kernel_bench.main()

    rows1 = table1_hll.main(scale)
    mean_err = sum(r["pct_error"] for r in rows1) / len(rows1)
    print(f"table1_mean_hll_error,{0:.1f},{mean_err:.2f}%"
          f" (paper: <7%; theory m=128: 9.2%)")

    rows2 = fig2_hybrid.main(scale)
    vs_lsh = sum(1 for r in rows2 if r["hybrid_s"] <= 1.1 * r["lsh_s"])
    near_best = sum(1 for r in rows2 if r["hybrid_s"] <= max(
        2.0 * min(r["lsh_s"], r["linear_s"]),
        min(r["lsh_s"], r["linear_s"]) + 0.01))
    print(f"fig2_hybrid_vs_lsh,{0:.1f},{vs_lsh}/{len(rows2)} radii with "
          f"hybrid <= 1.1x LSH-only (paper: hybrid never loses to LSH)")
    print(f"fig2_hybrid_near_best,{0:.1f},{near_best}/{len(rows2)} radii "
          f"with hybrid within 2x/10ms of best single strategy")

    rows3 = fig3_output.main(scale)
    mono = all(rows3[i]["pct_linear_calls"] <= rows3[i + 1]
               ["pct_linear_calls"] + 1e-9 for i in range(len(rows3) - 1))
    print(f"fig3_linear_calls_monotone,{0:.1f},{mono}")

    try:
        roofline_report.main()
    except Exception as e:  # dry-run results may not exist yet
        print(f"roofline_report,0.0,skipped ({e})")
    print(f"total_bench_seconds,{1e6*(time.time()-t0):.0f},"
          f"scale={scale}")


if __name__ == "__main__":
    main()
