"""Observability benchmark: query-path overhead + measured misroute rate.

Two questions, one synthetic corpus (docs/observability.md):

1. **What does tracing cost?**  The same query batch is timed in three
   modes: ``QueryTracer`` disabled (the production fast path — one
   attribute check), enabled at the default ``sample_every=16`` (one
   traced batch in sixteen — what a production service pays), and
   enabled at ``sample_every=1`` (every batch traced: phase-synced
   timings + the ``count_candidates`` pass that prices the actual
   candidate set — the debug setting).  Passes are interleaved and the
   min per mode is taken, so container hiccups only inflate, never
   flatter; the sampled mode is timed over exactly ``sample_every``
   batches so each window amortizes exactly one traced batch.
   ``obs_overhead_frac`` (enabled-default vs disabled) is asserted
   < 5% in CI; ``trace_overhead_frac`` (every-batch vs disabled) is
   reported for docs/observability.md but not gated — pricing the
   actual candidate set is real device work (~an extra gather+dedupe),
   and sampling, not wishful timing, is what keeps it off the SLO.

2. **Is the router's cost model calibrated?**  The corpus is a
   mixed-density ladder: a handful of tight clusters sized geometrically
   *around the Eq. (1)/(2) crossover* (with beta=1 and L tables a
   cluster of ~n/(L+1) rows prices identically under both strategies)
   plus scattered background rows.  Queries from the border clusters
   land where the HLL candSize error (m=32, ~18% stderr) and the
   gather-cap truncation can flip the decision, so the tracer's derived
   ``misroute_rate`` is nonzero without being degenerate — exactly the
   signal the spans exist to expose.  Queries from deep clusters and
   background route unambiguously and keep the rate well below 1.

A churn phase (inserts past the delta capacity) runs before timing so
the event log records the real freeze → merge_scheduled → swap
lifecycle and the per-phase ``work_seconds`` accumulator is nonzero;
both are emitted for the CI asserts.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CostModel
from repro.core.lsh import make_family
from repro.obs import SPAN_FIELDS, Observability
from repro.streaming import CompactionPolicy, DynamicHybridIndex

# clusters sized relative to the crossover k* = n_scan/(L+beta/alpha):
# the outer rungs route unambiguously, the dense middle rungs straddle
# the boundary (HLL candSize error and gather-cap truncation flip them)
LADDER = (0.6, 0.9, 1.0, 1.05, 1.1, 1.2, 1.5)
D = 16
L = 8


def _corpus(n: int, rng: np.random.Generator):
    """Mixed-density rows: crossover-ladder clusters + background.

    ``n`` here is the final *scan* size — the caller keeps every frozen
    segment power-of-two so no pad rows inflate the linear cost and the
    ladder's crossover math stays exact.  Returns (x, cluster_slices)
    with clusters contiguous — the query sampler wants membership.
    """
    k_star = n / (L + 1.0)            # alpha=1, beta=1: cost ~ (L+1)*k
    sizes = [max(int(f * k_star), 8) for f in LADDER]
    n_bg = n - sum(sizes)
    assert n_bg > 0, "corpus too small for the ladder"
    centers = rng.normal(size=(len(sizes), D)) * 8.0
    parts, slices, lo = [], [], 0
    for c, k in zip(centers, sizes):
        parts.append(c + rng.normal(size=(k, D)) * 0.003)
        slices.append((lo, lo + k))
        lo += k
    parts.append(rng.normal(size=(n_bg, D)) * 2.0)
    return np.concatenate(parts).astype(np.float32), slices


def _queries(x: np.ndarray, slices, rng: np.random.Generator,
             per_cluster: int, total: int) -> np.ndarray:
    idx = []
    for lo, hi in slices:
        idx.extend(rng.integers(lo, hi, size=per_cluster).tolist())
    bg_lo = slices[-1][1]
    idx.extend(rng.integers(bg_lo, len(x), size=total - len(idx)).tolist())
    return x[np.asarray(idx)]


def _timed_pass(idx, q, r: float, reps: int) -> float:
    t0 = time.perf_counter()
    for _ in range(reps):
        res = idx.query(q, r)
        for out in (res.lsh_out, res.lin_out):
            if out is not None:
                jax.block_until_ready(out[2])
    return time.perf_counter() - t0


def main(scale: float = 0.12, emit: str | None = None) -> Dict[str, object]:
    # Keep every frozen segment power-of-two so n_scan == n exactly:
    # linear cost is priced at segment *pad* sizes, and pad slack would
    # silently move the crossover the ladder is aimed at.  Build is a
    # pow2 block; churn is two exact delta fills (two level-0 freezes of
    # delta_capacity rows each, merged once by the fanout=2 policy).
    target = max(int(100000 * scale), 1500)
    n_build = 1 << int(np.log2(target * 0.8))
    delta_capacity = max(n_build // 8, 128)      # pow2 since n_build is
    n_churn = 2 * delta_capacity
    n = n_build + n_churn
    rng = np.random.default_rng(7)
    x, slices = _corpus(n, rng)
    perm = rng.permutation(n)          # interleave clusters/background so
    x_stream = x[perm]                 # churn batches carry a mix of both

    obs = Observability.create(trace_capacity=4096)
    obs.tracer.enabled = False
    idx = DynamicHybridIndex(
        make_family("l2", d=D, L=L, r=1.0), num_buckets=512, m=32,
        cap=128, delta_capacity=delta_capacity,
        cost_model=CostModel(alpha=1.0, beta=1.0),
        policy=CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                                fanout=2),
        key=0, obs=obs)
    idx.build(x_stream[:n_build])

    # churn: freezes + synchronous merges populate the event log and the
    # per-phase work accumulator
    chunk = delta_capacity // 2
    for lo in range(n_build, n, chunk):
        idx.insert(x_stream[lo:lo + chunk])

    q = jnp.asarray(_queries(x, slices, rng, per_cluster=16, total=128))
    r = 1.0
    reps = 3
    sample_every = obs.tracer.sample_every     # the production default

    # warm both compiled paths (jit caches) before any timing
    _timed_pass(idx, q, r, 1)
    obs.tracer.enabled = True
    obs.tracer.sample_every = 1
    _timed_pass(idx, q, r, 1)

    t_dis, t_full, t_samp = [], [], []
    for _ in range(3):                 # interleaved: drift hits all modes
        obs.tracer.enabled = False
        t_dis.append(_timed_pass(idx, q, r, reps))
        obs.tracer.enabled = True
        obs.tracer.sample_every = 1    # every batch traced (debug mode)
        t_full.append(_timed_pass(idx, q, r, reps))
        # default sampled mode: time exactly sample_every batches, so
        # each window amortizes exactly one traced batch
        obs.tracer.sample_every = sample_every
        t_samp.append(_timed_pass(idx, q, r, sample_every))
    query_s_disabled = min(t_dis) / reps
    query_s_traced = min(t_full) / reps
    query_s_enabled = min(t_samp) / sample_every
    overhead = query_s_enabled / max(query_s_disabled, 1e-12) - 1.0
    trace_overhead = query_s_traced / max(query_s_disabled, 1e-12) - 1.0

    summary = obs.tracer.summary()
    spans = obs.tracer.spans()
    stats = idx.index_stats()
    out = {
        "n": int(idx.n), "d": D, "tables": L, "num_buckets": 512,
        "m": 32, "cap": 128, "beta_over_alpha": 1.0, "scale": scale,
        "ladder": list(LADDER), "n_queries": int(q.shape[0]),
        "reps": reps,
        "trace_sample_every": sample_every,
        "query_s_disabled": query_s_disabled,
        "query_s_enabled": query_s_enabled,
        "query_s_traced": query_s_traced,
        "obs_overhead_frac": overhead,
        "trace_overhead_frac": trace_overhead,
        "queries_traced": summary["queries"],
        "misroutes": summary["misroutes"],
        "misroute_rate": summary["misroute_rate"],
        "frac_lsh": summary["frac_lsh"],
        "by_route": summary["by_route"],
        "spans_lsh": sum(1 for s in spans if s["strategy"] == "lsh"),
        "spans_linear": sum(1 for s in spans if s["strategy"] == "linear"),
        "span_fields": list(SPAN_FIELDS),
        "events_by_kind": obs.events.counts_by_kind(),
        "events_dropped": obs.events.dropped,
        "work_seconds": stats["work_seconds"],
        "segments": stats["segments"],
    }
    if emit:
        with open(emit, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.12)
    ap.add_argument("--emit", default=None)
    args = ap.parse_args()
    print(json.dumps(main(args.scale, emit=args.emit), indent=2))
