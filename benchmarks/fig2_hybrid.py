"""Paper Figure 2: query-set CPU time of Hybrid vs LSH vs Linear search
across radii on the four (synthetic analogue) datasets.

The paper's claim to validate: hybrid ~= LSH at small radii, beats LSH
as radii grow (hard queries appear), converges to linear; on the
webspam-like skewed dataset hybrid beats BOTH at moderate radii.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import DATASETS, build_index, pick_radii, prep, timed


def run(scale: float = 0.2, seed: int = 0,
        datasets=DATASETS) -> List[Dict]:
    rows = []
    for name in datasets:
        x, q, metric = prep(name, scale, seed=seed)
        qj = jnp.asarray(q)
        for r in pick_radii(x, metric):
            idx = build_index(name, x, metric, r, seed=seed)

            def t(force):
                # fresh partition each call; timing includes routing
                return timed(lambda: idx.query(qj, r, force=force),
                             warmup=1, iters=3)

            t_hybrid = t(None)
            t_lsh = t("lsh")
            t_linear = t("linear")
            res = idx.query(qj, r)
            rows.append({
                "dataset": name, "r": round(r, 5),
                "hybrid_s": t_hybrid, "lsh_s": t_lsh, "linear_s": t_linear,
                "frac_linear": res.frac_linear,
                "mean_collisions": float(np.mean(
                    np.asarray(res.route.collisions))),
                "mean_cand_est": float(np.mean(
                    np.asarray(res.route.cand_est))),
            })
    return rows


def main(scale: float = 0.2, datasets=DATASETS):
    rows = run(scale, datasets=datasets)
    print("fig2,dataset,r,hybrid_s,lsh_s,linear_s,frac_linear")
    for r in rows:
        print(f"fig2,{r['dataset']},{r['r']},{r['hybrid_s']:.4f},"
              f"{r['lsh_s']:.4f},{r['linear_s']:.4f},"
              f"{r['frac_linear']:.2f}")
    return rows


if __name__ == "__main__":
    main()
