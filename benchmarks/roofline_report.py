"""Build the EXPERIMENTS.md roofline table from results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def load(tag: str = "") -> List[Dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("tag", "") == tag:
            rows.append(r)
    return rows


def fmt_table(rows: List[Dict], mesh: str = "16x16") -> str:
    head = ("| arch | shape | compute s | memory s | collective s | "
            "dominant | model/HLO flops | roofline frac | GiB/dev |\n"
            "|---|---|---|---|---|---|---|---|---|\n")
    out = [head]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | — | — |\n")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"ERROR | — | — | — |\n")
            continue
        t = r["terms"]
        gib = r["memory"].get("total_bytes_per_device", 0) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{t['dominant']} | {t.get('useful_flops_ratio', 0):.2f} | "
            f"{t.get('roofline_fraction', 0):.3f} | {gib:.2f} |\n")
    return "".join(out)


def fmt_compare(base: List[Dict], opt: List[Dict],
                mesh: str = "16x16") -> str:
    """Baseline vs optimized-flags bound + roofline fraction."""
    key = lambda r: (r["arch"], r["shape"])
    omap = {key(r): r for r in opt if r.get("mesh") == mesh
            and r["status"] == "ok"}
    out = ["| arch | shape | base bound s | opt bound s | speedup | "
           "base frac | opt frac |\n|---|---|---|---|---|---|---|\n"]
    for r in base:
        if r.get("mesh") != mesh or r["status"] != "ok":
            continue
        o = omap.get(key(r))
        if o is None:
            continue
        bb = max(r["terms"]["compute_s"], r["terms"]["memory_s"],
                 r["terms"]["collective_s"])
        ob = max(o["terms"]["compute_s"], o["terms"]["memory_s"],
                 o["terms"]["collective_s"])
        out.append(
            f"| {r['arch']} | {r['shape']} | {bb:.3e} | {ob:.3e} | "
            f"{bb / max(ob, 1e-12):.2f}x | "
            f"{r['terms'].get('roofline_fraction', 0):.4f} | "
            f"{o['terms'].get('roofline_fraction', 0):.4f} |\n")
    return "".join(out)


def main():
    rows = load()
    ok = [r for r in rows if r["status"] == "ok"]
    err = [r for r in rows if r["status"] == "error"]
    skip = [r for r in rows if r["status"] == "skipped"]
    print(f"# cells: ok={len(ok)} skipped={len(skip)} error={len(err)}")
    for mesh in ("16x16", "2x16x16"):
        sub = [r for r in rows if r.get("mesh") == mesh]
        if sub:
            print(f"\n## mesh {mesh}\n")
            print(fmt_table(rows, mesh))
    opt = load(tag="opt")
    if opt:
        print("\n## baseline vs optimized flags (16x16) — see "
              "EXPERIMENTS.md §Perf\n")
        print(fmt_compare(rows, opt))
    for r in err:
        print("ERROR:", r["arch"], r["shape"], r["mesh"],
              r.get("error", "")[:200])


if __name__ == "__main__":
    main()
