"""Kernel micro-benchmarks: CPU-path (ref) timings + Pallas interpret
correctness spot check.  On TPU the same ops dispatch to the Pallas
kernels; interpret-mode timings are not meaningful, so we report the
ref path (what the CPU benchmarks actually execute) and the kernel's
VMEM working set per tile (the quantity that matters on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.kernels import ops


def main():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16384, 256)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32))
    rows = []

    for metric in ("l2", "l1", "cosine"):
        f = jax.jit(lambda a, b, m=metric: ops.pairwise_dist(a, b, m))
        t = timed(f, q, x)
        gflops = 2 * q.shape[0] * x.shape[0] * x.shape[1] / t / 1e9
        rows.append((f"dist_{metric}", 1e6 * t, f"{gflops:.1f}GFLOP/s"))

    qc = jnp.asarray(rng.integers(0, 2**32, (256, 2), dtype=np.uint32))
    xc = jnp.asarray(rng.integers(0, 2**32, (16384, 2), dtype=np.uint32))
    f = jax.jit(ops.hamming_dist)
    rows.append(("hamming", 1e6 * timed(f, qc, xc), "64-bit codes"))

    r = jnp.asarray(rng.normal(size=(256, 20 * 24)).astype(np.float32))
    f = jax.jit(lambda a, b: ops.simhash_fingerprint(a, b, L=20, k=24))
    rows.append(("simhash", 1e6 * timed(f, x, r), "L=20 k=24"))

    regs = jnp.asarray(rng.integers(0, 24, (256, 20, 128)), jnp.uint8)
    f = jax.jit(ops.hll_merge_estimate)
    rows.append(("hll_merge", 1e6 * timed(f, regs), "m=128 L=20"))

    print("kernel,us_per_call,derived")
    for name, us, derived in rows:
        print(f"kernel_{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()
