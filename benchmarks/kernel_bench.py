"""Kernel micro-benchmarks + fused-vs-composed query-path comparison.

Two sections:

  * legacy micro rows — per-op ref-path timings (CPU) with the derived
    throughput column, unchanged CSV contract.
  * fused scan comparison — for each route (linear, lsh) x metric
    (l2, l1, cosine, hamming), time the fused kernel entry point
    (``ops.fused_linear_scan`` / ``ops.fused_lsh_scan``) against the
    composed pipeline it replaces (pairwise_dist -> compare ->
    broadcast ids; dedupe_sorted -> x[ids] -> rowwise_dist -> compare),
    and price both against the analytic HBM-traffic roofline
    (``launch.roofline.{linear,lsh}_scan_traffic`` / ``HBM_BW``).

On CPU hosts both variants dispatch to the same jnp oracles, so the
speedup hovers around 1.0 — the figure is meaningful on TPU, where the
fused path deletes the intermediate HBM round-trips the traffic model
counts.  ``--emit BENCH_kernels.json`` writes the machine-readable
results (schema: docs/benchmarks.md); CI asserts the schema and that
every ``fused_speedup_composed`` entry is finite, and only asserts
speedup > 1 on a real TPU backend.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.core.lsh.tables import build_tables, gather_candidates
from repro.core.search import dedupe_sorted, rowwise_dist
from repro.kernels import ops
from repro.launch import roofline

_METRIC_RADII = {"l2": 7.0, "l1": 60.0, "cosine": 0.3, "hamming": 24.0}


def _micro_rows(rng):
    """Legacy per-op micro benchmarks (ref path on CPU)."""
    x = jnp.asarray(rng.normal(size=(16384, 256)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32))
    rows = []

    for metric in ("l2", "l1", "cosine"):
        f = jax.jit(lambda a, b, m=metric: ops.pairwise_dist(a, b, m))
        t = timed(f, q, x)
        gflops = 2 * q.shape[0] * x.shape[0] * x.shape[1] / t / 1e9
        rows.append((f"dist_{metric}", 1e6 * t, f"{gflops:.1f}GFLOP/s"))

    qc = jnp.asarray(rng.integers(0, 2**32, (256, 2), dtype=np.uint32))
    xc = jnp.asarray(rng.integers(0, 2**32, (16384, 2), dtype=np.uint32))
    f = jax.jit(ops.hamming_dist)
    rows.append(("hamming", 1e6 * timed(f, qc, xc), "64-bit codes"))

    r = jnp.asarray(rng.normal(size=(256, 20 * 24)).astype(np.float32))
    f = jax.jit(lambda a, b: ops.simhash_fingerprint(a, b, L=20, k=24))
    rows.append(("simhash", 1e6 * timed(f, x, r), "L=20 k=24"))

    regs = jnp.asarray(rng.integers(0, 24, (256, 20, 128)), jnp.uint8)
    f = jax.jit(ops.hll_merge_estimate)
    rows.append(("hll_merge", 1e6 * timed(f, regs), "m=128 L=20"))
    return rows


def _composed_linear(q, x, r, metric):
    """The pre-fusion linear route: full distance matrix -> compare."""
    if metric == "hamming":
        dists = ops.hamming_dist(q, x).astype(jnp.float32)
    else:
        dists = ops.pairwise_dist(q, x, metric)
    thresh = ops.metric_radius_transform(metric, r)
    mask = dists <= thresh
    ids = jnp.broadcast_to(
        jnp.arange(x.shape[0], dtype=jnp.int32)[None, :], dists.shape)
    return ids, dists, mask


def _composed_lsh(x, cands, q, r, metric):
    """The pre-fusion LSH verification: dedup -> gather -> rowwise."""
    n = x.shape[0]
    ids, uniq = dedupe_sorted(cands, n)
    rows = x[jnp.clip(ids, 0, n - 1)]
    dists = rowwise_dist(rows, q[:, None, :], metric).astype(jnp.float32)
    thresh = ops.metric_radius_transform(metric, r)
    mask = uniq & (dists <= thresh)
    return ids, dists, mask


def _route_rows(rng, scale: float) -> Dict[str, Dict[str, float]]:
    """Fused vs composed per route x metric, plus the roofline terms."""
    n = max(int(16384 * scale), 512)
    nq = max(int(256 * scale), 32)
    d, W = 128, 2
    L, B, cap = 8, max(n // 64, 16), 32
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(nq, d)).astype(np.float32))
    xc = jnp.asarray(rng.integers(0, 2**32, (n, W), dtype=np.uint32))
    qc = jnp.asarray(rng.integers(0, 2**32, (nq, W), dtype=np.uint32))
    bids = jnp.asarray(rng.integers(0, B, size=(n, L), dtype=np.int32))
    tables = build_tables(jnp.arange(n, dtype=jnp.int32), bids, B, 16)
    qb = jnp.asarray(rng.integers(0, B, size=(nq, L), dtype=np.int32))
    cands = jax.jit(gather_candidates, static_argnames=("cap", "sentinel"))(
        tables, qb, cap, n)
    c = int(cands.shape[1])

    out: Dict[str, Dict[str, float]] = {}
    for metric in ("l2", "l1", "cosine", "hamming"):
        r = _METRIC_RADII[metric]
        qq, xx = (qc, xc) if metric == "hamming" else (q, x)
        dim = W if metric == "hamming" else d

        fused = jax.jit(lambda a, b, m=metric, rr=r:
                        ops.fused_linear_scan(a, b, rr, m))
        comp = jax.jit(lambda a, b, m=metric, rr=r:
                       _composed_linear(a, b, rr, m))
        tf, tc_ = timed(fused, qq, xx), timed(comp, qq, xx)
        traffic = roofline.linear_scan_traffic(nq, n, dim)
        out[f"linear_{metric}"] = {
            "fused_s": tf, "composed_s": tc_,
            "fused_speedup_composed": tc_ / max(tf, 1e-12),
            "candidates_per_s": nq * n / max(tf, 1e-12),
            "fused_bytes": traffic["fused_bytes"],
            "composed_bytes": traffic["composed_bytes"],
            "roofline_fused_s": roofline.scan_memory_seconds(
                traffic["fused_bytes"]),
            "roofline_composed_s": roofline.scan_memory_seconds(
                traffic["composed_bytes"]),
        }

        fused = jax.jit(lambda a, cd, b, m=metric, rr=r:
                        ops.fused_lsh_scan(a, jnp.sort(cd, axis=-1), b,
                                           rr, m))
        comp = jax.jit(lambda a, cd, b, m=metric, rr=r:
                       _composed_lsh(a, cd, b, rr, m))
        tf, tc_ = timed(fused, xx, cands, qq), timed(comp, xx, cands, qq)
        traffic = roofline.lsh_scan_traffic(nq, c, dim)
        out[f"lsh_{metric}"] = {
            "fused_s": tf, "composed_s": tc_,
            "fused_speedup_composed": tc_ / max(tf, 1e-12),
            "candidates_per_s": nq * c / max(tf, 1e-12),
            "fused_bytes": traffic["fused_bytes"],
            "composed_bytes": traffic["composed_bytes"],
            "roofline_fused_s": roofline.scan_memory_seconds(
                traffic["fused_bytes"]),
            "roofline_composed_s": roofline.scan_memory_seconds(
                traffic["composed_bytes"]),
        }
    out["_shapes"] = {"n": n, "nq": nq, "d": d, "candidates": c}
    return out


def main(scale: float | None = None, emit: str | None = None):
    """Print the CSV rows; with ``emit`` also write BENCH_kernels.json."""
    rng = np.random.default_rng(0)
    rows = _micro_rows(rng)
    print("kernel,us_per_call,derived")
    for name, us, derived in rows:
        print(f"kernel_{name},{us:.1f},{derived}")

    if scale is None and emit is None:
        return rows          # legacy benchmarks.run call: micro rows only

    routes = _route_rows(rng, scale if scale is not None else 0.12)
    shapes = routes.pop("_shapes")
    for key, row in sorted(routes.items()):
        print(f"kernel_fused_{key},{1e6 * row['fused_s']:.1f},"
              f"{row['fused_speedup_composed']:.2f}x composed; "
              f"{row['candidates_per_s'] / 1e6:.1f}M cand/s; "
              f"roofline {1e6 * row['roofline_fused_s']:.1f}us")

    out = {
        "impl": ops.resolve_impl(None),
        "on_tpu": jax.default_backend() == "tpu",
        "backend": jax.default_backend(),
        "shapes": shapes,
        "hbm_bw": roofline.HBM_BW,
        "routes": routes,
    }
    if emit:
        with open(emit, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.12)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--emit", default=None)
    args = ap.parse_args()
    main(0.03 if args.quick else args.scale, emit=args.emit)
