"""Paper Figure 3: output-size distribution and %linear-search calls as
a function of the radius, on the webspam-like skewed dataset.

Validates: output sizes vary wildly (hard queries exist) and the
fraction of hybrid queries routed to linear search grows with r.
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_index, pick_radii, prep


def run(scale: float = 0.2, seed: int = 0,
        dataset: str = "webspam") -> List[Dict]:
    x, q, metric = prep(dataset, scale, seed=seed)
    qj = jnp.asarray(q)
    rows = []
    for r in pick_radii(x, metric, n_radii=4):
        idx = build_index(dataset, x, metric, r, seed=seed)
        res = idx.query(qj, r)
        sizes = np.array([len(res.neighbors(i))
                          for i in range(res.n_queries)])
        rows.append({
            "dataset": dataset, "r": round(r, 5),
            "out_mean": float(sizes.mean()),
            "out_max": int(sizes.max()), "out_min": int(sizes.min()),
            "pct_linear_calls": 100.0 * res.frac_linear,
        })
    return rows


def main(scale: float = 0.2):
    rows = run(scale)
    print("fig3,r,out_mean,out_max,out_min,pct_linear_calls")
    for r in rows:
        print(f"fig3,{r['r']},{r['out_mean']:.1f},{r['out_max']},"
              f"{r['out_min']},{r['pct_linear_calls']:.1f}")
    return rows


if __name__ == "__main__":
    main()
