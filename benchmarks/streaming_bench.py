"""Streaming index benchmark: incremental maintenance vs full rebuild.

Measures, at a given ``--scale``:

  * insert throughput into the delta segment (docs/s, steady state)
  * query latency on the streamed index vs a freshly rebuilt static one
  * the cost of keeping the corpus current: incremental insert+compact
    vs the full ``HybridLSHIndex.build()`` the static core would need

Emits a JSON blob (``--emit``) so the perf trajectory is tracked from
this PR on.
"""
from __future__ import annotations

import json
import time
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core import CostModel, HybridLSHIndex
from repro.core.lsh import make_family
from repro.data import clustered_dataset
from repro.streaming import CompactionPolicy, DynamicHybridIndex


def main(scale: float = 0.12, emit: str | None = None) -> Dict[str, float]:
    n = max(2000, int(50000 * scale))
    n_insert = max(256, n // 8)
    d, L, B, m, r = 16, 8, 1024, 64, 1.2
    rng = np.random.default_rng(0)
    x = np.asarray(clustered_dataset(n + n_insert, d, n_clusters=32,
                                     dense_core_frac=0.2, core_scale=0.05,
                                     seed=0, metric="l2"),
                   dtype=np.float32)
    q = x[rng.integers(0, n, 64)]
    fam = make_family("l2", d=d, L=L, r=1.0)

    def build_static(rows):
        idx = HybridLSHIndex(fam, num_buckets=B, m=m, cap=256, key=0,
                             cost_model=CostModel(alpha=1.0, beta=10.0))
        t0 = time.perf_counter()
        idx.build(jnp.asarray(rows))
        idx.query(jnp.asarray(q), r)          # warm query path
        return idx, time.perf_counter() - t0

    static, build_s = build_static(x[:n])

    dyn = DynamicHybridIndex(fam, num_buckets=B, m=m, cap=256,
                             delta_capacity=max(1024, n_insert),
                             cost_model=CostModel(alpha=1.0, beta=10.0),
                             policy=CompactionPolicy(delta_fill=2.0,
                                                     tombstone_ratio=2.0),
                             key=0)
    dyn.build(x[:n])
    dyn.insert(x[n:n + 64])                   # warm the insert path
    batch = 64
    t0 = time.perf_counter()
    for lo in range(n + 64, n + n_insert, batch):
        dyn.insert(x[lo:lo + batch])
    insert_s = time.perf_counter() - t0
    inserted = n_insert - 64

    # the static core's only way to absorb those docs: full rebuild
    _, rebuild_s = build_static(x[:n + n_insert])

    def time_query(idx, iters=5):
        idx.query(jnp.asarray(q), r)          # warm
        t0 = time.perf_counter()
        for _ in range(iters):
            idx.query(jnp.asarray(q), r)
        return (time.perf_counter() - t0) / iters

    q_static = time_query(static)
    q_dyn = time_query(dyn)                   # main + populated delta

    t0 = time.perf_counter()
    dyn.compact()
    compact_s = time.perf_counter() - t0
    q_dyn_compacted = time_query(dyn)

    out = {
        "n": n, "n_insert": inserted, "queries": 64,
        "insert_docs_per_s": inserted / max(insert_s, 1e-9),
        "insert_total_s": insert_s,
        "full_rebuild_s": rebuild_s,
        "initial_build_s": build_s,
        "speedup_insert_vs_rebuild": rebuild_s / max(insert_s, 1e-9),
        "query_batch_s_static": q_static,
        "query_batch_s_dynamic": q_dyn,
        "query_batch_s_after_compact": q_dyn_compacted,
        "compact_s": compact_s,
    }
    if emit:
        with open(emit, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
