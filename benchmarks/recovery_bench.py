"""Recovery benchmark: snapshot stall + warm-standby restore time.

Measures the incremental-checkpoint PR's headline claims at equal
churn and merge backlog:

  * snapshot stall — both disciplines checkpoint once (cold), take a
    second churn wave that queues fresh merge work, then checkpoint
    again; the SECOND checkpoint is timed.  The flush-barrier
    discipline pays O(pending compaction) of inline merge work plus a
    full-tree rewrite; the consistent-cut incremental discipline
    writes O(new delta + manifest) (unchanged frozen levels dedup
    against the chunk store by content address).
    ``snapshot_stall_cut`` is the ratio of the two steady-state
    checkpoint-call wall times.
  * incremental bytes — a second snapshot after delta-only churn
    rewrites only the delta, tombstones, and manifest;
    ``incremental_bytes_frac`` is its written bytes over the full
    flattened state size.
  * recovery time — restore into a FRESH index (the warm standby),
    asserted bit-identical on forced-route reported sets
    (``restore_identical``).  With >= 2 host devices the elastic path
    runs too: a 2-shard checkpoint taken mid-merge restored onto a
    1-shard mesh (``elastic_restore_s`` / ``elastic_identical``).

Each discipline gets one untimed warm run (jit caches) on its own
fresh index before the timed run, mirroring ``lsm_bench``.  Emits
``BENCH_recovery.json``; schema in docs/benchmarks.md, CI gate in the
``recovery-bench-smoke`` job.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import CostModel
from repro.core.lsh import make_family
from repro.data import clustered_dataset
from repro.streaming import CompactionPolicy, DynamicHybridIndex

R = 1.2


def _mk(fam, delta_capacity: int, budget: int) -> DynamicHybridIndex:
    policy = CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                              fanout=2, step_rows=budget)
    # cap must dominate any candidate set: the identity checks compare
    # full reported sets, and truncation order is not a restore
    # invariant
    return DynamicHybridIndex(fam, num_buckets=1024, m=64, cap=8192,
                              delta_capacity=delta_capacity,
                              cost_model=CostModel(alpha=1.0, beta=10.0),
                              policy=policy, key=0)


def _churn(idx, x, n: int, n_churn: int, delta_capacity: int):
    """Build + insert churn in delta-sized batches (each fill freezes a
    level-0 segment and queues merges the budgeted policy leaves
    unrun) + a tombstone sweep: the deep backlog both disciplines
    snapshot."""
    idx.build(x[:n])
    lo = n
    while lo < n + n_churn:
        hi = min(lo + delta_capacity, n + n_churn)
        idx.insert(x[lo:hi])
        lo = hi
    idx.delete(range(0, n, 9))
    return idx


def _sets(idx, q):
    return {f: idx.query(jnp.asarray(q), R, force=f).neighbor_sets()
            for f in ("lsh", "linear")}


def _drain(idx):
    while idx.has_compaction_work:
        idx.compact_step(1 << 30)


def main(scale: float = 0.12, emit: str | None = None) -> Dict[str, object]:
    import tempfile
    n = max(12000, int(100000 * scale))
    n_churn = max(2048, n // 4)
    n_churn2 = n_churn // 2
    delta_capacity = 256
    budget = delta_capacity // 2
    d, L = 16, 8
    x = np.asarray(clustered_dataset(n + n_churn + n_churn2 + 64, d,
                                     n_clusters=32, dense_core_frac=0.2,
                                     core_scale=0.05, seed=0, metric="l2"),
                   np.float32)
    q = x[::97][:16]
    fam = make_family("l2", d=d, L=L, r=1.0)

    def churn2(idx):
        """Second churn wave between checkpoints: re-queues merge work
        and dirties the delta + recent tombstones (deletes target the
        fresh rows — the common churn shape — so the dirtied set stays
        proportional to the wave, not the corpus)."""
        lo = n + n_churn
        while lo < n + n_churn + n_churn2:
            hi = min(lo + delta_capacity, n + n_churn + n_churn2)
            idx.insert(x[lo:hi])
            lo = hi
        idx.delete(range(n + n_churn, n + n_churn + n_churn2, 3))
        return idx

    # ------------------------------------------------ flush discipline
    def flush_run() -> float:
        idx = _churn(_mk(fam, delta_capacity, budget), x, n, n_churn,
                     delta_capacity)
        with tempfile.TemporaryDirectory() as dd:
            mgr = CheckpointManager(dd)
            _drain(idx)
            mgr.save_index(1, idx)            # cold checkpoint, untimed
            churn2(idx)
            t0 = time.perf_counter()
            _drain(idx)                       # the old barrier
            mgr.save_index(2, idx)
            return time.perf_counter() - t0

    flush_run()                               # warm merge/build jits
    flush_stall_s = flush_run()

    # -------------------------------------------------- cut discipline
    idx = _churn(_mk(fam, delta_capacity, budget), x, n, n_churn,
                 delta_capacity)
    cut_dir = tempfile.mkdtemp()
    mgr_cut = CheckpointManager(cut_dir)
    t0 = time.perf_counter()
    mgr_cut.save_index(1, idx, incremental=True)
    cold_cut_stall_s = time.perf_counter() - t0

    churn2(idx)
    pending_at_cut = int(idx.pending_merges)
    full_state_bytes = int(sum(np.asarray(l).nbytes for l in
                               jax.tree_util.tree_leaves(idx.state_dict())))
    b0 = mgr_cut.stats()["bytes_written"]
    t0 = time.perf_counter()
    mgr_cut.save_index(2, idx, incremental=True)
    cut_stall_s = time.perf_counter() - t0
    mstats = mgr_cut.stats()
    incremental_save_bytes = int(mstats["bytes_written"] - b0)

    # --------------------------------------------- warm-standby restore
    standby = _mk(fam, delta_capacity, budget)
    t0 = time.perf_counter()
    assert mgr_cut.restore_index(standby) == 2
    restore_s = time.perf_counter() - t0
    _drain(idx)
    _drain(standby)
    restore_identical = _sets(idx, q) == _sets(standby, q)

    out: Dict[str, object] = {
        "n": n, "n_churn": n_churn, "delta_capacity": delta_capacity,
        "budget_rows": budget, "pending_merges_at_cut": pending_at_cut,
        # headline: steady-state checkpoint-call stall, flush vs cut
        "flush_checkpoint_stall_s": flush_stall_s,
        "cut_checkpoint_stall_s": cut_stall_s,
        "cold_cut_stall_s": cold_cut_stall_s,
        "snapshot_stall_cut": flush_stall_s / max(cut_stall_s, 1e-9),
        # headline: incremental snapshot writes a fraction of the tree
        "full_state_bytes": full_state_bytes,
        "incremental_save_bytes": incremental_save_bytes,
        "incremental_bytes_frac": (incremental_save_bytes
                                   / max(full_state_bytes, 1)),
        "chunks_written": mstats["chunks_written"],
        "chunks_reused": mstats["chunks_reused"],
        "bytes_reused": mstats["bytes_reused"],
        # headline: warm-standby recovery
        "restore_s": restore_s,
        "restore_identical": bool(restore_identical),
        "elastic_restore_s": None,
        "elastic_identical": None,
        "shards_saved": None,
    }

    # ----------------------------- elastic failover (needs >= 2 devices)
    if len(jax.devices()) >= 2:
        from repro.streaming import ShardedDynamicHybridIndex
        n_sh = min(n, 4000)
        mesh2 = jax.make_mesh((2,), ("data",))
        mesh1 = jax.make_mesh((1,), ("data",))

        def mk_sh(mesh):
            return ShardedDynamicHybridIndex(
                fam, mesh=mesh, num_buckets=1024, m=64, cap=8192,
                delta_capacity=delta_capacity,
                policy=CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                                        fanout=2, step_rows=budget),
                routing="per_shard", max_out=n_sh + 512, key=0)

        sh = mk_sh(mesh2)
        sh.build(x[:n_sh])
        sh.insert(x[n_sh:n_sh + 512])
        sh.delete(range(0, n_sh, 11))
        sh.compact_step(budget)               # checkpoint mid-merge
        sh_dir = tempfile.mkdtemp()
        mgr_sh = CheckpointManager(sh_dir)
        mgr_sh.save_index(1, sh, incremental=True)
        narrow = mk_sh(mesh1)
        t0 = time.perf_counter()
        assert mgr_sh.restore_index(narrow) == 1
        out["elastic_restore_s"] = time.perf_counter() - t0
        assert narrow.validate_locations() == narrow.n
        _drain(sh)
        _drain(narrow)
        out["elastic_identical"] = bool(_sets(sh, q) == _sets(narrow, q))
        out["shards_saved"] = 2

    if emit:
        with open(emit, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.12)
    ap.add_argument("--emit", metavar="PATH", default=None)
    args = ap.parse_args()
    print(json.dumps(main(args.scale, emit=args.emit), indent=2))
