"""LSM segment-stack benchmark: query-batch stall under compaction.

Simulates a serving loop under insert churn — per round: insert a
batch, run any maintenance the mode prescribes, serve a query batch —
and measures the *round* latency distribution (the stall a query batch
actually experiences when maintenance lands in front of it) under three
maintenance disciplines at equal corpus/churn:

  * monolithic — the PR-1 design: when the delta fills, the whole
    index rebuilds through one blocking ``build_tables`` pass (full
    compaction) before inserts proceed.  Worst-case round ~ O(n).
  * sync      — the tiered level stack with synchronous merges: fills
    freeze a level-0 segment (O(delta_capacity)); level overflows merge
    inline.  Worst-case round ~ O(level size), amortized O(log n).
  * budgeted  — the same stack with ``step_rows`` set: merges advance
    in bounded ``compact_step`` ticks between rounds, queries are
    served from the old level list until the merged segment swaps in.
    Worst-case round ~ O(freeze + budget).

Emits ``BENCH_lsm.json`` with p99/max round latency per mode, the
headline ``stall_cut_vs_monolithic`` (worst monolithic round / worst
budgeted round), insert throughput, and the per-level merge counters.

``--async`` (``async_main``) runs the follow-up comparison: budgeted
*ticks* still pay every staging gather on the serving thread, one per
round; the ``CompactionDriver`` moves the gathers to a worker thread
and leaves the serving thread only the per-round ``drain()`` (a flag
check, plus the atomic swap when one is staged-ready).  Per round the
maintenance call itself is timed, so the emitted
``serving_maint_s_tick`` / ``serving_maint_s_driver`` totals are
exactly the serving-thread time each discipline spends on compaction
at equal churn — the headline ``serving_stall_cut`` is their ratio.
Each mode takes the *min of two timed passes* (container hiccups only
inflate), after an untimed jit-warming pass.  Emitted as
``BENCH_async.json`` and asserted in CI (docs/benchmarks.md).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core import CostModel
from repro.core.lsh import make_family
from repro.data import clustered_dataset
from repro.streaming import (CompactionDriver, CompactionPolicy,
                             DynamicHybridIndex)

NO_AUTO = CompactionPolicy(delta_fill=2.0, tombstone_ratio=2.0)


def _run_mode(mode: str, fam, x, n, q, r, batch: int, cap: int,
              delta_capacity: int, budget: int) -> Dict[str, object]:
    policies = {
        "monolithic": NO_AUTO,   # fills handled by explicit full compact
        "sync": CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                                 fanout=2),
        "budgeted": CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                                     fanout=2, step_rows=budget),
    }

    def serving_loop(record: bool):
        """One full churn run on a fresh index.  The first (untimed)
        pass populates every jit cache the mode will hit, so the timed
        pass measures steady-state maintenance work, not compiles —
        otherwise mode ordering in this process would let later modes
        inherit earlier modes' compilations."""
        idx = DynamicHybridIndex(fam, num_buckets=1024, m=64, cap=cap,
                                 delta_capacity=delta_capacity,
                                 cost_model=CostModel(alpha=1.0, beta=10.0),
                                 policy=policies[mode], key=0)
        idx.build(x[:n])
        idx.query(jnp.asarray(q), r)
        idx.insert(x[n:n + batch])
        lat, t_insert = [], 0.0
        lo = n + batch
        while lo < x.shape[0]:
            hi = min(lo + batch, x.shape[0])
            t0 = time.perf_counter()
            if mode == "monolithic":
                # PR-1 discipline: a full blocking rebuild (gather +
                # re-hash + build over the whole corpus) when the delta
                # cannot absorb the batch
                if int(idx.delta.count) + (hi - lo) > delta_capacity:
                    idx.build(x[:lo], ids=np.arange(lo))
                    idx.stats.record("delta_full", t0, 0)
            t1 = time.perf_counter()
            idx.insert(x[lo:hi])
            t_insert += time.perf_counter() - t1
            if mode == "budgeted":
                idx.compact_step()                # off-query-path tick
            idx.query(jnp.asarray(q), r)
            if record:
                lat.append(time.perf_counter() - t0)
            lo = hi
        return idx, lat, t_insert

    serving_loop(record=False)                    # warm every jit cache
    idx, lat, t_insert = serving_loop(record=True)
    st = idx.index_stats()
    return {
        "round_p99_s": float(np.quantile(lat, 0.99)),
        "round_max_s": float(np.max(lat)),
        "round_mean_s": float(np.mean(lat)),
        "insert_seconds": t_insert,
        "freezes": st["freezes"],
        "compactions": st["compactions"],
        "compact_steps": st["compact_steps"],
        "merges_per_level": st["merges_per_level"],
        "segments": st["segments"],
        "pending_merges": st["pending_merges"],
    }


def main(scale: float = 0.12, emit: str | None = None) -> Dict[str, object]:
    # The corpus must dwarf the delta for the stall asymmetry to show:
    # a monolithic rebuild is O(n), a freeze + budgeted tick is
    # O(delta_capacity) — at equal churn.
    n = max(24000, int(200000 * scale))
    n_churn = max(1536, n // 8)
    batch, delta_capacity = 128, 512
    budget = delta_capacity // 2
    d, L, r = 16, 8, 1.2
    rng = np.random.default_rng(0)
    x = np.asarray(clustered_dataset(n + batch + n_churn, d, n_clusters=32,
                                     dense_core_frac=0.2, core_scale=0.05,
                                     seed=0, metric="l2"), np.float32)
    q = x[rng.integers(0, n, 32)]
    fam = make_family("l2", d=d, L=L, r=1.0)

    modes = {m: _run_mode(m, fam, x, n, q, r, batch, 256,
                          delta_capacity, budget)
             for m in ("monolithic", "sync", "budgeted")}
    churned = n_churn
    out: Dict[str, object] = {
        "n": n, "n_churn": churned, "batch": batch,
        "delta_capacity": delta_capacity, "budget_rows": budget,
        "insert_docs_per_s": churned / max(
            modes["budgeted"]["insert_seconds"], 1e-9),
        # headline: budgeted compaction cuts the worst query-batch stall
        "stall_cut_vs_monolithic": (modes["monolithic"]["round_max_s"]
                                    / max(modes["budgeted"]["round_max_s"],
                                          1e-9)),
        "stall_cut_vs_sync": (modes["sync"]["round_max_s"]
                              / max(modes["budgeted"]["round_max_s"],
                                    1e-9)),
    }
    for m, row in modes.items():
        for k, v in row.items():
            out[f"{m}_{k}"] = v
    if emit:
        with open(emit, "w") as f:
            json.dump(out, f, indent=2)
    return out


# ---------------------------------------------------------------------------
# Async driver vs tick-based: serving-thread compaction time
# ---------------------------------------------------------------------------
def _run_async_mode(mode: str, fam, x, n, q, r, batch: int, cap: int,
                    delta_capacity: int, budget: int,
                    record: bool) -> Dict[str, object]:
    """One churn run: insert batch -> maintenance -> query batch.

    ``mode`` picks the maintenance discipline at equal policy/budget:
    "tick" runs one serving-thread ``compact_step`` per round; "driver"
    runs the worker-thread driver and per-round ``drain()``.  The
    maintenance call is timed separately from the round so the emitted
    totals isolate exactly the serving-thread compaction cost.
    """
    policy = CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                              fanout=2, step_rows=budget)
    idx = DynamicHybridIndex(fam, num_buckets=1024, m=64, cap=cap,
                             delta_capacity=delta_capacity,
                             cost_model=CostModel(alpha=1.0, beta=10.0),
                             policy=policy, key=0)
    idx.build(x[:n])
    idx.query(jnp.asarray(q), r)
    idx.insert(x[n:n + batch])
    drv = (CompactionDriver(idx, budget_rows=budget, poll_s=0.0005).start()
           if mode == "driver" else None)
    maint_s, lat = 0.0, []
    lo = n + batch
    while lo < x.shape[0]:
        hi = min(lo + batch, x.shape[0])
        t0 = time.perf_counter()
        idx.insert(x[lo:hi])
        if drv is not None:
            drv.notify()
        t1 = time.perf_counter()
        if drv is not None:
            drv.drain()
        else:
            idx.compact_step()
        maint_s += time.perf_counter() - t1
        idx.query(jnp.asarray(q), r)
        if record:
            lat.append(time.perf_counter() - t0)
        lo = hi
    # snapshot counters BEFORE the shutdown flush so both modes report
    # exactly what completed during the measured rounds (the flush's
    # leftover merges must not inflate the driver's numbers)
    st = idx.index_stats()
    out: Dict[str, object] = {"maint_s": maint_s, "lat": lat,
                              "compactions": st["compactions"],
                              "freezes": st["freezes"],
                              "pending_merges": st["pending_merges"]}
    if drv is not None:
        out["driver_stats"] = drv.stats()
        drv.stop(flush=True)
    return out


def async_main(scale: float = 0.12,
               emit: str | None = None) -> Dict[str, object]:
    # smaller corpus than main(): the measured asymmetry is per-round
    # staging-gather time vs drain time, which does not need the corpus
    # to dwarf the delta — only sustained merge pressure, hence the
    # aggressive fanout=2 policy and heavy relative churn.
    n = max(8000, int(60000 * scale))
    n_churn = max(4096, n // 4)
    batch, delta_capacity = 128, 256
    budget = delta_capacity // 2
    d, L, r = 16, 8, 1.2
    rng = np.random.default_rng(0)
    x = np.asarray(clustered_dataset(n + batch + n_churn, d, n_clusters=32,
                                     dense_core_frac=0.2, core_scale=0.05,
                                     seed=0, metric="l2"), np.float32)
    q = x[rng.integers(0, n, 32)]
    fam = make_family("l2", d=d, L=L, r=1.0)

    modes: Dict[str, Dict[str, object]] = {}
    for mode in ("tick", "driver"):
        _run_async_mode(mode, fam, x, n, q, r, batch, 256,
                        delta_capacity, budget, record=False)  # warm jits
        runs = [_run_async_mode(mode, fam, x, n, q, r, batch, 256,
                                delta_capacity, budget, record=True)
                for _ in range(2)]
        best = min(runs, key=lambda rr: rr["maint_s"])
        modes[mode] = best

    lat_t, lat_d = modes["tick"]["lat"], modes["driver"]["lat"]
    dstats = modes["driver"]["driver_stats"]
    out: Dict[str, object] = {
        "n": n, "n_churn": n_churn, "batch": batch,
        "delta_capacity": delta_capacity, "budget_rows": budget,
        "rounds": len(lat_t),
        # headline: serving-thread seconds spent on compaction per run
        "serving_maint_s_tick": modes["tick"]["maint_s"],
        "serving_maint_s_driver": modes["driver"]["maint_s"],
        "serving_stall_cut": (modes["tick"]["maint_s"]
                              / max(modes["driver"]["maint_s"], 1e-9)),
        "tick_round_p99_s": float(np.quantile(lat_t, 0.99)),
        "tick_round_max_s": float(np.max(lat_t)),
        "tick_round_mean_s": float(np.mean(lat_t)),
        "driver_round_p99_s": float(np.quantile(lat_d, 0.99)),
        "driver_round_max_s": float(np.max(lat_d)),
        "driver_round_mean_s": float(np.mean(lat_d)),
        # structural backstop: the gathers + pre-builds really ran on
        # the worker
        "driver_stage_calls": dstats["stage_calls"],
        "driver_prepares": dstats["prepares"],
        "driver_applied": dstats["applied"],
        "driver_worker_errors": dstats["worker_errors"],
        "tick_compactions": modes["tick"]["compactions"],
        "driver_compactions": modes["driver"]["compactions"],
        "tick_freezes": modes["tick"]["freezes"],
        "driver_freezes": modes["driver"]["freezes"],
        # backlog each mode left when the rounds ended (the driver's is
        # flushed at shutdown, after measurement)
        "tick_pending_merges": modes["tick"]["pending_merges"],
        "driver_pending_merges": modes["driver"]["pending_merges"],
    }
    if emit:
        with open(emit, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.12)
    ap.add_argument("--emit", metavar="PATH", default=None)
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="serving-thread stall: tick-based vs driver "
                         "(emits BENCH_async.json schema)")
    args = ap.parse_args()
    fn = async_main if args.async_ else main
    print(json.dumps(fn(args.scale, emit=args.emit), indent=2))
