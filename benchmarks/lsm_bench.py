"""LSM segment-stack benchmark: query-batch stall under compaction.

Simulates a serving loop under insert churn — per round: insert a
batch, run any maintenance the mode prescribes, serve a query batch —
and measures the *round* latency distribution (the stall a query batch
actually experiences when maintenance lands in front of it) under three
maintenance disciplines at equal corpus/churn:

  * monolithic — the PR-1 design: when the delta fills, the whole
    index rebuilds through one blocking ``build_tables`` pass (full
    compaction) before inserts proceed.  Worst-case round ~ O(n).
  * sync      — the tiered level stack with synchronous merges: fills
    freeze a level-0 segment (O(delta_capacity)); level overflows merge
    inline.  Worst-case round ~ O(level size), amortized O(log n).
  * budgeted  — the same stack with ``step_rows`` set: merges advance
    in bounded ``compact_step`` ticks between rounds, queries are
    served from the old level list until the merged segment swaps in.
    Worst-case round ~ O(freeze + budget).

Emits ``BENCH_lsm.json`` with p99/max round latency per mode, the
headline ``stall_cut_vs_monolithic`` (worst monolithic round / worst
budgeted round), insert throughput, and the per-level merge counters.
"""
from __future__ import annotations

import json
import time
from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core import CostModel
from repro.core.lsh import make_family
from repro.data import clustered_dataset
from repro.streaming import CompactionPolicy, DynamicHybridIndex

NO_AUTO = CompactionPolicy(delta_fill=2.0, tombstone_ratio=2.0)


def _run_mode(mode: str, fam, x, n, q, r, batch: int, cap: int,
              delta_capacity: int, budget: int) -> Dict[str, object]:
    policies = {
        "monolithic": NO_AUTO,   # fills handled by explicit full compact
        "sync": CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                                 fanout=2),
        "budgeted": CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                                     fanout=2, step_rows=budget),
    }

    def serving_loop(record: bool):
        """One full churn run on a fresh index.  The first (untimed)
        pass populates every jit cache the mode will hit, so the timed
        pass measures steady-state maintenance work, not compiles —
        otherwise mode ordering in this process would let later modes
        inherit earlier modes' compilations."""
        idx = DynamicHybridIndex(fam, num_buckets=1024, m=64, cap=cap,
                                 delta_capacity=delta_capacity,
                                 cost_model=CostModel(alpha=1.0, beta=10.0),
                                 policy=policies[mode], key=0)
        idx.build(x[:n])
        idx.query(jnp.asarray(q), r)
        idx.insert(x[n:n + batch])
        lat, t_insert = [], 0.0
        lo = n + batch
        while lo < x.shape[0]:
            hi = min(lo + batch, x.shape[0])
            t0 = time.perf_counter()
            if mode == "monolithic":
                # PR-1 discipline: a full blocking rebuild (gather +
                # re-hash + build over the whole corpus) when the delta
                # cannot absorb the batch
                if int(idx.delta.count) + (hi - lo) > delta_capacity:
                    idx.build(x[:lo], ids=np.arange(lo))
                    idx.stats.record("delta_full", t0, 0)
            t1 = time.perf_counter()
            idx.insert(x[lo:hi])
            t_insert += time.perf_counter() - t1
            if mode == "budgeted":
                idx.compact_step()                # off-query-path tick
            idx.query(jnp.asarray(q), r)
            if record:
                lat.append(time.perf_counter() - t0)
            lo = hi
        return idx, lat, t_insert

    serving_loop(record=False)                    # warm every jit cache
    idx, lat, t_insert = serving_loop(record=True)
    st = idx.index_stats()
    return {
        "round_p99_s": float(np.quantile(lat, 0.99)),
        "round_max_s": float(np.max(lat)),
        "round_mean_s": float(np.mean(lat)),
        "insert_seconds": t_insert,
        "freezes": st["freezes"],
        "compactions": st["compactions"],
        "compact_steps": st["compact_steps"],
        "merges_per_level": st["merges_per_level"],
        "segments": st["segments"],
        "pending_merges": st["pending_merges"],
    }


def main(scale: float = 0.12, emit: str | None = None) -> Dict[str, object]:
    # The corpus must dwarf the delta for the stall asymmetry to show:
    # a monolithic rebuild is O(n), a freeze + budgeted tick is
    # O(delta_capacity) — at equal churn.
    n = max(24000, int(200000 * scale))
    n_churn = max(1536, n // 8)
    batch, delta_capacity = 128, 512
    budget = delta_capacity // 2
    d, L, r = 16, 8, 1.2
    rng = np.random.default_rng(0)
    x = np.asarray(clustered_dataset(n + batch + n_churn, d, n_clusters=32,
                                     dense_core_frac=0.2, core_scale=0.05,
                                     seed=0, metric="l2"), np.float32)
    q = x[rng.integers(0, n, 32)]
    fam = make_family("l2", d=d, L=L, r=1.0)

    modes = {m: _run_mode(m, fam, x, n, q, r, batch, 256,
                          delta_capacity, budget)
             for m in ("monolithic", "sync", "budgeted")}
    churned = n_churn
    out: Dict[str, object] = {
        "n": n, "n_churn": churned, "batch": batch,
        "delta_capacity": delta_capacity, "budget_rows": budget,
        "insert_docs_per_s": churned / max(
            modes["budgeted"]["insert_seconds"], 1e-9),
        # headline: budgeted compaction cuts the worst query-batch stall
        "stall_cut_vs_monolithic": (modes["monolithic"]["round_max_s"]
                                    / max(modes["budgeted"]["round_max_s"],
                                          1e-9)),
        "stall_cut_vs_sync": (modes["sync"]["round_max_s"]
                              / max(modes["budgeted"]["round_max_s"],
                                    1e-9)),
    }
    for m, row in modes.items():
        for k, v in row.items():
            out[f"{m}_{k}"] = v
    if emit:
        with open(emit, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
