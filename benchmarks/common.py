"""Shared benchmark utilities: dataset prep, radius selection, timing."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CostModel, HybridLSHIndex, PAPER_PRESETS
from repro.core.lsh import make_family
from repro.data import paper_dataset, query_split

DATASETS = ("corel", "covertype", "webspam", "mnist")


def timed(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(jax.tree_util.tree_leaves(fn(*args)))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(jax.tree_util.tree_leaves(fn(*args)))
    return (time.perf_counter() - t0) / iters


def pick_radii(x: np.ndarray, metric: str, n_radii: int = 4,
               seed: int = 0) -> List[float]:
    """Radii at increasing output-size quantiles of the distance dist."""
    if metric == "hamming":
        return [2.0, 6.0, 12.0, 20.0][:n_radii]
    rng = np.random.default_rng(seed)
    a = x[rng.integers(0, len(x), 2000)]
    b = x[rng.integers(0, len(x), 2000)]
    if metric == "l2":
        d = np.linalg.norm(a - b, axis=1)
    elif metric == "l1":
        d = np.abs(a - b).sum(1)
    else:
        d = 1.0 - (a * b).sum(1) / np.maximum(
            np.linalg.norm(a, axis=1) * np.linalg.norm(b, axis=1), 1e-9)
    qs = np.quantile(d, [0.0005, 0.005, 0.03, 0.12][:n_radii])
    return [float(q) for q in qs]


def calibrate_cost_model(idx: HybridLSHIndex, probe: jnp.ndarray,
                         r: float) -> CostModel:
    """Fit (alpha, beta) in SECONDS from probe timings on this machine.

    The paper sets beta/alpha per dataset by hand, noting the ratio
    "obviously depends on the implementation".  Here both strategies
    are timed once on a probe batch and Eq. (1)/(2) are solved for the
    effective constants — the router then compares real predicted
    seconds.  (Auto-calibration; build-time cost ~2 probe queries.)
    """
    n = max(idx.n, 1)
    nb = probe.shape[0]
    t_lin = timed(lambda: idx.query(probe, r, force="linear")) / nb
    t_lsh = timed(lambda: idx.query(probe, r, force="lsh")) / nb
    est = idx.estimate(probe)
    coll = float(np.mean(np.asarray(est.collisions)))
    cand = float(np.mean(np.asarray(est.cand_est)))
    beta = t_lin / n
    alpha = max((t_lsh - beta * cand) / max(coll, 1.0), beta * 1e-3)
    return CostModel(alpha=alpha, beta=beta)


def build_index(name: str, x: np.ndarray, metric: str, r: float, *,
                L: int = 20, m: int = 64, delta: float = 0.1,
                seed: int = 0, calibrate: bool = True) -> HybridLSHIndex:
    d_or_bits = x.shape[1] * (32 if metric == "hamming" else 1)
    fam = make_family(metric, d=d_or_bits, L=L, r=r, delta=delta)
    n = x.shape[0]
    num_buckets = 1 << max(10, min(16, int(np.log2(max(n, 2) / 4)) + 1))
    cap = 256
    idx = HybridLSHIndex(fam, num_buckets=num_buckets, m=m, cap=cap,
                         cost_model=PAPER_PRESETS[name], key=seed)
    idx.build(jnp.asarray(x))
    if calibrate:
        idx.cost_model = calibrate_cost_model(idx, jnp.asarray(x[:16]), r)
    return idx


def prep(name: str, scale: float, n_queries: int = 100, seed: int = 0):
    x, metric = paper_dataset(name, scale=scale, seed=seed)
    x, q = query_split(x, n_queries=n_queries, seed=seed)
    return x, q, metric
