"""Closed-loop serving benchmark: coalescing + result cache vs naive.

One service, one static index, one repeat-heavy request stream
(docs/serving.md) served three ways:

  * **naive** — one ``svc.query`` per request: per-request embed +
    route + report, the pre-PR-8 serving loop.
  * **coalesced** — ``submit``/``drain_batches`` with the cache
    disabled: cross-request pow2 shape buckets, one embed and one
    routed index query per formed batch.
  * **coalesced+cache** — same, plus the version-keyed ``ResultCache``:
    repeats inside the stream are served from memory.

Two measurements per mode, both on warmed jit caches:

  1. **Closed-loop capacity** — serve the whole stream as fast as the
     mode allows; min over passes, so container hiccups only inflate.
  2. **Open-loop sustained QPS** — requests arrive on a fixed-rate
     clock (latency is measured from the *scheduled* arrival, so a
     backlog is charged to every request it delays — no coordinated
     omission).  The reported ``sustained_qps`` is the highest rate on
     a per-mode grid (fractions of that mode's own capacity) whose p99
     stays inside the SLO.

A hit-rate sweep re-serves streams with {1.0, 0.5, 0.1} unique-query
fractions through a fresh cache, mapping hit rate to throughput.  The
emitted JSON carries the scheduler's queue-wait/batch-size histograms
and the cache counters from ``svc.metrics()`` for BENCH_serve.json
(schema: docs/benchmarks.md; gated by the serve-bench-smoke CI job).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data import lm_batch
from repro.models import init_params
from repro.models.parallel import ParallelConfig
from repro.serve import RetrievalConfig, RetrievalService
from repro.serve.cache import ResultCache

SEQ = 12
SLO_S = 0.5                       # generous CI-scale p99 target
RATE_FRACS = (0.9, 0.7, 0.5, 0.35, 0.25, 0.15, 0.1)
MAX_BATCH = 32
MIN_BUCKET = 8
MAX_WAIT_S = 0.002
CACHE_BYTES = 8 << 20


def _service(n_corpus_batches: int) -> RetrievalService:
    cfg = reduced_config(get_config("yi-6b"))
    par = ParallelConfig(mesh=None, attn_chunk_q=8, attn_chunk_k=8,
                         logits_chunk=8, remat="none")
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = RetrievalService(cfg, par, params, RetrievalConfig(
        radius=0.5, tables=8, num_buckets=256, hll_m=32, cap=64,
        delta_capacity=64,
        coalesce_max_batch=MAX_BATCH, coalesce_min_bucket=MIN_BUCKET,
        coalesce_max_wait_s=MAX_WAIT_S, result_cache_bytes=CACHE_BYTES))
    corpus = []
    for i in range(n_corpus_batches):
        b = lm_batch(3, i, batch=32, seq=SEQ, vocab=svc.cfg.vocab,
                     cfg=svc.cfg)
        b.pop("labels")
        corpus.append(b)
    svc.index_corpus(corpus)
    return svc


def _query_pool(svc: RetrievalService, n: int) -> np.ndarray:
    """n distinct single-query token rows (disjoint seed from corpus)."""
    rows = []
    step = 0
    while sum(r.shape[0] for r in rows) < n:
        rows.append(np.asarray(lm_batch(
            9, step, batch=32, seq=SEQ, vocab=svc.cfg.vocab)["tokens"]))
        step += 1
    return np.concatenate(rows)[:n]


def _stream(pool: np.ndarray, n_requests: int, n_distinct: int,
            seed: int) -> np.ndarray:
    """Repeat-heavy request stream: n_requests rows drawn from the
    first n_distinct pool rows (each distinct row appears at least
    once, so the fresh-cache hit rate is exactly 1 - distinct/n)."""
    rng = np.random.default_rng(seed)
    picks = np.concatenate([np.arange(n_distinct), rng.integers(
        0, n_distinct, size=n_requests - n_distinct)])
    rng.shuffle(picks)
    return pool[picks]


def _set_cache(svc: RetrievalService, max_bytes: int) -> None:
    # per-mode cache swap: fresh counters, same registry instruments
    svc.cache = ResultCache(max_bytes, registry=svc.obs.registry)


def _warm(svc: RetrievalService, stream: np.ndarray) -> None:
    """Compile every shape the bench will hit: the naive single-row
    path plus each pow2 bucket the coalesced path can form."""
    sizes = [1]
    b = MIN_BUCKET
    while b <= MAX_BATCH:
        sizes.append(b)
        b *= 2
    for k in sizes:
        res, _ = svc.query({"tokens": jnp.asarray(stream[:k])})
        res.reported(0)


# ------------------------------------------------------------ closed loop
def _closed_loop(svc: RetrievalService, stream: np.ndarray,
                 mode: str) -> float:
    t0 = time.perf_counter()
    if mode == "naive":
        for row in stream:
            res, _ = svc.query({"tokens": jnp.asarray(row[None])})
            res.reported(0)           # materialize, as the callers do
    else:
        for row in stream:
            uid = svc.submit(row)
            assert uid is not None, "admission reject in closed loop"
        served = svc.drain_batches(force=True)
        assert len(served) == len(stream)
    return time.perf_counter() - t0


def _capacity_qps(svc, stream, mode: str, passes: int = 2) -> float:
    _closed_loop(svc, stream, mode)             # warm (and fill cache)
    best = min(_closed_loop(svc, stream, mode) for _ in range(passes))
    return len(stream) / max(best, 1e-9)


# -------------------------------------------------------------- open loop
def _open_loop(svc: RetrievalService, stream: np.ndarray, rate_qps: float,
               mode: str) -> Dict[str, float]:
    """Serve the stream with arrivals on a fixed-rate clock; per-request
    latency runs from the scheduled arrival to result materialization."""
    arrivals = np.arange(len(stream)) / rate_qps
    lat: List[float] = []
    t0 = time.perf_counter()
    if mode == "naive":
        for i, row in enumerate(stream):
            now = time.perf_counter() - t0
            if now < arrivals[i]:
                time.sleep(arrivals[i] - now)
            res, _ = svc.query({"tokens": jnp.asarray(row[None])})
            res.reported(0)
            lat.append(time.perf_counter() - t0 - arrivals[i])
    else:
        pending: Dict[int, float] = {}
        i = 0
        while i < len(stream) or pending:
            now = time.perf_counter() - t0
            while i < len(stream) and arrivals[i] <= now:
                uid = svc.submit(stream[i])
                assert uid is not None, "admission reject in open loop"
                pending[uid] = arrivals[i]
                i += 1
            out = svc.drain_batches()
            done = time.perf_counter() - t0
            for uid in out:
                lat.append(done - pending.pop(uid))
            if out:
                continue
            if pending:                   # inside the coalescing deadline
                time.sleep(MAX_WAIT_S / 4)
            elif i < len(stream):         # idle until the next arrival
                dt = arrivals[i] - (time.perf_counter() - t0)
                if dt > 0:
                    time.sleep(min(dt, 0.01))
    lat_a = np.asarray(lat)
    return {"rate_qps": float(rate_qps),
            "p50_s": float(np.percentile(lat_a, 50)),
            "p99_s": float(np.percentile(lat_a, 99)),
            "max_s": float(lat_a.max())}


def _sustained(svc, stream, mode: str, capacity_qps: float):
    """Highest grid rate (fractions of this mode's capacity) whose open
    -loop p99 meets the SLO; falls back to the lowest rate tried."""
    trials = []
    for frac in RATE_FRACS:
        t = _open_loop(svc, stream, frac * capacity_qps, mode)
        t["capacity_frac"] = frac
        trials.append(t)
        if t["p99_s"] <= SLO_S:
            return t, trials
    return trials[-1], trials


# ------------------------------------------------------------------ main
def main(scale: float = 0.12, emit: str | None = None) -> Dict[str, object]:
    n_requests = 96 if scale < 0.06 else 160
    n_distinct = 12
    svc = _service(n_corpus_batches=4)
    pool = _query_pool(svc, n_requests)
    stream = _stream(pool, n_requests, n_distinct, seed=4)
    _warm(svc, stream)

    modes = {}
    for mode, cache_bytes in (("naive", 0), ("coalesced", 0),
                              ("coalesced_cache", CACHE_BYTES)):
        _set_cache(svc, cache_bytes)
        cap = _capacity_qps(svc, stream, mode)
        best, trials = _sustained(svc, stream, mode, cap)
        modes[mode] = {"capacity_qps": cap,
                       "sustained_qps": best["rate_qps"],
                       "p99_s_at_sustained": best["p99_s"],
                       "p50_s_at_sustained": best["p50_s"],
                       "slo_met": best["p99_s"] <= SLO_S,
                       "trials": trials}
        if mode == "coalesced_cache":
            cs = svc.cache.stats()
            # steady-state: capacity passes + open-loop trials replay
            # the same 12-distinct stream into a warm cache
            modes[mode]["cache_hit_rate_steady"] = cs["hit_rate"]

    # fresh-cache hit rate of the headline stream (1 - distinct/n)
    _set_cache(svc, CACHE_BYTES)
    _closed_loop(svc, stream, "coalesced_cache")
    headline_hit_rate = svc.cache.stats()["hit_rate"]

    sweep = []
    for frac in (1.0, 0.5, 0.1):
        distinct = max(int(n_requests * frac), 1)
        s = _stream(pool, n_requests, distinct, seed=5)
        times, rates = [], []
        for _ in range(2):            # fresh cache per pass: the rate is
            _set_cache(svc, CACHE_BYTES)      # a cold-stream property
            times.append(_closed_loop(svc, s, "coalesced_cache"))
            rates.append(svc.cache.stats()["hit_rate"])
        sweep.append({"unique_frac": frac, "distinct": distinct,
                      "hit_rate": rates[-1],
                      "qps": len(s) / max(min(times), 1e-9)})

    hists = svc.metrics()["registry"]["histograms"]
    hist = {k: v for k, v in hists.items()
            if k.startswith(("repro_scheduler_queue_wait_seconds",
                             "repro_scheduler_batch_size"))}
    out = {
        "scale": scale, "seq": SEQ, "n_requests": n_requests,
        "n_distinct": n_distinct, "slo_s": SLO_S,
        "max_batch": MAX_BATCH, "min_bucket": MIN_BUCKET,
        "max_wait_s": MAX_WAIT_S, "cache_bytes": CACHE_BYTES,
        "corpus_docs": int(svc.stats["index_size"]),
        "modes": modes,
        "sustained_qps_naive": modes["naive"]["sustained_qps"],
        "sustained_qps_coalesced": modes["coalesced"]["sustained_qps"],
        "sustained_qps_coalesced_cache":
            modes["coalesced_cache"]["sustained_qps"],
        "speedup_coalesced_vs_naive":
            modes["coalesced"]["sustained_qps"]
            / max(modes["naive"]["sustained_qps"], 1e-9),
        "speedup_cache_vs_naive":
            modes["coalesced_cache"]["sustained_qps"]
            / max(modes["naive"]["sustained_qps"], 1e-9),
        "cache_hit_rate": headline_hit_rate,
        "hit_rate_sweep": sweep,
        "scheduler_stats": svc.stats["scheduler"],
        "cache_stats": svc.stats["cache"],
        "histograms": hist,
    }
    if emit:
        with open(emit, "w") as f:
            json.dump(out, f, indent=2)
    return out


# ------------------------------------------------------------ multi-tenant
def _mt_service(noisy_quota) -> "RetrievalService":
    """One multi-tenant service: a small 'quiet' collection and a 4x
    larger 'noisy' one (mixed tenant sizes), budgeted compaction so the
    drain-path ticks are deterministic for CI."""
    from repro.serve import TenantQuota  # noqa: F401  (re-exported)
    cfg = reduced_config(get_config("yi-6b"))
    par = ParallelConfig(mesh=None, attn_chunk_q=8, attn_chunk_k=8,
                         logits_chunk=8, remat="none")
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = RetrievalService(cfg, par, params, RetrievalConfig(
        radius=0.5, tables=8, num_buckets=256, hll_m=32, cap=64,
        delta_capacity=64, compact_step_rows=32,
        coalesce_max_batch=MAX_BATCH, coalesce_min_bucket=MIN_BUCKET,
        coalesce_max_wait_s=0.0, result_cache_bytes=0))

    def batches(seed, n):
        out = []
        for i in range(n):
            b = lm_batch(seed, i, batch=32, seq=SEQ, vocab=cfg.vocab,
                         cfg=cfg)
            b.pop("labels")
            out.append(b)
        return out

    svc.create_collection("quiet", batches(21, 1))
    svc.create_collection("noisy", batches(22, 4), quota=noisy_quota)
    return svc


def _mt_warm(svc, pool: np.ndarray) -> None:
    """Compile every shape either tenant's routed path can hit: each
    pow2 bucket size, per collection (no default corpus here)."""
    sizes, b = [1], MIN_BUCKET
    while b <= MAX_BATCH:
        sizes.append(b)
        b *= 2
    for name in ("quiet", "noisy"):
        for k in sizes:
            res, _ = svc.query({"tokens": jnp.asarray(pool[:k])},
                               collection=name)
            res.reported(0)


MT_QUIET_ROWS = 8                 # rows per quiet request


def _mt_quiet_latencies(svc, quiet_rows, noisy_rows, flood_per_round,
                        churn_every: int, rounds: int) -> Dict[str, object]:
    """Per-round wall latency of ONE quiet-tenant request submitted
    BEHIND a same-round flood burst from the noisy tenant (worst case
    for a FIFO drain), with periodic insert churn into the noisy
    collection.

    Compaction from the churn is drained OUTSIDE the measured window
    (its serving-thread cost is BENCH_async's subject, not this
    bench's): what stays inside is exactly the flood's own traffic —
    whatever the token bucket admits rides the quiet request's batch.
    Returns latencies + admission counts for the phase."""
    cfg = svc.cfg
    lat = []
    admitted = rejected = 0
    for i in range(rounds):
        if churn_every and i % churn_every == churn_every - 1:
            b = lm_batch(23, i, batch=32, seq=SEQ, vocab=cfg.vocab,
                         cfg=cfg)
            b.pop("labels")
            svc.add_documents([b], collection="noisy")
            while svc.compaction_tick():     # unmeasured, both phases
                pass
        for k in range(flood_per_round):
            row = noisy_rows[(i * flood_per_round + k) % len(noisy_rows)]
            if svc.submit(row, collection="noisy") is not None:
                admitted += 1
            else:
                rejected += 1
        j = (MT_QUIET_ROWS * i) % (len(quiet_rows) - MT_QUIET_ROWS + 1)
        qrows = quiet_rows[j:j + MT_QUIET_ROWS]
        t0 = time.perf_counter()
        uid = svc.submit(qrows, collection="quiet")
        assert uid is not None, "quiet tenant must always be admitted"
        served = svc.drain_batches(force=True)
        lat.append(time.perf_counter() - t0)
        assert uid in served
    return np.asarray(lat), admitted, rejected


def _mt_phase(svc, quiet_rows, noisy_rows, flood_per_round, rounds,
              passes: int = 2) -> Dict[str, object]:
    """One measured phase: ``passes`` runs, elementwise-min latencies
    (the bench's usual hiccup guard — a first-contact jit compile or a
    container stall only ever inflates, so the min is the structural
    cost), percentiles over the min rounds."""
    runs, admitted, rejected = [], 0, 0
    for _ in range(passes):
        lat, a, r = _mt_quiet_latencies(svc, quiet_rows, noisy_rows,
                                        flood_per_round=flood_per_round,
                                        churn_every=8, rounds=rounds)
        runs.append(lat)
        admitted += a
        rejected += r
    lat_a = np.min(runs, axis=0)
    return {"p50_s": float(np.percentile(lat_a, 50)),
            "p99_s": float(np.percentile(lat_a, 99)),
            "max_s": float(lat_a.max()),
            "noisy_admitted": admitted, "noisy_rejected": rejected}


def multi_tenant_main(scale: float = 0.12,
                      emit: str | None = None) -> Dict[str, object]:
    """Flood-isolation benchmark (BENCH_serve_mt.json).

    A noisy tenant floods ``flood_per_round`` submits ahead of every
    quiet-tenant request, with insert churn into the noisy collection.
    Three phases over the same quiet stream: solo (no flood), flood
    against the noisy tenant's token-bucket quota, and flood with the
    quota lifted (the counterfactual).  The isolation claim CI gates
    on: the quota holds the quiet tenant's p99 under flood to <= 2x its
    solo p99, with the flood absorbed as quota rejects, not queue
    depth.
    """
    from repro.serve import TenantQuota
    rounds = 32 if scale < 0.06 else 64
    flood_per_round = 16
    quota = TenantQuota(rate=1.0, burst=2.0, weight=1.0)

    svc = _mt_service(noisy_quota=quota)
    pool = _query_pool(svc, 8 * rounds + flood_per_round)
    quiet_rows, noisy_rows = pool[:8 * rounds], pool[8 * rounds:]
    _mt_warm(svc, pool)

    # unmeasured warmup pass: compiles every mixed-batch and churned-
    # segment shape the measured phases will hit
    _mt_quiet_latencies(svc, quiet_rows, noisy_rows,
                        flood_per_round=flood_per_round, churn_every=8,
                        rounds=rounds // 2)
    # churn runs in BOTH phases (same cadence), so the flood/solo ratio
    # isolates the noisy tenant's traffic, not its compaction cost
    solo = _mt_phase(svc, quiet_rows, noisy_rows,
                     flood_per_round=0, rounds=rounds)
    flood = _mt_phase(svc, quiet_rows, noisy_rows,
                      flood_per_round=flood_per_round, rounds=rounds)
    svc.drain_batches(force=True)
    tenants = svc.stats["scheduler"]["tenants"]

    # counterfactual: same flood, quota lifted — what admission control
    # is buying (not CI-gated; queue pressure is machine-dependent)
    svc_nq = _mt_service(noisy_quota=TenantQuota())
    _mt_warm(svc_nq, pool)
    _mt_quiet_latencies(svc_nq, quiet_rows, noisy_rows,
                        flood_per_round=flood_per_round, churn_every=8,
                        rounds=rounds // 2)
    noquota = _mt_phase(svc_nq, quiet_rows, noisy_rows,
                        flood_per_round=flood_per_round, rounds=rounds)

    out = {
        "scale": scale, "seq": SEQ, "rounds": rounds,
        "flood_per_round": flood_per_round,
        "quota_noisy_rate": quota.rate, "quota_noisy_burst": quota.burst,
        "quiet_docs": int(svc.collections.get("quiet").index.n),
        "noisy_docs": int(svc.collections.get("noisy").index.n),
        "quiet_p50_solo_s": solo["p50_s"],
        "quiet_p99_solo_s": solo["p99_s"],
        "quiet_p50_flood_s": flood["p50_s"],
        "quiet_p99_flood_s": flood["p99_s"],
        "quiet_p99_flood_noquota_s": noquota["p99_s"],
        "isolation_ratio_p99":
            flood["p99_s"] / max(solo["p99_s"], 1e-9),
        "noquota_ratio_p99":
            noquota["p99_s"] / max(solo["p99_s"], 1e-9),
        "noisy_admitted": flood["noisy_admitted"],
        "noisy_rejected": flood["noisy_rejected"],
        "tenant_stats": tenants,
        "collection_stats": svc.stats["collections"],
    }
    if emit:
        with open(emit, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.12)
    ap.add_argument("--emit", default=None)
    ap.add_argument("--multi-tenant", action="store_true",
                    help="run the flood-isolation bench "
                         "(BENCH_serve_mt.json) instead")
    args = ap.parse_args()
    fn = multi_tenant_main if args.multi_tenant else main
    print(json.dumps(fn(args.scale, emit=args.emit), indent=2))
