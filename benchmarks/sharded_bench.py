"""Sharded streaming benchmark: churn throughput + routing-policy latency.

Measures, at a given ``--scale``, on a host-platform CPU mesh (the
driver forces >= 2 devices via XLA_FLAGS before jax import):

  * churn throughput — steady-state mixed insert/delete batches into the
    per-shard delta segments / tombstone bitmaps (docs/s)
  * query latency under both routing policies ("global" vs "per_shard")
    on the churned index, and again after per-shard compaction
  * compaction cost (per-shard build_tables rebuild)

``--skew`` (``skew_main``) instead drives a *skewed* insert stream (all
rows pinned to shard 0, the key-hash-placement failure mode) through
identical indexes under ``keep_local`` vs ``load_balance`` merge-time
placement, and reports p50/p99 query-batch latency for each.  With
keep_local the hoarding shard pins every level's common ``n_pad`` (all
shards pad to the max shard's rows), so all shards pay its scan cost;
load_balance water-fills rows across shards at each merge, halving (at
S=2) the padded rows per shard.  The gated latencies are measured on
the linear route — the one Eq. 2 prices at the padded scan size, i.e.
the cost term skew actually inflates (the LSH route's cap-bounded
gathers are padded-size independent; its hybrid numbers are emitted as
``p*_hybrid_*`` context).  Emitted as BENCH_rebalance.json; CI asserts
the p99 delta is non-negative and the padded-row cut is real.

Emits a JSON blob (``--emit``) so the sharded perf trajectory is
tracked alongside BENCH_streaming.json.
"""
from __future__ import annotations

import json
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CostModel
from repro.core.lsh import make_family
from repro.data import clustered_dataset
from repro.streaming import CompactionPolicy, ShardedDynamicHybridIndex


def main(scale: float = 0.12, emit: str | None = None) -> Dict[str, float]:
    n = max(2000, int(50000 * scale))
    n_churn = max(256, n // 8)
    d, L, B, m, r = 16, 8, 1024, 64, 1.2
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    shards = mesh.shape["data"]
    x = np.asarray(clustered_dataset(n + n_churn, d, n_clusters=32,
                                     dense_core_frac=0.2, core_scale=0.05,
                                     seed=0, metric="l2"), np.float32)
    rng = np.random.default_rng(0)
    q = x[rng.integers(0, n, 64)]
    fam = make_family("l2", d=d, L=L, r=1.0)

    def build(routing):
        idx = ShardedDynamicHybridIndex(
            fam, num_buckets=B, mesh=mesh, m=m, cap=256,
            delta_capacity=max(1024, n_churn),
            cost_model=CostModel(alpha=1.0, beta=10.0),
            policy=CompactionPolicy(delta_fill=2.0, tombstone_ratio=2.0),
            routing=routing, max_out=256, key=0)
        idx.build(x[:n])
        return idx

    ins_batch, del_batch = 64, 32

    def churn(i, timed):
        """Identical mixed insert/delete stream; optionally timed."""
        t0 = time.perf_counter()
        ops = 0
        for lo in range(n + 64, n + n_churn, ins_batch):
            take = min(ins_batch, n + n_churn - lo)
            i.insert(x[lo:lo + take])
            i.delete(range(lo - n, lo - n + del_batch))
            ops += take + del_batch
        return ops, (time.perf_counter() - t0) if timed else 0.0

    idx = build("per_shard")
    # warm the mutation + query paths (jit compile)
    idx.insert(x[n:n + 64])
    idx.delete(range(0, 32))
    idx.query(jnp.asarray(q), r)
    ops, churn_s = churn(idx, timed=True)

    def time_query(i, iters=5):
        i.query(jnp.asarray(q), r)            # warm
        t0 = time.perf_counter()
        for _ in range(iters):
            i.query(jnp.asarray(q), r)
        return (time.perf_counter() - t0) / iters

    q_per_shard = time_query(idx)

    # same corpus through the same churn, global routing: the latency
    # ratio isolates the policy, not churn state
    glob = build("global")
    glob.insert(x[n:n + 64])
    glob.delete(range(0, 32))
    churn(glob, timed=False)
    q_global = time_query(glob)

    t0 = time.perf_counter()
    idx.compact()
    compact_s = time.perf_counter() - t0
    q_after = time_query(idx)
    st = idx.index_stats()

    out = {
        "n": n, "n_churn_ops": ops, "shards": int(shards), "queries": 64,
        "churn_docs_per_s": ops / max(churn_s, 1e-9),
        "churn_total_s": churn_s,
        "query_batch_s_per_shard": q_per_shard,
        "query_batch_s_global": q_global,
        "query_batch_s_after_compact": q_after,
        "compact_s": compact_s,
        "compact_total_s": st["total_seconds"],
        "n_live": st["n_live"],
    }
    if emit:
        with open(emit, "w") as f:
            json.dump(out, f, indent=2)
    return out


def skew_main(scale: float = 0.12,
              emit: str | None = None) -> Dict[str, float]:
    """Skewed-stream placement comparison (see module docstring).

    The CI-gated latencies (``p50/p99_{placement}_s``,
    ``skew_latency_delta_s``) are measured on the *linear route*
    (``force="linear"``): Eq. 2 prices that route at the padded scan
    size, which is exactly the term a hoarding shard inflates — and
    which the router's estimate therefore sees for every query.  The
    hybrid route's numbers ride along as ``p*_hybrid_*`` for context;
    when it picks LSH (cap-bounded bucket gathers, padded-size
    independent) the placements tie, which is itself the router working
    as designed.
    """
    n = max(6000, int(50000 * scale))
    d, L, B, m = 32, 8, 1024, 64
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    shards = int(mesh.shape["data"])
    x = np.asarray(clustered_dataset(n, d, n_clusters=32,
                                     dense_core_frac=0.2, core_scale=0.05,
                                     seed=0, metric="l2"), np.float32)
    rng = np.random.default_rng(0)
    q = jnp.asarray(x[rng.integers(0, n, 64)])
    fam = make_family("l2", d=d, L=L, r=1.0)
    r = 1.2
    cap = max(512, n // 4)

    def build(placement):
        idx = ShardedDynamicHybridIndex(
            fam, num_buckets=B, mesh=mesh, m=m, cap=256, delta_capacity=cap,
            cost_model=CostModel(alpha=1.0, beta=10.0),
            policy=CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                                    fanout=2, step_rows=None),
            placement=placement, routing="global", max_out=256, key=0)
        # the skewed stream: every insert batch pinned to shard 0
        # (key-hash placement); merges drain synchronously, so each
        # placement policy's steady state is what queries see
        for lo in range(0, n, 512):
            idx.insert(x[lo:lo + 512], shard=0)
        return idx

    placements = ("keep_local", "load_balance")
    out: Dict[str, float] = {"n": n, "shards": shards, "queries": 64,
                             "measured_route": "linear"}
    idxs = {}
    for placement in placements:
        idx = build(placement)
        idx.query(q, r, force="linear")              # warm (jit compile)
        hyb = idx.query(q, r)
        out[f"frac_lsh_hybrid_{placement}"] = float(
            np.asarray(hyb.used_lsh).mean())
        idxs[placement] = idx
        st = idx.index_stats()
        loads = np.asarray(st["live_per_shard"]) + np.asarray(
            st["delta_per_shard"])
        out[f"sum_n_pad_{placement}"] = int(sum(st["level_n_pads"]))
        out[f"max_shard_frac_{placement}"] = float(
            loads.max() / max(loads.sum(), 1))
        out[f"rows_moved_{placement}"] = int(st["rows_moved"])
        out[f"shard_skew_{placement}"] = float(st["shard_skew"])

    # interleave the timed runs so ambient noise (CI runner hiccups, GC
    # pauses) lands on both placements alike instead of biasing one.
    # p99 is the MIN of per-round p99s: external contamination can only
    # inflate a round's tail (p99 of 25 samples is essentially its max),
    # so the least-contaminated round is the best available observation
    # of the workload's own tail — a shared-runner hiccup in one or two
    # rounds cannot flip the sign of the CI-gated delta
    def measure(force, rounds=3, iters=25):
        lat: Dict[str, list] = {p: [] for p in placements}
        for _ in range(rounds):
            rd: Dict[str, list] = {p: [] for p in placements}
            for _ in range(iters):
                for placement in placements:
                    t0 = time.perf_counter()
                    idxs[placement].query(q, r, force=force)
                    rd[placement].append(time.perf_counter() - t0)
            for placement in placements:
                lat[placement].append(np.asarray(rd[placement]))
        return {p: (float(np.quantile(np.concatenate(s), 0.5)),
                    float(min(np.quantile(x_, 0.99) for x_ in s)))
                for p, s in lat.items()}

    linear = measure("linear")
    hybrid = measure(None, rounds=1)
    for placement in placements:
        out[f"p50_{placement}_s"], out[f"p99_{placement}_s"] = \
            linear[placement]
        (out[f"p50_hybrid_{placement}_s"],
         out[f"p99_hybrid_{placement}_s"]) = hybrid[placement]
    out["skew_latency_delta_s"] = (out["p99_keep_local_s"]
                                   - out["p99_load_balance_s"])
    out["skew_p50_delta_s"] = (out["p50_keep_local_s"]
                               - out["p50_load_balance_s"])
    out["padded_rows_cut"] = (out["sum_n_pad_keep_local"]
                              / max(out["sum_n_pad_load_balance"], 1))
    if emit:
        with open(emit, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    import argparse
    import os
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--skew", action="store_true",
                    help="run the skewed-stream placement comparison "
                         "(keep_local vs load_balance) instead of the "
                         "churn/routing bench")
    ap.add_argument("--scale", type=float, default=0.12)
    ap.add_argument("--emit", metavar="PATH", default=None)
    args = ap.parse_args()
    flags = os.environ.get("XLA_FLAGS", "")
    if len(jax.devices()) < 2 and "host_platform_device_count" not in flags:
        # sharding needs >= 2 devices, and the flag must precede the
        # jax import (already done at module top) — re-exec once with
        # it set; the env check makes the re-exec terminate
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=2").strip()
        os.execv(sys.executable, [sys.executable] + sys.argv)
    run = skew_main if args.skew else main
    print(json.dumps(run(args.scale, emit=args.emit), indent=2))
