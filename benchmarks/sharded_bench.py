"""Sharded streaming benchmark: churn throughput + routing-policy latency.

Measures, at a given ``--scale``, on a host-platform CPU mesh (the
driver forces >= 2 devices via XLA_FLAGS before jax import):

  * churn throughput — steady-state mixed insert/delete batches into the
    per-shard delta segments / tombstone bitmaps (docs/s)
  * query latency under both routing policies ("global" vs "per_shard")
    on the churned index, and again after per-shard compaction
  * compaction cost (per-shard build_tables rebuild)

Emits a JSON blob (``--emit``) so the sharded perf trajectory is
tracked alongside BENCH_streaming.json.
"""
from __future__ import annotations

import json
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CostModel
from repro.core.lsh import make_family
from repro.data import clustered_dataset
from repro.streaming import CompactionPolicy, ShardedDynamicHybridIndex


def main(scale: float = 0.12, emit: str | None = None) -> Dict[str, float]:
    n = max(2000, int(50000 * scale))
    n_churn = max(256, n // 8)
    d, L, B, m, r = 16, 8, 1024, 64, 1.2
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    shards = mesh.shape["data"]
    x = np.asarray(clustered_dataset(n + n_churn, d, n_clusters=32,
                                     dense_core_frac=0.2, core_scale=0.05,
                                     seed=0, metric="l2"), np.float32)
    rng = np.random.default_rng(0)
    q = x[rng.integers(0, n, 64)]
    fam = make_family("l2", d=d, L=L, r=1.0)

    def build(routing):
        idx = ShardedDynamicHybridIndex(
            fam, num_buckets=B, mesh=mesh, m=m, cap=256,
            delta_capacity=max(1024, n_churn),
            cost_model=CostModel(alpha=1.0, beta=10.0),
            policy=CompactionPolicy(delta_fill=2.0, tombstone_ratio=2.0),
            routing=routing, max_out=256, key=0)
        idx.build(x[:n])
        return idx

    ins_batch, del_batch = 64, 32

    def churn(i, timed):
        """Identical mixed insert/delete stream; optionally timed."""
        t0 = time.perf_counter()
        ops = 0
        for lo in range(n + 64, n + n_churn, ins_batch):
            take = min(ins_batch, n + n_churn - lo)
            i.insert(x[lo:lo + take])
            i.delete(range(lo - n, lo - n + del_batch))
            ops += take + del_batch
        return ops, (time.perf_counter() - t0) if timed else 0.0

    idx = build("per_shard")
    # warm the mutation + query paths (jit compile)
    idx.insert(x[n:n + 64])
    idx.delete(range(0, 32))
    idx.query(jnp.asarray(q), r)
    ops, churn_s = churn(idx, timed=True)

    def time_query(i, iters=5):
        i.query(jnp.asarray(q), r)            # warm
        t0 = time.perf_counter()
        for _ in range(iters):
            i.query(jnp.asarray(q), r)
        return (time.perf_counter() - t0) / iters

    q_per_shard = time_query(idx)

    # same corpus through the same churn, global routing: the latency
    # ratio isolates the policy, not churn state
    glob = build("global")
    glob.insert(x[n:n + 64])
    glob.delete(range(0, 32))
    churn(glob, timed=False)
    q_global = time_query(glob)

    t0 = time.perf_counter()
    idx.compact()
    compact_s = time.perf_counter() - t0
    q_after = time_query(idx)
    st = idx.index_stats()

    out = {
        "n": n, "n_churn_ops": ops, "shards": int(shards), "queries": 64,
        "churn_docs_per_s": ops / max(churn_s, 1e-9),
        "churn_total_s": churn_s,
        "query_batch_s_per_shard": q_per_shard,
        "query_batch_s_global": q_global,
        "query_batch_s_after_compact": q_after,
        "compact_s": compact_s,
        "compact_total_s": st["total_seconds"],
        "n_live": st["n_live"],
    }
    if emit:
        with open(emit, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
