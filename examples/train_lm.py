"""Train a small LM with the full production loop: deterministic data,
AdamW + cosine schedule + clipping, remat, async atomic checkpoints,
crash-resume (rerun the script — it continues from the last commit).

  PYTHONPATH=src python examples/train_lm.py [--steps 60]
"""
import argparse
import logging

from repro.configs import get_config, reduced_config
from repro.models.parallel import ParallelConfig
from repro.train import LoopConfig, TrainConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    cfg = reduced_config(get_config(args.arch), d_model=128, d_ff=256)
    par = ParallelConfig(mesh=None, attn_chunk_q=64, attn_chunk_k=64,
                         logits_chunk=64)
    hist = train_loop(
        cfg, par, batch=8, seq=64,
        tcfg=TrainConfig(peak_lr=1e-3, warmup_steps=10,
                         total_steps=args.steps),
        lcfg=LoopConfig(steps=args.steps, ckpt_every=20, log_every=5,
                        ckpt_dir=args.ckpt_dir))
    print(f"loss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
          f"over {hist['step'][-1] + 1} steps")


if __name__ == "__main__":
    main()
