"""End-to-end serving driver (the paper's kind: search/serving).

A small LM encodes documents and queries; corpus embeddings live in the
distributed-ready Hybrid LSH index; batched retrieval requests flow
through the shape-bucketing scheduler and the paper's cost-based router.

  PYTHONPATH=src python examples/serve_retrieval.py
"""
import numpy as np
import jax

from repro.configs import get_config, reduced_config
from repro.data import lm_batch
from repro.models import init_params
from repro.models.parallel import ParallelConfig
from repro.serve import (RetrievalConfig, RetrievalService,
                         ShapeBucketScheduler)


def main():
    cfg = reduced_config(get_config("yi-6b"), d_model=96)
    par = ParallelConfig(mesh=None, attn_chunk_q=32, attn_chunk_k=32,
                         logits_chunk=32, remat="none")
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = RetrievalService(cfg, par, params,
                           RetrievalConfig(radius=0.35, tables=12,
                                           num_buckets=1024, hll_m=64,
                                           delta_capacity=512,
                                           compact_step_rows=256))

    # Index a synthetic corpus of 2048 "documents".
    corpus = []
    for i in range(32):
        b = lm_batch(7, i, batch=64, seq=24, vocab=cfg.vocab, cfg=cfg)
        b.pop("labels")
        corpus.append(b)
    n = svc.index_corpus(corpus)
    print(f"indexed {n} documents "
          f"(L={svc.index.family.L}, k={svc.index.family.k})")

    # Batched requests through the scheduler; LSM merge work (freezes
    # from live inserts) advances between batches via the tick hook.
    sched = ShapeBucketScheduler(max_batch=32,
                                 background_tick=svc.compaction_tick)
    for i in range(50):
        sched.submit(i)
    while sched.queue:
        reqs, padded = sched.next_batch()
        qb = lm_batch(11, reqs[0].uid, batch=max(padded, 1), seq=24,
                      vocab=cfg.vocab, cfg=cfg)
        qb.pop("labels")
        res, emb = svc.query(qb)
        sizes = [len(res.neighbors(i)) for i in range(len(reqs))]
        print(f"  batch of {len(reqs)} (padded {padded}): "
              f"mean neighbors {np.mean(sizes):.1f}, "
              f"linear fraction {res.frac_linear:.2f}")
    print("service stats:", svc.stats)


if __name__ == "__main__":
    main()
