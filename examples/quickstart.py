"""Quickstart: build a Hybrid LSH index, report r-near neighbors, and
watch the router choose strategies (Algorithms 1+2 of the paper).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import CostModel, HybridLSHIndex
from repro.core.lsh import make_family
from repro.data import clustered_dataset, query_split


def main():
    # A dataset with a dense core: some queries are "hard" (paper Fig 1).
    x = clustered_dataset(20000, 32, n_clusters=16, dense_core_frac=0.25,
                          core_scale=0.02, seed=0)
    x, queries = query_split(x, n_queries=50, seed=0)
    r = 0.45

    fam = make_family("l2", d=32, L=20, r=r, delta=0.1)
    index = HybridLSHIndex(
        fam, num_buckets=2048, m=64, cap=256,
        cost_model=CostModel(alpha=1.0, beta=10.0), key=0)
    index.build(jnp.asarray(x))
    print(f"indexed n={index.n} d=32, L={fam.L} k={fam.k}, "
          f"HLL m={index.m}")
    print("index memory:", {k: f"{v/1e6:.1f}MB" if k.endswith('bytes')
                            else round(v, 4)
                            for k, v in index.memory_stats().items()})

    est = index.estimate(jnp.asarray(queries))
    print(f"\nper-query cost estimates (first 8):")
    for i in range(8):
        print(f"  q{i}: #collisions={int(est.collisions[i]):6d} "
              f"candSize~{float(est.cand_est[i]):8.1f} "
              f"LSHCost={float(est.lsh_cost[i]):10.1f} "
              f"LinearCost={est.linear_cost:10.1f} "
              f"-> {'LSH' if bool(est.use_lsh[i]) else 'LINEAR'}")

    res = index.query(jnp.asarray(queries), r)
    sizes = [len(res.neighbors(i)) for i in range(res.n_queries)]
    print(f"\nreported output sizes: mean={np.mean(sizes):.1f} "
          f"max={max(sizes)} min={min(sizes)}")
    print(f"fraction routed to linear search: {res.frac_linear:.2f}")


if __name__ == "__main__":
    main()
