"""Mini reproduction of the paper's Figure 2 on one dataset analogue:
hybrid vs LSH-only vs linear-only CPU time across radii (webspam-like
skewed data, where the paper shows hybrid beating BOTH).

  PYTHONPATH=src python examples/paper_repro.py [--scale 0.1]
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.08)
    args = ap.parse_args()

    from benchmarks.fig2_hybrid import run
    rows = run(scale=args.scale, datasets=("webspam",))
    print(f"{'r':>9} {'hybrid':>9} {'lsh':>9} {'linear':>9} "
          f"{'%linear-routed':>14}")
    for row in rows:
        best = min(row["lsh_s"], row["linear_s"])
        mark = " <- hybrid wins" if row["hybrid_s"] < best else ""
        print(f"{row['r']:9.4f} {row['hybrid_s']:9.4f} {row['lsh_s']:9.4f} "
              f"{row['linear_s']:9.4f} {100*row['frac_linear']:13.0f}%"
              f"{mark}")


if __name__ == "__main__":
    main()
