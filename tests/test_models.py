"""Model-zoo numerics: blockwise attention vs naive, chunked SSM scans
vs step-by-step recurrence, prefill+decode vs full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import (ParallelConfig, decode_step, init_params, prefill)
from repro.models.attention import blockwise_attention
from repro.models.ssm import mamba1_scan, ssd_scan

PAR = ParallelConfig(mesh=None, attn_chunk_q=8, attn_chunk_k=8,
                     logits_chunk=8, remat="none")
RNG = np.random.default_rng(0)


def _naive_attention(q, k, v, causal, window):
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, hd)
    scores = np.einsum("bsngh,btnh->bngst", qg, k) / np.sqrt(hd)
    mask = np.ones((s, s), bool)
    if causal:
        mask &= np.tril(np.ones((s, s), bool))
    if window:
        i, j = np.indices((s, s))
        mask &= (i - j) < window
    scores = np.where(mask[None, None, None], scores, -1e30)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    out = np.einsum("bngst,btnh->bsngh", p, v)
    return out.reshape(b, s, h, hd)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 5),
                                           (False, 0)])
@pytest.mark.parametrize("s,h,hkv", [(32, 4, 2), (16, 4, 1), (24, 2, 2)])
def test_blockwise_attention_matches_naive(causal, window, s, h, hkv):
    b, hd = 2, 16
    q = RNG.normal(size=(b, s, h, hd)).astype(np.float32)
    k = RNG.normal(size=(b, s, hkv, hd)).astype(np.float32)
    v = RNG.normal(size=(b, s, hkv, hd)).astype(np.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    got = blockwise_attention(jnp.asarray(q), jnp.asarray(k),
                              jnp.asarray(v), pos, pos, causal=causal,
                              window=window, chunk_q=8, chunk_k=8)
    want = _naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_mamba1_chunked_matches_sequential():
    b, s, di, n = 2, 32, 8, 4
    x = RNG.normal(size=(b, s, di)).astype(np.float32)
    dt = np.abs(RNG.normal(size=(b, s, di))).astype(np.float32) * 0.1
    bm = RNG.normal(size=(b, s, n)).astype(np.float32)
    cm = RNG.normal(size=(b, s, n)).astype(np.float32)
    a = -np.abs(RNG.normal(size=(di, n))).astype(np.float32)
    h0 = np.zeros((b, di, n), np.float32)
    y, hf = mamba1_scan(*map(jnp.asarray, (x, dt, bm, cm, a, h0)), chunk=8)
    # sequential reference
    h = h0.copy()
    ys = np.zeros((b, s, di), np.float32)
    for t in range(s):
        h = np.exp(dt[:, t, :, None] * a) * h \
            + (dt[:, t] * x[:, t])[..., None] * bm[:, t, None, :]
        ys[:, t] = np.einsum("bdn,bn->bd", h, cm[:, t])
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), h, rtol=2e-4, atol=2e-4)


def test_ssd_chunked_matches_sequential():
    b, s, nh, p, n = 2, 32, 3, 8, 4
    x = RNG.normal(size=(b, s, nh, p)).astype(np.float32)
    dt = np.abs(RNG.normal(size=(b, s, nh))).astype(np.float32) * 0.1
    bm = RNG.normal(size=(b, s, n)).astype(np.float32)
    cm = RNG.normal(size=(b, s, n)).astype(np.float32)
    a = -np.abs(RNG.normal(size=(nh,))).astype(np.float32)
    h0 = np.zeros((b, nh, p, n), np.float32)
    y, hf = ssd_scan(*map(jnp.asarray, (x, dt, bm, cm, a, h0)), chunk=8)
    h = h0.copy()
    ys = np.zeros((b, s, nh, p), np.float32)
    for t in range(s):
        decay = np.exp(dt[:, t] * a)                       # (b, nh)
        upd = np.einsum("bhp,bn,bh->bhpn", x[:, t], bm[:, t], dt[:, t])
        h = h * decay[..., None, None] + upd
        ys[:, t] = np.einsum("bhpn,bn->bhp", h, cm[:, t])
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), h, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["yi-6b", "gemma3-27b", "falcon-mamba-7b",
                                  "zamba2-1.2b", "whisper-small",
                                  "granite-moe-1b-a400m",
                                  "llama-3.2-vision-11b"])
def test_decode_matches_prefill(arch):
    """h_last from prefill(seq[:t]) + decode steps == prefill(seq).

    The strongest cache-correctness test: covers full/sliding-window
    KV caches, mamba conv+ssm states, cross-attn memory caches, MoE
    decode, and the shared-attn block."""
    cfg = reduced_config(get_config(arch))
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe is not None:  # avoid capacity-drop mismatches
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s_total, s_prompt = 2, 12, 8
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (b, s_total), 0, cfg.vocab)
    batch = {"tokens": toks[:, :s_prompt]}
    full = {"tokens": toks}
    if cfg.encoder_layers:
        fr = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model),
                               jnp.float32)
        batch["frames"] = fr
        full["frames"] = fr
    if cfg.num_image_tokens:
        im = jax.random.normal(key, (b, cfg.num_image_tokens, cfg.d_model),
                               jnp.float32)
        batch["image_embeds"] = im
        full["image_embeds"] = im

    h, caches, lengths = prefill(params, batch, cfg, PAR,
                                 cache_len=s_total)
    for t in range(s_prompt, s_total):
        h, caches = decode_step(params, caches, toks[:, t],
                                jnp.full((b,), t, jnp.int32), cfg, PAR)
    h_ref, _, _ = prefill(params, full, cfg, PAR, cache_len=s_total)
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(h_ref, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_moe_routes_all_tokens_with_high_capacity():
    from repro.models.moe import init_moe, moe_apply
    params = init_moe(jax.random.PRNGKey(0), 16, 32, 8, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 8, 16)).astype(np.float32))
    out, aux = moe_apply(params, x, top_k=2, capacity_factor=8.0)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0
    # permutation invariance of tokens (same multiset of outputs)
    xp = x[:, ::-1]
    outp, _ = moe_apply(params, xp, top_k=2, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(outp[:, ::-1]), np.asarray(out),
                               rtol=1e-4, atol=1e-4)
