"""Reusable differential-test harness for multi-tenant serving.

Three pieces, shared by ``test_collections.py`` and
``test_cache_churn.py``:

  * ``decode_ops`` — a deterministic decoder from raw integer streams
    (the hypothesis strategy surface the shim supports) into *valid*
    multi-collection op streams: create / insert / delete / query /
    compact / drop over a fixed name alphabet.  Validity is enforced by
    rewriting, never by skipping, so every input int produces exactly
    one op and equal int streams produce equal op streams — the
    property the mirror construction depends on.
  * ``MirrorOracle`` — runs one op stream simultaneously against a
    multi-tenant ``RetrievalService`` and N independent single-tenant
    mirror services (one per collection name, each hosting its one
    collection through the SAME ``create_collection`` code path), and
    asserts after every op that each collection's documents, and on
    every query its reported (ids, dists), are bit-identical to its
    mirror's.  Any cross-tenant bleed — shared-state corruption, cache
    aliasing, mis-routed compaction — shows up as a divergence.
  * ``assert_reported_identical`` — reported-set comparison: per query,
    identical id sets and bitwise-equal distances.  ``strict_order``
    additionally pins the reporting order; the default sorts by id,
    because segment-structure timing (budgeted tick interleave, async
    staging pace) can permute candidate order while the reported SET
    is the invariant the paper's Algorithm 2 guarantees.

Comparison points are always quiesced: pending merge state legitimately
diverges between a multi-tenant service (whose tick round-robins ONE
pending collection per turn) and a solo mirror — but fully-drained
states must coincide, and candidate generation is segmentation-
invariant once they do.
"""
import numpy as np

OPS = ("create", "insert", "delete", "query", "compact", "drop")


class CrashError(RuntimeError):
    """Raised by ``CrashPoint`` — a distinct type so tests can catch
    exactly the injected fault and never mask a real bug."""


class CrashPoint:
    """Crash injector for ``CheckpointManager(fault_hook=...)``.

    The manager calls its hook at named points during a save —
    ``"leaf"`` after each leaf/chunk lands, ``"pre_commit"`` just
    before the COMMITTED marker, ``"post_commit"`` just after.  A
    ``CrashPoint(point, after=n)`` raises ``CrashError`` on the
    (n+1)-th hit of its named point, simulating the process dying
    mid-save; every other point passes through.  ``hits`` counts
    matches seen, ``fired`` records whether the crash happened — a
    test can assert the injection actually triggered.
    """

    def __init__(self, point, after=0):
        self.point = point
        self.after = int(after)
        self.hits = 0
        self.fired = False

    def __call__(self, point, **info):
        if point != self.point:
            return
        self.hits += 1
        if self.hits > self.after:
            self.fired = True
            raise CrashError(f"injected crash at {point!r} "
                             f"(hit {self.hits}, info {info})")


def decode_ops(ints, names=("a", "b", "c")):
    """Decode a raw integer stream into a valid op stream.

    Returns ``[(kind, name, arg), ...]`` with one op per input int.
    Invalid draws are rewritten deterministically (create on a live
    name -> insert; insert/delete/query/drop on a dead name -> create),
    tracking liveness inside the decoder, so the result replays against
    any conforming service without errors.  ``compact`` is global (its
    name operand is ignored by appliers).
    """
    names = tuple(names)
    live = set()
    ops = []
    for v in ints:
        v = int(v) & 0x7FFFFFFF
        kind = OPS[v % len(OPS)]
        name = names[(v // len(OPS)) % len(names)]
        arg = v // (len(OPS) * len(names))
        if kind == "create":
            if name in live:
                kind = "insert"
        elif kind == "drop":
            if name not in live:
                kind = "create"
        elif kind in ("insert", "delete", "query"):
            if name not in live:
                kind = "create"
        if kind == "create":
            live.add(name)
        elif kind == "drop":
            live.discard(name)
        ops.append((kind, name, arg))
    return ops


def replay_liveness(ops):
    """The liveness trace a valid op stream implies: ``[(op, live_set),
    ...]`` with the live set AFTER each op.  Raises AssertionError on
    any op illegal in its prefix state — the validity oracle for
    ``decode_ops``."""
    live = set()
    trace = []
    for kind, name, arg in ops:
        if kind == "create":
            assert name not in live, (kind, name)
            live.add(name)
        elif kind == "drop":
            assert name in live, (kind, name)
            live.remove(name)
        elif kind in ("insert", "delete", "query"):
            assert name in live, (kind, name)
        else:
            assert kind == "compact", kind
        trace.append(((kind, name, arg), frozenset(live)))
    return trace


def assert_reported_identical(res_a, res_b, strict_order=False):
    """Both results report the same neighbors for every query.

    Identical id sets with bitwise-equal distances; ``strict_order``
    additionally requires the same reporting order.
    """
    assert res_a.n_queries == res_b.n_queries, \
        (res_a.n_queries, res_b.n_queries)
    for i in range(res_a.n_queries):
        ids_a, dists_a = (np.asarray(x) for x in res_a.reported(i))
        ids_b, dists_b = (np.asarray(x) for x in res_b.reported(i))
        if not strict_order:
            oa, ob = np.argsort(ids_a), np.argsort(ids_b)
            ids_a, dists_a = ids_a[oa], dists_a[oa]
            ids_b, dists_b = ids_b[ob], dists_b[ob]
        np.testing.assert_array_equal(ids_a, ids_b,
                                      err_msg=f"query {i}: ids differ")
        np.testing.assert_array_equal(dists_a, dists_b,
                                      err_msg=f"query {i}: dists differ")


def quiesce(svc):
    """Drain ALL pending merge work so the service's per-collection
    stacks are in their deterministic fully-compacted state (async: the
    driver flush barrier; sync/budgeted: tick to completion)."""
    if getattr(svc, "driver", None) is not None:
        svc.driver.flush()
    ticks = 0
    while svc.compaction_tick():
        ticks += 1
        assert ticks < 10_000, "compaction_tick never drained"


class MirrorOracle:
    """One multi-tenant service vs N single-tenant mirrors.

    Args:
      make_service: zero-arg factory for a fresh ``RetrievalService``
        (all services — the multi-tenant one and every mirror — come
        from the same factory, so config and params are identical).
      names: the collection-name alphabet; one mirror service per name.
      insert_fn: ``(name, arg) -> token batch`` for insert ops —
        must be deterministic in (name, arg) so both sides embed the
        same documents.
      query_fn: ``(arg) -> token batch`` for query ops.
    """

    def __init__(self, make_service, names, insert_fn, query_fn):
        self.svc = make_service()
        self.mirrors = {n: make_service() for n in names}
        self.names = tuple(names)
        self.insert_fn = insert_fn
        self.query_fn = query_fn
        self.live_ids = {n: [] for n in names}
        self.ops_applied = 0
        self.queries_checked = 0

    # ------------------------------------------------------------ applying
    def _pair(self, name):
        return self.svc, self.mirrors[name]

    def apply(self, op):
        """Apply one decoded op to the multi-tenant service AND the
        op's mirror, asserting equivalence of every observable."""
        kind, name, arg = op
        if kind == "create":
            self.svc.create_collection(name)
            self.mirrors[name].create_collection(name)
            self.live_ids[name] = []
        elif kind == "drop":
            self.svc.drop_collection(name)
            self.mirrors[name].drop_collection(name)
            self.live_ids[name] = []
        elif kind == "insert":
            batch = self.insert_fn(name, arg)
            ids_m = self.svc.add_documents([batch], collection=name)
            ids_s = self.mirrors[name].add_documents([batch],
                                                     collection=name)
            np.testing.assert_array_equal(ids_m, ids_s)
            self.live_ids[name].extend(int(i) for i in ids_m)
        elif kind == "delete":
            ids = self.live_ids[name]
            if ids:
                k = 1 + arg % max(1, len(ids) // 4)
                off = arg % len(ids)
                victims = [ids[(off + j) % len(ids)] for j in range(k)]
                victims = sorted(set(victims))
                n_m = self.svc.remove_documents(victims, collection=name)
                n_s = self.mirrors[name].remove_documents(victims,
                                                          collection=name)
                assert n_m == n_s == len(victims), (n_m, n_s, victims)
                self.live_ids[name] = [i for i in ids
                                       if i not in set(victims)]
        elif kind == "query":
            self.check_query(name, arg)
        elif kind == "compact":
            quiesce(self.svc)
            for m in self.mirrors.values():
                quiesce(m)
        else:  # pragma: no cover
            raise ValueError(op)
        self.ops_applied += 1
        self.assert_isolated()

    def run(self, ops):
        for op in ops:
            self.apply(op)
        # final sweep: every live collection answers identically
        for name in self.names:
            if name in self.svc.collections:
                self.check_query(name, arg=0)

    # ------------------------------------------------------------ checking
    def check_query(self, name, arg):
        """Quiesced direct-query comparison for one collection."""
        svc, mirror = self._pair(name)
        quiesce(svc)
        quiesce(mirror)
        qb = self.query_fn(arg)
        res_m, _ = svc.query(qb, collection=name)
        res_s, _ = mirror.query(qb, collection=name)
        assert_reported_identical(res_m, res_s)
        self.queries_checked += 1

    def assert_isolated(self):
        """Structural isolation: the multi-tenant service hosts exactly
        the live collections, each with its mirror's live-doc count and
        version-relevant corpus size."""
        for name in self.names:
            in_multi = name in self.svc.collections
            in_mirror = name in self.mirrors[name].collections
            assert in_multi == in_mirror, (name, in_multi, in_mirror)
            if in_multi:
                n_m = int(self.svc.collections.get(name).index.n)
                n_s = int(self.mirrors[name].collections.get(name).index.n)
                assert n_m == n_s == len(self.live_ids[name]), \
                    (name, n_m, n_s, len(self.live_ids[name]))

    def check_submit_round(self, arg=0):
        """The coalesced submit/drain path reports the same thing the
        mirrors' does, per collection, in one interleaved round."""
        live = [n for n in self.names if n in self.svc.collections]
        if not live:
            return
        quiesce(self.svc)
        qb = self.query_fn(arg)
        uids = {n: self.svc.submit(qb, collection=n) for n in live}
        res = self.svc.drain_batches(force=True)
        for n in live:
            mirror = self.mirrors[n]
            quiesce(mirror)
            direct, _ = mirror.query(qb, collection=n)
            r = res[uids[n]]
            for i in range(r.n_queries):
                ids_d, dists_d = (np.asarray(x) for x in direct.reported(i))
                order_m = np.argsort(np.asarray(r.ids[i]))
                order_d = np.argsort(ids_d)
                np.testing.assert_array_equal(
                    np.asarray(r.ids[i])[order_m], ids_d[order_d])
                np.testing.assert_array_equal(
                    np.asarray(r.dists[i])[order_m], dists_d[order_d])
        self.queries_checked += len(live)

    def close(self):
        self.svc.shutdown()
        for m in self.mirrors.values():
            m.shutdown()
