"""Per-arch smoke tests (assignment requirement): reduced same-family
config, one forward/train step on CPU, asserting output shapes and
no NaNs.  The FULL configs are exercised only via launch/dryrun.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCH_NAMES, SHAPES, get_config, reduced_config,
                           shape_applicable)
from repro.models import ParallelConfig, forward_train, init_params
from repro.train.step import TrainConfig, init_state, make_train_step

PAR = ParallelConfig(mesh=None, attn_chunk_q=8, attn_chunk_k=8,
                     logits_chunk=8, remat="block")


def _batch(cfg, b=2, s=16, key=0):
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab),
             "labels": jax.random.randint(k, (b, s), 0, cfg.vocab)}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            k, (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.num_image_tokens:
        batch["image_embeds"] = jax.random.normal(
            k, (b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_structure(arch):
    cfg = get_config(arch)
    assert len(cfg.pattern) * cfg.n_repeats + len(cfg.tail) == cfg.n_layers
    assert cfg.vocab % 16 == 0, "vocab must shard over the model axis"
    n = cfg.num_params()
    assert n > 1e8, (arch, n)  # full configs are real-model sized
    assert cfg.num_active_params() <= n


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_train_step(arch):
    """One full optimizer step on the reduced config: loss finite,
    params update, shapes preserved."""
    cfg = reduced_config(get_config(arch))
    state = init_state(cfg, jax.random.PRNGKey(0))
    step = make_train_step(cfg, PAR, TrainConfig(total_steps=10,
                                                 warmup_steps=0))
    batch = _batch(cfg)
    new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    before = jax.tree_util.tree_leaves(state["params"])
    after = jax.tree_util.tree_leaves(new_state["params"])
    assert any(not np.allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32))
               for a, b in zip(after, before))
    for a, b in zip(after, before):
        assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_forward_no_nan(arch):
    cfg = reduced_config(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(
        lambda p, b: forward_train(p, b, cfg, PAR))(params, _batch(cfg))
    assert np.isfinite(float(loss)), arch
    assert float(metrics["ce_loss"]) > 0


def test_shape_skip_policy():
    """long_500k runs exactly for the sub-quadratic archs."""
    runners = {a for a in ARCH_NAMES
               if shape_applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert runners == {"gemma3-27b", "falcon-mamba-7b", "zamba2-1.2b"}
    for a in ARCH_NAMES:  # every other shape runs everywhere
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(a), SHAPES[s])[0]
