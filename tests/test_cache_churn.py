"""Result-cache correctness under churn.

The cache key embeds the index's monotonic ``version``, so staleness
is impossible by construction — these tests prove the construction:

  * every mutation path (insert, delete, freeze, merge swap, sharded
    rebalance, full compact, restore-style stack replacement) bumps the
    version;
  * with mutations interleaved between repeated queries (sync,
    budgeted, and async compaction modes), the cached service's
    reported (ids, dists) stay bit-identical to an uncached service at
    every drained state.

The hand-written interleavings below are the named regression cases;
``test_cache_churn_property_stream`` drives the same cached-vs-plain
twin through *generated* op streams (the shared ``harness.decode_ops``
strategy the multi-tenant differential tests use), so the churn
coverage is no longer limited to the sequences someone thought of.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_shim import given, settings, strategies as st

from harness import decode_ops, quiesce
from repro.configs import get_config, reduced_config
from repro.core import CostModel
from repro.core.lsh import make_family
from repro.data import lm_batch
from repro.models import init_params
from repro.models.parallel import ParallelConfig
from repro.serve import RetrievalConfig, RetrievalService
from repro.streaming import (CompactionPolicy, DynamicHybridIndex,
                             ShardedDynamicHybridIndex)

PAR = ParallelConfig(mesh=None, attn_chunk_q=8, attn_chunk_k=8,
                     logits_chunk=8, remat="none")


# --------------------------------------------------------------------------
# version bumps on every mutation path (index level, no LM)
# --------------------------------------------------------------------------
def test_version_bumps_single_host():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(96, 8)).astype(np.float32)
    idx = DynamicHybridIndex(
        make_family("l2", d=8, L=4, r=1.0), num_buckets=64, m=32, cap=32,
        delta_capacity=16,
        cost_model=CostModel(alpha=1.0, beta=1.0),
        policy=CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                                fanout=2, step_rows=8), key=0)

    def bumped(op):
        before = idx.version
        op()
        assert idx.version > before, op
        return idx.version

    bumped(lambda: idx.build(x[:32]))                       # build
    bumped(lambda: idx.insert(x[32:36]))                    # delta insert
    bumped(lambda: idx.delete([0, 1]))                      # tombstone
    bumped(lambda: idx.delete([33]))                        # delta kill
    v = idx.version
    assert idx.delete([10 ** 9]) == 0 and idx.version == v  # no-op: none
    # two delta fills -> level-0 freezes (bump each), then drive the
    # scheduled fanout=2 merge to its swap
    bumped(lambda: idx.insert(x[36:68]))                    # freeze path
    assert idx.has_compaction_work
    before = idx.version
    while idx.compact_step(budget_rows=8):
        pass
    assert idx.version > before                             # merge swap
    bumped(idx.compact)                                     # full fold
    # stack replacement can never run the version backwards
    state = idx.state_dict()
    bumped(lambda: idx.load_state_dict(state))


def test_version_bumps_sharded():
    mesh = jax.make_mesh((1,), ("data",))
    rng = np.random.default_rng(1)
    x = rng.normal(size=(96, 8)).astype(np.float32)
    idx = ShardedDynamicHybridIndex(
        make_family("l2", d=8, L=4, r=1.0), num_buckets=64, mesh=mesh,
        m=32, cap=32, delta_capacity=16,
        cost_model=CostModel(alpha=1.0, beta=1.0),
        policy=CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                                fanout=2, step_rows=8),
        placement="load_balance", key=0)

    def bumped(op):
        before = idx.version
        op()
        assert idx.version > before, op

    bumped(lambda: idx.build(x[:32]))                       # build
    bumped(lambda: idx.insert(x[32:36]))                    # delta insert
    bumped(lambda: idx.delete([0, 1]))                      # tombstone
    v = idx.version
    assert idx.delete([10 ** 9]) == 0 and idx.version == v  # no-op: none
    bumped(lambda: idx.insert(x[36:68]))                    # freeze path
    assert idx.has_compaction_work
    before = idx.version
    while idx.compact_step(budget_rows=8):
        pass
    # merge swap through the placement policy (the rebalance path)
    assert idx.version > before
    bumped(idx.compact)                                     # full fold


# --------------------------------------------------------------------------
# cached vs uncached bit-identity under interleaved churn
# --------------------------------------------------------------------------
def _corpus_batches(cfg, n_batches, start=0):
    out = []
    for i in range(start, start + n_batches):
        b = lm_batch(3, i, batch=32, seq=12, vocab=cfg.vocab, cfg=cfg)
        b.pop("labels")
        out.append(b)
    return out


def _service(cfg, params, **kw):
    base = dict(radius=0.5, tables=8, num_buckets=256, hll_m=32, cap=64,
                delta_capacity=64)
    base.update(kw)
    return RetrievalService(cfg, PAR, params, RetrievalConfig(**base))


def _drain_all(svc):
    out = svc.drain_batches(force=True)
    assert svc.stats["scheduler"]["queue_depth"] == 0
    return out


def _assert_identical(res_a, res_b, uids_a, uids_b):
    for ua, ub in zip(uids_a, uids_b):
        ra, rb = res_a[ua], res_b[ub]
        assert ra.n_queries == rb.n_queries
        for j in range(ra.n_queries):
            np.testing.assert_array_equal(ra.ids[j], rb.ids[j])
            np.testing.assert_array_equal(ra.dists[j], rb.dists[j])


@pytest.mark.parametrize("mode", ["sync", "budgeted"])
def test_cache_churn_equivalence(mode):
    """Interleave add/remove/compaction with repeated queries: the
    cached service must stay bit-identical to an uncached twin at every
    drained state, and repeats in an unchanged state must actually hit.

    Sync and budgeted modes evolve state deterministically, so the two
    services hold identical indexes after identical op sequences (the
    async driver's staging speed varies by thread timing — it gets the
    single-service recompute test below instead).
    """
    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    kw = {} if mode == "sync" else {"compact_step_rows": 32}
    cached = _service(cfg, params, **kw)
    plain = _service(cfg, params, result_cache_bytes=0, **kw)

    corpus = _corpus_batches(cfg, 2)
    extra = _corpus_batches(cfg, 2, start=2)
    for svc in (cached, plain):
        svc.index_corpus(corpus)
    qtok = np.asarray(corpus[0]["tokens"])[:6]     # repeat-heavy pool

    def query_round():
        ua = [cached.submit(qtok[i]) for i in range(6)]
        ub = [plain.submit(qtok[i]) for i in range(6)]
        ra, rb = _drain_all(cached), _drain_all(plain)
        _assert_identical(ra, rb, ua, ub)
        return ra, ua

    r0, u0 = query_round()
    assert not any(r0[u].cached for u in u0)

    # unchanged state: repeats hit and stay identical
    r1, u1 = query_round()
    assert all(r1[u].cached for u in u1)
    _assert_identical(r0, r1, u0, u1)
    assert cached.stats["cache"]["hits"] >= 6
    assert plain.stats["cache"]["hits"] == 0       # disabled twin

    ids_added = []
    for svc in (cached, plain):
        ids_added.append(svc.add_documents([extra[0]]))
    np.testing.assert_array_equal(ids_added[0], ids_added[1])
    r2, u2 = query_round()
    assert not any(r2[u].cached for u in u2)       # version moved

    for svc in (cached, plain):
        assert svc.remove_documents(ids_added[0][:16].tolist()) == 16
    r3, u3 = query_round()
    assert not any(r3[u].cached for u in u3)
    # removed docs can never ride back in via the cache
    gone = set(ids_added[0][:16].tolist())
    for u in u3:
        for j in range(r3[u].n_queries):
            assert gone.isdisjoint(r3[u].ids[j].tolist())

    # freeze + merge churn (delta overflow), then drain compaction fully
    for svc in (cached, plain):
        svc.add_documents([extra[1]])
        while svc.compaction_tick():
            pass
    r4, u4 = query_round()
    assert not any(r4[u].cached for u in u4)
    r5, u5 = query_round()                         # stable again: hits
    assert all(r5[u].cached for u in u5)
    _assert_identical(r4, r5, u4, u5)


def test_cache_churn_async_driver():
    """Async mode: the worker's staging pace is nondeterministic, so the
    oracle is the same service's own uncached recompute — served state
    only changes on control-thread calls, and after flush() the version
    is pinned, so a hit must be bit-identical to a fresh query()."""
    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = _service(cfg, params, async_compaction=True)
    corpus = _corpus_batches(cfg, 2)
    extra = _corpus_batches(cfg, 2, start=2)
    svc.index_corpus(corpus)
    qtok = np.asarray(corpus[0]["tokens"])[:4]

    def check_round():
        # quiesce: finish all staged merges so background drains during
        # the two query rounds cannot move the version between them
        svc.driver.flush()
        uids = [svc.submit(qtok[i]) for i in range(4)]
        res = svc.drain_batches(force=True)
        uids2 = [svc.submit(qtok[i]) for i in range(4)]
        res2 = svc.drain_batches(force=True)
        assert all(res2[u].cached for u in uids2)
        direct, _ = svc.query({"tokens": jnp.asarray(qtok)})
        for i, (u, u2) in enumerate(zip(uids, uids2)):
            ids_d, dists_d = direct.reported(i)
            for r in (res[u], res2[u2]):
                np.testing.assert_array_equal(r.ids[0], np.asarray(ids_d))
                np.testing.assert_array_equal(r.dists[0],
                                              np.asarray(dists_d))

    check_round()
    ids = svc.add_documents([extra[0]])
    check_round()
    assert svc.remove_documents(ids[:20].tolist()) == 20
    check_round()
    svc.add_documents([extra[1]])                  # freeze + merge churn
    check_round()
    assert svc.stats["cache"]["hits"] >= 16
    svc.shutdown()


# --------------------------------------------------------------------------
# generated churn: the shared op-stream strategy drives the twins
# --------------------------------------------------------------------------
_PROP_NAMES = ("p", "q")


@settings(max_examples=3, deadline=None)
@given(st.lists(st.integers(0, 2 ** 31 - 1), min_size=6, max_size=14))
def test_cache_churn_property_stream(ints):
    """Generated multi-collection op streams (create / insert / delete /
    query / compact / drop) keep a cached service bit-identical to its
    uncached twin at every query point — the named cases above, minus
    the hand-picked sequences."""
    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    cached = _service(cfg, params, compact_step_rows=32)
    plain = _service(cfg, params, result_cache_bytes=0,
                     compact_step_rows=32)
    twins = (cached, plain)
    live_ids = {n: [] for n in _PROP_NAMES}

    def batch_for(name, arg):
        b = lm_batch(50 + _PROP_NAMES.index(name), arg % 5, batch=16,
                     seq=12, vocab=cfg.vocab, cfg=cfg)
        b.pop("labels")
        return b

    qtok = np.asarray(batch_for("p", 0)["tokens"])[:4]

    def check_query(name):
        for svc in twins:
            quiesce(svc)
        uids = [[svc.submit(qtok[i], collection=name) for i in range(4)]
                for svc in twins]
        res = [svc.drain_batches(force=True) for svc in twins]
        _assert_identical(res[0], res[1], uids[0], uids[1])
        # repeats on the unchanged state: the cached twin hits, stays
        # identical to the plain twin's recompute
        uid2 = [[svc.submit(qtok[i], collection=name) for i in range(4)]
                for svc in twins]
        res2 = [svc.drain_batches(force=True) for svc in twins]
        assert all(res2[0][u].cached for u in uid2[0])
        assert not any(res2[1][u].cached for u in uid2[1])
        _assert_identical(res2[0], res2[1], uid2[0], uid2[1])

    for kind, name, arg in decode_ops(ints, names=_PROP_NAMES):
        if kind == "create":
            for svc in twins:
                svc.create_collection(name)
            live_ids[name] = []
        elif kind == "drop":
            for svc in twins:
                svc.drop_collection(name)
            live_ids[name] = []
        elif kind == "insert":
            got = [svc.add_documents([batch_for(name, arg)],
                                     collection=name) for svc in twins]
            np.testing.assert_array_equal(got[0], got[1])
            live_ids[name].extend(int(i) for i in got[0])
        elif kind == "delete":
            ids = live_ids[name]
            if ids:
                victims = sorted({ids[(arg + j) % len(ids)]
                                  for j in range(1 + arg % 4)})
                counts = {svc.remove_documents(victims, collection=name)
                          for svc in twins}
                assert counts == {len(victims)}
                live_ids[name] = [i for i in ids if i not in set(victims)]
        elif kind == "query":
            check_query(name)
        elif kind == "compact":
            for svc in twins:
                quiesce(svc)
    for name in _PROP_NAMES:
        if name in cached.collections:
            check_query(name)
