"""Crash-fault injection + recovery: kill-and-restore differential.

The contract under test (docs/recovery.md): a service checkpointed
with the consistent-cut incremental snapshot can be killed at ANY
point of a save — after any leaf/chunk write, just before or just
after the COMMITTED marker — or mid-``apply_staged``, and a FRESH
service restored from the directory reports bit-identical neighbor
sets to an uninterrupted replay of the ops the last committed step
captured.  Exercised in all three compaction modes (sync / budgeted /
async), with the crash injected through the ``CheckpointManager``
``fault_hook`` seam (``harness.CrashPoint``), plus a property form
over random op streams (in-repo hypothesis shim when hypothesis is
absent).

Recovery goes through a NEW ``CheckpointManager`` on the same
directory, so every test also exercises the torn-write litter sweep a
real restart performs.
"""
import os

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_shim import given, settings, strategies as st

from harness import (CrashError, CrashPoint, assert_reported_identical,
                     quiesce)
from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.data import lm_batch
from repro.models import init_params
from repro.models.parallel import ParallelConfig
from repro.serve import RetrievalConfig, RetrievalService

PAR = ParallelConfig(mesh=None, attn_chunk_q=8, attn_chunk_k=8,
                     logits_chunk=8, remat="none")
MODES = ("sync", "budgeted", "async")
CRASH_POINTS = ("leaf", "pre_commit", "post_commit", "apply_staged")

_CACHE = {}


def _cfg_params():
    if "cfg" not in _CACHE:
        cfg = reduced_config(get_config("yi-6b"))
        _CACHE["cfg"] = cfg
        _CACHE["params"] = init_params(cfg, jax.random.PRNGKey(0))
    return _CACHE["cfg"], _CACHE["params"]


def _factory(mode, cfg, params):
    kw = dict(radius=0.5, tables=8, num_buckets=256, hll_m=32, cap=64,
              delta_capacity=64)
    if mode == "budgeted":
        kw["compact_step_rows"] = 32
    elif mode == "async":
        kw["async_compaction"] = True
        kw["compact_step_rows"] = 32

    def make():
        return RetrievalService(cfg, PAR, params, RetrievalConfig(**kw))
    return make


def _insert_batch(cfg, arg):
    b = lm_batch(100, arg % 7, batch=16, seq=12, vocab=cfg.vocab, cfg=cfg)
    b.pop("labels")
    return b


def _query_batch(cfg, arg=0):
    b = lm_batch(4, arg % 3, batch=4, seq=12, vocab=cfg.vocab, cfg=cfg)
    b.pop("labels")
    return b


def _run_ops(svc, cfg, ops, live):
    """Deterministic replay: equal (ops, prior live list) on two
    services produce identical corpora — the mirror construction."""
    for kind, arg in ops:
        if kind == "ins":
            ids = svc.add_documents([_insert_batch(cfg, arg)])
            live.extend(int(i) for i in ids)
        elif live:
            k = 1 + arg % 3
            victims = sorted({live[(arg + j) % len(live)]
                              for j in range(k)})
            assert svc.remove_documents(victims) == len(victims)
            live[:] = [i for i in live if i not in set(victims)]


def _trigger_apply_staged_crash(svc):
    """Simulate the process dying mid-swap: stage the head merge to
    ready, then kill the control thread inside ``apply_staged``.  Disk
    is untouched, so recovery must come entirely from the last
    committed step (staged progress is volatile by contract).  Sync
    mode may have no pending merge — then the crash degenerates to
    dying before the next checkpoint began, which the same restore
    covers."""
    idx = svc.index
    if svc.driver is not None:
        svc.driver.stop()
    guard = 0
    while idx.has_compaction_work and not idx.staged_ready:
        idx.stage_step(1 << 30)
        guard += 1
        assert guard < 10_000, "staging never reached ready"

    def _boom(*a, **k):
        raise CrashError("injected crash mid-apply_staged")

    if idx.staged_ready:
        idx.apply_staged = _boom
        with pytest.raises(CrashError):
            idx.apply_staged()


def _restore_and_compare(make, cfg, d, expect_step, replay):
    """Fresh manager (runs the litter sweep a restart performs) +
    fresh service restore, differential-compared against an
    uninterrupted replay of the committed prefix."""
    mgr = CheckpointManager(d)            # restart: sweeps torn writes
    assert mgr.latest_step() == expect_step
    for root, _, files in os.walk(d):
        litter = [f for f in files if f.endswith(".tmp")]
        assert litter == [], (root, litter)
    fresh = make()
    mirror = make()
    try:
        assert fresh.restore(mgr) == expect_step
        ml = []
        for ops in replay:
            if ops == "corpus":
                ml = list(range(
                    mirror.index_corpus([_insert_batch(cfg, 0)])))
            else:
                _run_ops(mirror, cfg, ops, ml)
        quiesce(fresh)
        quiesce(mirror)
        qb = _query_batch(cfg)
        res_a, _ = fresh.query(qb)
        res_b, _ = mirror.query(qb)
        assert_reported_identical(res_a, res_b)
        assert int(fresh.index.n) == len(ml)
    finally:
        fresh.shutdown(flush=False)
        mirror.shutdown(flush=False)


OPS1 = [("ins", 1), ("del", 3), ("ins", 2)]
OPS2 = [("ins", 4), ("del", 7), ("ins", 6)]


@pytest.mark.parametrize("point", CRASH_POINTS)
@pytest.mark.parametrize("mode", MODES)
def test_crash_restore_differential(mode, point, tmp_path):
    """Kill the service at a named crash point; a fresh service
    restored from the directory answers bit-identically to an
    uninterrupted replay of the last committed step's ops."""
    cfg, params = _cfg_params()
    make = _factory(mode, cfg, params)
    d = str(tmp_path)
    svc = make()
    try:
        live = list(range(svc.index_corpus([_insert_batch(cfg, 0)])))
        _run_ops(svc, cfg, OPS1, live)
        mgr = CheckpointManager(d)
        svc.checkpoint(mgr, 1)            # committed baseline
        assert mgr.latest_step() == 1
        _run_ops(svc, cfg, OPS2, live)
        if point == "apply_staged":
            _trigger_apply_staged_crash(svc)
            expect = 1
        else:
            crash = CrashPoint(point, after=2 if point == "leaf" else 0)
            cmgr = CheckpointManager(d, fault_hook=crash)
            with pytest.raises(CrashError):
                svc.checkpoint(cmgr, 2)
            assert crash.fired
            # dying after COMMITTED landed means step 2 is the truth;
            # any earlier death must fall back to step 1
            expect = 2 if point == "post_commit" else 1
    finally:
        svc.shutdown(flush=False)         # abandon the crashed process
    replay = ["corpus", OPS1] + ([OPS2] if expect == 2 else [])
    _restore_and_compare(make, cfg, d, expect, replay)


@settings(max_examples=3, deadline=None)
@given(st.lists(st.integers(0, 2 ** 31 - 1), min_size=1, max_size=6),
       st.sampled_from(CRASH_POINTS))
def test_crash_restore_random_streams(ints, point):
    """Property form: under a RANDOM op stream, a crash at any named
    point still restores bit-identically (sync mode — the mode axis is
    covered exhaustively above)."""
    import tempfile
    cfg, params = _cfg_params()
    make = _factory("sync", cfg, params)
    ops = [("ins" if v % 2 else "del", v >> 1) for v in ints]
    with tempfile.TemporaryDirectory() as d:
        svc = make()
        try:
            live = list(range(
                svc.index_corpus([_insert_batch(cfg, 0)])))
            _run_ops(svc, cfg, ops, live)
            mgr = CheckpointManager(d)
            svc.checkpoint(mgr, 1)
            _run_ops(svc, cfg, OPS2, live)
            if point == "apply_staged":
                _trigger_apply_staged_crash(svc)
                expect = 1
            else:
                crash = CrashPoint(point)
                cmgr = CheckpointManager(d, fault_hook=crash)
                with pytest.raises(CrashError):
                    svc.checkpoint(cmgr, 2)
                expect = 2 if point == "post_commit" else 1
        finally:
            svc.shutdown(flush=False)
        replay = ["corpus", ops] + ([OPS2] if expect == 2 else [])
        _restore_and_compare(make, cfg, d, expect, replay)


def test_consistent_cut_skips_flush_barrier():
    """The default checkpoint barrier must NOT drain queued merges:
    after a "cut" checkpoint the async driver reports zero flushes and
    one consistent cut, and pending merge work survives the snapshot
    (the old barrier ran it all inline)."""
    import tempfile
    cfg, params = _cfg_params()
    svc = _factory("async", cfg, params)()
    try:
        svc.index_corpus([_insert_batch(cfg, 0)])
        live = list(range(16))
        _run_ops(svc, cfg, [("ins", i) for i in range(1, 6)], live)
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            svc.checkpoint(mgr, 1)
            st_ = svc.driver.stats()
            assert st_["flushes"] == 0
            assert st_["cuts"] == 1
            assert mgr.stats()["incremental_saves"] == 1
            svc.checkpoint(mgr, 2, barrier="flush")
            st_ = svc.driver.stats()
            assert st_["flushes"] == 1
            assert not svc.index.has_compaction_work
    finally:
        svc.shutdown(flush=False)


def test_incremental_snapshot_reuses_frozen_chunks():
    """Back-to-back cut checkpoints of a churning service share the
    unchanged frozen-level chunks byte-for-byte: the second save's
    reused bytes dominate its written bytes for the stable levels."""
    import tempfile
    cfg, params = _cfg_params()
    svc = _factory("budgeted", cfg, params)()
    try:
        svc.index_corpus([_insert_batch(cfg, 0)])
        live = list(range(16))
        _run_ops(svc, cfg, [("ins", i) for i in range(1, 5)], live)
        quiesce(svc)                     # a stable frozen level exists
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            svc.checkpoint(mgr, 1)
            _run_ops(svc, cfg, [("ins", 9)], live)   # delta-only churn
            svc.checkpoint(mgr, 2)
            s = mgr.stats()
            assert s["incremental_saves"] == 2
            assert s["chunks_reused"] > 0
            assert s["bytes_reused"] > 0
    finally:
        svc.shutdown(flush=False)
