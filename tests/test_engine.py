"""Segment engine: the shared estimate/route/partition/search pipeline.

Checks that the compat wrappers (estimate_routes*) and the index-facing
engine path agree, that static segments are the dead-count zero case of
the unified estimator, that the deprecated ``core.router`` shim warns
and re-exports, and the satellite fixes (memory_stats before build,
exact n_linear).
"""
import jax.numpy as jnp
import numpy as np

from repro.core import CostModel, HybridLSHIndex
from repro.core.engine import (QueryEngine, SegmentEstimate, TableSegment,
                               estimate_routes, estimate_routes_dynamic,
                               finalize_route)
from repro.core.lsh import make_family
from repro.data import clustered_dataset
from repro.streaming import CompactionPolicy, DynamicHybridIndex
from repro.streaming import delta as delta_lib

D, L, B, M, CAP, R = 8, 4, 256, 32, 2048, 1.2


def _data(n=600):
    return np.asarray(clustered_dataset(n, D, n_clusters=8,
                                        dense_core_frac=0.2,
                                        core_scale=0.05, seed=0,
                                        metric="l2"), np.float32)


def _fam():
    return make_family("l2", d=D, L=L, r=1.0)


def test_static_estimate_matches_router_wrapper():
    """Index path (QueryEngine) == router compat wrapper, exactly."""
    x = _data()
    idx = HybridLSHIndex(_fam(), num_buckets=B, m=M, cap=CAP, key=0).build(x)
    q = jnp.asarray(x[::50][:8])
    qb = idx._bucket_fn(idx.params, q)
    a = idx.estimate(q)
    b = estimate_routes(idx.tables, qb, idx.cost_model, idx.n)
    for f in ("collisions", "cand_est", "lsh_cost", "use_lsh"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), f)
    assert a.linear_cost == b.linear_cost
    # static segment == unified path with zero dead counts
    seg = TableSegment(tables=idx.tables, n_live=idx.n, n_scan=idx.n)
    term = seg.estimate_terms(qb)
    assert term.dead_collisions is None
    c = finalize_route([term], idx.cost_model)
    np.testing.assert_array_equal(np.asarray(a.cand_est),
                                  np.asarray(c.cand_est))


def test_dynamic_estimate_matches_router_wrapper():
    """Streaming index path == the tombstone-aware compat wrapper."""
    x = _data()
    dyn = DynamicHybridIndex(_fam(), num_buckets=B, m=M, cap=CAP, key=0,
                             delta_capacity=256,
                             policy=CompactionPolicy(2.0, 2.0))
    dyn.build(x[:450])
    dyn.insert(x[450:])
    dyn.delete(range(40, 120, 2))
    q = jnp.asarray(x[::40][:8])
    qb = dyn._bucket_fn(dyn.params, q)
    a = dyn.estimate(q)
    d_coll, d_dist = delta_lib.collision_stats(dyn.delta, qb)
    b = estimate_routes_dynamic(
        dyn.main.tables, qb, dyn.cost_model, dyn.n,
        tomb_counts=dyn.tomb.counts, delta_collisions=d_coll,
        delta_distinct=d_dist, n_scan=dyn.main.n + int(dyn.delta.count))
    for f in ("collisions", "cand_est", "lsh_cost", "use_lsh"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), f)
    assert a.linear_cost == b.linear_cost


def test_finalize_route_combines_sketch_and_exact_terms():
    cm = CostModel(alpha=1.0, beta=2.0)
    sketchless = SegmentEstimate(collisions=jnp.asarray([5, 0]),
                                 cand_exact=jnp.asarray([3, 0]),
                                 n_live=10, n_scan=10)
    r = finalize_route([sketchless], cm)
    np.testing.assert_allclose(np.asarray(r.cand_est), [3.0, 0.0])
    np.testing.assert_allclose(np.asarray(r.lsh_cost), [11.0, 0.0])
    assert r.linear_cost == 20.0
    assert np.asarray(r.use_lsh).tolist() == [True, True]
    # structural clamp: candSize can never exceed live collisions
    clamped = SegmentEstimate(collisions=jnp.asarray([2]),
                              cand_exact=jnp.asarray([7]),
                              n_live=10, n_scan=10)
    assert float(finalize_route([clamped], cm).cand_est[0]) == 2.0


def test_memory_stats_before_build_is_zeroed():
    idx = HybridLSHIndex(_fam(), num_buckets=B, m=M, cap=CAP, key=0)
    st = idx.memory_stats()   # must not raise before build()
    assert st == {"perm_bytes": 0, "starts_bytes": 0, "hll_bytes": 0,
                  "hll_overhead_vs_data": 0.0}
    idx.build(_data(200))
    assert idx.memory_stats()["perm_bytes"] > 0


def test_query_result_n_linear_dedups_padding():
    x = _data(300)
    idx = HybridLSHIndex(_fam(), num_buckets=B, m=M, cap=CAP, key=0).build(x)
    q = jnp.asarray(x[:13])   # odd count: both groups get pow2 padding
    res = idx.query(q, R, force="linear")
    assert res.n_linear == 13 and len(res.lin_idx) == 16
    assert res.frac_linear == 1.0
    res = idx.query(q, R, force="lsh")
    assert res.n_linear == 0 and res.frac_linear == 0.0
    res = idx.query(q, R)
    assert res.n_linear == len(set(np.asarray(res.lin_idx).tolist()))
    engine = QueryEngine(idx.cost_model)
    assert engine.cost_model is idx.cost_model


def test_router_shim_warns_and_reexports():
    """The deprecated ``core.router`` shim: one intentional import site
    — it must warn and hand back the engine's objects unchanged, so it
    can be deleted (with this test) next release."""
    import importlib
    import warnings

    import repro.core.router as router_mod
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        router_mod = importlib.reload(router_mod)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    assert router_mod.estimate_routes is estimate_routes
    assert router_mod.estimate_routes_dynamic is estimate_routes_dynamic
    assert router_mod.finalize_route is finalize_route
