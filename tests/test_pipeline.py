"""GPipe pipeline: pipelined result == sequential stack (8-dev subprocess)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    script = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed import gpipe, bubble_fraction

mesh = jax.make_mesh((4, 2), ("stage", "model"))
n_stages, n_micro, mb, d = 4, 8, 4, 16

k = jax.random.PRNGKey(0)
w = jax.random.normal(k, (n_stages, d, d)) * 0.3
b = jax.random.normal(k, (n_stages, d)) * 0.1
xs = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

def stage_fn(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])

out = jax.jit(lambda p, x: gpipe(stage_fn, p, x, mesh=mesh,
                                 axis="stage"))({"w": w, "b": b}, xs)

# sequential reference
ref = xs
for s in range(n_stages):
    ref = jnp.tanh(ref @ w[s] + b[s])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=2e-5, atol=2e-5)
assert abs(bubble_fraction(8, 4) - 3/11) < 1e-9
print("PIPE_OK")
"""
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"
    assert "PIPE_OK" in out.stdout
