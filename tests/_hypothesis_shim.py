"""Deterministic fallback for ``hypothesis`` when it is not installed.

Implements the tiny strategy surface the test suite uses (``integers``,
``lists``, ``sampled_from``) plus ``given``/``settings`` decorators that
replay a fixed number of seeded pseudo-random examples.  Not a property
tester — no shrinking, no example database — but it keeps the property
tests *running* (instead of skipped) on minimal images.
"""
from __future__ import annotations

import zlib

import numpy as np

_DEFAULT_EXAMPLES = 20


class _Strategy:
    def example(self, rng: np.random.Generator):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def example(self, rng):
        return int(rng.integers(self.lo, self.hi + 1))


class _Lists(_Strategy):
    def __init__(self, elem: _Strategy, min_size: int, max_size: int):
        self.elem, self.min_size, self.max_size = elem, min_size, max_size

    def example(self, rng):
        size = int(rng.integers(self.min_size, self.max_size + 1))
        return [self.elem.example(rng) for _ in range(size)]


class _SampledFrom(_Strategy):
    def __init__(self, seq):
        self.seq = list(seq)

    def example(self, rng):
        return self.seq[int(rng.integers(0, len(self.seq)))]


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def lists(elem: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        return _Lists(elem, min_size, max_size)

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        return _SampledFrom(seq)


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        # NOTE: no functools.wraps — pytest would introspect the wrapped
        # signature and treat the example parameters as fixtures.
        def wrapper():
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_EXAMPLES)
            # crc32, not hash(): str hashing is salted per process and
            # would make "deterministic" examples unreproducible.
            rng = np.random.default_rng(
                zlib.crc32(fn.__name__.encode()) & 0xFFFFFFFF)
            for _ in range(n):
                fn(*[s.example(rng) for s in strats])
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
