"""Checkpoint manager: roundtrip, atomicity, retention, elastic
restore, content-addressed incremental saves, crash litter hygiene."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from harness import CrashError, CrashPoint
from repro.checkpoint import CheckpointManager, array_digest
from repro.obs.schema import CHECKPOINT_STATS_KEYS


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"blocks": ({"w": jax.random.normal(k, (4, 8))},
                              {"w": jax.random.normal(k, (8, 4))}),
                   "tail": ()},
        "opt": {"step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(10, s, blocking=True)
    restored, step = mgr.restore(s)
    assert step == 10
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["blocks"][0]["w"]),
        np.asarray(s["params"]["blocks"][0]["w"]))
    assert isinstance(restored["params"]["blocks"], tuple)
    assert int(restored["opt"]["step"]) == 7


def test_uncommitted_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(5, s, blocking=True)
    # simulate a crash mid-save: directory without COMMITTED marker
    d = os.path.join(str(tmp_path), "step_0000000009")
    os.makedirs(d)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        f.write("{}")
    assert mgr.latest_step() == 5


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    s = _state()
    for step in (1, 2, 3, 4):
        mgr.save(step, s, blocking=True)
    assert mgr.committed_steps() == [3, 4]


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(1, s, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_elastic_restore_new_sharding(tmp_path):
    """Restore with target_shardings puts leaves on the current mesh —
    the checkpoint format is mesh-agnostic."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(3, s, blocking=True)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), s)
    restored, step = mgr.restore(s, target_shardings=sh)
    assert step == 3
    leaf = restored["params"]["blocks"][0]["w"]
    assert leaf.sharding == NamedSharding(mesh, P())


def _chunk_files(tmp_path):
    d = os.path.join(str(tmp_path), "chunks")
    return sorted(os.listdir(d)) if os.path.isdir(d) else []


def test_incremental_roundtrip_and_chunk_reuse(tmp_path):
    """Incremental saves are content-addressed: identical leaves across
    steps share one chunk file, only changed leaves write bytes, and
    restore is bit-exact from the chunk store."""
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save_incremental(1, s, blocking=True)
    n1 = len(_chunk_files(tmp_path))
    s2 = dict(s, opt={"step": jnp.int32(8)})      # one leaf changes
    mgr.save_incremental(2, s2, blocking=True)
    st = mgr.stats()
    assert st["incremental_saves"] == 2
    assert st["chunks_written"] == n1 + 1         # only the new leaf
    assert st["chunks_reused"] == n1 - 1          # params shared
    assert st["bytes_reused"] > 0
    restored, step = mgr.restore(s)
    assert step == 2
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["blocks"][0]["w"]),
        np.asarray(s["params"]["blocks"][0]["w"]))
    assert int(restored["opt"]["step"]) == 8


def test_incremental_digest_hints_trusted_only_with_chunk(tmp_path):
    """A digest hint whose chunk file is missing must be recomputed,
    not trusted — otherwise a stale hint silently drops a leaf."""
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    bogus = {path: "0" * 32 for path in ("opt/step",)}
    mgr.save_incremental(1, s, digests=bogus, blocking=True)
    restored, step = mgr.restore(s)
    assert step == 1 and int(restored["opt"]["step"]) == 7


def test_chunk_gc_follows_retention(tmp_path):
    """Chunks referenced only by GC'd steps are removed; chunks shared
    with kept steps survive."""
    mgr = CheckpointManager(str(tmp_path), keep=1)
    s = _state()
    mgr.save_incremental(1, s, blocking=True)
    s2 = dict(s, opt={"step": jnp.int32(9)})
    mgr.save_incremental(2, s2, blocking=True)
    assert mgr.committed_steps() == [2]
    assert mgr.stats()["chunks_gced"] >= 1        # step 1's opt leaf
    # every surviving chunk is referenced by the kept manifest
    restored, step = mgr.restore(s)
    assert step == 2 and int(restored["opt"]["step"]) == 9


def test_crashed_save_swept_on_restart(tmp_path):
    """A save killed before COMMITTED leaves a torn step; a new
    manager on the directory (the restart) sweeps it and serves the
    newest committed step, with no .tmp litter anywhere."""
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save_incremental(1, s, blocking=True)
    crash = CrashPoint("pre_commit")
    cmgr = CheckpointManager(str(tmp_path), fault_hook=crash)
    with pytest.raises(CrashError):
        cmgr.save_incremental(2, _state(1), blocking=True)
    assert crash.fired
    mgr2 = CheckpointManager(str(tmp_path))       # restart
    assert mgr2.latest_step() == 1
    assert mgr2.stats()["litter_swept"] >= 1
    for root, _, files in os.walk(str(tmp_path)):
        assert not [f for f in files if f.endswith(".tmp")], root
    restored, step = mgr2.restore(s)
    assert step == 1 and int(restored["opt"]["step"]) == 7


def test_crash_mid_leaf_full_save_swept(tmp_path):
    """The fault seam covers the full (non-incremental) writer too:
    dying after the first leaf leaves an uncommitted step dir that the
    next manager init removes."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(), blocking=True)
    crash = CrashPoint("leaf", after=1)
    cmgr = CheckpointManager(str(tmp_path), fault_hook=crash)
    with pytest.raises(CrashError):
        cmgr.save(2, _state(1), blocking=True)
    mgr2 = CheckpointManager(str(tmp_path))
    assert mgr2.latest_step() == 1
    assert mgr2.committed_steps() == [1]


def test_checkpoint_stats_schema_pinned(tmp_path):
    """stats() matches CHECKPOINT_STATS_KEYS exactly — the contract
    the BENCH emitter and dashboards scrape."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_incremental(1, _state(), blocking=True)
    assert frozenset(mgr.stats()) == CHECKPOINT_STATS_KEYS


def test_array_digest_dtype_and_shape_sensitive():
    """The content address covers dtype and shape, not just bytes —
    two different logical arrays with equal byte payloads must not
    alias a chunk."""
    a = np.arange(8, dtype=np.int32)
    assert array_digest(a) == array_digest(a.copy())
    assert array_digest(a) != array_digest(a.astype(np.float32))
    assert array_digest(a) != array_digest(a.reshape(2, 4))
    b = a.copy(); b[0] = 99
    assert array_digest(a) != array_digest(b)
