"""Checkpoint manager: roundtrip, atomicity, retention, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"blocks": ({"w": jax.random.normal(k, (4, 8))},
                              {"w": jax.random.normal(k, (8, 4))}),
                   "tail": ()},
        "opt": {"step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(10, s, blocking=True)
    restored, step = mgr.restore(s)
    assert step == 10
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["blocks"][0]["w"]),
        np.asarray(s["params"]["blocks"][0]["w"]))
    assert isinstance(restored["params"]["blocks"], tuple)
    assert int(restored["opt"]["step"]) == 7


def test_uncommitted_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(5, s, blocking=True)
    # simulate a crash mid-save: directory without COMMITTED marker
    d = os.path.join(str(tmp_path), "step_0000000009")
    os.makedirs(d)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        f.write("{}")
    assert mgr.latest_step() == 5


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    s = _state()
    for step in (1, 2, 3, 4):
        mgr.save(step, s, blocking=True)
    assert mgr.committed_steps() == [3, 4]


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(1, s, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_elastic_restore_new_sharding(tmp_path):
    """Restore with target_shardings puts leaves on the current mesh —
    the checkpoint format is mesh-agnostic."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(3, s, blocking=True)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), s)
    restored, step = mgr.restore(s, target_shardings=sh)
    assert step == 3
    leaf = restored["params"]["blocks"][0]["w"]
    assert leaf.sharding == NamedSharding(mesh, P())
