import os
import sys

# src-layout import without install; single real CPU device (the
# 512-device XLA flag belongs ONLY to launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
