"""Multi-tenant collections: isolation, quotas, fairness, schemas.

The centerpiece is the differential harness (``harness.MirrorOracle``):
one multi-tenant service and N independent single-tenant mirrors run
the SAME decoded op stream, and every collection's reported sets must
stay bit-identical to its mirror's under interleaved add / remove /
compaction churn — in all three compaction modes.  Around it:
property tests for the op-stream decoder, scheduler quota/fairness
units with an injected clock, pinned stats schemas, driver fairness,
and checkpoint round-trips of the full collection tree.
"""
import dataclasses
import math
import tempfile

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_shim import given, settings, strategies as st

from harness import (MirrorOracle, assert_reported_identical, decode_ops,
                     quiesce, replay_liveness)
from repro.configs import get_config, reduced_config
from repro.core import CostModel
from repro.core.lsh import make_family
from repro.data import lm_batch
from repro.models import init_params
from repro.models.parallel import ParallelConfig
from repro.obs import Observability
from repro.obs.schema import (COLLECTION_MANAGER_KEYS,
                              COLLECTION_STATS_KEYS, DRIVER_STATS_KEYS,
                              SCHEDULER_STATS_KEYS, SCHEDULER_TENANT_KEYS)
from repro.serve import (RetrievalConfig, RetrievalService, ResultCache,
                         ShapeBucketScheduler, TenantQuota)
from repro.serve.collections import CollectionManager
from repro.streaming import (CompactionDriver, CompactionPolicy,
                             DynamicHybridIndex)

PAR = ParallelConfig(mesh=None, attn_chunk_q=8, attn_chunk_k=8,
                     logits_chunk=8, remat="none")
NAMES = ("a", "b", "c")


# --------------------------------------------------------------------------
# op-stream decoder properties
# --------------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 2 ** 31 - 1), min_size=0, max_size=40))
def test_decode_ops_always_valid(ints):
    """Every int stream decodes to a stream replayable without errors:
    creates only on dead names, drops/inserts/deletes/queries only on
    live ones (replay_liveness raises otherwise)."""
    ops = decode_ops(ints, names=NAMES)
    assert len(ops) == len(ints)            # rewritten, never skipped
    trace = replay_liveness(ops)
    assert len(trace) == len(ops)
    for (kind, name, arg), live in trace:
        assert kind in ("create", "insert", "delete", "query",
                        "compact", "drop")
        assert name in NAMES
        assert arg >= 0
        assert live <= set(NAMES)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 2 ** 31 - 1), min_size=0, max_size=40))
def test_decode_ops_deterministic(ints):
    """Equal int streams decode to equal op streams — the property the
    mirror construction (two services fed one stream) relies on."""
    assert decode_ops(ints, names=NAMES) == decode_ops(ints, names=NAMES)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 2 ** 31 - 1), min_size=1, max_size=40),
       st.integers(0, 2 ** 31 - 1))
def test_decode_ops_prefix_stable(ints, extra):
    """Appending input never rewrites the decoded prefix (the decoder
    is causal), so streams can be extended mid-run."""
    ops = decode_ops(ints, names=NAMES)
    assert decode_ops(ints + [extra], names=NAMES)[:len(ints)] == ops


def test_decode_ops_exercises_all_kinds():
    """The rewrite rules keep every op kind reachable."""
    ops = decode_ops(range(0, 600, 7), names=NAMES)
    assert {k for k, _, _ in ops} == {"create", "insert", "delete",
                                      "query", "compact", "drop"}


# --------------------------------------------------------------------------
# scheduler: per-tenant token buckets + weighted-fair drain (no LM)
# --------------------------------------------------------------------------
class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_tenant_quota_rejects_at_own_bucket():
    """A flooding tenant empties ITS bucket and gets rejected there,
    while the quiet tenant keeps being admitted; refill restores
    admission; the labeled reject counter carries the collection."""
    from repro.obs import MetricsRegistry
    clock = FakeClock()
    reg = MetricsRegistry(enabled=True)
    sched = ShapeBucketScheduler(max_batch=8, registry=reg, clock=clock)
    sched.set_quota("noisy", rate=2.0, burst=3.0)
    admitted = sum(sched.submit({"i": i}, collection="noisy") is not None
                   for i in range(10))
    assert admitted == 3                     # burst exhausted
    assert sched.submit({"i": 0}, collection="quiet") is not None
    ts = sched.stats()["tenants"]
    assert ts["noisy"]["rejects"] == 7 and ts["noisy"]["submits"] == 3
    assert ts["quiet"]["rejects"] == 0 and ts["quiet"]["submits"] == 1
    snap = reg.snapshot()["counters"]
    assert snap['repro_scheduler_rejects_total'
                '{collection="noisy",reason="quota"}'] == 7
    clock.t += 1.0                           # refill 2 tokens
    assert sched.submit({"i": 0}, collection="noisy") is not None
    assert sched.submit({"i": 1}, collection="noisy") is not None
    assert sched.submit({"i": 2}, collection="noisy") is None


def test_global_queue_bound_labeled_per_tenant():
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry(enabled=True)
    sched = ShapeBucketScheduler(max_batch=4, max_queue=2, registry=reg,
                                 clock=FakeClock())
    assert sched.submit({}, collection="a") is not None
    assert sched.submit({}, collection="b") is not None
    assert sched.submit({}, collection="a") is None       # queue full
    snap = reg.snapshot()["counters"]
    assert snap['repro_scheduler_rejects_total'
                '{collection="a",reason="queue_full"}'] == 1
    assert snap["repro_scheduler_rejects_total"] == 1     # aggregate


def test_weighted_fair_drain_shares_and_order():
    """Backlogged tenants split batch slots by quota weight; the popped
    batch preserves global submit (uid) order; a lone tenant drains
    pure FIFO."""
    clock = FakeClock()
    sched = ShapeBucketScheduler(max_batch=8, clock=clock)
    sched.set_quota("big", weight=3.0)
    sched.set_quota("small", weight=1.0)
    uids = {}
    for i in range(12):                      # interleave submits
        uids[("big", i)] = sched.submit({"i": i}, collection="big")
        uids[("small", i)] = sched.submit({"i": i}, collection="small")
    take, padded = sched.next_batch()
    assert padded == 8 and len(take) == 8
    by_col = {}
    for r in take:
        by_col.setdefault(r.collection, []).append(r)
    assert len(by_col["big"]) == 6 and len(by_col["small"]) == 2
    assert [r.uid for r in take] == sorted(r.uid for r in take)
    # each tenant's share is its own FIFO head
    assert [r.payload["i"] for r in by_col["big"]] == [0, 1, 2, 3, 4, 5]
    assert [r.payload["i"] for r in by_col["small"]] == [0, 1]


def test_weighted_drain_never_starves_quiet_tenant():
    """A 100-deep noisy backlog cannot push a quiet tenant's request
    out of the next batch — its queue-wait stays one drain, not a
    whole backlog flush."""
    clock = FakeClock()
    sched = ShapeBucketScheduler(max_batch=8, clock=clock)
    sched.set_quota("noisy", weight=1.0)
    sched.set_quota("quiet", weight=1.0)
    for i in range(100):
        sched.submit({"i": i}, collection="noisy")
    clock.t = 5.0
    quiet_uid = sched.submit({"i": -1}, collection="quiet")
    clock.t = 6.0
    take, _ = sched.next_batch()
    assert quiet_uid in {r.uid for r in take}
    ts = sched.stats()["tenants"]
    assert ts["quiet"]["queue_wait_max_s"] == 1.0
    assert ts["noisy"]["queue_wait_max_s"] == 6.0


def test_drop_collection_discards_queue_and_state():
    sched = ShapeBucketScheduler(max_batch=4, clock=FakeClock())
    for i in range(3):
        sched.submit({}, collection="x")
    sched.submit({}, collection="y")
    assert sched.drop_collection("x") == 3
    assert sched.stats()["queue_depth"] == 1
    assert "x" not in sched.stats()["tenants"]
    take, _ = sched.next_batch()
    assert [r.collection for r in take] == ["y"]


def test_scheduler_tenant_stats_schema_pinned():
    sched = ShapeBucketScheduler(max_batch=4, clock=FakeClock())
    sched.set_quota("t", rate=5.0, weight=2.0)
    sched.submit({}, collection="t")
    s = sched.stats()
    assert set(s) == SCHEDULER_STATS_KEYS
    assert set(s["tenants"]) == {"t"}
    assert set(s["tenants"]["t"]) == SCHEDULER_TENANT_KEYS
    assert s["tenants"]["t"]["burst"] == 5.0        # burst defaults rate
    assert s["tenants"]["t"]["weight"] == 2.0


# --------------------------------------------------------------------------
# collection manager over bare indexes (no LM)
# --------------------------------------------------------------------------
def _bare_factory(d=8, delta_capacity=16, step_rows=None):
    fam = make_family("l2", d=d, L=4, r=1.0)

    def factory(obs):
        return DynamicHybridIndex(
            fam, num_buckets=64, m=32, cap=32,
            delta_capacity=delta_capacity,
            cost_model=CostModel(alpha=1.0, beta=1.0),
            policy=CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                                    fanout=2, step_rows=step_rows),
            key=0, obs=obs)
    return factory


def test_manager_lifecycle_names_and_events():
    obs = Observability.create(enabled=True)
    mgr = CollectionManager(_bare_factory(), obs=obs)
    for bad in ("", "a/b", ".hidden", "sp ace", "-lead"):
        with pytest.raises(ValueError):
            mgr.create(bad)
    col = mgr.create("t1", quota=TenantQuota(rate=9.0, burst=9.0))
    with pytest.raises(ValueError):
        mgr.create("t1")                      # duplicate
    assert "t1" in mgr and len(mgr) == 1 and mgr.names() == ["t1"]
    with pytest.raises(KeyError):
        mgr.get("missing")
    # index events are stamped with the collection name (delta
    # overflow forces at least one freeze event through the facade)
    rng = np.random.default_rng(0)
    col.index.build(rng.normal(size=(8, 8)).astype(np.float32))
    col.index.insert(rng.normal(size=(16, 8)).astype(np.float32))
    col.index.insert(rng.normal(size=(16, 8)).astype(np.float32))
    kinds = {}
    for ev in obs.events.events():
        if ev.get("collection") == "t1":
            kinds[ev["kind"]] = kinds.get(ev["kind"], 0) + 1
    assert "collection_create" in kinds
    assert len(kinds) > 1                     # index events labeled too
    dropped = mgr.drop("t1")
    assert dropped is col and len(mgr) == 0
    assert any(ev["kind"] == "collection_drop"
               for ev in obs.events.events())
    # the name is reusable after a drop
    mgr.create("t1")


def test_manager_stats_schema_pinned():
    mgr = CollectionManager(_bare_factory())
    mgr.create("u")
    mgr.create("v", quota=TenantQuota(rate=4.0, burst=2.0, weight=3.0))
    mgr.get("u").index.build(np.random.default_rng(1)
                             .normal(size=(12, 8)).astype(np.float32))
    mgr.note_query("u", n_queries=5, n_linear=2)
    s = mgr.stats()
    assert set(s) == COLLECTION_MANAGER_KEYS
    assert s["n_collections"] == 2
    assert set(s["collections"]) == {"u", "v"}
    for sub in s["collections"].values():
        assert set(sub) == COLLECTION_STATS_KEYS
    assert s["collections"]["u"]["n_live"] == 12
    assert s["collections"]["u"]["queries"] == 5
    assert s["collections"]["u"]["linear_served"] == 2
    assert s["collections"]["v"]["quota_weight"] == 3.0
    mgr.drop("u")
    assert mgr.stats()["dropped_total"] == 1


def test_manager_drop_purges_cache_and_scheduler():
    """Dropping a collection removes its queued requests and cache
    entries — a re-created namesake restarts at version 0 and must
    never see the old tenant's cached results."""
    cache = ResultCache(max_bytes=1 << 16)
    sched = ShapeBucketScheduler(max_batch=4, clock=FakeClock())
    mgr = CollectionManager(_bare_factory(), scheduler=sched, cache=cache)
    mgr.create("t")
    sched.submit({}, collection="t")
    tok = np.arange(6, dtype=np.int32)[None, :]
    k = cache.key(0, 0.5, tok, collection="t")
    cache.put(k, [np.arange(3)], [np.zeros(3, np.float32)])
    assert cache.get(k) is not None
    mgr.drop("t")
    assert cache.get(k) is None
    assert sched.stats()["queue_depth"] == 0
    mgr.create("t")                            # fresh version-0 tenant
    assert cache.get(cache.key(0, 0.5, tok, collection="t")) is None


def test_driver_round_robin_fairness_two_collections():
    """One driver worker serves staged merge work for BOTH attached
    collections — the fairness counters show neither monopolized the
    worker, and both stacks drain."""
    obs = Observability.create(enabled=True)
    factory = _bare_factory(delta_capacity=16, step_rows=8)
    driver = CompactionDriver(budget_rows=8, obs=obs, poll_s=0.005)
    mgr = CollectionManager(factory, obs=obs, driver=driver)
    rng = np.random.default_rng(2)
    a = mgr.create("a", attach=False)
    b = mgr.create("b", attach=False)
    for col in (a, b):
        col.index.build(rng.normal(size=(8, 8)).astype(np.float32))
    mgr.attach_driver("a")
    mgr.attach_driver("b")
    driver.start()
    try:
        for _ in range(3):                    # overflow both deltas
            a.index.insert(rng.normal(size=(16, 8)).astype(np.float32))
            b.index.insert(rng.normal(size=(16, 8)).astype(np.float32))
            driver.notify()
        deadline = 200
        while (a.index.has_compaction_work or
               b.index.has_compaction_work) and deadline:
            driver.drain()
            import time
            time.sleep(0.01)
            deadline -= 1
    finally:
        driver.stop(flush=True)
    st = driver.stats()
    assert set(st) == DRIVER_STATS_KEYS
    assert st["collections"] == 2
    assert st["fairness"].get("a", 0) > 0
    assert st["fairness"].get("b", 0) > 0
    assert not a.index.has_compaction_work
    assert not b.index.has_compaction_work


# --------------------------------------------------------------------------
# service-level differential isolation (the tentpole proof)
# --------------------------------------------------------------------------
def _make_service_factory(mode, cfg, params):
    kw = dict(radius=0.5, tables=8, num_buckets=256, hll_m=32, cap=64,
              delta_capacity=64)
    if mode == "budgeted":
        kw["compact_step_rows"] = 32
    elif mode == "async":
        kw["async_compaction"] = True
        kw["compact_step_rows"] = 32

    def make():
        return RetrievalService(cfg, PAR, params, RetrievalConfig(**kw))
    return make


def _lm_cfg_params():
    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _batch_fns(cfg):
    def insert_fn(name, arg):
        seed = 100 + NAMES.index(name)
        b = lm_batch(seed, arg % 7, batch=16, seq=12, vocab=cfg.vocab,
                     cfg=cfg)
        b.pop("labels")
        return b

    def query_fn(arg):
        b = lm_batch(4, arg % 3, batch=4, seq=12, vocab=cfg.vocab,
                     cfg=cfg)
        b.pop("labels")
        return b
    return insert_fn, query_fn


# a fixed raw stream; decode_ops rewrites it into a valid mixed-kind
# stream over a/b/c (creates, inserts, deletes, queries, compacts, one
# drop+recreate) — the same stream drives all three modes
_RAW_STREAM = [0, 1, 2, 7, 13, 19, 45, 91, 121, 57, 38, 103, 5, 64,
               20, 33, 75, 9, 111, 58]


@pytest.mark.parametrize("mode", ["sync", "budgeted", "async"])
def test_differential_isolation_under_churn(mode):
    """The differential harness: a multi-tenant service and three
    single-tenant mirrors replay one op stream; per-collection reported
    sets stay bit-identical under interleaved add / remove / compaction
    churn, structural isolation holds after every op, and the coalesced
    submit path agrees too."""
    cfg, params = _lm_cfg_params()
    oracle = MirrorOracle(_make_service_factory(mode, cfg, params),
                          NAMES, *_batch_fns(cfg))
    try:
        ops = decode_ops(_RAW_STREAM, names=NAMES)
        assert {k for k, _, _ in ops} >= {"create", "insert", "query"}
        oracle.run(ops)
        oracle.check_submit_round()
        assert oracle.queries_checked > 0
    finally:
        oracle.close()


def test_drop_recreate_cache_isolation_service_level():
    """Bleed-specific regression: tenant 'a' is dropped and re-created
    with DIFFERENT documents; a repeated query must reflect the new
    corpus (old cached results purged), while tenant 'b' keeps its
    cache hits across the neighbor's churn."""
    cfg, params = _lm_cfg_params()
    svc = _make_service_factory("sync", cfg, params)()
    insert_fn, query_fn = _batch_fns(cfg)
    svc.create_collection("a", [insert_fn("a", 0)])
    svc.create_collection("b", [insert_fn("b", 0)])
    qb = query_fn(0)

    u1 = svc.submit(qb, collection="a")
    ub1 = svc.submit(qb, collection="b")
    r1 = svc.drain_batches(force=True)
    u2 = svc.submit(qb, collection="a")
    r2 = svc.drain_batches(force=True)
    assert r2[u2].cached                       # warm hit on same state

    svc.drop_collection("a")
    svc.create_collection("a", [insert_fn("a", 5)])   # different corpus
    u3 = svc.submit(qb, collection="a")
    ub2 = svc.submit(qb, collection="b")
    r3 = svc.drain_batches(force=True)
    assert not r3[u3].cached                   # purge was mandatory
    assert r3[ub2].cached                      # 'b' unaffected by churn
    direct, _ = svc.query(qb, collection="a")
    ids_d, _ = direct.reported(0)
    np.testing.assert_array_equal(
        np.sort(r3[u3].ids[0]), np.sort(np.asarray(ids_d)))
    for i in range(r1[ub1].n_queries):
        np.testing.assert_array_equal(r1[ub1].ids[i], r3[ub2].ids[i])
        np.testing.assert_array_equal(r1[ub1].dists[i], r3[ub2].dists[i])


def test_collection_checkpoint_roundtrip_and_names():
    """The full collection tree (default corpus + named tenants with
    quotas) survives save/restore into a FRESH service; the manifest
    lists tenant names without loading arrays."""
    from repro.checkpoint import CheckpointManager
    cfg, params = _lm_cfg_params()
    make = _make_service_factory("budgeted", cfg, params)
    insert_fn, query_fn = _batch_fns(cfg)
    svc = make()
    corpus = insert_fn("a", 3)
    svc.index_corpus([corpus])                 # default corpus rides too
    svc.create_collection("t1", [insert_fn("a", 0)],
                          quota=TenantQuota(rate=7.0, burst=3.0,
                                            weight=2.0))
    svc.create_collection("t2", [insert_fn("b", 0)])
    svc.remove_documents([0, 1], collection="t1")
    qb = query_fn(1)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        svc.checkpoint(mgr, 7)
        assert mgr.collection_names() == ["t1", "t2"]
        assert mgr.collection_names(7) == ["t1", "t2"]
        fresh = make()
        assert fresh.restore(mgr) == 7
    assert fresh.collections.names() == ["t1", "t2"]
    q = fresh.collections.get("t1").quota
    assert (q.rate, q.burst, q.weight) == (7.0, 3.0, 2.0)
    # restored quota is live on the scheduler, not just recorded
    ts = fresh.scheduler.stats()["tenants"]
    assert ts["t1"]["rate"] == 7.0 and ts["t1"]["weight"] == 2.0
    for name in ("t1", "t2"):
        quiesce(svc)
        quiesce(fresh)
        ra, _ = svc.query(qb, collection=name)
        rb, _ = fresh.query(qb, collection=name)
        assert_reported_identical(ra, rb, strict_order=True)
    r_def_a, _ = svc.query(qb)
    r_def_b, _ = fresh.query(qb)
    assert_reported_identical(r_def_a, r_def_b, strict_order=True)


def test_service_shares_engine_and_family_across_collections():
    """All tenants (and the default corpus) are built around ONE
    QueryEngine and ONE LSH family object — the jit/bucket_fn cache is
    shared by construction, not by coincidence."""
    cfg, params = _lm_cfg_params()
    svc = _make_service_factory("sync", cfg, params)()
    insert_fn, _ = _batch_fns(cfg)
    svc.index_corpus([insert_fn("a", 1)])
    svc.create_collection("x", [insert_fn("a", 0)])
    svc.create_collection("y", [insert_fn("b", 0)])
    eng = svc.index._engine
    assert svc.collections.get("x").index._engine is eng
    assert svc.collections.get("y").index._engine is eng
    fam = svc.index.family
    assert svc.collections.get("x").index.family is fam
    assert svc.collections.get("y").index.family is fam


def test_service_stats_carry_collections_subtree():
    cfg, params = _lm_cfg_params()
    svc = _make_service_factory("sync", cfg, params)()
    insert_fn, query_fn = _batch_fns(cfg)
    svc.create_collection("only", [insert_fn("a", 0)])
    svc.query(query_fn(0), collection="only")
    s = svc.stats
    assert set(s["collections"]) == COLLECTION_MANAGER_KEYS
    sub = s["collections"]["collections"]["only"]
    assert set(sub) == COLLECTION_STATS_KEYS
    assert sub["queries"] == 4
    # per-collection labeled series landed in the registry
    snap = svc.obs.registry.snapshot()["counters"]
    assert snap['repro_collection_queries_total{collection="only"}'] == 4
