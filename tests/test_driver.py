"""Async compaction driver: merge staging on a worker thread, swaps on
the control thread.

The load-bearing contracts:

  * equivalence under concurrency — queries issued while the driver's
    worker stages a merge (and at every drained state after a swap)
    report exactly what a fresh build on the surviving corpus reports;
  * no orphans — ``stop``/``flush`` leave no queued merge, no staged
    rows, and a consistent ``_loc`` map;
  * checkpoints — a snapshot taken mid-merge with the worker live
    round-trips (staged progress is volatile by contract), and the
    service-level ``checkpoint`` flushes first so the saved structure
    is merge-free.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import HybridLSHIndex
from repro.core.lsh import make_family
from repro.data import clustered_dataset
from repro.streaming import (CompactionDriver, CompactionPolicy,
                             DynamicHybridIndex,
                             ShardedDynamicHybridIndex)

D, L, B, M, CAP, R = 8, 4, 256, 32, 2048, 1.2


def _data(n=900, seed=0):
    x = np.asarray(clustered_dataset(n, D, n_clusters=12,
                                     dense_core_frac=0.2, core_scale=0.05,
                                     seed=seed, metric="l2"))
    return x.astype(np.float32)


def _fam():
    return make_family("l2", d=D, L=L, r=1.0)


def _dyn(**kw):
    kw.setdefault("delta_capacity", 128)
    kw.setdefault("policy", CompactionPolicy(delta_fill=1.0,
                                             tombstone_ratio=2.0,
                                             fanout=2, step_rows=48))
    return DynamicHybridIndex(_fam(), num_buckets=B, m=M, cap=CAP, key=0,
                              **kw)


def _fresh_sets(x, q, force, ext_ids=None):
    idx = HybridLSHIndex(_fam(), num_buckets=B, m=M, cap=CAP, key=0).build(x)
    sets = idx.query(jnp.asarray(q), R, force=force).neighbor_sets()
    if ext_ids is None:
        return sets
    return {k: {int(ext_ids[i]) for i in v} for k, v in sets.items()}


def _settle(dyn, drv, deadline_s=60.0):
    """Drain until the worker has staged everything and every swap has
    been applied (the steady state a serving loop reaches)."""
    t_end = time.time() + deadline_s
    while dyn.has_compaction_work and time.time() < t_end:
        drv.drain()
        time.sleep(0.002)
    assert not dyn.has_compaction_work, (dyn.index_stats(), drv.stats())


def test_driver_concurrent_churn_equivalence():
    """Queries at every drained state — merges staged by the worker
    while inserts/deletes land — match a fresh single-host build."""
    x = _data()
    q = x[::47][:10]
    dyn = _dyn().build(x[:256])
    drv = CompactionDriver(dyn, budget_rows=48, poll_s=0.001).start()
    try:
        live = np.ones(900, bool)
        checked = 0
        for lo in range(256, 900, 100):
            dyn.insert(x[lo:lo + 100])
            drv.notify()
            if lo == 456:
                dyn.delete(range(100, 200, 2))
                live[100:200:2] = False
            drv.drain()
            if lo in (456, 656):        # drained states mid-stream
                ids = np.nonzero(live)[0]
                got = dyn.query(q, R, force="linear").neighbor_sets()
                assert got == _fresh_sets(x[:lo + 100][live[:lo + 100]], q,
                                          "linear",
                                          ext_ids=ids[ids < lo + 100]), lo
                checked += 1
        assert checked == 2
        _settle(dyn, drv)
        st = drv.stats()
        assert st["stage_calls"] > 0        # the worker really staged
        assert st["applied"] > 0            # drains really swapped
        assert st["worker_errors"] == 0
        assert st["staged_rows"] == 0 and st["pending_gathers"] == 0
        ids = np.nonzero(live)[0]
        for force in ("lsh", "linear"):
            got = dyn.query(q, R, force=force).neighbor_sets()
            assert got == _fresh_sets(x[live], q, force, ext_ids=ids), force
    finally:
        drv.stop()


def test_driver_stop_flush_leaves_no_orphans():
    """stop(flush=True) with merges mid-stage completes them inline:
    nothing queued, nothing staged, _loc consistent (deletes work)."""
    x = _data(n=640)
    dyn = _dyn().build(x[:256])
    drv = CompactionDriver(dyn, budget_rows=32, poll_s=0.001).start()
    dyn.insert(x[256:640])                  # several freezes -> merges
    drv.notify()
    drv.stop(flush=True)
    st = drv.stats()
    assert st["worker_alive"] is False
    assert st["pending_gathers"] == 0 and st["staged_rows"] == 0
    assert not dyn.has_compaction_work
    # _loc survived every swap: rows merged under the driver delete fine
    assert dyn.delete(range(0, 640, 7)) == len(range(0, 640, 7))
    live = np.ones(640, bool)
    live[::7] = False
    ids = np.nonzero(live)[0]
    got = dyn.query(x[::80][:6], R, force="linear").neighbor_sets()
    assert got == _fresh_sets(x[live], x[::80][:6], "linear", ext_ids=ids)
    # a stopped driver restarts cleanly on the same index
    drv.start()
    assert drv.running
    dyn.insert(_data(n=700, seed=3)[640:700], ids=range(1000, 1060))
    drv.notify()
    _settle(dyn, drv)
    drv.stop(flush=True)
    assert not dyn.has_compaction_work


def test_delete_after_prepare_carried_as_tombstones():
    """Rows deleted after the worker pre-built the merged segment are
    masked (tombstoned in the new segment), never resurrected, and the
    dropped/dead accounting stays consistent."""
    x = _data(n=512)
    q = x[::40][:8]
    dyn = _dyn().build(x[:256])
    dyn.insert(x[256:512])               # two level-0 freezes -> merge
    assert dyn.has_compaction_work
    drv = CompactionDriver(dyn, budget_rows=64, poll_s=0.001).start()
    try:
        t_end = time.time() + 30
        while not (dyn.staged_ready
                   and dyn.stack.tasks[0].prepared is not None) \
                and time.time() < t_end:
            time.sleep(0.001)
        assert dyn.stack.tasks[0].prepared is not None
        dead = list(range(0, 500, 3))    # staged + prepared + delta rows
        assert dyn.delete(dead) == len(dead)
        assert drv.drain() >= 1          # swap applied on control thread
        # mid-merge deletes ride along tombstoned in the swapped-in
        # segment (max uid = the merged one) until the next merge
        merged = max(dyn.stack.segments, key=lambda s: s.uid)
        assert merged.n_dead > 0
        _settle(dyn, drv)                # cascades reclaim them
        assert drv.stats()["prepares"] >= 1
    finally:
        drv.stop(flush=True)
    live = np.ones(512, bool)
    live[dead] = False
    ids = np.nonzero(live)[0]
    for force in ("lsh", "linear"):
        got = dyn.query(q, R, force=force).neighbor_sets()
        assert got == _fresh_sets(x[live], q, force, ext_ids=ids), force
        flat = set().union(*got.values()) if got else set()
        assert flat.isdisjoint(dead)
    # _loc stayed consistent through the prepared swap
    assert dyn.delete(ids[:10].tolist()) == 10
    assert dyn.n == int(live.sum()) - 10


def test_driver_checkpoint_roundtrip_mid_merge(tmp_path):
    """A snapshot taken while the worker is mid-stage (no flush, no
    drain) round-trips: staged progress is volatile, the restored index
    re-derives its schedule and converges to the same answers."""
    x = _data()
    q = x[::70][:8]
    dyn = _dyn().build(x[:256])
    dyn.insert(x[256:600])
    assert dyn.has_compaction_work
    drv = CompactionDriver(dyn, budget_rows=16, poll_s=0.001).start()
    try:
        t_end = time.time() + 30
        while dyn.staged_rows == 0 and time.time() < t_end:
            time.sleep(0.001)
        assert dyn.staged_rows > 0          # worker is mid-stage
        mgr = CheckpointManager(str(tmp_path))
        mgr.save_index(3, dyn)              # no flush: truly mid-merge
    finally:
        drv.stop(flush=True)
    restored = _dyn()
    assert mgr.restore_index(restored) == 3
    drv2 = CompactionDriver(restored, budget_rows=64, poll_s=0.001).start()
    try:
        restored.insert(x[600:700])
        drv2.notify()
        _settle(restored, drv2)
    finally:
        drv2.stop(flush=True)
    dyn.insert(x[600:700])
    while dyn.compact_step(512):
        pass
    for f in ("lsh", "linear"):
        assert (restored.query(q, R, force=f).neighbor_sets()
                == dyn.query(q, R, force=f).neighbor_sets()), f


def test_driver_sharded_equivalence_and_locations():
    """The driver over the mesh-sharded index (1-device mesh, same code
    path): worker-staged merges + control-thread swaps with placement
    keep neighbor sets and the _loc invariant intact."""
    mesh = jax.make_mesh((1,), ("data",))
    x = _data()
    q = x[::60][:10]
    sh = ShardedDynamicHybridIndex(
        _fam(), num_buckets=B, mesh=mesh, m=M, cap=CAP, key=0,
        delta_capacity=128, max_out=900, placement="load_balance",
        policy=CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                                fanout=2, step_rows=48))
    sh.build(x[:256])
    drv = CompactionDriver(sh, budget_rows=48, poll_s=0.001).start()
    try:
        live = np.ones(900, bool)
        for lo in range(256, 900, 100):
            sh.insert(x[lo:lo + 100])
            drv.notify()
            if lo == 556:
                sh.delete(range(300, 400, 2))
                live[300:400:2] = False
            drv.drain()
        _settle(sh, drv)
        assert drv.stats()["worker_errors"] == 0
        sh.validate_locations()
        ids = np.nonzero(live)[0]
        for force in ("lsh", "linear"):
            got = sh.query(q, R, force=force).neighbor_sets()
            assert got == _fresh_sets(x[live], q, force, ext_ids=ids), force
    finally:
        drv.stop(flush=True)
    sh.validate_locations()
    assert sh.pending_merges == 0 and sh.staged_rows == 0


def test_service_async_compaction_lifecycle(tmp_path):
    """RetrievalService with async_compaction: driver lifecycle, tick
    counting (only ticks that ran work), checkpoint flush barrier, and
    restore/shutdown."""
    from repro.configs import get_config, reduced_config
    from repro.data import lm_batch
    from repro.models import init_params
    from repro.models.parallel import ParallelConfig
    from repro.serve import RetrievalConfig, RetrievalService

    par = ParallelConfig(mesh=None, attn_chunk_q=8, attn_chunk_k=8,
                         logits_chunk=8, remat="none")
    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = RetrievalService(cfg, par, params,
                           RetrievalConfig(radius=0.5, tables=8,
                                           num_buckets=256, hll_m=32,
                                           cap=64, delta_capacity=64,
                                           compact_fanout=2,
                                           async_compaction=True))

    def batch(seed):
        b = lm_batch(seed, 0, batch=32, seq=12, vocab=cfg.vocab, cfg=cfg)
        b.pop("labels")
        return b

    assert svc.index_corpus([batch(3)]) == 32
    assert svc.driver is not None and svc.driver.running
    assert svc.index.policy.step_rows == 32       # async default budget

    # a tick with no pending work is idle, not a compaction tick
    svc.compaction_tick()
    assert svc.stats["compaction_ticks"] == 0
    assert svc.stats["idle_ticks"] == 1

    # churn enough to freeze + schedule merges; ticks drain the swaps
    new_ids = svc.add_documents([batch(4), batch(5), batch(6)])
    assert len(new_ids) == 96
    t_end = time.time() + 60
    while svc.index.has_compaction_work and time.time() < t_end:
        svc.compaction_tick()
        time.sleep(0.002)
    assert not svc.index.has_compaction_work
    st = svc.stats
    assert st["driver"]["worker_alive"]
    assert st["driver"]["stage_calls"] > 0        # gathers ran off-thread
    assert st["driver"]["applied"] > 0
    assert st["compaction_ticks"] == st["driver"]["applied"]
    assert st["compactions"] > 0

    # queries still see everything that was added
    res, _ = svc.query(batch(5))
    found = sum(1 for i in range(32)
                if set(res.neighbors(i).tolist()) & set(new_ids.tolist()))
    assert found >= 28

    # checkpoint takes a consistent cut under the driver lock: no
    # flush, queued merge work survives the snapshot (the old barrier
    # stays opt-in via barrier="flush"; staged progress is volatile by
    # contract, so the snapshot is complete without it)
    svc.remove_documents(new_ids[:40].tolist())
    mgr = CheckpointManager(str(tmp_path))
    svc.checkpoint(mgr, step=9)
    assert mgr.latest_step() == 9
    assert svc.stats["driver"]["cuts"] == 1
    assert svc.stats["driver"]["flushes"] == 0
    n_at_ckpt = svc.index.n

    # mutate past the checkpoint, then restore back to it
    svc.remove_documents(new_ids[40:80].tolist())
    assert svc.index.n == n_at_ckpt - 40
    assert svc.restore(mgr) == 9
    assert svc.index.n == n_at_ckpt
    assert svc.driver.running                     # worker restarted

    svc.shutdown()
    assert svc.driver.running is False
