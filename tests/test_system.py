"""End-to-end behaviour of the paper's system (Algorithms 1+2).

The contract being tested, per the paper:
  * r-NN reporting with recall >= 1 - delta (probabilistic; we test at
    comfortable margins);
  * hybrid routing: easy queries -> LSH search, hard queries (dense
    core) -> linear search;
  * the HLL candSize estimate drives costs that match reality within
    the sketch's error;
  * linear-search results are exact.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.core import CostModel, HybridLSHIndex
from repro.core.lsh import make_family
from repro.data import clustered_dataset, query_split


def _dataset(metric="l2", n=6000, d=24, dense=0.3, seed=0):
    x = clustered_dataset(n, d, n_clusters=16, dense_core_frac=dense,
                          core_scale=0.02, seed=seed, metric=metric)
    return query_split(x, n_queries=40, seed=seed)


def _brute(metric, x, q, r):
    if metric == "l2":
        d = np.sqrt(((q[:, None] - x[None]) ** 2).sum(-1))
    elif metric == "l1":
        d = np.abs(q[:, None] - x[None]).sum(-1)
    else:
        qa = q / np.linalg.norm(q, axis=1, keepdims=True)
        xa = x / np.linalg.norm(x, axis=1, keepdims=True)
        d = 1 - qa @ xa.T
    return [set(np.nonzero(row <= r)[0].tolist()) for row in d]


def _radius_with_neighbors(metric, x, q, quantile=0.002):
    """Pick r from the empirical distance distribution so that the
    query set has non-trivial (but small) output sizes."""
    if metric == "l2":
        d = np.sqrt(((q[:8, None] - x[None]) ** 2).sum(-1))
    elif metric == "l1":
        d = np.abs(q[:8, None] - x[None]).sum(-1)
    else:
        qa = q[:8] / np.linalg.norm(q[:8], axis=1, keepdims=True)
        xa = x / np.linalg.norm(x, axis=1, keepdims=True)
        d = 1 - qa @ xa.T
    return float(np.quantile(d, quantile))


@pytest.mark.parametrize("metric", ["l2", "cosine", "l1"])
def test_recall_above_theory_bound(metric):
    """Mean recall >= 0.8x the worst-case theory bound
    1 - (1 - p1(r)^k)^L (p1(r) is the collision prob AT distance r;
    all true neighbors are at <= r, so aggregate recall should beat
    the bound; 0.8 slack absorbs sampling noise)."""
    x, q = _dataset(metric=metric, dense=0.0)
    r = _radius_with_neighbors(metric, x, q)
    L = 50  # the paper's table count
    fam = make_family(metric, d=x.shape[1], L=L, r=r, delta=0.1)
    idx = HybridLSHIndex(fam, num_buckets=1024, m=32, cap=512,
                         cost_model=CostModel(1.0, 10.0), key=0)
    idx.build(jnp.asarray(x))
    res = idx.query(jnp.asarray(q), r, force="lsh")
    gt = _brute(metric, x, q, r)
    recalls = []
    for i in range(len(q)):
        if not gt[i]:
            continue
        rep = set(res.neighbors(i).tolist())
        assert rep <= gt[i] or metric == "cosine", "no false positives"
        recalls.append(len(rep & gt[i]) / len(gt[i]))
    bound = 1.0 - (1.0 - fam.p1(r) ** fam.k) ** L
    assert np.mean(recalls) >= 0.8 * bound, (metric, np.mean(recalls),
                                             bound)
    # hybrid routing can only improve recall (linear is exact)
    res_h = idx.query(jnp.asarray(q), r)
    rec_h = []
    for i in range(len(q)):
        if gt[i]:
            rec_h.append(len(set(res_h.neighbors(i).tolist()) & gt[i])
                         / len(gt[i]))
    assert np.mean(rec_h) >= np.mean(recalls) - 1e-9


def test_linear_route_is_exact():
    x, q = _dataset(dense=0.5)
    r = 0.5
    fam = make_family("l2", d=x.shape[1], L=10, r=r)
    idx = HybridLSHIndex(fam, num_buckets=512, m=32, cap=128, key=1)
    idx.build(jnp.asarray(x))
    res = idx.query(jnp.asarray(q), r, force="linear")
    gt = _brute("l2", x, q, r)
    for i in range(len(q)):
        assert set(res.neighbors(i).tolist()) == gt[i]


def test_hard_queries_route_to_linear():
    """Dense-core dataset: queries in the core are 'hard' (paper Fig 1);
    the router must send (at least) those to linear search."""
    x, q = _dataset(dense=0.4, seed=2)
    r = 0.6
    fam = make_family("l2", d=x.shape[1], L=15, r=r)
    idx = HybridLSHIndex(fam, num_buckets=1024, m=64, cap=128,
                         cost_model=CostModel(alpha=1.0, beta=10.0), key=0)
    idx.build(jnp.asarray(x))
    est = idx.estimate(jnp.asarray(q))
    gt_sizes = np.array([len(s) for s in _brute("l2", x, q, r)])
    hard = gt_sizes > 0.3 * len(x)
    if hard.any() and (~hard).any():
        frac_lin_hard = float((~np.asarray(est.use_lsh))[hard].mean())
        frac_lin_easy = float((~np.asarray(est.use_lsh))[~hard].mean())
        assert frac_lin_hard >= frac_lin_easy


def test_cand_estimate_accuracy():
    """HLL candSize vs exact distinct collision count: <= ~3x the
    theoretical relative error (paper reports <7% at m=128)."""
    x, q = _dataset(dense=0.2, seed=3)
    r = 0.4
    fam = make_family("l2", d=x.shape[1], L=10, r=r)
    idx = HybridLSHIndex(fam, num_buckets=1024, m=128, cap=128, key=0)
    idx.build(jnp.asarray(x))
    est = idx.estimate(jnp.asarray(q))
    # exact distinct union per query
    qb = np.asarray(idx._bucket_fn(idx.params, jnp.asarray(q)))
    perm, starts = np.asarray(idx.tables.perm), np.asarray(idx.tables.starts)
    errs = []
    for i, row in enumerate(qb):
        seen = set()
        for j, b in enumerate(row):
            seen.update(perm[j, starts[j, b]:starts[j, b + 1]].tolist())
        exact = max(len(seen), 1)
        errs.append(abs(float(est.cand_est[i]) - exact) / exact)
    assert np.mean(errs) < 3 * 1.04 / np.sqrt(128), np.mean(errs)


def test_hybrid_beats_or_matches_both_on_skewed_data():
    """Work-proxy version of the paper's Fig. 2 claim: hybrid's total
    examined-point count <= min(LSH, linear) * 1.3 on skewed data."""
    x, q = _dataset(dense=0.35, seed=4)
    r = 0.5
    fam = make_family("l2", d=x.shape[1], L=15, r=r)
    idx = HybridLSHIndex(fam, num_buckets=1024, m=64, cap=512,
                         cost_model=CostModel(1.0, 10.0), key=0)
    idx.build(jnp.asarray(x))
    est = idx.estimate(jnp.asarray(q))
    n = x.shape[0]
    cm = idx.cost_model
    lsh_work = np.asarray(cm.lsh_cost(
        np.asarray(est.collisions, np.float64),
        np.asarray(est.cand_est, np.float64)))
    lin_work = cm.linear_cost(n)
    hybrid_work = np.minimum(lsh_work, lin_work).sum()
    assert hybrid_work <= 1.3 * min(lsh_work.sum(), lin_work * len(q))


def test_multiprobe_extends_cost_model():
    from repro.core import multiprobe as mp
    from repro.core.lsh import SimHash
    x, q = _dataset(metric="cosine", dense=0.0, seed=5)
    fam = SimHash(d=x.shape[1], L=6, k=12)
    params = fam.init(jax.random.PRNGKey(0))
    idx = HybridLSHIndex(fam, num_buckets=512, m=32, cap=64, key=0)
    idx.params = params
    idx.build(jnp.asarray(x))
    qj = jnp.asarray(q)
    qb1 = mp.probe_buckets(fam, params, qj, 1, 512)
    qb4 = mp.probe_buckets(fam, params, qj, 4, 512)
    c1 = np.asarray(mp.multiprobe_counts(idx.tables, qb1)).sum(1)
    c4 = np.asarray(mp.multiprobe_counts(idx.tables, qb4)).sum(1)
    assert (c4 >= c1).all()  # more probes, more collisions
    r1 = mp.multiprobe_registers(idx.tables, qb1)
    r4 = mp.multiprobe_registers(idx.tables, qb4)
    assert r1.shape[1] == 6 and r4.shape[1] == 24
    # probe-0 buckets of qb4 equal the base buckets
    np.testing.assert_array_equal(np.asarray(qb4)[:, :, 0],
                                  np.asarray(qb1)[:, :, 0])
