"""Optimizer substrate: AdamW vs numpy reference, clipping, schedule,
int8 error-feedback compression quantizer."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, global_norm, warmup_cosine)


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1)
    rng = np.random.default_rng(0)
    p0 = rng.normal(size=(4, 3)).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    state = adamw_init(params)
    lr = 1e-2

    p_np, m_np, v_np = p0.copy(), np.zeros_like(p0), np.zeros_like(p0)
    for t in range(1, 6):
        g = rng.normal(size=p0.shape).astype(np.float32)
        params, state = adamw_update({"w": jnp.asarray(g)}, state, params,
                                     lr, cfg)
        m_np = cfg.b1 * m_np + (1 - cfg.b1) * g
        v_np = cfg.b2 * v_np + (1 - cfg.b2) * g * g
        mh = m_np / (1 - cfg.b1 ** t)
        vh = v_np / (1 - cfg.b2 ** t)
        p_np = p_np - lr * (mh / (np.sqrt(vh) + cfg.eps)
                            + cfg.weight_decay * p_np)
        np.testing.assert_allclose(np.asarray(params["w"]), p_np,
                                   rtol=1e-5, atol=1e-6)
    assert int(state["step"]) == 5


def test_adamw_bf16_params_f32_moments():
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    state = adamw_init(params)
    assert state["m"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((8,), 0.5, jnp.bfloat16)}
    new_p, state = adamw_update(g, state, params, 0.1)
    assert new_p["w"].dtype == jnp.bfloat16
    assert state["v"]["w"].dtype == jnp.float32


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((6,), 4.0)}
    norm = float(global_norm(g))
    clipped, reported = clip_by_global_norm(g, 1.0)
    assert abs(float(reported) - norm) < 1e-5
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    # below threshold -> untouched
    small, _ = clip_by_global_norm(g, norm * 2)
    np.testing.assert_allclose(np.asarray(small["a"]), np.asarray(g["a"]),
                               rtol=1e-6)


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.int32(s), peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - 1.0) < 0.11
    assert lrs[-1] <= lrs[2]          # decayed below peak
    assert lrs[-1] >= 0.099           # min_ratio floor


def test_ef_quantizer_unbiased_over_steps():
    """Error feedback: quantization error must not accumulate — the sum
    of EF-compressed updates converges to the sum of true gradients."""
    from repro.optim.compression import _quantize
    rng = np.random.default_rng(1)
    g_true = rng.normal(size=(256,)).astype(np.float32) * 0.01
    e = np.zeros_like(g_true)
    applied = np.zeros_like(g_true)
    for _ in range(50):
        corrected = g_true + e

        class FakeAxes:  # pmax over a single shard == identity
            pass

        import repro.optim.compression as comp
        amax = np.abs(corrected).max()
        scale = max(amax, 1e-12) / 127.0
        q = np.clip(np.round(corrected / scale), -127, 127)
        deq = q * scale
        e = corrected - deq
        applied += deq
    total_err = np.abs(applied - 50 * g_true).max()
    assert total_err < 0.01 * np.abs(50 * g_true).max() + 1e-4
