"""Serving layer: generation loop, retrieval service, scheduler."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data import lm_batch
from repro.models import init_params
from repro.models.parallel import ParallelConfig
from repro.serve import (RetrievalConfig, RetrievalService,
                         ShapeBucketScheduler, generate)

PAR = ParallelConfig(mesh=None, attn_chunk_q=8, attn_chunk_k=8,
                     logits_chunk=8, remat="none")


def test_generate_greedy_deterministic():
    cfg = reduced_config(get_config("yi-6b"))
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                          0, cfg.vocab)}
    out1 = generate(params, batch, cfg, PAR, cache_len=16,
                    max_new_tokens=6)
    out2 = generate(params, batch, cfg, PAR, cache_len=16,
                    max_new_tokens=6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert (np.asarray(out1) >= 0).all()
    assert (np.asarray(out1) < cfg.vocab).all()


def test_retrieval_service_end_to_end():
    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = RetrievalService(cfg, PAR, params,
                           RetrievalConfig(radius=0.5, tables=8,
                                           num_buckets=256, hll_m=32,
                                           cap=64))
    corpus = []
    for i in range(4):
        b = lm_batch(3, i, batch=32, seq=12, vocab=cfg.vocab, cfg=cfg)
        b.pop("labels")
        corpus.append(b)
    n = svc.index_corpus(corpus)
    assert n == 128 and svc.index.n == 128

    qb = lm_batch(4, 0, batch=16, seq=12, vocab=cfg.vocab, cfg=cfg)
    qb.pop("labels")
    res, emb = svc.query(qb)
    assert emb.shape == (16, cfg.d_model)
    # embeddings are L2-normalized (cosine metric contract)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(emb), axis=1),
                               1.0, rtol=1e-4)
    assert res.n_queries == 16
    assert svc.stats["queries"] == 16

    # a corpus document used as query must report itself (self-match)
    self_q = corpus[0]
    res2, _ = svc.query(self_q)
    found = sum(1 for i in range(32) if len(res2.neighbors(i)) > 0)
    assert found >= 28  # >= 1 - delta of self-matches at distance 0


def test_retrieval_service_live_mutation():
    """add/remove documents mutate the serving index without a rebuild."""
    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = RetrievalService(cfg, PAR, params,
                           RetrievalConfig(radius=0.5, tables=8,
                                           num_buckets=256, hll_m=32,
                                           cap=64, delta_capacity=128))
    corpus = []
    for i in range(2):
        b = lm_batch(3, i, batch=32, seq=12, vocab=cfg.vocab, cfg=cfg)
        b.pop("labels")
        corpus.append(b)
    assert svc.index_corpus(corpus[:1]) == 32

    extra = corpus[1]
    new_ids = svc.add_documents([extra])
    assert len(new_ids) == 32 and svc.index.n == 64
    assert svc.stats["delta_live"] == 32          # no rebuild: delta holds them

    # added docs used as queries report themselves
    res, _ = svc.query(extra)
    found = sum(1 for i in range(32)
                if set(res.neighbors(i).tolist()) & set(new_ids.tolist()))
    assert found >= 28

    assert svc.remove_documents(new_ids.tolist()) == 32
    assert svc.index.n == 32
    res2, _ = svc.query(extra)
    reported = set().union(*(set(res2.neighbors(i).tolist())
                             for i in range(32)))
    assert reported.isdisjoint(set(new_ids.tolist()))
    assert "compactions" in svc.stats


def test_retrieval_service_exact_linear_stats():
    """stats accumulate the exact per-query linear count from the route
    partition, not the rounded frac_linear reconstruction."""
    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = RetrievalService(cfg, PAR, params,
                           RetrievalConfig(radius=0.5, tables=8,
                                           num_buckets=256, hll_m=32,
                                           cap=64))
    b = lm_batch(3, 0, batch=32, seq=12, vocab=cfg.vocab, cfg=cfg)
    b.pop("labels")
    svc.index_corpus([b])
    qb = lm_batch(4, 0, batch=16, seq=12, vocab=cfg.vocab, cfg=cfg)
    qb.pop("labels")
    total = 0
    for _ in range(3):
        res, _ = svc.query(qb)
        exact = len(set(np.asarray(res.lin_idx).tolist()))
        assert res.n_linear == exact          # pow2 padding deduped
        total += exact
    assert svc.stats["linear_served"] == total
    assert svc.stats["queries"] == 48


def test_retrieval_service_mesh_sharded():
    """RetrievalConfig.mesh routes the corpus into the sharded dynamic
    index; add/remove/query flow works through shard_map."""
    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1,), ("data",))     # 1-device mesh: same code path
    svc = RetrievalService(cfg, PAR, params,
                           RetrievalConfig(radius=0.5, tables=8,
                                           num_buckets=256, hll_m=32,
                                           cap=64, delta_capacity=128,
                                           mesh=mesh,
                                           shard_routing="per_shard"))
    corpus = []
    for i in range(2):
        b = lm_batch(3, i, batch=32, seq=12, vocab=cfg.vocab, cfg=cfg)
        b.pop("labels")
        corpus.append(b)
    assert svc.index_corpus(corpus[:1]) == 32
    assert svc.stats["shards"] == 1

    extra = corpus[1]
    new_ids = svc.add_documents([extra])
    assert len(new_ids) == 32 and svc.index.n == 64
    res, _ = svc.query(extra)
    found = sum(1 for i in range(32)
                if set(res.neighbors(i).tolist()) & set(new_ids.tolist()))
    assert found >= 28
    assert svc.remove_documents(new_ids.tolist()) == 32
    assert svc.index.n == 32
    res2, _ = svc.query(extra)
    reported = set().union(*(set(res2.neighbors(i).tolist())
                             for i in range(32)))
    assert reported.isdisjoint(set(new_ids.tolist()))
    assert "total_seconds" in svc.stats


def test_scheduler_pow2_bucketing():
    sched = ShapeBucketScheduler(max_batch=16, min_bucket=4)
    for i in range(21):
        sched.submit(i)
    reqs, padded = sched.next_batch()
    assert len(reqs) == 16 and padded == 16
    reqs, padded = sched.next_batch()
    assert len(reqs) == 5 and padded == 8
    reqs, padded = sched.next_batch()
    assert len(reqs) == 0 and padded == 0


def test_scheduler_empty_drain_and_tick_monotone():
    """Draining an empty queue is a well-formed no-op batch, and ticks
    increase by exactly one per next_batch when a background_tick is
    registered — never without one."""
    calls = []
    sched = ShapeBucketScheduler(max_batch=8, min_bucket=4,
                                 background_tick=lambda: calls.append(1))
    assert sched.ticks == 0
    seen = []
    for _ in range(3):                  # empty drains still tick
        reqs, padded = sched.next_batch()
        assert reqs == [] and padded == 0
        seen.append(sched.ticks)
    assert seen == [1, 2, 3] and len(calls) == 3

    plain = ShapeBucketScheduler(max_batch=8)
    plain.submit("x")
    plain.next_batch()
    assert plain.ticks == 0             # no hook, no ticks


def test_scheduler_all_linear_route_and_group():
    from repro.serve.scheduler import route_and_group
    use_lsh = np.zeros(10, bool)
    lsh_idx, lin_idx = route_and_group(use_lsh, min_bucket=4)
    assert len(lsh_idx) == 0            # empty group stays empty, no pad
    # the linear group covers every query, padded to pow2 by repetition
    assert set(lin_idx.tolist()) == set(range(10))
    assert len(lin_idx) == 16
    # all-LSH mirror
    lsh_idx2, lin_idx2 = route_and_group(~use_lsh, min_bucket=4)
    assert len(lin_idx2) == 0
    assert set(lsh_idx2.tolist()) == set(range(10))


def test_scheduler_registry_instruments():
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry(enabled=True)
    sched = ShapeBucketScheduler(max_batch=8, min_bucket=4, registry=reg,
                                 background_tick=lambda: None)
    for i in range(5):
        sched.submit(i)
    sched.next_batch()
    snap = reg.snapshot()
    assert snap["counters"]["repro_scheduler_submits_total"] == 5
    assert snap["counters"]["repro_scheduler_batches_total"] == 1
    assert snap["counters"]["repro_scheduler_ticks_total"] == 1
    assert snap["histograms"]["repro_scheduler_batch_size"]["count"] == 1


def test_retrieval_service_stats_schema_and_metrics(tmp_path):
    """stats keys match the documented schema exactly; metrics() is one
    JSON round-trippable snapshot; shutdown dumps it to disk."""
    from repro.obs.schema import WORK_PHASE_KEYS, retrieval_stats_keys

    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = RetrievalService(cfg, PAR, params,
                           RetrievalConfig(radius=0.5, tables=8,
                                           num_buckets=256, hll_m=32,
                                           cap=64, delta_capacity=128,
                                           async_compaction=True,
                                           obs_trace_sample_every=1))
    corpus = []
    for i in range(2):
        b = lm_batch(3, i, batch=32, seq=12, vocab=cfg.vocab, cfg=cfg)
        b.pop("labels")
        corpus.append(b)
    svc.index_corpus(corpus)

    st = svc.stats
    assert set(st) == retrieval_stats_keys(driver=True)
    assert set(st["work_seconds"]) == WORK_PHASE_KEYS
    from repro.obs.schema import DRIVER_STATS_KEYS
    assert set(st["driver"]) == DRIVER_STATS_KEYS

    qb = lm_batch(4, 0, batch=8, seq=12, vocab=cfg.vocab, cfg=cfg)
    qb.pop("labels")
    svc.query(qb)

    m = svc.metrics()
    m2 = json.loads(json.dumps(m))      # round-trip
    assert set(m2) == {"registry", "tracing", "events", "stats"}
    assert m2["registry"]["counters"]["repro_service_queries_total"] == 8
    assert m2["tracing"]["queries"] == 8
    assert m2["stats"]["queries"] == 8
    text = svc.metrics_text()
    assert "# TYPE repro_service_queries_total counter" in text
    assert "repro_index_live_docs 64" in text

    dump = tmp_path / "obs_dump.json"
    svc.shutdown(dump_path=str(dump))
    dumped = json.loads(dump.read_text())
    assert dumped["stats"]["queries"] == 8
    assert dumped["events"]["counts_by_kind"].get("shutdown") == 1
