"""Serving layer: generation loop, retrieval service, scheduler."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data import lm_batch
from repro.models import init_params
from repro.models.parallel import ParallelConfig
from repro.serve import (RetrievalConfig, RetrievalService,
                         ShapeBucketScheduler, generate)

PAR = ParallelConfig(mesh=None, attn_chunk_q=8, attn_chunk_k=8,
                     logits_chunk=8, remat="none")


def test_generate_greedy_deterministic():
    cfg = reduced_config(get_config("yi-6b"))
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                          0, cfg.vocab)}
    out1 = generate(params, batch, cfg, PAR, cache_len=16,
                    max_new_tokens=6)
    out2 = generate(params, batch, cfg, PAR, cache_len=16,
                    max_new_tokens=6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert (np.asarray(out1) >= 0).all()
    assert (np.asarray(out1) < cfg.vocab).all()


def test_retrieval_service_end_to_end():
    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = RetrievalService(cfg, PAR, params,
                           RetrievalConfig(radius=0.5, tables=8,
                                           num_buckets=256, hll_m=32,
                                           cap=64))
    corpus = []
    for i in range(4):
        b = lm_batch(3, i, batch=32, seq=12, vocab=cfg.vocab, cfg=cfg)
        b.pop("labels")
        corpus.append(b)
    n = svc.index_corpus(corpus)
    assert n == 128 and svc.index.n == 128

    qb = lm_batch(4, 0, batch=16, seq=12, vocab=cfg.vocab, cfg=cfg)
    qb.pop("labels")
    res, emb = svc.query(qb)
    assert emb.shape == (16, cfg.d_model)
    # embeddings are L2-normalized (cosine metric contract)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(emb), axis=1),
                               1.0, rtol=1e-4)
    assert res.n_queries == 16
    assert svc.stats["queries"] == 16

    # a corpus document used as query must report itself (self-match)
    self_q = corpus[0]
    res2, _ = svc.query(self_q)
    found = sum(1 for i in range(32) if len(res2.neighbors(i)) > 0)
    assert found >= 28  # >= 1 - delta of self-matches at distance 0


def test_retrieval_service_live_mutation():
    """add/remove documents mutate the serving index without a rebuild."""
    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = RetrievalService(cfg, PAR, params,
                           RetrievalConfig(radius=0.5, tables=8,
                                           num_buckets=256, hll_m=32,
                                           cap=64, delta_capacity=128))
    corpus = []
    for i in range(2):
        b = lm_batch(3, i, batch=32, seq=12, vocab=cfg.vocab, cfg=cfg)
        b.pop("labels")
        corpus.append(b)
    assert svc.index_corpus(corpus[:1]) == 32

    extra = corpus[1]
    new_ids = svc.add_documents([extra])
    assert len(new_ids) == 32 and svc.index.n == 64
    assert svc.stats["delta_live"] == 32          # no rebuild: delta holds them

    # added docs used as queries report themselves
    res, _ = svc.query(extra)
    found = sum(1 for i in range(32)
                if set(res.neighbors(i).tolist()) & set(new_ids.tolist()))
    assert found >= 28

    assert svc.remove_documents(new_ids.tolist()) == 32
    assert svc.index.n == 32
    res2, _ = svc.query(extra)
    reported = set().union(*(set(res2.neighbors(i).tolist())
                             for i in range(32)))
    assert reported.isdisjoint(set(new_ids.tolist()))
    assert "compactions" in svc.stats


def test_retrieval_service_exact_linear_stats():
    """stats accumulate the exact per-query linear count from the route
    partition, not the rounded frac_linear reconstruction."""
    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = RetrievalService(cfg, PAR, params,
                           RetrievalConfig(radius=0.5, tables=8,
                                           num_buckets=256, hll_m=32,
                                           cap=64))
    b = lm_batch(3, 0, batch=32, seq=12, vocab=cfg.vocab, cfg=cfg)
    b.pop("labels")
    svc.index_corpus([b])
    qb = lm_batch(4, 0, batch=16, seq=12, vocab=cfg.vocab, cfg=cfg)
    qb.pop("labels")
    total = 0
    for _ in range(3):
        res, _ = svc.query(qb)
        exact = len(set(np.asarray(res.lin_idx).tolist()))
        assert res.n_linear == exact          # pow2 padding deduped
        total += exact
    assert svc.stats["linear_served"] == total
    assert svc.stats["queries"] == 48


def test_retrieval_service_mesh_sharded():
    """RetrievalConfig.mesh routes the corpus into the sharded dynamic
    index; add/remove/query flow works through shard_map."""
    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1,), ("data",))     # 1-device mesh: same code path
    svc = RetrievalService(cfg, PAR, params,
                           RetrievalConfig(radius=0.5, tables=8,
                                           num_buckets=256, hll_m=32,
                                           cap=64, delta_capacity=128,
                                           mesh=mesh,
                                           shard_routing="per_shard"))
    corpus = []
    for i in range(2):
        b = lm_batch(3, i, batch=32, seq=12, vocab=cfg.vocab, cfg=cfg)
        b.pop("labels")
        corpus.append(b)
    assert svc.index_corpus(corpus[:1]) == 32
    assert svc.stats["shards"] == 1

    extra = corpus[1]
    new_ids = svc.add_documents([extra])
    assert len(new_ids) == 32 and svc.index.n == 64
    res, _ = svc.query(extra)
    found = sum(1 for i in range(32)
                if set(res.neighbors(i).tolist()) & set(new_ids.tolist()))
    assert found >= 28
    assert svc.remove_documents(new_ids.tolist()) == 32
    assert svc.index.n == 32
    res2, _ = svc.query(extra)
    reported = set().union(*(set(res2.neighbors(i).tolist())
                             for i in range(32)))
    assert reported.isdisjoint(set(new_ids.tolist()))
    assert "total_seconds" in svc.stats


def test_scheduler_pow2_bucketing():
    sched = ShapeBucketScheduler(max_batch=16, min_bucket=4)
    for i in range(21):
        sched.submit(i)
    reqs, padded = sched.next_batch()
    assert len(reqs) == 16 and padded == 16
    reqs, padded = sched.next_batch()
    assert len(reqs) == 5 and padded == 8
    reqs, padded = sched.next_batch()
    assert len(reqs) == 0 and padded == 0


def test_scheduler_empty_drain_and_tick_monotone():
    """Draining an empty queue is a well-formed no-op batch, and ticks
    increase by exactly one per next_batch when a background_tick is
    registered — never without one."""
    calls = []
    sched = ShapeBucketScheduler(max_batch=8, min_bucket=4,
                                 background_tick=lambda: calls.append(1))
    assert sched.ticks == 0
    seen = []
    for _ in range(3):                  # empty drains still tick
        reqs, padded = sched.next_batch()
        assert reqs == [] and padded == 0
        seen.append(sched.ticks)
    assert seen == [1, 2, 3] and len(calls) == 3

    plain = ShapeBucketScheduler(max_batch=8)
    plain.submit("x")
    plain.next_batch()
    assert plain.ticks == 0             # no hook, no ticks


def test_scheduler_all_linear_route_and_group():
    from repro.serve.scheduler import route_and_group
    use_lsh = np.zeros(10, bool)
    lsh_idx, lin_idx = route_and_group(use_lsh, min_bucket=4)
    assert len(lsh_idx) == 0            # empty group stays empty, no pad
    # the linear group covers every query, padded to pow2 by repetition
    assert set(lin_idx.tolist()) == set(range(10))
    assert len(lin_idx) == 16
    # all-LSH mirror
    lsh_idx2, lin_idx2 = route_and_group(~use_lsh, min_bucket=4)
    assert len(lin_idx2) == 0
    assert set(lsh_idx2.tolist()) == set(range(10))


def test_scheduler_registry_instruments():
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry(enabled=True)
    sched = ShapeBucketScheduler(max_batch=8, min_bucket=4, registry=reg,
                                 background_tick=lambda: None)
    for i in range(5):
        sched.submit(i)
    sched.next_batch()
    snap = reg.snapshot()
    assert snap["counters"]["repro_scheduler_submits_total"] == 5
    assert snap["counters"]["repro_scheduler_batches_total"] == 1
    assert snap["counters"]["repro_scheduler_ticks_total"] == 1
    assert snap["histograms"]["repro_scheduler_batch_size"]["count"] == 1


def test_scheduler_deadline_coalescing():
    """With max_wait_s set, small queues are held until the oldest
    request ages out (or the queue can fill max_batch); empty returns
    count no phantom batch."""
    now = [0.0]
    sched = ShapeBucketScheduler(max_batch=8, min_bucket=4,
                                 max_wait_s=1.0, clock=lambda: now[0])
    for i in range(3):
        sched.submit(i)
    reqs, padded = sched.next_batch()
    assert reqs == [] and padded == 0         # deadline not reached
    now[0] = 0.5
    assert sched.next_batch() == ([], 0)      # still inside the window
    now[0] = 1.25
    reqs, padded = sched.next_batch()
    assert len(reqs) == 3 and padded == 4     # aged out: coalesced batch
    assert all(abs(r.wait_s - 1.25) < 1e-9 for r in reqs)
    # a full max_batch dispatches immediately, deadline or not
    for i in range(8):
        sched.submit(i)
    reqs, padded = sched.next_batch()
    assert len(reqs) == 8 and padded == 8
    st = sched.stats()
    assert st["batches"] == 2 and st["requests_batched"] == 11
    assert abs(st["queue_wait_max_s"] - 1.25) < 1e-9


def test_scheduler_force_flush_inside_deadline():
    now = [0.0]
    sched = ShapeBucketScheduler(max_batch=8, min_bucket=4,
                                 max_wait_s=60.0, clock=lambda: now[0])
    sched.submit("a")
    assert sched.next_batch() == ([], 0)
    reqs, padded = sched.next_batch(force=True)
    assert len(reqs) == 1 and padded == 4
    assert sched.next_batch(force=True) == ([], 0)   # empty stays empty


def test_scheduler_admission_control():
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry(enabled=True)
    sched = ShapeBucketScheduler(max_batch=8, max_queue=4, registry=reg)
    uids = [sched.submit(i) for i in range(6)]
    assert all(u is not None for u in uids[:4])
    assert uids[4] is None and uids[5] is None       # shed, not queued
    assert len(sched.queue) == 4
    st = sched.stats()
    assert st["submits"] == 4 and st["rejects"] == 2
    snap = reg.snapshot()
    assert snap["counters"]["repro_scheduler_rejects_total"] == 2
    assert snap["counters"]["repro_scheduler_submits_total"] == 4
    # a drain frees capacity and admission recovers
    sched.next_batch()
    assert sched.submit("again") is not None


def test_scheduler_empty_drain_counts_no_phantom_batch():
    """The empty-pop metric fix: an empty (or deadline-held) drain must
    not bump batches_total or record a 0 in the batch-size histogram —
    but the background tick still runs every call."""
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry(enabled=True)
    sched = ShapeBucketScheduler(max_batch=8, min_bucket=4, registry=reg,
                                 background_tick=lambda: None)
    for _ in range(3):
        sched.next_batch()
    snap = reg.snapshot()
    assert snap["counters"]["repro_scheduler_ticks_total"] == 3
    assert snap["counters"].get("repro_scheduler_batches_total", 0) == 0
    assert snap["histograms"]["repro_scheduler_batch_size"]["count"] == 0
    sched.submit("x")
    sched.next_batch()
    snap = reg.snapshot()
    assert snap["counters"]["repro_scheduler_batches_total"] == 1
    assert snap["histograms"]["repro_scheduler_batch_size"]["count"] == 1
    assert snap["histograms"]["repro_scheduler_queue_wait_seconds"][
        "count"] == 1


def test_scheduler_stats_schema():
    from repro.obs.schema import SCHEDULER_STATS_KEYS
    sched = ShapeBucketScheduler(max_batch=8)
    assert set(sched.stats()) == SCHEDULER_STATS_KEYS


def test_result_cache_lru_and_version_purge():
    from repro.obs.schema import CACHE_STATS_KEYS
    from repro.serve import ResultCache

    def entry(seed, k=64):
        rng = np.random.default_rng(seed)
        return ([rng.integers(0, 100, k)], [rng.random(k, np.float32)])

    cache = ResultCache(max_bytes=4096)
    assert set(cache.stats()) == CACHE_STATS_KEYS
    tok = np.arange(8, dtype=np.int32)[None, :]
    keys = [cache.key(1, 0.5, tok + i) for i in range(6)]
    for i, k in enumerate(keys):
        cache.put(k, *entry(i))
    assert cache._bytes <= 4096
    assert len(cache) < 6                     # LRU sweep evicted
    assert cache.stats()["evictions"] > 0
    # newest entries survive; oldest are gone
    assert cache.get(keys[-1]) is not None
    assert cache.get(keys[0]) is None
    # a version move purges everything older on first sight (keys are
    # (collection, version, radius, fingerprint) since multi-tenancy)
    cache.put(cache.key(2, 0.5, tok), *entry(9))
    n_v1 = sum(1 for k in cache._entries if k[1] == 1)
    assert cache.purge_stale(2) == n_v1 and n_v1 >= 1
    assert all(k[1] == 2 for k in cache._entries)
    assert cache.purge_stale(2) == 0               # seen version: no scan
    assert cache.stats()["stale_drops"] == n_v1
    # distinct radius / dtype / shape fingerprints never collide
    assert cache.key(1, 0.5, tok) != cache.key(1, 0.6, tok)
    assert cache.key(1, 0.5, tok) != cache.key(
        1, 0.5, tok.astype(np.int64))
    # ... nor do per-collection keys; a collection's versions are
    # watermarked independently, and dropping it removes its entries
    ka = cache.key(2, 0.5, tok, collection="a")
    assert ka != cache.key(2, 0.5, tok)
    cache.put(ka, *entry(10))
    assert cache.purge_stale(2, collection="a") == 0
    assert cache.get(ka) is not None
    assert cache.drop_collection("a") == 1
    assert cache.get(ka) is None
    # disabled cache (byte budget 0) stores nothing
    off = ResultCache(max_bytes=0)
    assert not off.put(off.key(1, 0.5, tok), *entry(0))
    assert off.get(off.key(1, 0.5, tok)) is None


def test_submit_drain_matches_direct_query():
    """The coalesced path reports exactly what per-request query() does:
    multi-row requests are scattered back intact, and resubmits in an
    unchanged index state are served from the cache bit-identically."""
    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = RetrievalService(cfg, PAR, params,
                           RetrievalConfig(radius=0.5, tables=8,
                                           num_buckets=256, hll_m=32,
                                           cap=64))
    b = lm_batch(3, 0, batch=32, seq=12, vocab=cfg.vocab, cfg=cfg)
    b.pop("labels")
    svc.index_corpus([b])
    qb = lm_batch(4, 0, batch=6, seq=12, vocab=cfg.vocab, cfg=cfg)
    toks = np.asarray(qb["tokens"])

    # requests of 1, 2, and 3 query rows coalesce into one batch
    u1 = svc.submit(toks[0])                       # 1-D row: one query
    u2 = svc.submit({"tokens": toks[1:3]})
    u3 = svc.submit(toks[3:6])
    out = svc.drain_batches()
    assert set(out) == {u1, u2, u3}
    assert [out[u].n_queries for u in (u1, u2, u3)] == [1, 2, 3]
    assert not any(out[u].cached for u in (u1, u2, u3))

    direct, _ = svc.query({"tokens": jnp.asarray(toks)})
    flat_ids = [out[u].ids[j] for u in (u1, u2, u3)
                for j in range(out[u].n_queries)]
    flat_d = [out[u].dists[j] for u in (u1, u2, u3)
              for j in range(out[u].n_queries)]
    for i in range(6):
        ids_d, dists_d = direct.reported(i)
        np.testing.assert_array_equal(flat_ids[i], np.asarray(ids_d))
        np.testing.assert_array_equal(flat_d[i], np.asarray(dists_d))

    # same state, same queries -> pure cache hits, same bits
    u4 = svc.submit({"tokens": toks[1:3]})
    out2 = svc.drain_batches()
    assert out2[u4].cached
    for j in range(2):
        np.testing.assert_array_equal(out2[u4].ids[j], out[u2].ids[j])
        np.testing.assert_array_equal(out2[u4].dists[j], out[u2].dists[j])
    assert svc.stats["cache"]["hits"] == 1
    # serving counters advanced only for real (non-pad, non-hit) rows
    assert svc.stats["queries"] == 6 + 6           # drain + direct


def test_drain_respects_deadline_until_forced():
    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = RetrievalService(cfg, PAR, params,
                           RetrievalConfig(radius=0.5, tables=8,
                                           num_buckets=256, hll_m=32,
                                           cap=64,
                                           coalesce_max_wait_s=3600.0))
    b = lm_batch(3, 0, batch=32, seq=12, vocab=cfg.vocab, cfg=cfg)
    b.pop("labels")
    svc.index_corpus([b])
    u = svc.submit(np.asarray(b["tokens"])[0])
    assert svc.drain_batches() == {}               # held for coalescing
    assert svc.stats["scheduler"]["queue_depth"] == 1
    out = svc.drain_batches(force=True)
    assert set(out) == {u} and not out[u].cached


def test_retrieval_service_stats_schema_and_metrics(tmp_path):
    """stats keys match the documented schema exactly; metrics() is one
    JSON round-trippable snapshot; shutdown dumps it to disk."""
    from repro.obs.schema import WORK_PHASE_KEYS, retrieval_stats_keys

    cfg = reduced_config(get_config("yi-6b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = RetrievalService(cfg, PAR, params,
                           RetrievalConfig(radius=0.5, tables=8,
                                           num_buckets=256, hll_m=32,
                                           cap=64, delta_capacity=128,
                                           async_compaction=True,
                                           obs_trace_sample_every=1))
    corpus = []
    for i in range(2):
        b = lm_batch(3, i, batch=32, seq=12, vocab=cfg.vocab, cfg=cfg)
        b.pop("labels")
        corpus.append(b)
    svc.index_corpus(corpus)

    st = svc.stats
    assert set(st) == retrieval_stats_keys(driver=True)
    assert set(st["work_seconds"]) == WORK_PHASE_KEYS
    from repro.obs.schema import (CACHE_STATS_KEYS, DRIVER_STATS_KEYS,
                                  SCHEDULER_STATS_KEYS)
    assert set(st["driver"]) == DRIVER_STATS_KEYS
    assert set(st["scheduler"]) == SCHEDULER_STATS_KEYS
    assert set(st["cache"]) == CACHE_STATS_KEYS

    qb = lm_batch(4, 0, batch=8, seq=12, vocab=cfg.vocab, cfg=cfg)
    qb.pop("labels")
    svc.query(qb)

    m = svc.metrics()
    m2 = json.loads(json.dumps(m))      # round-trip
    assert set(m2) == {"registry", "tracing", "events", "stats"}
    assert m2["registry"]["counters"]["repro_service_queries_total"] == 8
    assert m2["tracing"]["queries"] == 8
    assert m2["stats"]["queries"] == 8
    text = svc.metrics_text()
    assert "# TYPE repro_service_queries_total counter" in text
    assert "repro_index_live_docs 64" in text

    dump = tmp_path / "obs_dump.json"
    svc.shutdown(dump_path=str(dump))
    dumped = json.loads(dump.read_text())
    assert dumped["stats"]["queries"] == 8
    assert dumped["events"]["counts_by_kind"].get("shutdown") == 1
