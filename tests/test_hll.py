"""HyperLogLog unit + property tests (paper Sec. 2/3 claims)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal images: deterministic fallback strategies
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import hll


def test_clz32_exact():
    vals = np.array([0, 1, 2, 3, 0x80000000, 0xFFFFFFFF, 0x00010000,
                     2**24 - 1, 2**24, 12345], dtype=np.uint32)
    got = np.asarray(hll.clz32(jnp.asarray(vals)))
    for v, g in zip(vals.tolist(), got.tolist()):
        expect = 32 if v == 0 else 32 - int(v).bit_length()
        assert g == expect, (v, g, expect)


@pytest.mark.parametrize("m", [32, 64, 128])
@pytest.mark.parametrize("n", [100, 2000, 50000])
def test_estimator_error_within_theory(m, n):
    """Relative error should be within ~4 sigma of 1.04/sqrt(m)."""
    ids = jnp.arange(n, dtype=jnp.int32)
    buckets = jnp.zeros((n,), jnp.int32)
    regs = hll.build_bucket_hlls(ids, buckets, 1, m)
    est = float(hll.estimate_cardinality(regs[0], m))
    rel = abs(est - n) / n
    assert rel < 4 * hll.relative_error(m), (m, n, est, rel)


def test_merge_equals_union():
    """HLL(A) max HLL(B) == HLL(A u B) exactly (same hash function)."""
    ids = jnp.arange(10000, dtype=jnp.int32)
    a = hll.build_bucket_hlls(ids[:7000], jnp.zeros(7000, jnp.int32), 1, 64)
    b = hll.build_bucket_hlls(ids[3000:], jnp.zeros(7000, jnp.int32), 1, 64)
    u = hll.build_bucket_hlls(ids, jnp.zeros(10000, jnp.int32), 1, 64)
    merged = hll.merge_registers(jnp.stack([a[0], b[0]]), axis=0)
    np.testing.assert_array_equal(np.asarray(merged), np.asarray(u[0]))


def test_duplicates_are_free():
    """Inserting the same ids twice must not change registers
    (the property that makes candSize a distinct count)."""
    ids = jnp.arange(1000, dtype=jnp.int32)
    once = hll.build_bucket_hlls(ids, jnp.zeros(1000, jnp.int32), 1, 64)
    twice = hll.build_bucket_hlls(jnp.concatenate([ids, ids]),
                                  jnp.zeros(2000, jnp.int32), 1, 64)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 2**30), min_size=1, max_size=500),
       st.sampled_from([32, 64]))
def test_property_estimate_tracks_distinct(ids, m):
    arr = jnp.asarray(np.array(ids, np.int32))
    regs = hll.build_bucket_hlls(arr, jnp.zeros(len(ids), jnp.int32), 1, m)
    est = float(hll.estimate_cardinality(regs[0], m))
    true = len(set(ids))
    assert est >= 0
    # generous bound: small-range correction makes small sets accurate
    assert abs(est - true) <= max(5.0, 6 * hll.relative_error(m) * true)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 64), st.integers(0, 2**20))
def test_property_merge_commutative(nsets, seed):
    rng = np.random.default_rng(seed)
    regs = jnp.asarray(rng.integers(0, 20, (nsets, 32)).astype(np.int32))
    perm = rng.permutation(nsets)
    a = hll.merge_registers(regs, axis=0)
    b = hll.merge_registers(regs[perm], axis=0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bucket_build_matches_per_bucket():
    """Fused segment_max build == per-bucket independent builds."""
    rng = np.random.default_rng(3)
    n, nb, m = 5000, 16, 32
    ids = jnp.arange(n, dtype=jnp.int32)
    buckets = jnp.asarray(rng.integers(0, nb, n).astype(np.int32))
    fused = hll.build_bucket_hlls(ids, buckets, nb, m)
    for b in range(0, nb, 5):
        sel = np.asarray(buckets) == b
        sub = hll.build_bucket_hlls(ids[sel], jnp.zeros(int(sel.sum()),
                                                        jnp.int32), 1, m)
        np.testing.assert_array_equal(np.asarray(fused[b]),
                                      np.asarray(sub[0]))
