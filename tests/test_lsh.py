"""LSH families + CSR tables: collision probabilities, invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal images: deterministic fallback strategies
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.lsh import (BitSampling, PStableL1, PStableL2, SimHash,
                            build_tables, bucket_counts, gather_candidates,
                            gather_registers, k_from_delta, make_family)


def test_k_from_delta_monotone():
    ks = [k_from_delta(p1, 50, 0.1) for p1 in (0.99, 0.9, 0.8, 0.6)]
    assert ks == sorted(ks, reverse=True)
    for p1, k in zip((0.99, 0.9, 0.8, 0.6), ks):
        # paper/E2LSH use ceil, which trades a bit of recall for speed;
        # the floor value k-1 must satisfy the (1-p1^k)^L <= delta bound.
        assert (1 - p1 ** (k - 1)) ** 50 <= 0.1 + 1e-12


def test_simhash_collision_probability():
    """Empirical 1-bit collision rate ~= 1 - theta/pi."""
    d, n = 64, 4000
    fam = SimHash(d=d, L=1, k=1)
    params = fam.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    x = rng.normal(size=(n, d)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    # construct pairs at a known angle
    for target_cos in (0.9, 0.5):
        noise = rng.normal(size=(n, d)).astype(np.float32)
        noise -= (noise * x).sum(1, keepdims=True) * x
        noise /= np.linalg.norm(noise, axis=1, keepdims=True)
        y = target_cos * x + np.sqrt(1 - target_cos**2) * noise
        cx = np.asarray(fam.codes(params, jnp.asarray(x)))[:, 0, 0] & 1
        cy = np.asarray(fam.codes(params, jnp.asarray(y)))[:, 0, 0] & 1
        emp = float((cx == cy).mean())
        theo = fam.p1(1.0 - target_cos)
        assert abs(emp - theo) < 0.05, (target_cos, emp, theo)


@pytest.mark.parametrize("metric,cls", [("l2", PStableL2), ("l1", PStableL1)])
def test_pstable_p1_in_range_and_monotone(metric, cls):
    fam = make_family(metric, d=16, L=5, r=1.0)
    assert isinstance(fam, cls)
    ps = [fam.p1(r) for r in (0.25, 0.5, 1.0, 2.0, 4.0)]
    assert all(0 < p < 1 for p in ps)
    assert ps == sorted(ps, reverse=True)  # farther -> less likely


def test_bitsampling_p1():
    fam = BitSampling(dim_bits=64, L=2, k=4)
    assert fam.p1(0) == 1.0
    assert abs(fam.p1(16) - 0.75) < 1e-12


def _build(n=2000, d=8, L=4, B=64, m=32, seed=0):
    fam = make_family("l2", d=d, L=L, r=1.0)
    params = fam.init(jax.random.PRNGKey(seed))
    x = jnp.asarray(np.random.default_rng(seed).normal(
        size=(n, d)).astype(np.float32))
    bids = fam.bucket_ids(params, x, B)
    tables = build_tables(jnp.arange(n, dtype=jnp.int32), bids, B, m)
    return fam, params, x, bids, tables


def test_csr_invariants():
    n, B = 2000, 64
    fam, params, x, bids, tables = _build(n=n, B=B)
    starts = np.asarray(tables.starts)
    perm = np.asarray(tables.perm)
    bids_np = np.asarray(bids)
    for j in range(tables.L):
        assert starts[j, 0] == 0 and starts[j, -1] == n
        assert np.all(np.diff(starts[j]) >= 0)
        assert sorted(perm[j].tolist()) == list(range(n))  # permutation
        # every point is inside its bucket's CSR range
        for b in range(0, B, 13):
            lo, hi = starts[j, b], starts[j, b + 1]
            members = set(perm[j, lo:hi].tolist())
            expect = set(np.nonzero(bids_np[:, j] == b)[0].tolist())
            assert members == expect


def test_bucket_counts_and_candidates():
    n = 2000
    fam, params, x, bids, tables = _build(n=n)
    q = x[:10]
    qb = fam.bucket_ids(params, q, tables.num_buckets)
    counts = np.asarray(bucket_counts(tables, qb))
    # self point must be among gathered candidates when cap is large
    cands = np.asarray(gather_candidates(tables, qb, cap=512, sentinel=n))
    for i in range(10):
        assert i in set(cands[i].tolist())
    # counts match the CSR sizes
    starts = np.asarray(tables.starts)
    for i in range(10):
        for j in range(tables.L):
            b = int(np.asarray(qb)[i, j])
            assert counts[i, j] == starts[j, b + 1] - starts[j, b]


def test_registers_gather_shape():
    fam, params, x, bids, tables = _build()
    qb = fam.bucket_ids(params, x[:7], tables.num_buckets)
    regs = gather_registers(tables, qb)
    assert regs.shape == (7, tables.L, tables.m)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(16, 128))
def test_property_bucket_ids_in_range(L, B_pow):
    B = 1 << int(np.log2(B_pow))
    fam = SimHash(d=8, L=L, k=9)
    params = fam.init(jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(50, 8)).astype(np.float32))
    b = np.asarray(fam.bucket_ids(params, x, B))
    assert b.shape == (50, L)
    assert (b >= 0).all() and (b < B).all()
