"""Multi-device tests (subprocess with 8 host platform devices):
  * sharded Hybrid LSH index == single-host results (collisions,
    candSize estimate, reported neighbors);
  * per-shard routing under skew;
  * sharded train step runs under the debug mesh and matches the
    unsharded loss;
  * int8 EF compressed psum == plain psum within quantization error.
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


def test_sharded_index_matches_single_host():
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core import CostModel, HybridLSHIndex
from repro.core.distributed import build_sharded, make_query_fn
from repro.core.lsh import make_family
from repro.data import clustered_dataset, query_split

assert len(jax.devices()) == 8
mesh = jax.make_mesh((8,), ("data",))
n, d, r = 4096, 16, 0.5
x = clustered_dataset(n + 64, d, n_clusters=8, dense_core_frac=0.2,
                      seed=0)
x, q = query_split(x, 64, seed=0)
x = x[:n]
fam = make_family("l2", d=d, L=40, r=r)
params = fam.init(jax.random.PRNGKey(0))
bound = 1.0 - (1.0 - fam.p1(r) ** fam.k) ** fam.L
cm = CostModel(1.0, 10.0)

state = build_sharded(fam, params, jnp.asarray(x), num_buckets=512,
                      m=32, mesh=mesh)
qfn = make_query_fn(fam, num_buckets=512, mesh=mesh, n_total=n,
                    cost_model=cm, metric="l2", cap=256, max_out=512,
                    policy="per_shard")
res = qfn(state, params, jnp.asarray(q), r)

# exact collision count check vs single-host index with same params
idx = HybridLSHIndex(fam, num_buckets=512, m=32, cap=256,
                     cost_model=cm, key=0)
idx.params = params
idx.build(jnp.asarray(x))
est = idx.estimate(jnp.asarray(q))

# NOTE: per-shard tables hash the same points with the same g_j, so
# summed collision counts must agree exactly.
np.testing.assert_array_equal(np.asarray(res["collisions"]),
                              np.asarray(est.collisions))

# distributed (pmax-merged) candSize estimate == single-host estimate
np.testing.assert_allclose(np.asarray(res["cand_est"]),
                           np.asarray(est.cand_est), rtol=1e-5)

# reported neighbor sets == brute force
D = np.sqrt(((q[:, None] - x[None]) ** 2).sum(-1))
ids = np.asarray(res["ids"]).reshape(-1, len(q), 512)
mask = np.asarray(res["mask"]).reshape(-1, len(q), 512)
miss = 0
total = 0
for i in range(len(q)):
    got = set()
    for s_ in range(ids.shape[0]):
        got |= set(ids[s_, i][mask[s_, i]].tolist())
    gt = set(np.nonzero(D[i] <= r)[0].tolist())
    assert got <= gt, "false positives"
    total += len(gt)
    miss += len(gt - got)
print("RECALL", (1.0 - miss / max(total, 1)) / bound)
print("USED_LSH", np.asarray(res["used_lsh"]).tolist())
""")
    # recall is normalized by the worst-case theory bound
    # 1-(1-p1(r)^k)^L in the subprocess script
    recall = float(out.split("RECALL")[1].split()[0])
    assert recall >= 0.8, out


def test_sharded_train_step_matches_single_device():
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced_config
from repro.launch.mesh import make_debug_mesh
from repro.models.parallel import ParallelConfig
from repro.train.step import TrainConfig, init_state, make_jitted_train_step
from repro.data import lm_batch

cfg = reduced_config(get_config("yi-6b"))
mesh = make_debug_mesh((4, 2), ("data", "model"))
par_sh = ParallelConfig(mesh=mesh, data_axes=("data",), seq_shard=True,
                        attn_chunk_q=8, attn_chunk_k=8, logits_chunk=8)
par_1 = ParallelConfig(mesh=None, attn_chunk_q=8, attn_chunk_k=8,
                       logits_chunk=8)
tcfg = TrainConfig(total_steps=10, warmup_steps=0)
# two independent states: the jitted steps DONATE their input state
state_a = init_state(cfg, jax.random.PRNGKey(0), tcfg)
state_b = init_state(cfg, jax.random.PRNGKey(0), tcfg)
batch = lm_batch(0, 0, batch=8, seq=16, vocab=cfg.vocab, cfg=cfg)

s1, m1 = make_jitted_train_step(cfg, par_1, tcfg)(state_a, batch)
s2, m2 = make_jitted_train_step(cfg, par_sh, tcfg)(state_b, batch)
print("LOSS1", float(m1["loss"]), "LOSS2", float(m2["loss"]))
d = jax.tree_util.tree_map(
    lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                       - b.astype(jnp.float32)))),
    s1["params"], s2["params"])
print("MAXDIFF", max(jax.tree_util.tree_leaves(d)))
""")
    l1 = float(out.split("LOSS1")[1].split()[0])
    l2 = float(out.split("LOSS2")[1].split()[0])
    assert abs(l1 - l2) < 5e-2 * max(1.0, abs(l1)), out
    maxdiff = float(out.split("MAXDIFF")[1].split()[0])
    assert maxdiff < 0.05, out


def test_compressed_psum_matches_plain():
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.optim.compression import compressed_psum

mesh = jax.make_mesh((8,), ("pod",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 1024)) * 0.01

def body(xs):
    return compressed_psum(xs[0], "pod", 8)

fn = jax.jit(shard_map(body, mesh=mesh, in_specs=P("pod"),
                       out_specs=P(None), check_rep=False))
got = np.asarray(fn(x))
want = np.asarray(x.mean(0))
err = np.abs(got - want).max() / (np.abs(want).max() + 1e-12)
print("RELERR", err)
""")
    err = float(out.split("RELERR")[1].split()[0])
    assert err < 0.02, out


def test_flash_decode_seq_sharded_matches_plain():
    out = _run(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.models.attention import flash_decode
from repro.models.parallel import ParallelConfig

mesh = jax.make_mesh((2, 4), ("data", "model"))
par = ParallelConfig(mesh=mesh, data_axes=("data",),
                     decode_seq_shard=("model",))
b, h, hkv, hd, s = 4, 8, 2, 16, 64
k = jax.random.PRNGKey(0)
q = jax.random.normal(k, (b, h, hd))
kc = jax.random.normal(k, (b, s, hkv, hd))
vc = jax.random.normal(k, (b, s, hkv, hd))
lengths = jnp.array([64, 50, 33, 7], jnp.int32)

plain = flash_decode(q, kc, vc, lengths, None, seq_axes=())
shard = jax.jit(lambda *a: flash_decode(*a, par, seq_axes=("model",)))(
    q, kc, vc, lengths)
np.testing.assert_allclose(np.asarray(plain), np.asarray(shard),
                           rtol=2e-5, atol=2e-5)
print("FLASH_OK")
""")
    assert "FLASH_OK" in out
