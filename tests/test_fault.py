"""Fault tolerance: crash/restart resume equivalence, straggler log."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models.parallel import ParallelConfig
from repro.train import LoopConfig, TrainConfig, train_loop

PAR = ParallelConfig(mesh=None, attn_chunk_q=16, attn_chunk_k=16,
                     logits_chunk=16, remat="none")
TCFG = TrainConfig(peak_lr=1e-3, warmup_steps=2, total_steps=12)


def _loop(ckpt_dir, steps=12, **kw):
    cfg = reduced_config(get_config("yi-6b"))
    return train_loop(
        cfg, PAR, batch=2, seq=16, tcfg=TCFG,
        lcfg=LoopConfig(steps=steps, ckpt_every=4, log_every=1,
                        ckpt_dir=ckpt_dir), **kw)


class _CrashAt:
    def __init__(self, step):
        self.step = step

    def __call__(self, step):
        if step == self.step:
            raise RuntimeError(f"injected failure at step {step}")


def test_crash_restart_matches_uninterrupted(tmp_path):
    """Kill at step 7, relaunch, final loss == single uninterrupted run
    (deterministic data + exact state restore)."""
    d1 = str(tmp_path / "a")
    hist_ref = _loop(d1)

    d2 = str(tmp_path / "b")
    with pytest.raises(RuntimeError):
        _loop(d2, failure_injector=_CrashAt(7))
    hist_resumed = _loop(d2)  # same command, resumes from step 4

    assert hist_resumed["step"][-1] == hist_ref["step"][-1]
    np.testing.assert_allclose(hist_resumed["loss"][-1],
                               hist_ref["loss"][-1], rtol=1e-4)


def test_straggler_watchdog_fires():
    hist = _loop(None, steps=10,
                 step_delay_injector=lambda s: 0.35 if s == 8 else 0.0)
    assert any(s[0] == 8 for s in hist["stragglers"]), hist["stragglers"]


def test_loss_decreases():
    hist = _loop(None, steps=12)
    assert hist["loss"][-1] < hist["loss"][0]
