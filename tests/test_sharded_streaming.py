"""Sharded dynamic index (subprocess with 2 host platform devices):

  * churn equivalence — after mixed insert/delete/compact churn,
    ``ShardedDynamicHybridIndex`` reports exactly the neighbor sets of
    a fresh single-host ``DynamicHybridIndex.build()`` on the surviving
    corpus, per forced route, for BOTH routing policies; un-forced
    hybrid reports sandwich between the LSH and linear truths;
  * checkpoint round-trip of the sharded segment leaves.
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = _SRC
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


_COMMON = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core.lsh import make_family
from repro.data import clustered_dataset
from repro.streaming import (CompactionPolicy, DynamicHybridIndex,
                             ShardedDynamicHybridIndex)

assert len(jax.devices()) == 2
D, L, B, M, CAP, R = 8, 4, 256, 32, 2048, 1.2
NO_AUTO = CompactionPolicy(delta_fill=2.0, tombstone_ratio=2.0)
mesh = jax.make_mesh((2,), ("data",))
fam = make_family("l2", d=D, L=L, r=1.0)
x = np.asarray(clustered_dataset(900, D, n_clusters=12,
                                 dense_core_frac=0.2, core_scale=0.05,
                                 seed=0, metric="l2"), np.float32)
q = x[::60][:12]

def churn(idx):
    # build + insert + delete + compact + more inserts/deletes: the
    # final index holds main (compacted, padded) AND delta rows AND
    # fresh tombstones in both segment kinds.
    idx.build(x[:600])
    idx.insert(x[600:800])
    idx.delete(range(50, 150))
    idx.compact()
    idx.insert(x[800:])
    dead2 = list(range(200, 260)) + list(range(820, 860))
    assert idx.delete(dead2) == 100
    assert idx.delete([50, 10**6]) == 0      # double/unknown: no-ops
    return idx

live = np.ones(900, bool)
live[50:150] = False
live[200:260] = False
live[820:860] = False
live_ids = np.nonzero(live)[0]
fresh = DynamicHybridIndex(fam, num_buckets=B, m=M, cap=CAP, key=0,
                           delta_capacity=512, policy=NO_AUTO)
fresh.build(x[live], ids=live_ids)
want = {f: fresh.query(q, R, force=f).neighbor_sets()
        for f in ("lsh", "linear")}
"""


def test_churn_equivalence_both_policies():
    out = _run(_COMMON + r"""
for policy in ("global", "per_shard"):
    sh = ShardedDynamicHybridIndex(fam, num_buckets=B, mesh=mesh, m=M,
                                   cap=CAP, delta_capacity=256,
                                   policy=NO_AUTO, routing=policy,
                                   max_out=900, key=0)
    churn(sh)
    assert sh.n == fresh.n == int(live.sum())
    st = sh.index_stats()
    assert st["compactions"] == 1 and st["delta_count"] > 0
    for force in ("lsh", "linear"):
        got = sh.query(q, R, force=force).neighbor_sets()
        assert got == want[force], (policy, force)
    # un-forced hybrid: per-shard strategy mixing stays sandwiched
    # between the two single-host truths (LSH subset <= linear truth)
    res = sh.query(q, R)
    got = res.neighbor_sets()
    for i in got:
        assert want["lsh"][i] <= got[i] <= want["linear"][i], (policy, i)
    print("POLICY_OK", policy, np.asarray(res.used_lsh).tolist())
print("ALL_OK")
""")
    assert "ALL_OK" in out
    assert out.count("POLICY_OK") == 2


def test_sharded_checkpoint_roundtrip(tmp_path):
    out = _run(_COMMON + rf"""
import tempfile
from repro.checkpoint import CheckpointManager

sh = ShardedDynamicHybridIndex(fam, num_buckets=B, mesh=mesh, m=M, cap=CAP,
                               delta_capacity=256, policy=NO_AUTO,
                               routing="per_shard", max_out=900, key=0)
churn(sh)
mgr = CheckpointManager({str(tmp_path)!r})
mgr.save_index(3, sh)

restored = ShardedDynamicHybridIndex(fam, num_buckets=B, mesh=mesh, m=M,
                                     cap=CAP, delta_capacity=256,
                                     policy=NO_AUTO, routing="per_shard",
                                     max_out=900, key=0)
assert mgr.restore_index(restored) == 3
for f in ("lsh", "linear"):
    assert (restored.query(q, R, force=f).neighbor_sets()
            == sh.query(q, R, force=f).neighbor_sets()), f
a, b = sh.index_stats(), restored.index_stats()
for key in ("n_live", "n_main", "n_main_dead", "delta_count",
            "delta_live", "live_per_shard", "delta_per_shard"):
    assert a[key] == b[key], key
# the restored index keeps streaming: ids continue past the old max
new = restored.insert(x[:4])
assert new.min() >= 900
assert restored.n == sh.n + 4
assert restored.delete(new.tolist()) == 4
print("CKPT_OK")
""")
    assert "CKPT_OK" in out


def test_sharded_lsm_budgeted_merge_equivalence():
    """Freezes build per-shard level-0 entries; budgeted compact_step
    advances merges off the query path; reported sets match the fresh
    single-host truth at every intermediate point — including deletes
    that land while a merge is staged."""
    out = _run(_COMMON + r"""
lsm = CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0, fanout=2,
                       step_rows=64)
sh = ShardedDynamicHybridIndex(fam, num_buckets=B, mesh=mesh, m=M,
                               cap=CAP, delta_capacity=64,
                               policy=lsm, routing="per_shard",
                               max_out=900, key=0)
sh.build(x[:256])
sh.insert(x[256:600])          # several freezes; merges queue, unrun
st = sh.index_stats()
assert st["freezes"] >= 2 and st["segments"] >= 2, st
assert sh.has_compaction_work
live2 = np.ones(900, bool); live2[600:] = False
def check(live_mask, note):
    ids = np.nonzero(live_mask)[0]
    f2 = DynamicHybridIndex(fam, num_buckets=B, m=M, cap=CAP, key=0,
                            delta_capacity=512, policy=NO_AUTO)
    f2.build(x[live_mask], ids=ids)
    for force in ("lsh", "linear"):
        got = sh.query(q, R, force=force).neighbor_sets()
        want2 = f2.query(q, R, force=force).neighbor_sets()
        assert got == want2, (note, force)
check(live2, "pre-step")
sh.compact_step(64)            # stage part of a merge
check(live2, "mid-stage")
dead = list(range(0, 500, 5))  # staged + unstaged + delta rows
assert sh.delete(dead) == len(dead)
live2[dead] = False
check(live2, "deleted-mid-merge")
while sh.compact_step(128):
    pass
assert not sh.has_compaction_work
check(live2, "drained")
st = sh.index_stats()
assert st["compactions"] >= 1 and st["compact_steps"] > 0, st
assert st["merges_per_level"], st
print("LSM_OK")
""")
    assert "LSM_OK" in out


def test_rebalance_churn_equivalence():
    """Skewed insert stream (every batch pinned to shard 0) under
    round_robin and load_balance placement: rows move between shards at
    every merge, yet reported neighbor sets match a fresh single-host
    build at EVERY intermediate compaction state — mid-merge deletes
    included — and the _loc map stays consistent (every ext id resolves
    to a live device row with the matching stored id) after each step."""
    out = _run(_COMMON + r"""
lsm = CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0, fanout=2,
                       step_rows=64)
for placement in ("round_robin", "load_balance"):
    sh = ShardedDynamicHybridIndex(fam, num_buckets=B, mesh=mesh, m=M,
                                   cap=CAP, delta_capacity=64,
                                   policy=lsm, routing="per_shard",
                                   max_out=900, key=0,
                                   placement=placement)
    sh.build(x[:128])
    sh.insert(x[128:500], shard=0)      # the skewed stream
    assert sh.has_compaction_work
    sh.validate_locations()
    live3 = np.ones(900, bool); live3[500:] = False
    def check(note):
        ids = np.nonzero(live3)[0]
        f3 = DynamicHybridIndex(fam, num_buckets=B, m=M, cap=CAP, key=0,
                                delta_capacity=512, policy=NO_AUTO)
        f3.build(x[live3], ids=ids)
        for force in ("lsh", "linear"):
            got = sh.query(q, R, force=force).neighbor_sets()
            want3 = f3.query(q, R, force=force).neighbor_sets()
            assert got == want3, (placement, note, force)
    check("pre-step")
    sh.compact_step(64)                 # stage part of a merge
    sh.validate_locations()
    dead = list(range(0, 450, 7))       # staged + unstaged + delta rows
    assert sh.delete(dead) == len(dead)
    live3[dead] = False
    sh.validate_locations()
    check("deleted-mid-merge")
    steps = 0
    while sh.compact_step(96):          # every intermediate state
        sh.validate_locations()
        check("step-%d" % steps)
        steps += 1
    sh.validate_locations()
    check("drained")
    st = sh.index_stats()
    assert st["rows_moved"] > 0, (placement, st)
    assert st["placement"] == placement, st
    if placement == "load_balance":
        assert st["shard_skew"] < 1.5, st
    print("REBALANCE_OK", placement, st["rows_moved"],
          round(st["shard_skew"], 3))
print("ALL_OK")
""")
    assert "ALL_OK" in out
    assert out.count("REBALANCE_OK") == 2


def test_rebalance_checkpoint_roundtrip(tmp_path):
    """Placement policy + rebalanced (moved-row) level layouts survive a
    save/restore; the restored index keeps rebalancing."""
    out = _run(_COMMON + rf"""
from repro.checkpoint import CheckpointManager

lsm = CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0, fanout=2,
                       step_rows=64)
def mk(placement):
    return ShardedDynamicHybridIndex(fam, num_buckets=B, mesh=mesh, m=M,
                                     cap=CAP, delta_capacity=64,
                                     policy=lsm, routing="per_shard",
                                     max_out=900, key=0,
                                     placement=placement)
sh = mk("load_balance")
sh.build(x[:128])
sh.insert(x[128:500], shard=0)
while sh.compact_step(128):
    pass
st = sh.index_stats()
assert st["rows_moved"] > 0, st
mgr = CheckpointManager({str(tmp_path)!r})
mgr.save_index(7, sh)

restored = mk("keep_local")     # ctor arg loses to the checkpoint
assert mgr.restore_index(restored) == 7
assert restored.placement.name == "load_balance"
restored.validate_locations()
b = restored.index_stats()
for key in ("n_live", "n_main", "segments", "levels", "live_per_shard",
            "delta_per_shard", "shard_skew"):
    assert st[key] == b[key], key
for f in ("lsh", "linear"):
    assert (restored.query(q, R, force=f).neighbor_sets()
            == sh.query(q, R, force=f).neighbor_sets()), f
# keeps streaming AND keeps rebalancing after restore
restored.insert(x[500:700], shard=0)
while restored.compact_step(128):
    pass
restored.validate_locations()
assert restored.index_stats()["rows_moved"] > 0
assert restored.index_stats()["shard_skew"] < 1.5
print("REBAL_CKPT_OK")
""")
    assert "REBAL_CKPT_OK" in out


def test_sharded_checkpoint_mid_merge(tmp_path):
    """Save -> restore a sharded stack mid-merge: query-set equality
    with the live index; the restored index re-derives its merge
    schedule and keeps streaming."""
    out = _run(_COMMON + rf"""
from repro.checkpoint import CheckpointManager

lsm = CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0, fanout=2,
                       step_rows=64)
def mk():
    return ShardedDynamicHybridIndex(fam, num_buckets=B, mesh=mesh, m=M,
                                     cap=CAP, delta_capacity=64,
                                     policy=lsm, routing="per_shard",
                                     max_out=900, key=0)
sh = mk()
sh.build(x[:256])
sh.insert(x[256:600])
sh.delete(range(32, 96))
assert sh.has_compaction_work
sh.compact_step(64)                       # mid-merge snapshot
mgr = CheckpointManager({str(tmp_path)!r})
mgr.save_index(5, sh)

restored = mk()
assert mgr.restore_index(restored) == 5
for f in ("lsh", "linear"):
    assert (restored.query(q, R, force=f).neighbor_sets()
            == sh.query(q, R, force=f).neighbor_sets()), f
a, b = sh.index_stats(), restored.index_stats()
for key in ("n_live", "n_main", "n_main_dead", "delta_count",
            "delta_live", "segments", "levels", "live_per_shard",
            "delta_per_shard"):
    assert a[key] == b[key], key
# both finish their compaction; the restored one keeps streaming
new = restored.insert(x[600:620])
assert new.min() >= 600
while restored.compact_step(512):
    pass
while sh.compact_step(512):
    pass
sh.insert(x[600:620], ids=new)
for f in ("lsh", "linear"):
    assert (restored.query(q, R, force=f).neighbor_sets()
            == sh.query(q, R, force=f).neighbor_sets()), f

# pre-stack (PR-2) checkpoint format migrates: "main" -> one level
# (dict(...) not literals: this script is an f-string, braces are taken)
restored.compact()
sd = restored.state_dict()
lv = dict(sd["levels"]["0000"]); lv.pop("meta")
old = dict(params=sd["params"], main=lv, delta=sd["delta"],
           meta=dict(next_id=sd["meta"]["next_id"],
                     built=sd["meta"]["built"]))
mig = mk()
mig.load_state_dict(old)
assert mig.n == restored.n and mig.index_stats()["segments"] == 1
for f in ("lsh", "linear"):
    assert (mig.query(q, R, force=f).neighbor_sets()
            == restored.query(q, R, force=f).neighbor_sets()), f
print("CKPT_MID_OK")
""")
    assert "CKPT_MID_OK" in out


def test_elastic_restore_different_shard_count(tmp_path):
    """Warm-standby failover onto a DIFFERENT mesh shape: a 2-shard
    stack checkpointed mid-merge (incremental, content-addressed)
    restores onto a 1-shard mesh — live rows re-deal round-robin, dead
    rows drop, the staged schedule re-derives — with bit-identical
    reported sets per forced route, a consistent _loc map, and the
    restored index still streaming.  Then back up: the 1-shard state
    restores onto the 2-shard mesh and still agrees."""
    out = _run(_COMMON + rf"""
from repro.checkpoint import CheckpointManager

lsm = CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0, fanout=2,
                       step_rows=64)
mesh1 = jax.make_mesh((1,), ("data",))
def mk(m):
    return ShardedDynamicHybridIndex(fam, num_buckets=B, mesh=m, m=M,
                                     cap=CAP, delta_capacity=64,
                                     policy=lsm, routing="per_shard",
                                     max_out=900, key=0)
sh = mk(mesh)
sh.build(x[:256])
sh.insert(x[256:600])
sh.delete(range(32, 96))
assert sh.has_compaction_work
sh.compact_step(64)                       # mid-merge snapshot
mgr = CheckpointManager({str(tmp_path)!r})
mgr.save_index(5, sh, incremental=True)

narrow = mk(mesh1)                        # standby on a smaller mesh
assert mgr.restore_index(narrow) == 5
assert narrow.n == sh.n
assert narrow.validate_locations() == narrow.n
for f in ("lsh", "linear"):
    assert (narrow.query(q, R, force=f).neighbor_sets()
            == sh.query(q, R, force=f).neighbor_sets()), f
# both drain their (re-derived) schedules and still agree
while narrow.compact_step(512):
    pass
while sh.compact_step(512):
    pass
for f in ("lsh", "linear"):
    assert (narrow.query(q, R, force=f).neighbor_sets()
            == sh.query(q, R, force=f).neighbor_sets()), f
# the narrow standby keeps streaming with fresh ids
new = narrow.insert(x[600:620])
assert new.min() >= 600 and narrow.delete(new.tolist()) == 20
narrow.validate_locations()

# scale back out: 1-shard state onto the 2-shard mesh
mgr.save_index(6, narrow, incremental=True)
wide = mk(mesh)
assert mgr.restore_index(wide) == 6
assert wide.n == narrow.n
assert wide.validate_locations() == wide.n
for f in ("lsh", "linear"):
    assert (wide.query(q, R, force=f).neighbor_sets()
            == narrow.query(q, R, force=f).neighbor_sets()), f
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out
