"""Streaming index subsystem: delta inserts, tombstoned deletes,
HLL-aware compaction, corrected routing, checkpoint round-trip.

The load-bearing contract: a mixed insert/delete workload must report
exactly the candidate sets a fresh ``HybridLSHIndex.build()`` on the
surviving corpus reports (same family params, truncation-free cap) —
per route, since LSH and linear search have different reporting sets.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import CostModel, HybridLSHIndex, hll
from repro.core.lsh import make_family
from repro.data import clustered_dataset
from repro.streaming import CompactionPolicy, DynamicHybridIndex
from repro.streaming import delta as delta_lib

D, L, B, M, CAP, R = 8, 4, 256, 32, 2048, 1.2
NO_AUTO = CompactionPolicy(delta_fill=2.0, tombstone_ratio=2.0)


def _data(n=900, seed=0):
    x = np.asarray(clustered_dataset(n, D, n_clusters=12,
                                     dense_core_frac=0.2, core_scale=0.05,
                                     seed=seed, metric="l2"))
    return x.astype(np.float32)


def _fam():
    return make_family("l2", d=D, L=L, r=1.0)


def _dyn(**kw):
    kw.setdefault("policy", NO_AUTO)
    kw.setdefault("delta_capacity", 256)
    return DynamicHybridIndex(_fam(), num_buckets=B, m=M, cap=CAP, key=0,
                              **kw)


def _fresh_sets(x, q, force, ext_ids=None):
    idx = HybridLSHIndex(_fam(), num_buckets=B, m=M, cap=CAP, key=0).build(x)
    sets = idx.query(jnp.asarray(q), R, force=force).neighbor_sets()
    if ext_ids is None:
        return sets
    return {k: {int(ext_ids[i]) for i in v} for k, v in sets.items()}


def test_insert_then_query_matches_fresh():
    """Insert-then-query == rebuild-from-scratch, per route (exact)."""
    x = _data()
    q = x[::60][:12]
    dyn = _dyn().build(x[:600])
    dyn.insert(x[600:750])
    dyn.insert(x[750:])          # second batch exercises append offsets
    assert dyn.n == 900
    for force in ("lsh", "linear"):
        got = dyn.query(q, R, force=force).neighbor_sets()
        want = _fresh_sets(x, q, force)
        assert got == want, force
    # self-queries must report themselves through either segment
    assert all(60 * i in got[i] for i in range(12))


def test_delete_masks_reported_ids():
    x = _data()
    q = x[::60][:10]
    dyn = _dyn().build(x[:700])
    dyn.insert(x[700:])
    dead = list(range(50, 150)) + list(range(720, 760))  # main + delta
    assert dyn.delete(dead) == 140
    assert dyn.delete([50, 10**6]) == 0       # double/unknown: no-ops
    with pytest.raises(KeyError):
        dyn.delete([50], strict=True)
    live = np.ones(900, bool)
    live[dead] = False
    live_ids = np.nonzero(live)[0]
    for force in ("lsh", "linear"):
        got = dyn.query(q, R, force=force).neighbor_sets()
        want = _fresh_sets(x[live], q, force, ext_ids=live_ids)
        assert got == want, force
        flat = set().union(*got.values()) if got else set()
        assert flat.isdisjoint(dead)


def test_compaction_preserves_neighbor_sets():
    x = _data()
    q = x[::45][:12]
    dyn = _dyn(delta_capacity=512).build(x[:600])
    dyn.insert(x[600:])
    dyn.delete(range(0, 120, 2))
    before = {f: dyn.query(q, R, force=f).neighbor_sets()
              for f in ("lsh", "linear")}
    dyn.compact()
    st = dyn.index_stats()
    assert st["compactions"] == 1 and st["delta_count"] == 0
    assert st["n_main"] == dyn.n == 900 - 60
    assert st["total_seconds"] == st["last_seconds"] > 0
    for f in ("lsh", "linear"):
        assert dyn.query(q, R, force=f).neighbor_sets() == before[f], f
    dyn.compact()   # cumulative: total keeps growing, last resets
    st2 = dyn.index_stats()
    assert st2["total_seconds"] > st2["last_seconds"] > 0
    assert st2["total_seconds"] > st["total_seconds"]


def test_auto_compaction_triggers():
    """Delta fills freeze level-0 segments; level overflow merges them;
    tombstone pressure folds the whole stack (dead rows reclaimed)."""
    x = _data()
    dyn = _dyn(delta_capacity=64,
               policy=CompactionPolicy(delta_fill=1.0,
                                       tombstone_ratio=0.25, fanout=4))
    dyn.build(x[:300])
    dyn.insert(x[300:600])       # >> delta capacity: fills force freezes
    st = dyn.index_stats()
    assert st["freezes"] >= 4            # one seal per delta fill
    assert st["compactions"] >= 1        # level-0 overflow merged
    assert st["merges_per_level"].get(1, 0) >= 1
    assert dyn.n == 600
    n_before = st["compactions"]
    dyn.delete(range(0, 200))    # 200/frozen > 0.25 tombstone ratio
    st = dyn.index_stats()
    assert st["compactions"] > n_before and st["n_main_dead"] == 0
    assert dyn.n == 400


def test_cost_estimate_within_hll_bounds():
    """After mixed churn, the corrected candSize tracks the live truth."""
    x = _data()
    dyn = _dyn().build(x[:700])
    dyn.insert(x[700:])
    dyn.delete(range(100, 300, 3))
    q = x[::37][:16]
    qb = np.asarray(dyn._bucket_fn(dyn.params, jnp.asarray(q)))   # (Q, L)
    est = dyn.estimate(jnp.asarray(q))
    cand = np.asarray(est.cand_est)
    coll = np.asarray(est.collisions)

    mb = np.asarray(dyn.main.bucket_ids)                 # (n_main, L)
    mlive = np.asarray(dyn.tomb.live[:dyn.main.n])
    dcap = dyn.delta.capacity
    db = np.asarray(dyn.delta.bucket_ids[:dcap])
    dlive = np.asarray(dyn.delta.live[:dcap])
    slack_frac = 6 * hll.relative_error(M)
    for i in range(len(q)):
        hit_main = (mb == qb[i][None, :]).any(1)
        true_all = int(hit_main.sum())                   # incl. tombstoned
        dead_coll = int(((mb == qb[i][None, :]) & ~mlive[:, None]).sum())
        hit_d = ((db == qb[i][None, :]).any(1) & dlive)
        delta_distinct = int(hit_d.sum())
        live_coll = int(coll[i])
        slack = max(8.0, slack_frac * true_all)
        hi = min(true_all + slack - dead_coll + delta_distinct,
                 min(live_coll, dyn.n) + 1e-3)
        lo = min(max(0.0, true_all - slack - dead_coll) + delta_distinct,
                 min(live_coll, dyn.n))
        assert lo - 1e-3 <= cand[i] <= hi + 1e-3, (i, cand[i], lo, hi)
        # exact live collision count (CSR - tombstones + delta)
        live_main_coll = int(((mb == qb[i][None, :]) & mlive[:, None]).sum())
        delta_coll = int(((db == qb[i][None, :]) & dlive[:, None]).sum())
        assert live_coll == live_main_coll + delta_coll


def test_checkpoint_roundtrip_segment_state(tmp_path):
    x = _data()
    q = x[::70][:8]
    dyn = _dyn().build(x[:650])
    dyn.insert(x[650:])
    dyn.delete(range(200, 260))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_index(7, dyn)

    restored = _dyn()
    assert mgr.restore_index(restored) == 7
    for f in ("lsh", "linear"):
        assert (restored.query(q, R, force=f).neighbor_sets()
                == dyn.query(q, R, force=f).neighbor_sets()), f
    a, b = dyn.index_stats(), restored.index_stats()
    for key in ("n_live", "n_main", "n_main_dead", "delta_count",
                "delta_live"):
        assert a[key] == b[key], key
    # the restored index keeps streaming: ids continue past the old max
    new = restored.insert(x[:4])
    assert new.min() >= 900
    assert restored.n == dyn.n + 4


def test_empty_start_and_delta_only_queries():
    x = _data(n=200)
    dyn = _dyn(delta_capacity=256)
    dyn.insert(x[:100])                       # no main segment yet
    assert dyn.main is None and dyn.n == 100
    got = dyn.query(x[:5], R, force="lsh").neighbor_sets()
    want = _fresh_sets(x[:100], x[:5], "lsh")
    assert got == want
    dyn.compact()                             # first compaction creates main
    assert dyn.main is not None
    assert dyn.index_stats()["n_main"] == 100  # real rows (pads excluded)
    assert dyn.query(x[:5], R, force="lsh").neighbor_sets() == want


def test_no_retrace_on_repeated_inserts():
    """Same-size inserts reuse one jit entry (count is traced state)."""
    x = _data(n=400)
    dyn = _dyn(delta_capacity=512).build(x[:100])
    dyn.insert(x[100:108])
    base = delta_lib.insert._cache_size()
    for lo in range(108, 300, 8):
        dyn.insert(x[lo:lo + 8])
    assert delta_lib.insert._cache_size() == base
    # deletes likewise: repeated same-size batches, one entry
    dyn.delete(range(0, 4))
    base_kill = delta_lib.kill._cache_size()
    for lo in range(104, 160, 4):
        dyn.delete(range(lo, lo + 4))
    assert delta_lib.kill._cache_size() >= base_kill  # delta path
    assert delta_lib.insert._cache_size() == base     # still no retrace


def test_hybrid_routing_still_works_under_churn():
    """Hybrid (un-forced) routing on a churned index: recall holds."""
    x = _data()
    dyn = _dyn(cost_model=CostModel(alpha=1.0, beta=10.0)).build(x[:800])
    dyn.insert(x[800:])
    dyn.delete(range(0, 100, 5))
    q = x[100:140]
    res = dyn.query(q, R)
    # linear-route answers are exact; LSH-route answers must contain the
    # self-match (distance 0 collides in every table).
    for i in range(len(q)):
        assert 100 + i in res.neighbors(i).tolist()


# ---------------------------------------------------------------------------
# LSM segment stack: freezes, tiered merges, budgeted off-query-path steps
# ---------------------------------------------------------------------------
def test_lsm_stack_equivalence_under_churn():
    """Churn over a multi-level stack — including queries issued while a
    merge is mid-flight — reports exactly the fresh-build sets."""
    x = _data()
    q = x[::47][:10]
    dyn = _dyn(delta_capacity=128,
               policy=CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                                       fanout=3, step_rows=96))
    dyn.build(x[:400])
    dyn.insert(x[400:700])
    dyn.delete(range(100, 200, 2))
    dyn.insert(x[700:])
    dyn.delete(range(650, 680))
    st = dyn.index_stats()
    assert st["segments"] >= 2           # the level stack is in play
    live = np.ones(900, bool)
    live[100:200:2] = False
    live[650:680] = False
    live_ids = np.nonzero(live)[0]
    want = {f: _fresh_sets(x[live], q, f, ext_ids=live_ids)
            for f in ("lsh", "linear")}
    # mid-merge: advance pending work a little, query between steps
    for _ in range(3):
        if dyn.stack.has_work:
            dyn.compact_step(64)
    for force in ("lsh", "linear"):
        assert dyn.query(q, R, force=force).neighbor_sets() == want[force]
    # drain to completion: merged segments swapped in, sets unchanged
    while dyn.compact_step(256):
        pass
    assert not dyn.stack.has_work
    for force in ("lsh", "linear"):
        assert dyn.query(q, R, force=force).neighbor_sets() == want[force]
    st = dyn.index_stats()
    assert st["compact_steps"] > 0 and st["merges_per_level"]


def test_delete_during_budgeted_merge_not_resurrected():
    """Rows deleted after being staged into a pending merge must not
    come back when the merged segment swaps in."""
    x = _data(n=512)
    q = x[::40][:8]
    dyn = _dyn(delta_capacity=128,
               policy=CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                                       fanout=2, step_rows=64))
    dyn.build(x[:256])
    dyn.insert(x[256:512])               # two freezes -> merge scheduled
    assert dyn.stack.has_work
    dyn.compact_step(64)                 # stage part of the inputs
    dead = list(range(0, 500, 3))        # hits staged + unstaged + delta
    assert dyn.delete(dead) == len(dead)
    while dyn.compact_step(128):
        pass
    live = np.ones(512, bool)
    live[dead] = False
    live_ids = np.nonzero(live)[0]
    for force in ("lsh", "linear"):
        got = dyn.query(q, R, force=force).neighbor_sets()
        assert got == _fresh_sets(x[live], q, force, ext_ids=live_ids)
        flat = set().union(*got.values()) if got else set()
        assert flat.isdisjoint(dead)
    # the swap kept the id -> location map consistent: delete moved rows
    assert dyn.delete(live_ids[:10].tolist()) == 10
    assert dyn.n == int(live.sum()) - 10


def test_compact_step_budget_bounds_staging():
    """Each staging step gathers at most budget_rows rows; queries stay
    correct at every intermediate point."""
    x = _data(n=600)
    dyn = _dyn(delta_capacity=128,
               policy=CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                                       fanout=2, step_rows=50))
    dyn.build(x[:256])
    dyn.insert(x[256:600])
    assert dyn.stack.has_work
    want = _fresh_sets(x, x[:6], "lsh")
    steps = 0
    while dyn.compact_step(50):
        steps += 1
        assert dyn.query(x[:6], R, force="lsh").neighbor_sets() == want
        assert steps < 100
    # a ~256-row merge at budget 50 needs several staging steps + swap
    assert dyn.index_stats()["last_merge_steps"] >= 3


def test_multiprobe_over_stack_and_delta():
    """Multi-probe applies to frozen levels AND the delta through the
    engine's segment path: monotone supersets, verified within r."""
    x = _data()
    fam = make_family("cosine", d=D, L=L, r=0.3)
    dyn = DynamicHybridIndex(fam, num_buckets=B, m=M, cap=CAP, key=0,
                             delta_capacity=128, policy=NO_AUTO)
    dyn.build(x[:500])
    dyn.insert(x[500:700])       # one freeze (128) + 72 delta rows
    assert dyn.index_stats()["segments"] == 2
    q = x[::50][:8]
    r = 0.4
    base = dyn.query(q, r, force="lsh", num_probes=1).neighbor_sets()
    probed = dyn.query(q, r, force="lsh", num_probes=3).neighbor_sets()
    lin = dyn.query(q, r, force="linear").neighbor_sets()
    for i in base:
        assert base[i] <= probed[i] <= lin[i], i
    e1 = dyn.estimate(q, num_probes=1)
    e3 = dyn.estimate(q, num_probes=3)
    assert (np.asarray(e3.collisions) >= np.asarray(e1.collisions)).all()
    with pytest.raises(ValueError):
        _dyn().build(x[:64]).query(q, R, num_probes=2)  # l2: no margins


def test_checkpoint_roundtrip_multilevel_mid_merge(tmp_path):
    """Save -> restore a stack mid-merge: query sets equal the live
    index; the restored index re-derives its merge schedule and keeps
    streaming."""
    x = _data()
    q = x[::70][:8]
    policy = CompactionPolicy(delta_fill=1.0, tombstone_ratio=2.0,
                              fanout=2, step_rows=64)
    dyn = _dyn(delta_capacity=128, policy=policy)
    dyn.build(x[:256])
    dyn.insert(x[256:600])
    dyn.delete(range(64, 128))
    assert dyn.index_stats()["segments"] >= 2
    assert dyn.stack.has_work
    dyn.compact_step(64)                 # mid-merge snapshot
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_index(11, dyn)

    restored = _dyn(delta_capacity=128, policy=policy)
    assert mgr.restore_index(restored) == 11
    for f in ("lsh", "linear"):
        assert (restored.query(q, R, force=f).neighbor_sets()
                == dyn.query(q, R, force=f).neighbor_sets()), f
    a, b = dyn.index_stats(), restored.index_stats()
    for key in ("n_live", "n_main", "n_main_dead", "delta_count",
                "delta_live", "segments", "levels"):
        assert a[key] == b[key], key
    # both finish their compaction (restored re-schedules on mutation)
    new = restored.insert(x[600:620])
    assert new.min() >= 600              # ids continue past the old max
    while restored.compact_step(512):
        pass
    while dyn.compact_step(512):
        pass
    dyn.insert(x[600:620], ids=new)
    for f in ("lsh", "linear"):
        assert (restored.query(q, R, force=f).neighbor_sets()
                == dyn.query(q, R, force=f).neighbor_sets()), f


def test_load_state_dict_migrates_pre_stack_checkpoint():
    """A pre-level-stack checkpoint (one 'main' subtree, no segment
    meta) restores as a single frozen segment instead of silently
    dropping the corpus."""
    x = _data(n=400)
    q = x[::40][:8]
    dyn = _dyn().build(x[:350])
    dyn.delete(range(40, 90))
    sd = dyn.state_dict()
    seg = dict(sd["segments"]["0000"])
    seg.pop("meta")
    old = {"params": sd["params"], "main": seg, "delta": sd["delta"],
           "meta": {"next_id": sd["meta"]["next_id"],
                    "delta_d": sd["meta"]["delta_d"]}}
    mig = _dyn().load_state_dict(old)
    assert mig.n == dyn.n and mig.index_stats()["segments"] == 1
    for f in ("lsh", "linear"):
        assert (mig.query(q, R, force=f).neighbor_sets()
                == dyn.query(q, R, force=f).neighbor_sets()), f
    # keeps streaming: the migrated segment is deletable/insertable
    assert mig.delete([100]) == 1
    assert mig.insert(x[350:354]).min() >= 350
