"""Streaming index subsystem: delta inserts, tombstoned deletes,
HLL-aware compaction, corrected routing, checkpoint round-trip.

The load-bearing contract: a mixed insert/delete workload must report
exactly the candidate sets a fresh ``HybridLSHIndex.build()`` on the
surviving corpus reports (same family params, truncation-free cap) —
per route, since LSH and linear search have different reporting sets.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import CostModel, HybridLSHIndex, hll
from repro.core.lsh import make_family
from repro.data import clustered_dataset
from repro.streaming import CompactionPolicy, DynamicHybridIndex
from repro.streaming import delta as delta_lib

D, L, B, M, CAP, R = 8, 4, 256, 32, 2048, 1.2
NO_AUTO = CompactionPolicy(delta_fill=2.0, tombstone_ratio=2.0)


def _data(n=900, seed=0):
    x = np.asarray(clustered_dataset(n, D, n_clusters=12,
                                     dense_core_frac=0.2, core_scale=0.05,
                                     seed=seed, metric="l2"))
    return x.astype(np.float32)


def _fam():
    return make_family("l2", d=D, L=L, r=1.0)


def _dyn(**kw):
    kw.setdefault("policy", NO_AUTO)
    kw.setdefault("delta_capacity", 256)
    return DynamicHybridIndex(_fam(), num_buckets=B, m=M, cap=CAP, key=0,
                              **kw)


def _fresh_sets(x, q, force, ext_ids=None):
    idx = HybridLSHIndex(_fam(), num_buckets=B, m=M, cap=CAP, key=0).build(x)
    sets = idx.query(jnp.asarray(q), R, force=force).neighbor_sets()
    if ext_ids is None:
        return sets
    return {k: {int(ext_ids[i]) for i in v} for k, v in sets.items()}


def test_insert_then_query_matches_fresh():
    """Insert-then-query == rebuild-from-scratch, per route (exact)."""
    x = _data()
    q = x[::60][:12]
    dyn = _dyn().build(x[:600])
    dyn.insert(x[600:750])
    dyn.insert(x[750:])          # second batch exercises append offsets
    assert dyn.n == 900
    for force in ("lsh", "linear"):
        got = dyn.query(q, R, force=force).neighbor_sets()
        want = _fresh_sets(x, q, force)
        assert got == want, force
    # self-queries must report themselves through either segment
    assert all(60 * i in got[i] for i in range(12))


def test_delete_masks_reported_ids():
    x = _data()
    q = x[::60][:10]
    dyn = _dyn().build(x[:700])
    dyn.insert(x[700:])
    dead = list(range(50, 150)) + list(range(720, 760))  # main + delta
    assert dyn.delete(dead) == 140
    assert dyn.delete([50, 10**6]) == 0       # double/unknown: no-ops
    with pytest.raises(KeyError):
        dyn.delete([50], strict=True)
    live = np.ones(900, bool)
    live[dead] = False
    live_ids = np.nonzero(live)[0]
    for force in ("lsh", "linear"):
        got = dyn.query(q, R, force=force).neighbor_sets()
        want = _fresh_sets(x[live], q, force, ext_ids=live_ids)
        assert got == want, force
        flat = set().union(*got.values()) if got else set()
        assert flat.isdisjoint(dead)


def test_compaction_preserves_neighbor_sets():
    x = _data()
    q = x[::45][:12]
    dyn = _dyn(delta_capacity=512).build(x[:600])
    dyn.insert(x[600:])
    dyn.delete(range(0, 120, 2))
    before = {f: dyn.query(q, R, force=f).neighbor_sets()
              for f in ("lsh", "linear")}
    dyn.compact()
    st = dyn.index_stats()
    assert st["compactions"] == 1 and st["delta_count"] == 0
    assert st["n_main"] == dyn.n == 900 - 60
    assert st["total_seconds"] == st["last_seconds"] > 0
    for f in ("lsh", "linear"):
        assert dyn.query(q, R, force=f).neighbor_sets() == before[f], f
    dyn.compact()   # cumulative: total keeps growing, last resets
    st2 = dyn.index_stats()
    assert st2["total_seconds"] > st2["last_seconds"] > 0
    assert st2["total_seconds"] > st["total_seconds"]


def test_auto_compaction_triggers():
    x = _data()
    dyn = _dyn(delta_capacity=64,
               policy=CompactionPolicy(delta_fill=1.0,
                                       tombstone_ratio=0.25))
    dyn.build(x[:300])
    dyn.insert(x[300:600])       # >> delta capacity: fills force compaction
    assert dyn.index_stats()["compactions"] >= 3
    assert dyn.n == 600
    n_before = dyn.index_stats()["compactions"]
    dyn.delete(range(0, 200))    # 200/600 > 0.25 tombstone ratio
    st = dyn.index_stats()
    assert st["compactions"] > n_before and st["n_main_dead"] == 0
    assert dyn.n == 400


def test_cost_estimate_within_hll_bounds():
    """After mixed churn, the corrected candSize tracks the live truth."""
    x = _data()
    dyn = _dyn().build(x[:700])
    dyn.insert(x[700:])
    dyn.delete(range(100, 300, 3))
    q = x[::37][:16]
    qb = np.asarray(dyn._bucket_fn(dyn.params, jnp.asarray(q)))   # (Q, L)
    est = dyn.estimate(jnp.asarray(q))
    cand = np.asarray(est.cand_est)
    coll = np.asarray(est.collisions)

    mb = np.asarray(dyn.main.bucket_ids)                 # (n_main, L)
    mlive = np.asarray(dyn.tomb.live[:dyn.main.n])
    dcap = dyn.delta.capacity
    db = np.asarray(dyn.delta.bucket_ids[:dcap])
    dlive = np.asarray(dyn.delta.live[:dcap])
    slack_frac = 6 * hll.relative_error(M)
    for i in range(len(q)):
        hit_main = (mb == qb[i][None, :]).any(1)
        true_all = int(hit_main.sum())                   # incl. tombstoned
        dead_coll = int(((mb == qb[i][None, :]) & ~mlive[:, None]).sum())
        hit_d = ((db == qb[i][None, :]).any(1) & dlive)
        delta_distinct = int(hit_d.sum())
        live_coll = int(coll[i])
        slack = max(8.0, slack_frac * true_all)
        hi = min(true_all + slack - dead_coll + delta_distinct,
                 min(live_coll, dyn.n) + 1e-3)
        lo = min(max(0.0, true_all - slack - dead_coll) + delta_distinct,
                 min(live_coll, dyn.n))
        assert lo - 1e-3 <= cand[i] <= hi + 1e-3, (i, cand[i], lo, hi)
        # exact live collision count (CSR - tombstones + delta)
        live_main_coll = int(((mb == qb[i][None, :]) & mlive[:, None]).sum())
        delta_coll = int(((db == qb[i][None, :]) & dlive[:, None]).sum())
        assert live_coll == live_main_coll + delta_coll


def test_checkpoint_roundtrip_segment_state(tmp_path):
    x = _data()
    q = x[::70][:8]
    dyn = _dyn().build(x[:650])
    dyn.insert(x[650:])
    dyn.delete(range(200, 260))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_index(7, dyn)

    restored = _dyn()
    assert mgr.restore_index(restored) == 7
    for f in ("lsh", "linear"):
        assert (restored.query(q, R, force=f).neighbor_sets()
                == dyn.query(q, R, force=f).neighbor_sets()), f
    a, b = dyn.index_stats(), restored.index_stats()
    for key in ("n_live", "n_main", "n_main_dead", "delta_count",
                "delta_live"):
        assert a[key] == b[key], key
    # the restored index keeps streaming: ids continue past the old max
    new = restored.insert(x[:4])
    assert new.min() >= 900
    assert restored.n == dyn.n + 4


def test_empty_start_and_delta_only_queries():
    x = _data(n=200)
    dyn = _dyn(delta_capacity=256)
    dyn.insert(x[:100])                       # no main segment yet
    assert dyn.main is None and dyn.n == 100
    got = dyn.query(x[:5], R, force="lsh").neighbor_sets()
    want = _fresh_sets(x[:100], x[:5], "lsh")
    assert got == want
    dyn.compact()                             # first compaction creates main
    assert dyn.main is not None and dyn.main.n == 100
    assert dyn.query(x[:5], R, force="lsh").neighbor_sets() == want


def test_no_retrace_on_repeated_inserts():
    """Same-size inserts reuse one jit entry (count is traced state)."""
    x = _data(n=400)
    dyn = _dyn(delta_capacity=512).build(x[:100])
    dyn.insert(x[100:108])
    base = delta_lib.insert._cache_size()
    for lo in range(108, 300, 8):
        dyn.insert(x[lo:lo + 8])
    assert delta_lib.insert._cache_size() == base
    # deletes likewise: repeated same-size batches, one entry
    dyn.delete(range(0, 4))
    base_kill = delta_lib.kill._cache_size()
    for lo in range(104, 160, 4):
        dyn.delete(range(lo, lo + 4))
    assert delta_lib.kill._cache_size() >= base_kill  # delta path
    assert delta_lib.insert._cache_size() == base     # still no retrace


def test_hybrid_routing_still_works_under_churn():
    """Hybrid (un-forced) routing on a churned index: recall holds."""
    x = _data()
    dyn = _dyn(cost_model=CostModel(alpha=1.0, beta=10.0)).build(x[:800])
    dyn.insert(x[800:])
    dyn.delete(range(0, 100, 5))
    q = x[100:140]
    res = dyn.query(q, R)
    # linear-route answers are exact; LSH-route answers must contain the
    # self-match (distance 0 collides in every table).
    for i in range(len(q)):
        assert 100 + i in res.neighbors(i).tolist()
