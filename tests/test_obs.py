"""Observability substrate: registry, tracer, event log, schemas.

The load-bearing contracts: (1) tracing is observation only — a traced
query returns bit-identical results to the untraced fast path; (2) the
stats-key schemas in ``repro.obs.schema`` are asserted *exact*, so a
renamed key fails in review instead of breaking dashboards after
merge; (3) compaction work time is measured once — the driver and the
index report the same ``work_seconds`` dict.
"""
import json
import pathlib
import re
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CostModel
from repro.core.lsh import make_family
from repro.obs import (NULL_REGISTRY, SPAN_FIELDS, EventLog, MetricsRegistry,
                       Observability, QueryTracer, WorkPhases, time_block,
                       to_prometheus)
from repro.obs.schema import (DRIVER_STATS_KEYS, EVENT_BASE_FIELDS,
                              INDEX_STATS_KEYS, SHARDED_INDEX_EXTRA_KEYS,
                              WORK_PHASE_KEYS)
from repro.streaming import (CompactionDriver, CompactionPolicy,
                             DynamicHybridIndex)

D, L = 8, 4


def _dyn(obs=None, **kw):
    kw.setdefault("policy", CompactionPolicy(delta_fill=1.0,
                                             tombstone_ratio=2.0, fanout=2))
    kw.setdefault("delta_capacity", 128)
    return DynamicHybridIndex(make_family("l2", d=D, L=L, r=1.0),
                              num_buckets=256, m=32, cap=256, key=0,
                              cost_model=CostModel(alpha=1.0, beta=1.0),
                              obs=obs, **kw)


def _data(n=512, seed=0):
    rng = np.random.default_rng(seed)
    # half clustered (LSH-friendly), half spread — both routes exercised
    a = rng.normal(size=(n // 2, D)).astype(np.float32) * 0.05
    b = rng.normal(size=(n - n // 2, D)).astype(np.float32) * 3.0
    return np.concatenate([a, b])


# ---------------------------------------------------------------- registry
def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry(enabled=True)
    c = reg.counter("c_total", help="a counter")
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = reg.gauge("g", help="a gauge")
    g.set(7.5)
    assert g.value == 7.5
    h = reg.histogram("h_seconds", buckets=(1.0, 10.0), help="a histogram")
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    assert h.count == 3 and h.sum == 55.5
    # cumulative buckets: <=1 gets 1, <=10 gets 2, +Inf gets 3
    assert [n for _, n in h.cumulative()] == [1, 2, 3]


def test_registry_labels_key_identity():
    reg = MetricsRegistry(enabled=True)
    a = reg.counter("x_total", labels={"route": "lsh"})
    b = reg.counter("x_total", labels={"route": "lsh"})
    c = reg.counter("x_total", labels={"route": "linear"})
    assert a is b and a is not c
    a.inc(2)
    snap = reg.snapshot()
    assert json.dumps(snap)            # JSON-serializable
    assert snap["counters"]['x_total{route="lsh"}'] == 2
    assert snap["counters"]['x_total{route="linear"}'] == 0


def test_registry_disabled_is_noop():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("c_total")
    c.inc(10)
    assert c.value == 0                 # shared null instrument
    assert reg.collect() == []
    assert reg.snapshot()["counters"] == {}
    # the shared null registry behaves the same
    NULL_REGISTRY.counter("whatever").inc()
    assert NULL_REGISTRY.collect() == []


def test_registry_thread_safety_smoke():
    reg = MetricsRegistry(enabled=True)
    c = reg.counter("n_total")

    def work():
        for _ in range(1000):
            c.inc()

    ts = [threading.Thread(target=work) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == 4000


def test_prometheus_exposition_format():
    reg = MetricsRegistry(enabled=True)
    reg.counter("q_total", help="queries", labels={"route": "lsh"}).inc(3)
    reg.gauge("live").set(12)
    h = reg.histogram("lat_seconds", buckets=(0.1,), help="latency")
    h.observe(0.05)
    h.observe(0.5)
    text = to_prometheus(reg)
    assert "# TYPE q_total counter" in text
    assert 'q_total{route="lsh"} 3' in text
    assert "# TYPE lat_seconds histogram" in text
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="+Inf"} 2' in text
    assert "lat_seconds_sum" in text and "lat_seconds_count 2" in text
    # every non-comment line is "name{labels} value"
    for line in text.strip().splitlines():
        if not line.startswith("#"):
            assert re.match(r'^[a-zA-Z_:][\w:]*(\{[^}]*\})? \S+$', line)


def test_work_phases_and_time_block():
    ph = WorkPhases("stage", "apply")
    with time_block(phases=ph, phase="stage") as tb:
        pass
    assert tb.elapsed >= 0
    ph.add("apply", 1.5)
    d = ph.as_dict()
    assert set(d) == {"stage", "apply", "total"}
    assert d["total"] == pytest.approx(d["stage"] + 1.5)
    assert ph.total == pytest.approx(d["total"])


# --------------------------------------------------------------- event log
def test_event_log_ring_bound_and_seq():
    log = EventLog(capacity=4)
    for i in range(10):
        log.emit("tick", i=i)
    assert len(log) == 4 and log.seq == 10 and log.dropped == 6
    evs = log.events()
    assert [e["i"] for e in evs] == [6, 7, 8, 9]        # newest-last
    assert all(EVENT_BASE_FIELDS <= set(e) for e in evs)
    log.emit("other")
    assert log.events(kind="other")[0]["seq"] == 10
    assert log.counts_by_kind() == {"tick": 3, "other": 1}
    assert len(log.events(limit=2)) == 2


def test_event_log_disabled_noop():
    log = EventLog(capacity=4, enabled=False)
    log.emit("tick")
    assert len(log) == 0 and log.seq == 0


# ------------------------------------------------------------ query tracing
def test_traced_query_results_identical_and_spans():
    obs = Observability.create(trace_capacity=1024, trace_sample_every=1)
    obs.tracer.enabled = False
    x = _data()
    idx = _dyn(obs=obs).build(x[:384])
    idx.insert(x[384:])                 # freeze + delta: multiple segments
    q = jnp.asarray(x[::40][:12])

    plain = idx.query(q, 1.2).neighbor_sets()
    obs.tracer.enabled = True
    traced = idx.query(q, 1.2).neighbor_sets()
    assert traced == plain              # tracing is observation only

    spans = obs.tracer.spans()
    assert len(spans) == 12
    assert all(set(SPAN_FIELDS) <= set(s) for s in spans)
    for s in spans:
        assert s["strategy"] in ("lsh", "linear") and not s["forced"]
        assert s["cand_actual"] <= idx.n
        # re-priced Eq. 1 must actually use cand_actual
        assert s["lsh_cost_actual"] == pytest.approx(
            s["collisions"] + s["cand_actual"])
    rate = obs.tracer.misroute_rate
    assert np.isfinite(rate) and 0.0 <= rate <= 1.0


def test_forced_queries_excluded_from_rate():
    obs = Observability.create(trace_sample_every=1)
    x = _data(256)
    idx = _dyn(obs=obs, delta_capacity=512).build(x)
    q = jnp.asarray(x[:8])
    idx.query(q, 1.2, force="lsh")
    idx.query(q, 1.2, force="linear")
    s = obs.tracer.summary()
    assert s["queries"] == 0 and s["forced_queries"] == 16
    assert len(obs.tracer.spans(strategy="lsh")) == 8
    assert all(sp["forced"] for sp in obs.tracer.spans())


def test_tracer_sampling_gates_batches():
    obs = Observability.create(trace_sample_every=4)
    x = _data(256)
    idx = _dyn(obs=obs, delta_capacity=512).build(x)
    q = jnp.asarray(x[:4])
    for _ in range(8):
        idx.query(q, 1.2)
    s = obs.tracer.summary()
    # batches 0 and 4 sample; 8 batches seen
    assert s["batches_seen"] == 8 and s["batches_traced"] == 2
    assert s["queries"] == 8
    assert s["last_batch"]["phase_seconds"].keys() >= {"estimate"}


# ------------------------------------------------------------ stats schemas
def test_index_and_driver_stats_schema_exact():
    obs = Observability.create(trace_sample_every=1)
    x = _data()
    idx = _dyn(obs=obs).build(x[:256])
    for lo in range(256, 512, 64):
        idx.insert(x[lo:lo + 64])       # freezes + scheduled merges
    st = idx.index_stats()
    assert set(st) == INDEX_STATS_KEYS
    assert set(st["work_seconds"]) == WORK_PHASE_KEYS

    drv = CompactionDriver(idx)         # inherits idx.obs
    drv.start()
    try:
        drv.flush()
        ds = drv.stats()
    finally:
        drv.stop()
    assert set(ds) == DRIVER_STATS_KEYS
    # one measurement, two surfaces: the driver reports the index's dict
    assert ds["work_seconds"] == idx.index_stats()["work_seconds"]
    assert ds["work_seconds"]["total"] > 0
    kinds = obs.events.counts_by_kind()
    assert kinds.get("freeze", 0) >= 2
    assert kinds.get("swap", 0) >= 1
    assert kinds.get("driver_start") == 1 and kinds.get("driver_stop") == 1
    assert kinds.get("flush_barrier", 0) >= 1


def test_sharded_stats_schema_exact():
    import jax
    from repro.streaming import ShardedDynamicHybridIndex
    mesh = jax.make_mesh((1,), ("data",))
    obs = Observability.create()
    idx = ShardedDynamicHybridIndex(
        make_family("l2", d=D, L=L, r=1.0), mesh=mesh, num_buckets=256,
        m=32, cap=256, delta_capacity=128, key=0, obs=obs)
    idx.build(_data(256))
    st = idx.index_stats()
    assert set(st) == INDEX_STATS_KEYS | SHARDED_INDEX_EXTRA_KEYS
    assert set(st["work_seconds"]) == WORK_PHASE_KEYS


# ----------------------------------------------------------- import hygiene
def test_no_repro_module_imports_deprecated_router():
    """New code must import repro.core.engine, not the core.router shim."""
    src = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"
    offenders = []
    for p in src.rglob("*.py"):
        if p.name == "router.py" and p.parent.name == "core":
            continue                    # the shim itself
        text = p.read_text()
        if re.search(r"from\s+repro\.core\.router\s+import|"
                     r"from\s+repro\.core\s+import\s+router\b|"
                     r"import\s+repro\.core\.router\b|"
                     r"from\s+\.router\s+import", text):
            offenders.append(str(p.relative_to(src)))
    assert offenders == []
