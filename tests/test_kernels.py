"""Per-kernel allclose sweeps: Pallas (interpret=True) vs ref oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _pts(n, d, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=(n, d)).astype(dtype))


@pytest.mark.parametrize("metric", ["l2", "l1", "cosine"])
@pytest.mark.parametrize("shape", [(8, 16, 7), (100, 130, 70),
                                   (128, 256, 128), (33, 257, 129)])
def test_distance_kernels_match_ref(metric, shape):
    q, n, d = shape
    qa, xa = _pts(q, d), _pts(n, d)
    a = ops.pairwise_dist(qa, xa, metric, impl="pallas_interpret")
    b = ops.pairwise_dist(qa, xa, metric, impl="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_distance_kernel_dtypes(dtype):
    qa, xa = _pts(16, 32, dtype), _pts(64, 32, dtype)
    a = ops.pairwise_dist(qa, xa, "l2", impl="pallas_interpret")
    b = ops.pairwise_dist(qa, xa, "l2", impl="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("shape", [(4, 10, 1), (60, 200, 2), (128, 128, 8)])
def test_hamming_kernel_exact(shape):
    q, n, w = shape
    qc = jnp.asarray(RNG.integers(0, 2**32, (q, w), dtype=np.uint32))
    xc = jnp.asarray(RNG.integers(0, 2**32, (n, w), dtype=np.uint32))
    a = ops.hamming_dist(qc, xc, impl="pallas_interpret")
    b = ops.hamming_dist(qc, xc, impl="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # cross-check against numpy bit counting
    qa, xa = np.asarray(qc), np.asarray(xc)
    expect = np.zeros((q, n), np.int64)
    for i in range(q):
        x = qa[i][None] ^ xa
        expect[i] = np.unpackbits(x.view(np.uint8), axis=1).sum(1)
    np.testing.assert_array_equal(np.asarray(a), expect)


@pytest.mark.parametrize("L,k", [(3, 8), (5, 31), (2, 32), (4, 40), (1, 64)])
def test_simhash_kernel_exact(L, k):
    x = _pts(130, 48)
    r = _pts(48, L * k)
    a = ops.simhash_fingerprint(x, r, L=L, k=k, impl="pallas_interpret")
    b = ops.simhash_fingerprint(x, r, L=L, k=k, impl="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (130, L, (k + 31) // 32)


def test_simhash_matches_family_packing():
    """Kernel fingerprints == families.SimHash.codes bit-for-bit."""
    from repro.core.lsh import SimHash
    fam = SimHash(d=32, L=4, k=17)
    params = fam.init(jax.random.PRNGKey(1))
    x = _pts(64, 32)
    a = fam.codes(params, x)
    b = ops.simhash_fingerprint(x, params["R"], L=4, k=17, impl="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("q,L,m", [(8, 3, 32), (64, 20, 128), (5, 1, 64)])
def test_hll_merge_kernel(q, L, m):
    regs = jnp.asarray(RNG.integers(0, 25, (q, L, m)).astype(np.uint8))
    a = ops.hll_merge_estimate(regs, impl="pallas_interpret")
    b = ops.hll_merge_estimate(regs, impl="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_l2_transform_threshold():
    """ops returns squared L2; radius transform must square r."""
    assert ops.metric_radius_transform("l2", 3.0) == 9.0
    assert ops.metric_radius_transform("cosine", 0.5) == 0.5
