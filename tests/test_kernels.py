"""Per-kernel allclose sweeps: Pallas (interpret=True) vs ref oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _pts(n, d, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=(n, d)).astype(dtype))


@pytest.mark.parametrize("metric", ["l2", "l1", "cosine"])
@pytest.mark.parametrize("shape", [(8, 16, 7), (100, 130, 70),
                                   (128, 256, 128), (33, 257, 129)])
def test_distance_kernels_match_ref(metric, shape):
    q, n, d = shape
    qa, xa = _pts(q, d), _pts(n, d)
    a = ops.pairwise_dist(qa, xa, metric, impl="pallas_interpret")
    b = ops.pairwise_dist(qa, xa, metric, impl="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_distance_kernel_dtypes(dtype):
    qa, xa = _pts(16, 32, dtype), _pts(64, 32, dtype)
    a = ops.pairwise_dist(qa, xa, "l2", impl="pallas_interpret")
    b = ops.pairwise_dist(qa, xa, "l2", impl="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("shape", [(4, 10, 1), (60, 200, 2), (128, 128, 8)])
def test_hamming_kernel_exact(shape):
    q, n, w = shape
    qc = jnp.asarray(RNG.integers(0, 2**32, (q, w), dtype=np.uint32))
    xc = jnp.asarray(RNG.integers(0, 2**32, (n, w), dtype=np.uint32))
    a = ops.hamming_dist(qc, xc, impl="pallas_interpret")
    b = ops.hamming_dist(qc, xc, impl="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # cross-check against numpy bit counting
    qa, xa = np.asarray(qc), np.asarray(xc)
    expect = np.zeros((q, n), np.int64)
    for i in range(q):
        x = qa[i][None] ^ xa
        expect[i] = np.unpackbits(x.view(np.uint8), axis=1).sum(1)
    np.testing.assert_array_equal(np.asarray(a), expect)


@pytest.mark.parametrize("L,k", [(3, 8), (5, 31), (2, 32), (4, 40), (1, 64)])
def test_simhash_kernel_exact(L, k):
    x = _pts(130, 48)
    r = _pts(48, L * k)
    a = ops.simhash_fingerprint(x, r, L=L, k=k, impl="pallas_interpret")
    b = ops.simhash_fingerprint(x, r, L=L, k=k, impl="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (130, L, (k + 31) // 32)


def test_simhash_matches_family_packing():
    """Kernel fingerprints == families.SimHash.codes bit-for-bit."""
    from repro.core.lsh import SimHash
    fam = SimHash(d=32, L=4, k=17)
    params = fam.init(jax.random.PRNGKey(1))
    x = _pts(64, 32)
    a = fam.codes(params, x)
    b = ops.simhash_fingerprint(x, params["R"], L=4, k=17, impl="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("q,L,m", [(8, 3, 32), (64, 20, 128), (5, 1, 64)])
def test_hll_merge_kernel(q, L, m):
    regs = jnp.asarray(RNG.integers(0, 25, (q, L, m)).astype(np.uint8))
    a = ops.hll_merge_estimate(regs, impl="pallas_interpret")
    b = ops.hll_merge_estimate(regs, impl="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_l2_transform_threshold():
    """ops returns squared L2; radius transform must square r."""
    assert ops.metric_radius_transform("l2", 3.0) == 9.0
    assert ops.metric_radius_transform("cosine", 0.5) == 0.5


# ---------------------------------------------------------------------------
# Fused query-path kernels (fused_scan.py) vs the composed oracles.
#
# Radii sit away from any realized distance, so the report masks are
# insensitive to float reassociation and must match EXACTLY (ids too);
# raw distances are allclose (the kernel and XLA reduce in different
# orders).  The "ref" impl *is* the composed pipeline, so dispatch-level
# bit-identity off-TPU holds by construction.
# ---------------------------------------------------------------------------
_FUSED_RADII = {"l2": 7.0, "l1": 55.0, "cosine": 0.9, "hamming": 300.0}


def _fused_pair(metric, q, n):
    if metric == "hamming":
        qa = jnp.asarray(RNG.integers(0, 2**32, (q, 3), dtype=np.uint32))
        xa = jnp.asarray(RNG.integers(0, 2**32, (n, 3), dtype=np.uint32))
    else:
        d = 37
        qa, xa = _pts(q, d), _pts(n, d)
    return qa, xa


@pytest.mark.parametrize("metric", ["l2", "l1", "cosine", "hamming"])
@pytest.mark.parametrize("q,n", [(8, 100), (33, 257)])
def test_fused_linear_scan_matches_ref(metric, q, n):
    qa, xa = _fused_pair(metric, q, n)
    r = _FUSED_RADII[metric]
    ia, da, ma = ops.fused_linear_scan(qa, xa, r, metric,
                                       impl="pallas_interpret")
    ib, db, mb = ops.fused_linear_scan(qa, xa, r, metric, impl="ref")
    assert ia.shape == da.shape == ma.shape == (q, n)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb))
    np.testing.assert_allclose(np.asarray(da), np.asarray(db),
                               rtol=3e-4, atol=3e-4)
    assert int(np.asarray(ma).sum()) > 0      # radii actually report


@pytest.mark.parametrize("metric", ["l2", "l1", "cosine", "hamming"])
def test_fused_lsh_scan_handcrafted_candidates(metric):
    """Duplicates, sentinel padding, and an all-sentinel (empty-bucket)
    row all mask identically in the kernel and the oracle."""
    n = 40
    qa, xa = _fused_pair(metric, 3, n)
    sent = n
    ids = jnp.asarray(np.array([
        [0, 0, 0, 1, 2, 2, 5, sent],            # duplicate runs
        [3, 7, 7, 9, sent, sent, sent, sent],   # sentinel tail
        [sent] * 8,                             # empty bucket row
    ], np.int32))
    ids = jnp.sort(ids, axis=-1)
    r = _FUSED_RADII[metric]
    ia, da, ma = ops.fused_lsh_scan(xa, ids, qa, r, metric,
                                    impl="pallas_interpret")
    ib, db, mb = ops.fused_lsh_scan(xa, ids, qa, r, metric, impl="ref")
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb))
    ma_np, da_np = np.asarray(ma), np.asarray(da)
    np.testing.assert_allclose(da_np[ma_np], np.asarray(db)[ma_np],
                               rtol=3e-4, atol=3e-4)
    assert not ma_np[2].any()                  # all-sentinel row reports 0
    # duplicates report at most once: masked ids are unique per query
    for qi in range(2):
        rep = np.asarray(ia)[qi][ma_np[qi]]
        assert len(rep) == len(set(rep.tolist()))


@pytest.mark.parametrize("metric", ["l2", "hamming"])
def test_fused_lsh_search_end_to_end(metric):
    """lsh_search with real tables + multi-probe tidx + cap truncation:
    interpret and ref dispatches agree on ids/mask exactly."""
    from repro.core.lsh.tables import build_tables
    from repro.core.search import lsh_search
    n, q, L, B, cap = 150, 33, 4, 8, 2        # tiny cap => truncation
    qa, xa = _fused_pair(metric, q, n)
    bids = jnp.asarray(RNG.integers(0, B, size=(n, L), dtype=np.int32))
    tables = build_tables(jnp.arange(n, dtype=jnp.int32), bids, B, 16)
    tidx = jnp.asarray(np.repeat(np.arange(L), 2).astype(np.int32))
    qb = jnp.asarray(RNG.integers(0, B, size=(q, L * 2), dtype=np.int32))
    r = _FUSED_RADII[metric]
    a = lsh_search(xa, tables, qb, qa, r, metric, cap, q_chunk=16,
                   tidx=tidx, impl="pallas_interpret")
    b = lsh_search(xa, tables, qb, qa, r, metric, cap, q_chunk=16,
                   tidx=tidx, impl="ref")
    assert a[0].shape == (q, L * 2 * cap)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(b[2]))
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("nq", [7, 32, 33, 65])
def test_search_chunking_pads_odd_batches(nq):
    """No batch size falls back to full materialization: results are
    invariant to q_chunk (chunked == unchunked == chunk-padded)."""
    from repro.core.search import linear_search
    qa, xa = _fused_pair("l2", nq, 97)
    base = linear_search(xa, qa, 7.0, "l2", impl="ref", q_chunk=0)
    for q_chunk in (16, 32):
        got = linear_search(xa, qa, 7.0, "l2", impl="ref", q_chunk=q_chunk)
        for ga, ba in zip(got, base):
            assert ga.shape == ba.shape == (nq, 97)
            np.testing.assert_array_equal(np.asarray(ga), np.asarray(ba))
