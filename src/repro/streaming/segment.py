"""Immutable frozen segments + the multi-level LSM segment stack.

A ``FrozenSegment`` is one sealed unit of the streaming index: corpus
rows + CSR ``LSHTables`` + per-bucket HLLs (the paper's Algorithm 1
fusion) + a tombstone bitmap.  Rows are padded to a power of two and
pad rows are *hashed out of the bucket space* (bucket ``B``), which the
CSR ``segment_sum`` and the HLL ``segment_max`` drop exactly — padding
costs capacity, never correctness — so repeated freezes of the same
delta capacity reuse one compiled build.

``SegmentStack`` arranges frozen segments into LSM levels:

  * level 0 holds *minor* segments sealed straight from the delta
    (``freeze``: O(delta_capacity), no rebuild of older data);
  * a tiered ``CompactionPolicy`` merges a level's segments into one
    segment at the next level when the level overflows, so each row is
    rewritten O(log n) times over its lifetime instead of once per
    delta fill.

Merges are materialized as ``MergeTask`` work items and advanced in
bounded ``compact_step(budget_rows)`` increments: each step gathers and
hashes at most ``budget_rows`` live rows into host staging buffers;
the final step runs the fused ``build_tables`` over the staged rows and
*atomically swaps* the merged segment in (queries keep being served
from the old level list until then).  Rows deleted while staged are
re-checked against the input tombstones at swap time, so churn during
a merge never resurrects dead rows.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import array_digest
from repro.core.engine import _pad_size
from repro.core.lsh.tables import LSHTables, build_tables
from repro.obs.metrics import WorkPhases, time_block
from repro.streaming import tombstones as tomb_lib

__all__ = ["MainSegment", "build_main", "FrozenSegment", "freeze_segment",
           "frozen_digests", "mark_rows_dead", "MergeTask", "MergeResult",
           "SegmentStack"]


@dataclasses.dataclass
class MainSegment:
    x: jax.Array            # (n, d) corpus rows (may include pad rows)
    ids: jax.Array          # (n,) int32 external doc ids (-1 on pad rows)
    bucket_ids: jax.Array   # (n, L) int32 per-table buckets (B on pad rows)
    tables: LSHTables

    @property
    def n(self) -> int:
        return int(self.x.shape[0])


def build_main(x: jax.Array, ext_ids: jax.Array, bucket_fn, params,
               num_buckets: int, m: int, chunk: int = 65536) -> MainSegment:
    """Algorithm 1 on an exact (unpadded) row block; kept for callers
    that manage their own padding."""
    x = jnp.asarray(x)
    n = x.shape[0]
    bids = [bucket_fn(params, x[lo:lo + chunk]) for lo in range(0, n, chunk)]
    bucket_ids = jnp.concatenate(bids, axis=0)          # (n, L)
    tables = build_tables(jnp.arange(n, dtype=jnp.int32), bucket_ids,
                          num_buckets, m)
    return MainSegment(x=x, ids=jnp.asarray(ext_ids, jnp.int32),
                       bucket_ids=bucket_ids.astype(jnp.int32),
                       tables=tables)


@dataclasses.dataclass
class FrozenSegment:
    """One immutable level entry: padded rows + tables + tombstones."""

    uid: int                # stack-unique id (stable across merges of others)
    level: int              # LSM level (0 = freshly frozen delta)
    seg: MainSegment        # n_pad rows; pads hashed out of bucket space
    tomb: tomb_lib.Tombstones
    n_rows: int             # real rows (tombstoned included, pads excluded)
    n_live: int
    # content addresses of the immutable leaves, computed lazily by
    # frozen_digests() and cached here — only tombstone state ever
    # rebinds after construction, so these stay valid for the
    # segment's lifetime
    digests: Optional[Dict[str, str]] = None

    @property
    def n_pad(self) -> int:
        return self.seg.n

    @property
    def n_dead(self) -> int:
        return self.n_rows - self.n_live


def frozen_digests(f: FrozenSegment) -> Dict[str, str]:
    """Content addresses of a frozen segment's immutable leaves.

    Computed once per segment and cached on it, so an incremental
    checkpoint (``CheckpointManager.save_incremental``) can reference
    unchanged level chunks without re-hashing — snapshot hashing cost
    stays O(delta + tombstones), not O(index).  The mutable leaves
    (``live``/``tomb_counts``, rebound by ``mark_rows_dead``) are
    deliberately NOT here: they re-hash every snapshot.
    """
    if f.digests is None:
        t = f.seg.tables
        f.digests = {k: array_digest(np.asarray(v)) for k, v in (
            ("x", f.seg.x), ("ids", f.seg.ids),
            ("bucket_ids", f.seg.bucket_ids), ("perm", t.perm),
            ("starts", t.starts), ("registers", t.registers))}
    return f.digests


def freeze_segment(x: np.ndarray, ext_ids: np.ndarray, bucket_fn, params,
                   num_buckets: int, m: int, *, uid: int, level: int,
                   bucket_rows: Optional[np.ndarray] = None
                   ) -> FrozenSegment:
    """Seal live rows into an immutable padded segment (Algorithm 1).

    ``bucket_rows`` (k, L) skips re-hashing when the caller staged the
    hashes already (budgeted merges); pad lanes always hash to bucket
    ``num_buckets`` so the fused build drops them exactly.
    """
    x = np.asarray(x)
    k = int(x.shape[0])
    n_pad = _pad_size(max(k, 1))
    pad_shape = (n_pad,) + tuple(x.shape[1:])
    x_p = np.zeros(pad_shape, x.dtype)
    x_p[:k] = x
    ids_p = np.full((n_pad,), -1, np.int32)
    ids_p[:k] = ext_ids
    valid = np.zeros((n_pad,), bool)
    valid[:k] = True
    x_j = jnp.asarray(x_p)
    valid_j = jnp.asarray(valid)
    if bucket_rows is None:
        chunk = 65536
        if n_pad > chunk:
            bids = jnp.concatenate(
                [bucket_fn(params, x_j[lo:lo + chunk])
                 for lo in range(0, n_pad, chunk)], axis=0).astype(jnp.int32)
        else:
            bids = bucket_fn(params, x_j).astype(jnp.int32)
    else:
        L = bucket_rows.shape[1] if k else 0
        if L == 0:      # empty freeze: hash the (zero) pad rows for L
            bids = bucket_fn(params, x_j).astype(jnp.int32)
        else:
            b_p = np.full((n_pad, L), num_buckets, np.int32)
            b_p[:k] = bucket_rows
            bids = jnp.asarray(b_p)
    bids = jnp.where(valid_j[:, None], bids, num_buckets)
    tables = build_tables(jnp.arange(n_pad, dtype=jnp.int32), bids,
                          num_buckets, m)
    live = jnp.concatenate([valid_j, jnp.zeros((1,), bool)])
    tomb = tomb_lib.Tombstones(
        live=live, counts=jnp.zeros((tables.L, num_buckets), jnp.int32))
    seg = MainSegment(x=x_j, ids=jnp.asarray(ids_p),
                      bucket_ids=bids.astype(jnp.int32), tables=tables)
    return FrozenSegment(uid=uid, level=level, seg=seg, tomb=tomb,
                         n_rows=k, n_live=k)


def mark_rows_dead(f: FrozenSegment, rows: Sequence[int]) -> None:
    """Tombstone ``rows`` of a frozen segment in place.

    The one home of the padded mark-dead idiom: the row batch pads to a
    power of two (bounded jit shapes) with pad lanes pointing at row 0's
    buckets but adding 0 to the dead counts.  Updates the live bitmap,
    the per-bucket dead counts, and ``n_live``.  Control-thread-only
    (rebinds ``f.tomb``, which queries and merge re-checks read).
    """
    k = len(rows)
    if k == 0:
        return
    pk = _pad_size(k)
    rows_p = np.zeros(pk, np.int32)
    rows_p[:k] = rows
    valid = np.zeros(pk, bool)
    valid[:k] = True
    row_buckets = f.seg.bucket_ids[jnp.asarray(rows_p)]
    f.tomb = tomb_lib.mark_dead(f.tomb, jnp.asarray(rows_p), row_buckets,
                                jnp.asarray(valid))
    f.n_live -= k


# ---------------------------------------------------------------------------
# Budgeted merges
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class MergeTask:
    """A scheduled levels merge with incremental staging state."""

    uids: List[int]
    target_level: int
    reason: str
    # staging: per chunk — source (uid, row indices), rows, ids, hashes
    src: List[Tuple[int, np.ndarray]] = dataclasses.field(
        default_factory=list)
    rows: List[np.ndarray] = dataclasses.field(default_factory=list)
    ids: List[np.ndarray] = dataclasses.field(default_factory=list)
    bids: List[np.ndarray] = dataclasses.field(default_factory=list)
    input_idx: int = 0      # cursor: which input segment
    row_off: int = 0        # cursor: next row within it
    steps: int = 0
    work_seconds: float = 0.0   # sum of this task's compact_step durations
    # worker-side speculative build of the merged segment (uid unset,
    # -1): populated by prepare_staged() once staging completes; the
    # control-thread swap then only re-checks tombstones + rewires
    prepared: Optional["FrozenSegment"] = None

    @property
    def staged_done(self) -> bool:
        return self.input_idx >= len(self.uids)

    @property
    def staged_rows(self) -> int:
        """Live rows gathered into this task's staging buffers so far."""
        return sum(len(r) for r in self.rows)


@dataclasses.dataclass
class MergeResult:
    """Outcome of a completed (swapped-in) merge.

    ``dropped`` counts dead rows reclaimed (not carried into the new
    segment).  On the classic inline path that includes rows deleted
    mid-merge; on the prepared path (worker pre-built the segment) such
    rows ride along *tombstoned* in the new segment instead — masked
    from every query exactly like a normal delete, reclaimed at the
    next merge — so ``dropped`` there counts only rows already dead
    when staged.  ``moved`` lists live rows only.
    """

    new: Optional[FrozenSegment]          # None when every row was dead
    removed_uids: List[int]
    moved: List[Tuple[int, int]]          # (ext_id, new row) pairs
    dropped: int                          # dead rows reclaimed
    steps: int
    reason: str
    seconds: float                        # accumulated step work time
    target_level: int = 0


class SegmentStack:
    """The frozen half of a streaming index: level list + merge queue.

    Owns only structure — *which* immutable segments exist, at what
    level, and what merge work is pending.  The index above it owns the
    delta, the tombstone writes, the external-id location map, and the
    decision of *when* to schedule (``CompactionPolicy``).

    Thread-safety contract (the ``CompactionDriver`` split): merge work
    divides into a *staging* half (``stage_step`` — pure reads of
    immutable segment rows into the task's private host buffers) and an
    *apply* half (``apply_staged`` — mutates the level list and swaps
    the merged segment in).  Staging may run on a background worker
    thread concurrently with inserts (delta-only), deletes (tombstone
    rebinds; the swap re-checks them), freezes (list appends), and
    queries.  ``apply_staged``, ``compact_step``, and anything that
    resets the stack (``build``/``compact``/``load_state_dict`` on the
    index above) are control-thread-only and must be mutually excluded
    from staging — the driver's lock does exactly that.
    """

    def __init__(self, phases: Optional[WorkPhases] = None) -> None:
        self.segments: List[FrozenSegment] = []
        self.tasks: List[MergeTask] = []     # FIFO; tasks[0] is active
        self._next_uid = 0
        # Monotonic structure version: bumped on every segment-list
        # change (freeze/add, merge swap).  The index above folds it
        # into its own ``version`` — the result-cache invalidation key —
        # so a cached query result can never outlive the segment list it
        # was computed against.  Tombstone writes bump the *index*
        # version (deletes go through the index, not the stack).
        self.version = 0
        # Shared work-phase accumulator (the index passes its own so the
        # numbers survive stack resets).  Every timed interval below is
        # measured ONCE and added to both ``task.work_seconds`` (the
        # per-merge total flowing into ``MergeResult.seconds``) and a
        # phase here — "stage" (gather+hash), "build" (speculative
        # prepare), "apply" (swap half).
        self.phases = phases if phases is not None else WorkPhases(
            "stage", "build", "apply", "full")

    # ------------------------------------------------------------- intro
    def next_uid(self) -> int:
        """Allocate a stack-unique segment id (stable across merges of
        other segments; never reused)."""
        u = self._next_uid
        self._next_uid += 1
        return u

    def add(self, seg: FrozenSegment) -> None:
        """Append a frozen segment to the level list."""
        self.segments.append(seg)
        self.version += 1

    def by_uid(self, uid: int) -> FrozenSegment:
        """The segment with this uid; KeyError once it merged away."""
        for s in self.segments:
            if s.uid == uid:
                return s
        raise KeyError(uid)

    # ------------------------------------------------------------- sizes
    @property
    def n_rows(self) -> int:
        """Real frozen rows: tombstoned included, pad rows excluded."""
        return sum(s.n_rows for s in self.segments)

    @property
    def n_live(self) -> int:
        """Frozen rows not tombstoned."""
        return sum(s.n_live for s in self.segments)

    @property
    def n_dead(self) -> int:
        """Tombstoned frozen rows (reclaimed at the next merge)."""
        return self.n_rows - self.n_live

    def level_counts(self) -> Dict[int, int]:
        """level -> #segments, the ``CompactionPolicy`` trigger input."""
        out: Dict[int, int] = {}
        for s in self.segments:
            out[s.level] = out.get(s.level, 0) + 1
        return out

    def pending_uids(self) -> set:
        """Uids that are inputs of a queued merge (can't re-schedule)."""
        return {u for t in self.tasks for u in t.uids}

    @property
    def has_work(self) -> bool:
        """True while any merge is queued (``compact_step`` will act)."""
        return bool(self.tasks)

    @property
    def staged_ready(self) -> bool:
        """The head merge is fully staged and waits on ``apply_staged``."""
        return bool(self.tasks) and self.tasks[0].staged_done

    @property
    def staged_rows(self) -> int:
        """Rows currently held in staging buffers across queued merges."""
        return sum(t.staged_rows for t in self.tasks)

    # --------------------------------------------------------- scheduling
    def schedule(self, uids: Sequence[int], target_level: int,
                 reason: str) -> bool:
        """Queue a merge of ``uids`` unless any is already pending."""
        uids = [u for u in uids]
        if not uids or (set(uids) & self.pending_uids()):
            return False
        self.tasks.append(MergeTask(uids=uids, target_level=target_level,
                                    reason=reason))
        return True

    # -------------------------------------------------------------- steps
    def compact_step(self, budget_rows: int, bucket_fn, params,
                     num_buckets: int, m: int) -> Optional[MergeResult]:
        """Advance the active merge by one bounded step.

        A staging step gathers + hashes at most ``budget_rows`` live
        rows; once staging is complete the *next* step runs the fused
        build over the staged rows and swaps the merged segment in.
        Returns a ``MergeResult`` when a merge completed this step,
        else None.  No-op (returns None) when nothing is queued.
        """
        if not self.tasks:
            return None
        task = self.tasks[0]
        task.steps += 1
        res = None
        if not task.staged_done:
            with time_block(phases=self.phases, phase="stage") as tb:
                self._stage(task, max(int(budget_rows), 1))
            task.work_seconds += tb.elapsed
        if task.staged_done:
            # tiny merges finish in the same step when the budget
            # covered every row — the build below is their swap
            with time_block(phases=self.phases, phase="apply") as tb:
                res = self._finalize(task, num_buckets, m, bucket_fn,
                                     params)
            task.work_seconds += tb.elapsed
        if res is not None:
            res.seconds = task.work_seconds
        return res

    def stage_step(self, budget_rows: int) -> str:
        """Advance ONLY the staging half of the head merge (no swap).

        Safe to call from a background worker thread: it reads immutable
        segment rows into the task's private host buffers and never
        touches the level list.  Returns ``"idle"`` (nothing queued),
        ``"staging"`` (more gathers remain), or ``"ready"`` (staging is
        complete; a control-thread ``apply_staged`` must swap it in).
        """
        if not self.tasks:
            return "idle"
        task = self.tasks[0]
        if task.staged_done:
            return "ready"
        task.steps += 1
        with time_block(phases=self.phases, phase="stage") as tb:
            self._stage(task, max(int(budget_rows), 1))
        task.work_seconds += tb.elapsed
        return "ready" if task.staged_done else "staging"

    def prepare_staged(self, bucket_fn, params, num_buckets: int,
                       m: int) -> bool:
        """Speculatively build the head merge's output segment.

        Worker-thread-safe: once staging is complete the task's buffers
        are immutable, so the fused ``build_tables`` over them can run
        off-thread (the expensive half of a swap).  The control-thread
        ``apply_staged`` then only re-checks tombstones — rows deleted
        since staging are *marked dead in the prepared segment* rather
        than rebuilt away — assigns the uid, and swaps lists.  Returns
        True when a build ran (False: nothing staged-ready, already
        prepared, or zero staged rows — the classic path handles those).
        """
        if not self.tasks:
            return False
        task = self.tasks[0]
        if not task.staged_done or task.prepared is not None \
                or not task.rows:
            return False
        with time_block(phases=self.phases, phase="build") as tb:
            x = np.concatenate(task.rows, axis=0)
            ids = np.concatenate(task.ids, axis=0)
            bids = np.concatenate(task.bids, axis=0)
            task.prepared = freeze_segment(
                x, ids, bucket_fn, params, num_buckets, m,
                uid=-1, level=task.target_level, bucket_rows=bids)
        task.work_seconds += tb.elapsed
        return True

    def apply_staged(self, bucket_fn, params, num_buckets: int,
                     m: int) -> Optional[MergeResult]:
        """CONTROL-THREAD ONLY: swap a fully-staged head merge in.

        Runs the mid-merge delete re-check, the fused build over the
        surviving staged rows, and the atomic level-list swap.  Returns
        the ``MergeResult``, or None when no head merge is fully staged
        (nothing happens — staging stays with ``stage_step``).
        """
        if not self.tasks or not self.tasks[0].staged_done:
            return None
        task = self.tasks[0]
        task.steps += 1
        with time_block(phases=self.phases, phase="apply") as tb:
            res = self._finalize(task, num_buckets, m, bucket_fn, params)
        task.work_seconds += tb.elapsed
        res.seconds = task.work_seconds
        return res

    def _stage(self, task: MergeTask, budget: int) -> None:
        left = budget
        while left > 0 and not task.staged_done:
            seg = self.by_uid(task.uids[task.input_idx])
            if task.row_off >= seg.n_rows:
                task.input_idx += 1
                task.row_off = 0
                continue
            hi = min(seg.n_rows, task.row_off + left)
            idx = np.arange(task.row_off, hi)
            live = np.asarray(seg.tomb.live[task.row_off:hi])
            idx = idx[live]
            if len(idx):
                task.src.append((seg.uid, idx))
                task.rows.append(
                    np.asarray(seg.seg.x[task.row_off:hi])[live])
                task.ids.append(
                    np.asarray(seg.seg.ids[task.row_off:hi])[live])
                # rows keep the hashes they froze with (params are
                # immutable), so merges never re-hash — the budget
                # bounds a pure gather
                task.bids.append(np.asarray(
                    seg.seg.bucket_ids[task.row_off:hi])[live]
                    .astype(np.int32))
            left -= hi - task.row_off
            task.row_off = hi

    def _finalize(self, task: MergeTask, num_buckets: int, m: int,
                  bucket_fn, params) -> MergeResult:
        if task.prepared is not None:
            return self._swap_prepared(task)
        # Re-check staged rows against the *current* tombstones: deletes
        # that landed mid-merge must not resurrect at swap time.
        keep_x, keep_ids, keep_bids = [], [], []
        for (uid, idx), rows, ids, bids in zip(task.src, task.rows,
                                               task.ids, task.bids):
            seg = self.by_uid(uid)
            live = np.asarray(seg.tomb.live)[idx]
            if live.any():
                keep_x.append(rows[live])
                keep_ids.append(ids[live])
                keep_bids.append(bids[live])
        total_in = sum(s.n_rows for s in self.segments
                       if s.uid in task.uids)
        self.tasks.pop(0)
        removed = [u for u in task.uids]
        self.segments = [s for s in self.segments if s.uid not in removed]
        self.version += 1
        if not keep_x:
            return MergeResult(new=None, removed_uids=removed, moved=[],
                               dropped=total_in, steps=task.steps,
                               reason=task.reason,
                               seconds=task.work_seconds,
                               target_level=task.target_level)
        x = np.concatenate(keep_x, axis=0)
        ids = np.concatenate(keep_ids, axis=0)
        bids = np.concatenate(keep_bids, axis=0)
        new = freeze_segment(x, ids, bucket_fn, params, num_buckets, m,
                             uid=self.next_uid(), level=task.target_level,
                             bucket_rows=bids)
        self.add(new)
        moved = [(int(e), i) for i, e in enumerate(ids.tolist())]
        return MergeResult(new=new, removed_uids=removed, moved=moved,
                           dropped=total_in - len(ids), steps=task.steps,
                           reason=task.reason, seconds=task.work_seconds,
                           target_level=task.target_level)

    def _swap_prepared(self, task: MergeTask) -> MergeResult:
        """Swap in a worker-prepared segment: the control thread's share
        is the mid-merge delete re-check (deaths since staging become
        tombstones in the new segment — same mask a normal delete
        leaves, reclaimed at the next merge), the uid assignment, and
        the list swap.  No build runs here."""
        new = task.prepared
        dead_pos: List[int] = []      # new-segment rows deleted mid-merge
        moved: List[Tuple[int, int]] = []
        off = 0
        for (uid, idx), ids in zip(task.src, task.ids):
            live_now = np.asarray(self.by_uid(uid).tomb.live)[idx]
            pos = off + np.arange(len(idx))
            dead_pos.extend(pos[~live_now].tolist())
            moved.extend(zip(ids[live_now].tolist(),
                             pos[live_now].tolist()))
            off += len(idx)
        total_in = sum(s.n_rows for s in self.segments
                       if s.uid in task.uids)
        self.tasks.pop(0)
        removed = [u for u in task.uids]
        self.segments = [s for s in self.segments if s.uid not in removed]
        self.version += 1
        new.uid = self.next_uid()
        mark_rows_dead(new, dead_pos)
        self.add(new)
        return MergeResult(new=new, removed_uids=removed, moved=moved,
                           dropped=total_in - off, steps=task.steps,
                           reason=task.reason, seconds=task.work_seconds,
                           target_level=task.target_level)
