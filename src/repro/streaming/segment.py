"""The immutable main segment: corpus rows + CSR tables + per-bucket HLLs.

A thin wrapper over the static core's ``build_tables`` fusion
(Algorithm 1).  Rows are addressed by *internal* position (0..n-1) —
that is the id the HLL registers are keyed on, which keeps table/shard
merges exact — and mapped to external document ids via ``ids``.
``bucket_ids`` is retained so deletes can update the per-bucket
tombstone counts without re-hashing.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.lsh.tables import LSHTables, build_tables

__all__ = ["MainSegment", "build_main"]


@dataclasses.dataclass
class MainSegment:
    x: jax.Array            # (n, d) corpus rows
    ids: jax.Array          # (n,) int32 external doc ids
    bucket_ids: jax.Array   # (n, L) int32 per-table buckets
    tables: LSHTables

    @property
    def n(self) -> int:
        return int(self.x.shape[0])


def build_main(x: jax.Array, ext_ids: jax.Array, bucket_fn, params,
               num_buckets: int, m: int, chunk: int = 65536) -> MainSegment:
    """Algorithm 1 on a row block: chunked hashing + fused table build."""
    x = jnp.asarray(x)
    n = x.shape[0]
    bids = [bucket_fn(params, x[lo:lo + chunk]) for lo in range(0, n, chunk)]
    bucket_ids = jnp.concatenate(bids, axis=0)          # (n, L)
    tables = build_tables(jnp.arange(n, dtype=jnp.int32), bucket_ids,
                          num_buckets, m)
    return MainSegment(x=x, ids=jnp.asarray(ext_ids, jnp.int32),
                       bucket_ids=bucket_ids.astype(jnp.int32),
                       tables=tables)
