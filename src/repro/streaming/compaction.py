"""Compaction policy + bookkeeping for the streaming index.

Compaction folds the delta segment and drops tombstoned rows by
rebuilding the main segment through the existing ``build_tables`` fusion
— the one batch pass the paper's Algorithm 1 already optimizes.  It is
triggered by either pressure signal:

  * delta fill      — the fixed-capacity delta is (nearly) full, so
                      inserts would block;
  * tombstone ratio — dead main rows waste gather bandwidth and widen
                      the gap between the HLL estimate and live reality.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

__all__ = ["CompactionPolicy", "CompactionStats"]


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    delta_fill: float = 1.0        # compact when delta count/capacity >= this
    tombstone_ratio: float = 0.25  # compact when dead/main >= this

    def reason(self, *, delta_count: int, delta_capacity: int,
               n_main: int, n_dead: int) -> Optional[str]:
        """Why compaction should run now, or None."""
        if delta_capacity and delta_count / delta_capacity >= self.delta_fill:
            return "delta_full"
        if n_main and n_dead / n_main >= self.tombstone_ratio:
            return "tombstones"
        return None


@dataclasses.dataclass
class CompactionStats:
    compactions: int = 0
    last_reason: Optional[str] = None
    last_seconds: float = 0.0
    total_seconds: float = 0.0  # cumulative wall-clock spent compacting
    rows_dropped: int = 0       # tombstoned rows reclaimed, cumulative

    def record(self, reason: str, t0: float, dropped: int) -> None:
        self.compactions += 1
        self.last_reason = reason
        self.last_seconds = time.perf_counter() - t0
        self.total_seconds += self.last_seconds
        self.rows_dropped += int(dropped)

    def as_dict(self) -> Dict[str, object]:
        return {"compactions": self.compactions,
                "last_reason": self.last_reason,
                "last_seconds": self.last_seconds,
                "total_seconds": self.total_seconds,
                "rows_dropped": self.rows_dropped}
