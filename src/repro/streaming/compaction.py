"""Tiered compaction policy, merge-time placement, per-level bookkeeping.

The streaming index keeps its frozen segments in an LSM-style level
stack (``streaming.segment.SegmentStack``).  Three kinds of maintenance
work exist, and this module decides when each runs:

  * freeze  — the fixed-capacity delta is (nearly) full; its live rows
              are sealed into an immutable level-0 minor segment through
              the ``build_tables`` fusion.  O(delta_capacity), cheap.
  * merge   — a level holds >= ``fanout`` segments; they fuse into one
              segment at the next level.  Each row is merged O(log n)
              times over its lifetime instead of once per delta fill.
  * full    — the global tombstone ratio crossed ``tombstone_ratio``;
              every frozen segment merges into one, dropping dead rows.

Merges are *scheduled*, not run inline: the index materializes them as
merge-task work items whose gather cost is paid in bounded
``compact_step(budget_rows)`` increments off the query path.  With
``step_rows=None`` the index drains scheduled merges synchronously
(the simple single-host default); the serving layer sets ``step_rows``
and either interleaves ticks between query batches or — fully async —
hands the staging half to a ``streaming.driver.CompactionDriver``
worker thread, keeping only the atomic swap on the control thread
(docs/compaction.md walks the whole lifecycle).

Thread-safety: ``CompactionPolicy`` is frozen/stateless — safe from
any thread.  ``CompactionStats`` is written from the control thread
except ``record_step``, which the driver's worker also calls per
staging gather; it is a bare counter increment (GIL-atomic), and every
other mutation (``record_merge``, ``record_freeze``, ``record``) stays
control-thread-only, so ``as_dict()`` snapshots are always coherent.

For the mesh-sharded index a merge is also the one moment rows can
*move between shards* (the surviving rows sit in host-side staging
buffers anyway).  ``PlacementPolicy`` decides each surviving row's
target shard at swap time:

  * ``keep_local``   — rows stay on their origin shard (the PR 2/3
                       behavior; zero movement, skew persists forever)
  * ``round_robin``  — rows are dealt over shards in order, ignoring
                       current load (cheap, eventually-even)
  * ``load_balance`` — water-fill against per-shard live-row counts so
                       the post-merge max shard load is minimized while
                       moving as few rows as possible

Skew matters because sharded levels pad every shard to the *max* shard's
row count (one common ``n_pad`` per level keeps the level a single
stacked leaf): a shard hoarding rows inflates every shard's padded scan,
so the per-query cost estimate — and the latency it predicts — degrades
globally, exactly the density-skew failure mode the HLL estimator
exists to detect.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

__all__ = ["CompactionPolicy", "CompactionStats", "PlacementPolicy",
           "KeepLocalPlacement", "RoundRobinPlacement",
           "LoadBalancePlacement", "make_placement_policy",
           "water_fill_counts"]


# ---------------------------------------------------------------------------
# Merge-time shard placement
# ---------------------------------------------------------------------------
def water_fill_counts(base_load: np.ndarray, k: int) -> np.ndarray:
    """Split ``k`` fungible rows over shards to minimize the max load.

    Args:
      base_load: (S,) int — live rows each shard already holds outside
        the rows being placed.
      k: number of rows to place.

    Returns (S,) int counts summing to ``k``: the classic water-fill —
    raise the lowest-loaded shards to a common level, ties broken by
    shard order (deterministic).
    """
    base = np.asarray(base_load, np.int64)
    k = int(k)
    if k <= 0:
        return np.zeros_like(base)
    lo, hi = int(base.min()), int(base.max()) + k

    def deficit(level: int) -> int:
        return int(np.maximum(0, level - base).sum())

    while lo < hi:                      # largest level with deficit <= k
        mid = (lo + hi + 1) // 2
        if deficit(mid) <= k:
            lo = mid
        else:
            hi = mid - 1
    counts = np.maximum(0, lo - base)
    rem = k - int(counts.sum())
    order = np.argsort(base + counts, kind="stable")
    counts[order[:rem]] += 1
    return counts


class PlacementPolicy:
    """Assigns each surviving row of a staged merge to a target shard.

    Subclass and override ``assign`` for custom placement; the sharded
    index calls it once per completed merge, at swap time, after the
    mid-merge delete re-check (so only truly-live rows are placed).
    ``assign`` always runs on the control thread — even under the async
    ``CompactionDriver`` the swap (and with it placement) never moves
    off-thread, because ``base_load`` must be the live per-shard loads
    at the moment of the swap.  Policies may therefore keep state
    without locking, but must not block: a slow ``assign`` stalls the
    serving thread's drain.
    """

    name = "custom"

    def assign(self, origins: np.ndarray, base_load: np.ndarray,
               shards: int) -> np.ndarray:
        """Target shard per surviving merge row.

        Args:
          origins: (k,) int — each row's current (origin) shard.
          base_load: (S,) int — per-shard live rows *outside* this merge
            (remaining levels + delta), the load the placed rows add to.
          shards: shard count S.

        Returns (k,) int targets in [0, S).
        """
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.name!r})"


class KeepLocalPlacement(PlacementPolicy):
    """Rows never leave their shard (the pre-rebalancing invariant)."""

    name = "keep_local"

    def assign(self, origins, base_load, shards):
        return np.asarray(origins, np.int64)


class RoundRobinPlacement(PlacementPolicy):
    """Deal rows over shards in order, ignoring load and origin."""

    name = "round_robin"

    def assign(self, origins, base_load, shards):
        k = len(np.asarray(origins))
        return np.arange(k, dtype=np.int64) % int(shards)


class LoadBalancePlacement(PlacementPolicy):
    """Water-fill to the per-shard quota that minimizes max live load,
    keeping rows local whenever their origin shard has quota left (so
    movement is the minimum the quota permits)."""

    name = "load_balance"

    def assign(self, origins, base_load, shards):
        origins = np.asarray(origins, np.int64)
        k = len(origins)
        quota = water_fill_counts(base_load, k)
        targets = np.empty(k, np.int64)
        leftovers: List[int] = []
        for s in range(int(shards)):
            rows_s = np.nonzero(origins == s)[0]
            take = min(len(rows_s), int(quota[s]))
            targets[rows_s[:take]] = s
            quota[s] -= take
            leftovers.extend(rows_s[take:].tolist())
        if leftovers:
            fill = np.repeat(np.arange(int(shards)), quota)
            targets[np.asarray(leftovers, np.int64)] = fill
        return targets


_PLACEMENTS = {p.name: p for p in (KeepLocalPlacement, RoundRobinPlacement,
                                   LoadBalancePlacement)}


def make_placement_policy(spec: Union[str, PlacementPolicy, None]
                          ) -> PlacementPolicy:
    """Resolve a placement spec: a policy instance passes through, a
    name (``keep_local`` / ``round_robin`` / ``load_balance``) or None
    (-> ``keep_local``) constructs the built-in."""
    if spec is None:
        return KeepLocalPlacement()
    if isinstance(spec, PlacementPolicy):
        return spec
    try:
        return _PLACEMENTS[spec]()
    except KeyError:
        raise ValueError(
            f"unknown placement policy {spec!r}; "
            f"expected one of {sorted(_PLACEMENTS)}") from None


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    delta_fill: float = 1.0        # freeze when delta count/capacity >= this
    tombstone_ratio: float = 0.25  # full merge when dead/frozen-rows >= this
    fanout: int = 4                # merge a level when it holds >= fanout segs
    step_rows: Optional[int] = None  # None: drain merges synchronously;
    #                                  set: only budgeted compact_step() runs

    # ------------------------------------------------------------ triggers
    def freeze_reason(self, *, delta_count: int,
                      delta_capacity: int) -> Optional[str]:
        """Why the delta should freeze into a level-0 segment now."""
        if delta_capacity and delta_count / delta_capacity >= self.delta_fill:
            return "delta_full"
        return None

    def wants_full_merge(self, *, n_rows: int, n_dead: int) -> bool:
        """Global tombstone pressure: fold every level, drop dead rows."""
        return bool(n_rows) and n_dead / n_rows >= self.tombstone_ratio

    def merge_levels(self, level_counts: Dict[int, int]) -> List[int]:
        """Levels whose segment count overflowed (ascending).

        Fanout is clamped to >= 2: merging single-segment levels would
        cascade forever (every merge re-creates a one-segment level).
        """
        fanout = max(self.fanout, 2)
        return sorted(k for k, c in level_counts.items() if c >= fanout)

    def plan_merges(self, *, level_counts: Dict[int, int], n_rows: int,
                    n_dead: int, n_live: int, unit: int,
                    can_full: bool) -> List[Tuple[str, Optional[int], int]]:
        """The one merge-decision tree, shared by the single-host and
        sharded indexes: ``[(reason, source_level | None, target)]``.

        ``level_counts``/``n_*`` must already exclude segments that are
        inputs of a pending merge; ``can_full`` says no segment is
        pending (a tombstone full-merge needs every segment).  Tombstone
        pressure wins over level overflow — the full merge subsumes it.
        """
        if n_dead > 0 and can_full and self.wants_full_merge(
                n_rows=n_rows, n_dead=n_dead):
            return [("tombstones", None, self.level_for(n_live, unit))]
        return [("level_overflow", lv, lv + 1)
                for lv in self.merge_levels(level_counts)]

    def level_for(self, n_rows: int, unit: int) -> int:
        """Nominal level of a segment of ``n_rows`` built in one piece
        (``unit`` = the freeze granularity, i.e. the delta capacity)."""
        level, budget = 0, max(int(unit), 1)
        while n_rows > budget and level < 48:
            level += 1
            budget *= max(self.fanout, 2)
        return level

    # ------------------------------------------------- legacy entry point
    def reason(self, *, delta_count: int, delta_capacity: int,
               n_main: int, n_dead: int) -> Optional[str]:
        """Pre-stack trigger surface (kept for external callers)."""
        r = self.freeze_reason(delta_count=delta_count,
                               delta_capacity=delta_capacity)
        if r:
            return r
        if self.wants_full_merge(n_rows=n_main, n_dead=n_dead):
            return "tombstones"
        return None


@dataclasses.dataclass
class CompactionStats:
    """Cumulative maintenance counters, shared by both streaming
    indexes and surfaced through ``index_stats()``.

    ``steps`` counts budgeted advances — serving-thread ticks *and*
    driver-worker staging gathers (``record_step`` is the one method a
    worker thread may call; everything else is control-thread-only).
    ``record_merge``'s ``seconds`` is accumulated *work* time wherever
    it ran — under the async driver that is mostly worker time, so it
    no longer approximates serving-thread stall; the ``BENCH_async``
    bench measures that directly instead.
    """

    compactions: int = 0        # completed merges + full compactions
    freezes: int = 0            # delta -> level-0 seals
    last_reason: Optional[str] = None
    last_seconds: float = 0.0
    total_seconds: float = 0.0  # cumulative wall-clock spent compacting
    rows_dropped: int = 0       # tombstoned rows reclaimed, cumulative
    rows_frozen: int = 0
    rows_moved: int = 0         # rows rebalanced across shards at merges
    steps: int = 0              # compact_step() calls that advanced a merge
    last_merge_steps: int = 0   # steps the most recent merge took
    merges_per_level: Dict[int, int] = dataclasses.field(
        default_factory=dict)           # target level -> completed merges
    rows_merged_per_level: Dict[int, int] = dataclasses.field(
        default_factory=dict)           # target level -> rows written

    def record(self, reason: str, t0: float, dropped: int) -> None:
        self.compactions += 1
        self.last_reason = reason
        self.last_seconds = time.perf_counter() - t0
        self.total_seconds += self.last_seconds
        self.rows_dropped += int(dropped)

    def record_freeze(self, rows: int) -> None:
        self.freezes += 1
        self.rows_frozen += int(rows)

    def record_step(self) -> None:
        self.steps += 1

    def record_merge(self, level: int, rows: int, steps: int,
                     seconds: float, dropped: int,
                     reason: str = "merge", moved: int = 0) -> None:
        """``seconds`` is the merge's accumulated *work* time (the sum of
        its compact_step durations) — not schedule-to-swap wall clock,
        which under budgeted mode would count all the serving time
        interleaved between steps as time spent compacting.  ``moved``
        counts rows whose placement target differed from their origin
        shard (always 0 on single-host merges)."""
        self.compactions += 1
        self.last_reason = reason
        self.last_seconds = float(seconds)
        self.total_seconds += self.last_seconds
        self.rows_dropped += int(dropped)
        self.rows_moved += int(moved)
        self.last_merge_steps = int(steps)
        self.merges_per_level[int(level)] = (
            self.merges_per_level.get(int(level), 0) + 1)
        self.rows_merged_per_level[int(level)] = (
            self.rows_merged_per_level.get(int(level), 0) + int(rows))

    def as_dict(self) -> Dict[str, object]:
        return {"compactions": self.compactions,
                "freezes": self.freezes,
                "last_reason": self.last_reason,
                "last_seconds": self.last_seconds,
                "total_seconds": self.total_seconds,
                "rows_dropped": self.rows_dropped,
                "rows_frozen": self.rows_frozen,
                "rows_moved": self.rows_moved,
                "compact_steps": self.steps,
                "last_merge_steps": self.last_merge_steps,
                "merges_per_level": dict(self.merges_per_level),
                "rows_merged_per_level": dict(self.rows_merged_per_level)}
