"""DynamicHybridIndex — incremental inserts/deletes over the static core.

Segment architecture (LSM, multi-level):

  * delta segment  — fixed-capacity append-only buffers
    (``streaming.delta``); inserts are one fused ``.at[]`` scatter, so
    repeated same-size inserts never retrace.  Counts are exact.
  * segment stack  — immutable frozen segments arranged in levels
    (``streaming.segment.SegmentStack``).  When the delta fills it is
    *frozen* into a level-0 minor segment (CSR ``LSHTables`` +
    per-bucket HLLs over just the delta rows — O(delta_capacity), the
    older data is untouched); a tiered ``CompactionPolicy`` merges a
    level into the next when it overflows, so compaction cost
    amortizes O(log n)-style instead of O(n) per delta fill.
  * tombstones     — per-segment live bitmap + per-bucket dead counts;
    deletes never mutate tables.

Merges run *off the query path*: they are staged in bounded
``compact_step(budget_rows)`` increments (gather + hash at most
``budget_rows`` rows per step) and the merged segment swaps in
atomically; queries are served from the old level list until then.
With ``CompactionPolicy.step_rows=None`` (default) scheduled merges
drain synchronously after each mutation — the serving layer sets
``step_rows`` and ticks ``compact_step`` between query batches.

Queries hand the whole stack to the shared ``QueryEngine``
(``core.engine``): every frozen segment as a tombstone-aware
``TableSegment`` (corrected estimates, dead rows masked after search,
*external* ids reported), the delta as the exact ``DeltaView``.  A
mixed insert/delete workload therefore reports exactly the candidates
a fresh ``HybridLSHIndex.build()`` on the surviving corpus would (same
family parameters, cap permitting) — regardless of how many levels
exist or how far a pending merge has progressed.  ``num_probes > 1``
routes the multi-probe bucket set through the same path (SimHash
only).  The mesh-sharded variant lives in ``streaming.sharded``.
"""
from __future__ import annotations

import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import CostModel
from repro.core.engine import (QueryEngine, QueryResult, RouteEstimate,
                               TableSegment, _pad_size)
from repro.core.lsh.families import bucket_fn_for
from repro.core.lsh.tables import LSHTables
from repro.obs import Observability
from repro.obs.metrics import WorkPhases
from repro.streaming import delta as delta_lib
from repro.streaming import tombstones as tomb_lib
from repro.streaming.compaction import CompactionPolicy, CompactionStats
from repro.streaming.segment import (FrozenSegment, MainSegment,
                                     SegmentStack, freeze_segment,
                                     frozen_digests, mark_rows_dead)

__all__ = ["DynamicHybridIndex"]

_pad_pow2 = _pad_size                # same pow2 padding as the router groups


class DynamicHybridIndex:
    """Streaming Hybrid LSH index: insert / delete / freeze / merge / query.

    Shape conventions: corpus rows are (n, d); external ids are int64
    host-side, stored int32 on device; per-row buckets are (n, L) in
    [0, num_buckets), with *pad rows hashed to bucket num_buckets* —
    one past the bucket space, dropped exactly by the CSR/HLL
    reductions — so padded builds and padded query groups stay exact
    (see ``streaming.segment`` / docs/architecture.md).
    """

    def __init__(self, family, *, num_buckets: int, m: int = 64,
                 cap: int = 64, delta_capacity: int = 4096,
                 cost_model: CostModel = CostModel(alpha=1.0, beta=10.0),
                 policy: CompactionPolicy = CompactionPolicy(),
                 key: jax.Array | int = 0, impl: Optional[str] = None,
                 obs: Optional[Observability] = None,
                 engine: Optional[QueryEngine] = None):
        """Args:
          family: LSH family (``make_family``); owns metric + hashes.
          num_buckets: buckets per table B.
          m: HLL registers per bucket.
          cap: LSH candidate verification cap per (query, table).
          delta_capacity: delta slots before a freeze.
          cost_model: Algorithm 2 cost constants (alpha, beta).
          policy: freeze/merge triggers (``CompactionPolicy``).
          key: PRNG key (or int seed) for the family parameters.
          impl: kernel impl override (e.g. ``"pallas_interpret"``).
          obs: observability bundle (tracer + event log + registry);
            default is a fresh disabled bundle — no cost unless asked.
          engine: a shared ``QueryEngine`` (multi-tenant collections
            pass one so every tenant routes through the same engine +
            tracer); default builds a private one from ``cost_model``.
        """
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self.family = family
        self.params = family.init(key)
        self.num_buckets = int(num_buckets)
        self.m = int(m)
        self.cap = int(cap)
        self.delta_capacity = int(delta_capacity)
        self.cost_model = cost_model
        self.policy = policy
        self.impl = impl
        self.obs = obs if obs is not None else Observability.disabled()
        # Index-owned so the numbers survive stack resets
        # (build/compact/load_state_dict replace the SegmentStack).
        self.phases = WorkPhases("stage", "build", "apply", "full")
        self._engine = engine if engine is not None else QueryEngine(
            cost_model, impl=impl, tracer=self.obs.tracer)
        # shared across collections: bucket_fn_for is lru-cached on the
        # (hashable) family, so equal families reuse one jitted hash
        self._bucket_fn = bucket_fn_for(self.family, self.num_buckets)

        self.stack = SegmentStack(phases=self.phases)
        self.delta: Optional[delta_lib.DeltaSegment] = None
        self.stats = CompactionStats()
        # Result-cache invalidation: ``version`` must change whenever a
        # query could report differently.  Stack structure changes bump
        # ``stack.version``; delta inserts, deletes (tombstones + delta
        # kills), and wholesale stack replacements bump the base here.
        self._version_base = 0
        # Host bookkeeping: ext id -> ("m", uid, row) | ("d", slot).
        self._loc: Dict[int, tuple] = {}
        self._next_id = 0
        self._n_delta_live = 0
        self._inserts = 0
        self._deletes = 0

    # ------------------------------------------------------------- sizes
    @property
    def n(self) -> int:
        """Live document count (frozen live + delta live)."""
        return self.stack.n_live + self._n_delta_live

    @property
    def n_dead(self) -> int:
        return self.stack.n_dead

    @property
    def version(self) -> int:
        """Monotonic mutation version — the result-cache key component.

        Changes on every insert, delete, freeze, merge swap, and full
        rebuild; equal versions guarantee identical reported sets for
        the same (query, radius).  Monotone across stack replacements:
        ``_fold_version`` banks the outgoing stack's count first.
        """
        return self._version_base + self.stack.version

    def _fold_version(self) -> None:
        """Bank the current stack's version before replacing it, so the
        combined version can never run backwards when a fresh stack
        (version 0) is installed by build/compact/load_state_dict."""
        self._version_base += self.stack.version + 1

    # ------------------------------------------------- compat properties
    @property
    def main(self) -> Optional[MainSegment]:
        """The sole frozen segment, when the stack holds exactly one
        (the pre-stack "main segment" view; None otherwise)."""
        if len(self.stack.segments) == 1:
            return self.stack.segments[0].seg
        return None

    @property
    def tomb(self) -> Optional[tomb_lib.Tombstones]:
        if len(self.stack.segments) == 1:
            return self.stack.segments[0].tomb
        return None

    # ------------------------------------------------------------- build
    def build(self, x: jax.Array,
              ids: Optional[Sequence[int]] = None) -> "DynamicHybridIndex":
        """Initial batch build (Algorithm 1); returns self.

        Args: ``x`` (n, d) corpus rows; ``ids`` optional (n,) unique
        external ids (default 0..n-1).  Replaces any existing state.
        """
        x = np.asarray(x)
        if ids is None:
            ids = np.arange(x.shape[0], dtype=np.int64)
        else:
            ids = np.asarray(ids, np.int64)
            assert len(set(ids.tolist())) == len(ids), "duplicate ids"
        self._fold_version()
        self.stack = SegmentStack(phases=self.phases)
        self._loc = {}
        if x.shape[0] > 0:
            self._add_frozen(x, ids,
                             level=self.policy.level_for(
                                 x.shape[0], self.delta_capacity))
        self._reset_delta(x.shape[1] if x.ndim > 1 else 1, x.dtype)
        self._next_id = int(ids.max()) + 1 if len(ids) else 0
        return self

    def _add_frozen(self, x: np.ndarray, ext_ids: np.ndarray, level: int,
                    bucket_rows: Optional[np.ndarray] = None
                    ) -> FrozenSegment:
        seg = freeze_segment(x, np.asarray(ext_ids, np.int64),
                             self._bucket_fn, self.params,
                             self.num_buckets, self.m,
                             uid=self.stack.next_uid(), level=level,
                             bucket_rows=bucket_rows)
        self.stack.add(seg)
        for i, e in enumerate(np.asarray(ext_ids).tolist()):
            self._loc[int(e)] = ("m", seg.uid, i)
        return seg

    def _reset_delta(self, d: int, dtype) -> None:
        self.delta = delta_lib.make_delta(self.delta_capacity, d,
                                          self.family.L, dtype)
        self._n_delta_live = 0

    # ------------------------------------------------------------ insert
    def insert(self, rows: jax.Array,
               ids: Optional[Sequence[int]] = None) -> np.ndarray:
        """Append documents; returns their external ids as (k,) int64.

        Args: ``rows`` (k, d); ``ids`` optional (k,) unused external ids
        (KeyError on duplicates), default continues the running counter.
        Splits the batch by remaining delta capacity, freezing the delta
        into a level-0 segment between chunks when it fills — inserts
        never wait on a rebuild of older data.
        """
        rows = jnp.asarray(rows)
        if rows.shape[0] == 0:
            return np.zeros((0,), np.int64)
        if self.delta is None:  # first contact: empty index, delta-only
            self._reset_delta(rows.shape[1], rows.dtype)
        if ids is None:
            ids = np.arange(self._next_id, self._next_id + rows.shape[0],
                            dtype=np.int64)
        else:
            ids = np.asarray(ids, np.int64)
            if len(set(ids.tolist())) != len(ids):
                raise KeyError("duplicate ids within insert batch")
        for e in ids.tolist():
            if e in self._loc:
                raise KeyError(f"id {e} already indexed")
        lo = 0
        while lo < rows.shape[0]:
            free = self.delta.capacity - int(self.delta.count)
            if free == 0:
                self._freeze("delta_full")
                free = self.delta.capacity
            take = min(free, rows.shape[0] - lo)
            self._insert_chunk(rows[lo:lo + take], ids[lo:lo + take])
            lo += take
        self._next_id = max(self._next_id, int(ids.max()) + 1)
        self._maybe_compact()
        return ids

    def _insert_chunk(self, rows: jax.Array, ids: np.ndarray) -> None:
        k = rows.shape[0]
        pk = _pad_pow2(k)
        pad = [(0, pk - k)] + [(0, 0)] * (rows.ndim - 1)
        rows_p = jnp.pad(rows, pad)
        bids = self._bucket_fn(self.params, rows_p)     # (pk, L)
        ids_p = np.zeros(pk, np.int32)
        ids_p[:k] = ids
        valid = np.zeros(pk, bool)
        valid[:k] = True
        base = int(self.delta.count)
        self.delta = delta_lib.insert(self.delta, rows_p, bids,
                                      jnp.asarray(ids_p),
                                      jnp.asarray(valid))
        for i, e in enumerate(ids.tolist()):
            self._loc[int(e)] = ("d", base + i)
        self._n_delta_live += k
        self._inserts += k
        self._version_base += 1

    # ------------------------------------------------------------ delete
    def delete(self, ids: Iterable[int], strict: bool = False) -> int:
        """Tombstone documents by external id; returns #removed.

        Unknown (or already-deleted) ids are skipped unless ``strict``.
        """
        by_uid: Dict[int, List[int]] = {}
        delta_slots: List[int] = []
        for e in ids:
            loc = self._loc.pop(int(e), None)
            if loc is None:
                if strict:
                    raise KeyError(e)
                continue
            if loc[0] == "d":
                delta_slots.append(loc[1])
            else:
                by_uid.setdefault(loc[1], []).append(loc[2])
        removed = 0
        for uid, rows in by_uid.items():
            mark_rows_dead(self.stack.by_uid(uid), rows)
            removed += len(rows)
        if delta_slots:
            k = len(delta_slots)
            pk = _pad_pow2(k)
            slots_p = np.zeros(pk, np.int32)
            slots_p[:k] = delta_slots
            valid = np.zeros(pk, bool)
            valid[:k] = True
            self.delta = delta_lib.kill(self.delta, jnp.asarray(slots_p),
                                        jnp.asarray(valid))
            self._n_delta_live -= k
            removed += k
        self._deletes += removed
        if removed:
            self._version_base += 1
        self._maybe_compact()
        return removed

    # --------------------------------------------------------- compaction
    def _freeze(self, reason: str) -> None:
        """Seal the delta's live rows into a level-0 minor segment.

        O(delta_capacity): the delta already carries its hashes, so the
        freeze is one fused ``build_tables`` over at most capacity rows.
        """
        if self.delta is None or int(self.delta.count) == 0:
            return
        c = self.delta.capacity
        live = np.asarray(self.delta.live[:c])
        x = np.asarray(self.delta.x[:c])[live]
        ext = np.asarray(self.delta.ids[:c])[live].astype(np.int64)
        bids = np.asarray(self.delta.bucket_ids[:c])[live]
        self._reset_delta(self.delta.x.shape[1], self.delta.x.dtype)
        if len(ext) == 0:
            return
        self._add_frozen(x, ext, level=0, bucket_rows=bids)
        self.stats.record_freeze(len(ext))
        self.obs.events.emit("freeze", rows=len(ext), reason=reason)

    def _maybe_compact(self) -> None:
        if self.delta is not None:
            r = self.policy.freeze_reason(
                delta_count=int(self.delta.count),
                delta_capacity=self.delta_capacity)
            if r:
                self._freeze(r)
        self._schedule_merges()
        if self.policy.step_rows is None:
            self._drain()

    def _schedule_merges(self) -> None:
        """Materialize the policy's merge decisions as pending tasks."""
        segs = self.stack.segments
        if not segs:
            return
        pend = self.stack.pending_uids()
        free = [s for s in segs if s.uid not in pend]
        counts: Dict[int, int] = {}
        for s in free:
            counts[s.level] = counts.get(s.level, 0) + 1
        for reason, src, target in self.policy.plan_merges(
                level_counts=counts, n_rows=self.stack.n_rows,
                n_dead=self.stack.n_dead, n_live=self.stack.n_live,
                unit=self.delta_capacity, can_full=not pend):
            uids = [s.uid for s in free if src is None or s.level == src]
            if self.stack.schedule(uids, target, reason):
                self.obs.events.emit("merge_scheduled", uids=uids,
                                     target_level=target, reason=reason)

    def compact_step(self, budget_rows: Optional[int] = None) -> bool:
        """Advance pending merge work by one bounded step (off-query-path
        tick).  Gathers + hashes at most ``budget_rows`` rows; a merge
        whose staging is complete swaps its segment in atomically.
        Returns True while more work remains."""
        if not self.stack.has_work:
            return False
        budget = int(budget_rows or self.policy.step_rows
                     or max(self.delta_capacity, 1))
        res = self.stack.compact_step(budget, self._bucket_fn, self.params,
                                      self.num_buckets, self.m)
        self.stats.record_step()
        if res is not None:
            self._absorb_merge(res)
        return self.stack.has_work

    def _absorb_merge(self, res) -> None:
        """Fold a completed ``MergeResult`` into index state (the one
        post-swap block, shared by the tick and driver paths): ``_loc``
        rewrites for every surviving row, merge stats, and the cascade
        re-schedule.  Control-thread-only."""
        if res.new is not None:
            for e, i in res.moved:
                self._loc[e] = ("m", res.new.uid, i)
        self.stats.record_merge(res.target_level, len(res.moved),
                                res.steps, res.seconds, res.dropped,
                                reason=res.reason)
        self.obs.events.emit("swap", target_level=res.target_level,
                             rows=len(res.moved), dropped=res.dropped,
                             steps=res.steps, seconds=res.seconds,
                             reason=res.reason)
        self._schedule_merges()          # cascade up the levels

    # ---------------------------------------------- driver (async) surface
    @property
    def has_compaction_work(self) -> bool:
        """True while any merge is queued (parity with the sharded index
        — the one predicate drivers and serving ticks poll)."""
        return self.stack.has_work

    @property
    def staged_ready(self) -> bool:
        """A fully-staged merge awaits a control-thread ``apply_staged``."""
        return self.stack.staged_ready

    @property
    def staged_rows(self) -> int:
        """Rows currently gathered into merge staging buffers."""
        return self.stack.staged_rows

    @property
    def pending_merges(self) -> int:
        """Queued merge tasks (head may be partially staged)."""
        return len(self.stack.tasks)

    def stage_step(self, budget_rows: Optional[int] = None) -> str:
        """Advance ONLY the staging half of the active merge.

        The worker-thread half of the ``CompactionDriver`` split: gathers
        at most ``budget_rows`` live rows into the task's private host
        buffers without touching the served level list, so it is safe to
        run concurrently with inserts/deletes/queries on the control
        thread.  Returns ``"idle"`` | ``"staging"`` | ``"ready"``; once
        ``"ready"``, only a control-thread ``apply_staged`` makes
        further progress.
        """
        if not self.stack.has_work:
            return "idle"
        if self.stack.staged_ready:
            return "ready"
        budget = int(budget_rows or self.policy.step_rows
                     or max(self.delta_capacity, 1))
        st = self.stack.stage_step(budget)
        self.stats.record_step()
        return st

    def prepare_staged(self) -> bool:
        """Speculatively build the staged merge's output off-thread.

        Worker-thread-safe (the staging buffers are immutable once
        ``stage_step`` reports ``"ready"``): runs the fused build so
        the control thread's ``apply_staged`` shrinks to the delete
        re-check + uid + list swap + ``_loc`` rewrites.  Returns True
        when a build ran.
        """
        return self.stack.prepare_staged(self._bucket_fn, self.params,
                                         self.num_buckets, self.m)

    def apply_staged(self) -> bool:
        """CONTROL-THREAD ONLY: swap a fully-staged merge in.

        Runs the mid-merge delete re-check, the atomic level swap, the
        ``_loc`` rewrites for every surviving row, and schedules
        cascaded merges — plus the fused build when no worker
        ``prepare_staged`` pre-built it.  Returns True when a merge was
        applied (False: nothing fully staged — staging stays with the
        worker's ``stage_step``).
        """
        res = self.stack.apply_staged(self._bucket_fn, self.params,
                                      self.num_buckets, self.m)
        if res is None:
            return False
        self.stats.record_step()
        self._absorb_merge(res)
        return True

    def _drain(self) -> None:
        while self.stack.has_work:
            self.compact_step(budget_rows=max(self.stack.n_rows, 1))

    def compact(self, reason: str = "manual") -> None:
        """Blocking full compaction: fold every frozen segment + the
        delta into one segment (drops tombstones).  Pending merge
        staging is discarded, not drained — its inputs are still
        complete segments and the fold re-gathers everything, so
        finishing a partial merge first would just build a segment the
        fold immediately throws away."""
        t0 = time.perf_counter()
        self.stack.tasks = []
        if not self.stack.segments and self.delta is None:
            return
        dropped = self.stack.n_dead
        parts_x, parts_id, parts_b = [], [], []
        for f in self.stack.segments:
            live = np.asarray(f.tomb.live[:f.n_rows])
            parts_x.append(np.asarray(f.seg.x[:f.n_rows])[live])
            parts_id.append(np.asarray(f.seg.ids[:f.n_rows])[live])
            parts_b.append(np.asarray(f.seg.bucket_ids[:f.n_rows])[live])
        if self.delta is not None:
            c = self.delta.capacity
            dropped += int(self.delta.count) - self._n_delta_live
            live = np.asarray(self.delta.live[:c])
            parts_x.append(np.asarray(self.delta.x[:c])[live])
            parts_id.append(np.asarray(self.delta.ids[:c])[live])
            parts_b.append(np.asarray(self.delta.bucket_ids[:c])[live])
        if not parts_x:
            return
        x = np.concatenate(parts_x, axis=0)
        ext = np.concatenate(parts_id, axis=0).astype(np.int64)
        bids = np.concatenate(parts_b, axis=0)
        d = self.delta.x.shape[1] if self.delta is not None else (
            x.shape[1] if x.ndim > 1 else 1)
        dtype = self.delta.x.dtype if self.delta is not None else x.dtype
        self._fold_version()
        self.stack = SegmentStack(phases=self.phases)
        self._loc = {}
        if len(ext):
            self._add_frozen(x, ext,
                             level=self.policy.level_for(
                                 len(ext), self.delta_capacity),
                             bucket_rows=bids)
        self._reset_delta(d, dtype)
        self.stats.record(reason, t0, dropped)
        # record() measured the fold from t0; reuse its number — one
        # measurement, reported by both stats and the phase accumulator.
        self.phases.add("full", self.stats.last_seconds)
        self.obs.events.emit("full_compact", reason=reason, dropped=dropped,
                             seconds=self.stats.last_seconds)

    # ------------------------------------------------------------- query
    def _segments(self, tidx: Optional[jax.Array] = None) -> List:
        """The whole stack + delta as engine ``Segment`` adapters."""
        segs: List = []
        metric = self.family.metric
        for f in self.stack.segments:
            segs.append(TableSegment(
                tables=f.seg.tables, x=f.seg.x, metric=metric,
                cap=self.cap, impl=self.impl, live=f.tomb.live,
                tomb_counts=f.tomb.counts, ext_ids=f.seg.ids,
                n_live=f.n_live, n_scan=f.n_pad, tidx=tidx))
        segs.append(delta_lib.DeltaView(
            self.delta, metric, impl=self.impl,
            n_live=self._n_delta_live, n_scan=int(self.delta.count),
            tidx=tidx))
        return segs

    def _qbuckets(self, queries: jax.Array, num_probes: int
                  ) -> Tuple[jax.Array, Optional[jax.Array]]:
        if num_probes <= 1:
            return self._bucket_fn(self.params, queries), None
        if not hasattr(self.family, "margins"):
            raise ValueError(
                "multi-probe needs a family with probing sequences "
                f"(SimHash); got {type(self.family).__name__}")
        from repro.core import multiprobe as mp
        qbp = mp.probe_buckets(self.family, self.params, queries,
                               num_probes, self.num_buckets)
        return mp.flatten_probes(qbp)

    def estimate(self, queries: jax.Array,
                 num_probes: int = 1) -> RouteEstimate:
        assert self.delta is not None, "index is empty: build/insert first"
        qb, tidx = self._qbuckets(jnp.asarray(queries), num_probes)
        return self._engine.estimate(self._segments(tidx), qb)

    def query(self, queries: jax.Array, r: float,
              force: Optional[str] = None,
              num_probes: int = 1) -> QueryResult:
        """Hybrid r-NN reporting over the whole stack; ids are external.

        Args:
          queries: (Q, d) rows in the corpus metric space.
          r: report radius — every returned neighbor has dist <= r.
          force: None (hybrid) | "lsh" | "linear" strategy override.
          num_probes: > 1 probes the Lv et al. perturbation buckets in
            every frozen level AND the delta (SimHash families only).

        Returns a ``QueryResult`` (see ``core.engine``): per-strategy
        sentinel-padded buffers plus the ``RouteEstimate`` diagnostics.
        """
        assert self.delta is not None, "index is empty: build/insert first"
        queries = jnp.asarray(queries)
        qb, tidx = self._qbuckets(queries, num_probes)
        return self._engine.query(self._segments(tidx), queries, qb,
                                  float(r), force=force)

    # ------------------------------------------------------ observability
    @property
    def compaction_work_seconds(self) -> Dict[str, float]:
        """Per-phase compaction work (stage/build/apply/full + total) —
        the one accumulator behind ``index_stats()["work_seconds"]`` and
        the driver's ``stats()["work_seconds"]``, so the two surfaces
        can never disagree or double-count."""
        return self.phases.as_dict()

    def index_stats(self) -> Dict[str, object]:
        """Size/level/compaction counters snapshot (host ints/dicts):
        ``n_live``/``n_main``/``n_main_dead``, delta fill, segment and
        per-level counts, pending merges, per-phase ``work_seconds``,
        and every cumulative ``CompactionStats`` counter (freezes,
        merges_per_level, ...)."""
        out = {
            "n_live": self.n,
            "n_main": self.stack.n_rows,
            "n_main_dead": self.n_dead,
            "delta_count": int(self.delta.count) if self.delta else 0,
            "delta_live": self._n_delta_live,
            "delta_capacity": self.delta_capacity,
            "segments": len(self.stack.segments),
            "levels": self.stack.level_counts(),
            "pending_merges": len(self.stack.tasks),
            "inserts": self._inserts,
            "deletes": self._deletes,
            "work_seconds": self.compaction_work_seconds,
        }
        out.update(self.stats.as_dict())
        return out

    # -------------------------------------------------------- checkpoint
    def state_dict(self) -> Dict[str, Dict[str, np.ndarray]]:
        """Stack + delta state as a nested flat-array pytree.

        Frozen segments land under ``segments/<i>`` with their level/uid
        metadata; the structure varies with the stack, so restore goes
        through ``CheckpointManager.restore_index`` (manifest-driven, no
        template needed).  Staged merge progress is volatile: a pending
        merge's inputs are still complete segments, so dropping the
        staging on restore loses no data — the policy just re-schedules.
        """
        L = self.family.L
        d = self.delta.x.shape[1] if self.delta is not None else 0
        segments: Dict[str, Dict] = {}
        for i, f in enumerate(self.stack.segments):
            t = f.seg.tables
            segments[f"{i:04d}"] = {
                "x": np.asarray(f.seg.x),
                "ids": np.asarray(f.seg.ids),
                "bucket_ids": np.asarray(f.seg.bucket_ids),
                "perm": np.asarray(t.perm),
                "starts": np.asarray(t.starts),
                "registers": np.asarray(t.registers),
                "live": np.asarray(f.tomb.live),
                "tomb_counts": np.asarray(f.tomb.counts),
                "meta": {"uid": np.int64(f.uid),
                         "level": np.int64(f.level),
                         "n_rows": np.int64(f.n_rows),
                         "n_live": np.int64(f.n_live)},
            }
        delta = (self.delta if self.delta is not None
                 else delta_lib.make_delta(self.delta_capacity, 1, L))
        return {
            "params": self.params,
            "segments": segments,
            "delta": {"x": np.asarray(delta.x),
                      "bucket_ids": np.asarray(delta.bucket_ids),
                      "ids": np.asarray(delta.ids),
                      "live": np.asarray(delta.live),
                      "count": np.asarray(delta.count)},
            # delta_d == 0 marks "never populated": the saved delta row
            # width is a placeholder and must not survive a restore.
            "meta": {"next_id": np.int64(self._next_id),
                     "delta_d": np.int64(0 if self.delta is None else d),
                     "next_uid": np.int64(self.stack._next_uid)},
        }

    def state_digests(self) -> Dict[str, str]:
        """Content-address hints matching ``state_dict`` leaf paths,
        for the leaves that are immutable once frozen.

        ``CheckpointManager.save_incremental`` uses these to reference
        unchanged level chunks without re-hashing them; the tombstone
        bitmaps, delta, params, and meta change between snapshots and
        are never hinted (they re-hash each save).
        """
        out: Dict[str, str] = {}
        for i, f in enumerate(self.stack.segments):
            for k, dg in frozen_digests(f).items():
                out[f"segments/{i:04d}/{k}"] = dg
        return out

    def load_state_dict(self, state) -> "DynamicHybridIndex":
        """Restore stack + delta state saved by ``state_dict``."""
        self.params = jax.tree_util.tree_map(jnp.asarray, state["params"])
        self._bucket_fn = bucket_fn_for(self.family, self.num_buckets)
        self._fold_version()
        self.stack = SegmentStack(phases=self.phases)
        self._loc = {}
        segs = dict(state.get("segments") or {})
        ms = state.get("main")
        if ms is not None and np.asarray(ms["x"]).shape[0] > 0:
            # pre-stack checkpoint format (one "main" segment, exact
            # rows, no meta): migrate it to a single frozen segment —
            # ignoring it would silently restore an empty index
            n = int(np.asarray(ms["x"]).shape[0])
            segs["main"] = {
                **ms,
                "meta": {"uid": np.int64(0), "level": np.int64(
                    self.policy.level_for(n, self.delta_capacity)),
                    "n_rows": np.int64(n),
                    "n_live": np.asarray(ms["live"])[:n].sum()},
            }
        for key in sorted(segs):
            s = segs[key]
            meta = s["meta"]
            f = FrozenSegment(
                uid=int(np.asarray(meta["uid"])),
                level=int(np.asarray(meta["level"])),
                seg=MainSegment(
                    x=jnp.asarray(s["x"]),
                    ids=jnp.asarray(s["ids"], jnp.int32),
                    bucket_ids=jnp.asarray(s["bucket_ids"], jnp.int32),
                    tables=LSHTables(jnp.asarray(s["perm"], jnp.int32),
                                     jnp.asarray(s["starts"], jnp.int32),
                                     jnp.asarray(s["registers"],
                                                 jnp.uint8))),
                tomb=tomb_lib.Tombstones(
                    live=jnp.asarray(s["live"], bool),
                    counts=jnp.asarray(s["tomb_counts"], jnp.int32)),
                n_rows=int(np.asarray(meta["n_rows"])),
                n_live=int(np.asarray(meta["n_live"])))
            self.stack.add(f)
            live = np.asarray(f.tomb.live[:f.n_rows])
            eids = np.asarray(f.seg.ids[:f.n_rows])
            for i in np.nonzero(live)[0]:
                self._loc[int(eids[i])] = ("m", f.uid, int(i))
        self.stack._next_uid = int(np.asarray(
            state["meta"].get("next_uid",
                              max([s.uid for s in self.stack.segments],
                                  default=-1) + 1)))
        ds = state["delta"]
        if int(np.asarray(state["meta"].get("delta_d", 1))) == 0:
            self.delta = None        # saved before first build/insert
            self._n_delta_live = 0
        else:
            self.delta = delta_lib.DeltaSegment(
                x=jnp.asarray(ds["x"]),
                bucket_ids=jnp.asarray(ds["bucket_ids"], jnp.int32),
                ids=jnp.asarray(ds["ids"], jnp.int32),
                live=jnp.asarray(ds["live"], bool),
                count=jnp.asarray(ds["count"], jnp.int32))
            self.delta_capacity = self.delta.capacity
            dl = np.asarray(self.delta.live)
            self._n_delta_live = int(dl.sum())
            d_ids = np.asarray(self.delta.ids)
            for s in range(int(self.delta.count)):
                if dl[s]:
                    self._loc[int(d_ids[s])] = ("d", s)
        self._next_id = int(np.asarray(state["meta"]["next_id"]))
        return self
