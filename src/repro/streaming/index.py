"""DynamicHybridIndex — incremental inserts/deletes over the static core.

Segment architecture (LSM-flavoured, one level):

  * main segment   — immutable CSR ``LSHTables`` + per-bucket HLLs, built
    by the paper's Algorithm 1 fusion.  Deletes tombstone rows
    (``streaming.tombstones``); the tables never mutate.
  * delta segment  — fixed-capacity append-only buffers
    (``streaming.delta``); inserts are one fused ``.at[]`` scatter, so
    repeated same-size inserts never retrace.  Counts are exact.
  * compaction     — when the delta fills or tombstones accumulate
    (``CompactionPolicy``), live rows from both segments are folded into
    a fresh main segment via ``build_tables``.

Queries hand both segments to the shared ``QueryEngine``
(``core.engine``): the main segment as a tombstone-aware
``TableSegment`` (corrected estimates, dead rows masked after search,
*external* ids reported), the delta as the exact ``DeltaView``.  A
mixed insert/delete workload therefore reports exactly the candidates a
fresh ``HybridLSHIndex.build()`` on the surviving corpus would (same
family parameters, cap permitting).  The mesh-sharded variant lives in
``streaming.sharded``.
"""
from __future__ import annotations

import functools
from typing import Dict, Iterable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import CostModel
from repro.core.engine import (QueryEngine, QueryResult, RouteEstimate,
                               TableSegment, _pad_size)
from repro.core.lsh.tables import LSHTables
from repro.streaming import delta as delta_lib
from repro.streaming import tombstones as tomb_lib
from repro.streaming.compaction import CompactionPolicy, CompactionStats
from repro.streaming.segment import MainSegment, build_main

__all__ = ["DynamicHybridIndex"]

_pad_pow2 = _pad_size                # same pow2 padding as the router groups


class DynamicHybridIndex:
    """Streaming Hybrid LSH index: insert / delete / compact / query."""

    def __init__(self, family, *, num_buckets: int, m: int = 64,
                 cap: int = 64, delta_capacity: int = 4096,
                 cost_model: CostModel = CostModel(alpha=1.0, beta=10.0),
                 policy: CompactionPolicy = CompactionPolicy(),
                 key: jax.Array | int = 0, impl: Optional[str] = None):
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self.family = family
        self.params = family.init(key)
        self.num_buckets = int(num_buckets)
        self.m = int(m)
        self.cap = int(cap)
        self.delta_capacity = int(delta_capacity)
        self.cost_model = cost_model
        self.policy = policy
        self.impl = impl
        self._engine = QueryEngine(cost_model, impl=impl)
        self._bucket_fn = jax.jit(functools.partial(
            self.family.bucket_ids, num_buckets=self.num_buckets))

        self.main: Optional[MainSegment] = None
        self.tomb: Optional[tomb_lib.Tombstones] = None
        self.delta: Optional[delta_lib.DeltaSegment] = None
        self.stats = CompactionStats()
        # Host bookkeeping: external id -> ("m", row) | ("d", slot).
        self._loc: Dict[int, tuple] = {}
        self._next_id = 0
        self._n_main_live = 0
        self._n_delta_live = 0
        self._inserts = 0
        self._deletes = 0

    # ------------------------------------------------------------- sizes
    @property
    def n(self) -> int:
        """Live document count (main live + delta live)."""
        return self._n_main_live + self._n_delta_live

    @property
    def n_dead(self) -> int:
        return (self.main.n if self.main else 0) - self._n_main_live

    # ------------------------------------------------------------- build
    def build(self, x: jax.Array,
              ids: Optional[Sequence[int]] = None) -> "DynamicHybridIndex":
        """Initial batch build (Algorithm 1); ``ids`` default to 0..n-1."""
        x = jnp.asarray(x)
        if ids is None:
            ids = np.arange(x.shape[0], dtype=np.int64)
        else:
            ids = np.asarray(ids, np.int64)
            assert len(set(ids.tolist())) == len(ids), "duplicate ids"
        self._set_main(x, ids)
        self._reset_delta(x.shape[1], x.dtype)
        self._next_id = int(ids.max()) + 1 if len(ids) else 0
        return self

    def _set_main(self, x: jax.Array, ext_ids: np.ndarray) -> None:
        n = int(x.shape[0])
        if n == 0:
            self.main = None
            self.tomb = None
            self._n_main_live = 0
        else:
            self.main = build_main(x, jnp.asarray(ext_ids, jnp.int32),
                                   self._bucket_fn, self.params,
                                   self.num_buckets, self.m)
            self.tomb = tomb_lib.make_tombstones(
                n, self.main.tables.L, self.num_buckets)
            self._n_main_live = n
        self._loc = {int(e): ("m", i) for i, e in enumerate(ext_ids)}

    def _reset_delta(self, d: int, dtype) -> None:
        self.delta = delta_lib.make_delta(self.delta_capacity, d,
                                          self.family.L, dtype)
        self._n_delta_live = 0

    # ------------------------------------------------------------ insert
    def insert(self, rows: jax.Array,
               ids: Optional[Sequence[int]] = None) -> np.ndarray:
        """Append documents; returns their external ids.

        Splits the batch by remaining delta capacity, compacting between
        chunks when the delta fills — inserts never block indefinitely.
        """
        rows = jnp.asarray(rows)
        if rows.shape[0] == 0:
            return np.zeros((0,), np.int64)
        if self.delta is None:  # first contact: empty index, delta-only
            self._reset_delta(rows.shape[1], rows.dtype)
        if ids is None:
            ids = np.arange(self._next_id, self._next_id + rows.shape[0],
                            dtype=np.int64)
        else:
            ids = np.asarray(ids, np.int64)
            if len(set(ids.tolist())) != len(ids):
                raise KeyError("duplicate ids within insert batch")
        for e in ids.tolist():
            if e in self._loc:
                raise KeyError(f"id {e} already indexed")
        lo = 0
        while lo < rows.shape[0]:
            free = self.delta.capacity - int(self.delta.count)
            if free == 0:
                self.compact(reason="delta_full")
                free = self.delta.capacity
            take = min(free, rows.shape[0] - lo)
            self._insert_chunk(rows[lo:lo + take], ids[lo:lo + take])
            lo += take
        self._next_id = max(self._next_id, int(ids.max()) + 1)
        self._maybe_compact()
        return ids

    def _insert_chunk(self, rows: jax.Array, ids: np.ndarray) -> None:
        k = rows.shape[0]
        pk = _pad_pow2(k)
        pad = [(0, pk - k)] + [(0, 0)] * (rows.ndim - 1)
        rows_p = jnp.pad(rows, pad)
        bids = self._bucket_fn(self.params, rows_p)     # (pk, L)
        ids_p = np.zeros(pk, np.int32)
        ids_p[:k] = ids
        valid = np.zeros(pk, bool)
        valid[:k] = True
        base = int(self.delta.count)
        self.delta = delta_lib.insert(self.delta, rows_p, bids,
                                      jnp.asarray(ids_p),
                                      jnp.asarray(valid))
        for i, e in enumerate(ids.tolist()):
            self._loc[int(e)] = ("d", base + i)
        self._n_delta_live += k
        self._inserts += k

    # ------------------------------------------------------------ delete
    def delete(self, ids: Iterable[int], strict: bool = False) -> int:
        """Tombstone documents by external id; returns #removed.

        Unknown (or already-deleted) ids are skipped unless ``strict``.
        """
        main_rows, delta_slots = [], []
        for e in ids:
            loc = self._loc.pop(int(e), None)
            if loc is None:
                if strict:
                    raise KeyError(e)
                continue
            (main_rows if loc[0] == "m" else delta_slots).append(loc[1])
        if main_rows:
            k = len(main_rows)
            pk = _pad_pow2(k)
            rows_p = np.zeros(pk, np.int32)
            rows_p[:k] = main_rows
            valid = np.zeros(pk, bool)
            valid[:k] = True
            # padded lanes point at row 0's buckets but add 0 there
            row_buckets = self.main.bucket_ids[jnp.asarray(rows_p)]
            self.tomb = tomb_lib.mark_dead(self.tomb, jnp.asarray(rows_p),
                                           row_buckets, jnp.asarray(valid))
            self._n_main_live -= k
        if delta_slots:
            k = len(delta_slots)
            pk = _pad_pow2(k)
            slots_p = np.zeros(pk, np.int32)
            slots_p[:k] = delta_slots
            valid = np.zeros(pk, bool)
            valid[:k] = True
            self.delta = delta_lib.kill(self.delta, jnp.asarray(slots_p),
                                        jnp.asarray(valid))
            self._n_delta_live -= k
        removed = len(main_rows) + len(delta_slots)
        self._deletes += removed
        self._maybe_compact()
        return removed

    # --------------------------------------------------------- compaction
    def _maybe_compact(self) -> None:
        reason = self.policy.reason(
            delta_count=int(self.delta.count) if self.delta else 0,
            delta_capacity=self.delta_capacity,
            n_main=self.main.n if self.main else 0,
            n_dead=self.n_dead)
        if reason:
            self.compact(reason=reason)

    def compact(self, reason: str = "manual") -> None:
        """Fold delta + drop tombstones into a fresh main segment."""
        import time
        t0 = time.perf_counter()
        dropped = self.n_dead + (int(self.delta.count) - self._n_delta_live
                                 if self.delta else 0)
        parts_x, parts_id = [], []
        if self.main is not None:
            live = np.asarray(self.tomb.live[:self.main.n])
            parts_x.append(np.asarray(self.main.x)[live])
            parts_id.append(np.asarray(self.main.ids)[live])
        if self.delta is not None:
            c = self.delta.capacity
            live = np.asarray(self.delta.live[:c])
            parts_x.append(np.asarray(self.delta.x[:c])[live])
            parts_id.append(np.asarray(self.delta.ids[:c])[live])
        if not parts_x:
            return
        x = jnp.asarray(np.concatenate(parts_x, axis=0))
        ext = np.concatenate(parts_id, axis=0).astype(np.int64)
        self._set_main(x, ext)
        self._reset_delta(x.shape[1] if x.ndim > 1 else 1, x.dtype)
        self.stats.record(reason, t0, dropped)

    # ------------------------------------------------------------- query
    def _segments(self) -> List:
        """Both segments as engine ``Segment`` adapters (main may be absent)."""
        segs: List = []
        metric = self.family.metric
        if self.main is not None:
            segs.append(TableSegment(
                tables=self.main.tables, x=self.main.x, metric=metric,
                cap=self.cap, impl=self.impl, live=self.tomb.live,
                tomb_counts=self.tomb.counts, ext_ids=self.main.ids,
                n_live=self._n_main_live, n_scan=self.main.n))
        segs.append(delta_lib.DeltaView(
            self.delta, metric, impl=self.impl,
            n_live=self._n_delta_live, n_scan=int(self.delta.count)))
        return segs

    def estimate(self, queries: jax.Array) -> RouteEstimate:
        assert self.delta is not None, "index is empty: build/insert first"
        qb = self._bucket_fn(self.params, jnp.asarray(queries))
        return self._engine.estimate(self._segments(), qb)

    def query(self, queries: jax.Array, r: float,
              force: Optional[str] = None) -> QueryResult:
        """Hybrid r-NN reporting over both segments; ids are external."""
        assert self.delta is not None, "index is empty: build/insert first"
        queries = jnp.asarray(queries)
        qb = self._bucket_fn(self.params, queries)
        return self._engine.query(self._segments(), queries, qb, float(r),
                                  force=force)

    # ------------------------------------------------------ observability
    def index_stats(self) -> Dict[str, object]:
        out = {
            "n_live": self.n,
            "n_main": self.main.n if self.main else 0,
            "n_main_dead": self.n_dead,
            "delta_count": int(self.delta.count) if self.delta else 0,
            "delta_live": self._n_delta_live,
            "delta_capacity": self.delta_capacity,
            "inserts": self._inserts,
            "deletes": self._deletes,
        }
        out.update(self.stats.as_dict())
        return out

    # -------------------------------------------------------- checkpoint
    def state_dict(self) -> Dict[str, Dict[str, np.ndarray]]:
        """Segment state as a flat-array pytree (CheckpointManager-ready).

        The family config + cost model are constructor arguments, not
        state: restore into an index constructed with the same ones.
        An empty main segment is encoded as zero-length arrays so the
        tree structure (the restore template) is state-independent.
        """
        L = self.family.L
        d = self.delta.x.shape[1] if self.delta is not None else 0
        if self.main is not None:
            t = self.main.tables
            main = {"x": self.main.x, "ids": self.main.ids,
                    "bucket_ids": self.main.bucket_ids,
                    "perm": t.perm, "starts": t.starts,
                    "registers": t.registers,
                    "live": self.tomb.live, "tomb_counts": self.tomb.counts}
        else:
            main = {"x": np.zeros((0, d), np.float32),
                    "ids": np.zeros((0,), np.int32),
                    "bucket_ids": np.zeros((0, L), np.int32),
                    "perm": np.zeros((L, 0), np.int32),
                    "starts": np.zeros((L, self.num_buckets + 1), np.int32),
                    "registers": np.zeros((L, self.num_buckets, self.m),
                                          np.uint8),
                    "live": np.zeros((1,), bool),
                    "tomb_counts": np.zeros((L, self.num_buckets),
                                            np.int32)}
        delta = (self.delta if self.delta is not None
                 else delta_lib.make_delta(self.delta_capacity, 1, L))
        return {
            "params": self.params,
            "main": {k: np.asarray(v) for k, v in main.items()},
            "delta": {"x": np.asarray(delta.x),
                      "bucket_ids": np.asarray(delta.bucket_ids),
                      "ids": np.asarray(delta.ids),
                      "live": np.asarray(delta.live),
                      "count": np.asarray(delta.count)},
            # delta_d == 0 marks "never populated": the saved delta row
            # width is a placeholder and must not survive a restore.
            "meta": {"next_id": np.int64(self._next_id),
                     "delta_d": np.int64(0 if self.delta is None else d)},
        }

    def load_state_dict(self, state) -> "DynamicHybridIndex":
        """Restore segment state saved by ``state_dict``."""
        self.params = jax.tree_util.tree_map(jnp.asarray, state["params"])
        self._bucket_fn = jax.jit(functools.partial(
            self.family.bucket_ids, num_buckets=self.num_buckets))
        ms, ds = state["main"], state["delta"]
        x = jnp.asarray(ms["x"])
        if x.shape[0] > 0:
            self.main = MainSegment(
                x=x, ids=jnp.asarray(ms["ids"], jnp.int32),
                bucket_ids=jnp.asarray(ms["bucket_ids"], jnp.int32),
                tables=LSHTables(jnp.asarray(ms["perm"], jnp.int32),
                                 jnp.asarray(ms["starts"], jnp.int32),
                                 jnp.asarray(ms["registers"], jnp.uint8)))
            self.tomb = tomb_lib.Tombstones(
                live=jnp.asarray(ms["live"], bool),
                counts=jnp.asarray(ms["tomb_counts"], jnp.int32))
            self._n_main_live = int(np.asarray(ms["live"]).sum())
        else:
            self.main = None
            self.tomb = None
            self._n_main_live = 0
        if int(np.asarray(state["meta"].get("delta_d", 1))) == 0:
            self.delta = None        # saved before first build/insert
            self._n_delta_live = 0
            dl = np.zeros((0,), bool)
        else:
            self.delta = delta_lib.DeltaSegment(
                x=jnp.asarray(ds["x"]),
                bucket_ids=jnp.asarray(ds["bucket_ids"], jnp.int32),
                ids=jnp.asarray(ds["ids"], jnp.int32),
                live=jnp.asarray(ds["live"], bool),
                count=jnp.asarray(ds["count"], jnp.int32))
            self.delta_capacity = self.delta.capacity
            dl = np.asarray(self.delta.live)
            self._n_delta_live = int(dl.sum())
        self._next_id = int(np.asarray(state["meta"]["next_id"]))
        # Rebuild the host id -> location map from segment state.
        self._loc = {}
        if self.main is not None:
            live = np.asarray(self.tomb.live[:self.main.n])
            for i, e in enumerate(np.asarray(self.main.ids).tolist()):
                if live[i]:
                    self._loc[int(e)] = ("m", i)
        if self.delta is not None:
            d_ids = np.asarray(self.delta.ids)
            for s in range(int(self.delta.count)):
                if dl[s]:
                    self._loc[int(d_ids[s])] = ("d", s)
        return self
