"""ShardedDynamicHybridIndex — the streaming index over the mesh.

The fourth scenario the segment engine enables: every shard of the
``data`` axis owns a full dynamic-index worth of segment state —

  * main   — per-shard CSR tables + HLLs built by the ``build_tables``
             fusion over a *padded* row block.  Pad rows are hashed to
             bucket ``B`` (one past the bucket space), which the CSR
             ``segment_sum`` and the HLL ``segment_max`` drop exactly:
             padding costs capacity, never correctness.  HLLs are keyed
             on globally-unique internal ids (shard * n_pad + row), so
             a ``pmax`` of merged registers is the exact distinct-union
             sketch across shards — the paper's per-table merge,
             extended over the mesh.
  * tomb   — per-shard live bitmap + per-(table, bucket) dead counts
             (the engine's tombstone correction terms).
  * delta  — per-shard fixed-capacity delta segment; inserts/deletes
             are the same fused ``.at[]`` scatters as the single-host
             index, applied under ``shard_map``.

Queries run one ``shard_map``: each shard builds its engine segments
(``TableSegment`` + ``DeltaView``), merges ``SegmentEstimate`` terms
across shards (``psum`` collisions/dead/exact, ``pmax`` registers),
finalizes global and local routes via the shared ``finalize_route``,
and picks a strategy per the routing policy:

  * ``"global"``    — one decision from the mesh-wide Eq.(1)/(2) costs;
  * ``"per_shard"`` — each shard compares its local costs: the shard
    holding a dense cluster scans linearly while the others use LSH
    (query-adaptive parameter choice generalized to local density skew).

Compaction folds each shard's live main + delta rows into a fresh
padded main segment — per shard, through the same ``build_tables``
fusion, with no cross-shard row movement.  Reported ids are external;
after any churn the reported sets match a fresh single-host
``DynamicHybridIndex.build()`` on the surviving corpus per route.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.cost_model import CostModel
from repro.core.engine import (QueryEngine, SegmentEstimate, TableSegment,
                               _pad_size, compact_results, finalize_route)
from repro.core.lsh.tables import LSHTables, build_tables
from repro.core import hll as hll_lib
from repro.streaming import delta as delta_lib
from repro.streaming import tombstones as tomb_lib
from repro.streaming.compaction import CompactionPolicy, CompactionStats

__all__ = ["ShardedDynamicHybridIndex", "ShardedQueryResult"]


@dataclasses.dataclass
class ShardedQueryResult:
    """Union-over-shards reporting buffers + routing diagnostics."""

    ids: np.ndarray         # (S, Q, max_out) external doc ids
    dists: np.ndarray       # (S, Q, max_out)
    mask: np.ndarray        # (S, Q, max_out) reported r-near neighbors
    collisions: np.ndarray  # (Q,) global live collisions
    cand_est: np.ndarray    # (Q,) global corrected candSize estimate
    used_lsh: np.ndarray    # (S,) per-shard strategy decision
    n_queries: int

    def neighbors(self, i: int) -> np.ndarray:
        return self.ids[:, i][self.mask[:, i]]

    def neighbor_sets(self):
        return {i: set(self.neighbors(i).tolist())
                for i in range(self.n_queries)}

    @property
    def frac_linear(self) -> float:
        return float((~self.used_lsh).mean())

    @property
    def n_linear(self) -> int:
        """Queries served by linear search, scaled by the shard vote.

        Sharded routing is per-(batch, shard), so the exact per-query
        count of the single-host index degenerates to the shard
        fraction here.
        """
        return round(self.n_queries * self.frac_linear)


class ShardedDynamicHybridIndex:
    """Streaming Hybrid LSH index, row-sharded over a mesh axis."""

    def __init__(self, family, *, num_buckets: int, mesh: Mesh, m: int = 64,
                 cap: int = 64, delta_capacity: int = 1024,
                 cost_model: CostModel = CostModel(alpha=1.0, beta=10.0),
                 policy: CompactionPolicy = CompactionPolicy(),
                 routing: str = "per_shard", max_out: int = 512,
                 data_axis: str = "data", key: jax.Array | int = 0,
                 impl: Optional[str] = None):
        assert routing in ("global", "per_shard"), routing
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self.family = family
        self.params = family.init(key)
        self.num_buckets = int(num_buckets)
        self.m = int(m)
        self.cap = int(cap)
        self.delta_capacity = int(delta_capacity)
        self.cost_model = cost_model
        self.policy = policy
        self.routing = routing
        self.max_out = int(max_out)
        self.mesh = mesh
        self.data_axis = data_axis
        self.shards = int(mesh.shape[data_axis])
        self.impl = impl
        self._engine = QueryEngine(cost_model, impl=impl)
        self._shard = NamedSharding(mesh, P(data_axis))
        self.stats = CompactionStats()

        # device leaves (leading dim = shard axis); None until first use
        self._main = None     # dict: x, ids, bucket_ids, perm, starts,
        #                       registers, live, tomb_counts
        self._delta = None    # dict: x, bucket_ids, ids, live, count
        self._n_pad = 0       # per-shard main capacity (rows incl. pads)
        self._d = None        # row width
        self._dtype = None

        # host bookkeeping
        self._loc: Dict[int, tuple] = {}   # ext -> (shard, "m"|"d", pos)
        self._next_id = 0
        S = self.shards
        self._main_rows_s = np.zeros(S, np.int64)   # real rows (incl. dead)
        self._main_live_s = np.zeros(S, np.int64)
        self._delta_count_s = np.zeros(S, np.int64)
        self._delta_live_s = np.zeros(S, np.int64)
        self._inserts = 0
        self._deletes = 0
        self._fn_cache: Dict[tuple, object] = {}

    # ------------------------------------------------------------- sizes
    @property
    def n(self) -> int:
        return int(self._main_live_s.sum() + self._delta_live_s.sum())

    @property
    def n_dead(self) -> int:
        return int(self._main_rows_s.sum() - self._main_live_s.sum())

    # ------------------------------------------------------------- build
    def build(self, x: jax.Array,
              ids: Optional[Sequence[int]] = None
              ) -> "ShardedDynamicHybridIndex":
        """Initial batch build; rows round-robin over shards."""
        x = np.asarray(x)
        n = x.shape[0]
        if ids is None:
            ids = np.arange(n, dtype=np.int64)
        else:
            ids = np.asarray(ids, np.int64)
            assert len(set(ids.tolist())) == len(ids), "duplicate ids"
        self._d, self._dtype = int(x.shape[1]), x.dtype
        S = self.shards
        parts = [(x[s::S], ids[s::S]) for s in range(S)]
        self._set_main(parts)
        self._reset_delta()
        self._next_id = int(ids.max()) + 1 if n else 0
        return self

    def _set_main(self, parts: List[Tuple[np.ndarray, np.ndarray]]) -> None:
        """Per-shard (rows, ext_ids) -> padded sharded main segment."""
        S = self.shards
        ks = [int(p[0].shape[0]) for p in parts]
        n_pad = _pad_size(max(max(ks), 1))
        xs = np.zeros((S, n_pad, self._d), self._dtype)
        ext = np.full((S, n_pad), -1, np.int32)
        valid = np.zeros((S, n_pad), bool)
        self._loc = {e: loc for e, loc in self._loc.items()
                     if loc[1] == "d"}  # main locations are re-derived
        for s, (rows, eids) in enumerate(parts):
            k = ks[s]
            xs[s, :k] = rows
            ext[s, :k] = eids
            valid[s, :k] = True
            for i, e in enumerate(eids.tolist()):
                self._loc[int(e)] = (s, "m", i)
        self._n_pad = n_pad
        self._main_rows_s = np.asarray(ks, np.int64)
        self._main_live_s = np.asarray(ks, np.int64)
        put = lambda a: jax.device_put(jnp.asarray(a), self._shard)
        bids, perm, starts, regs = self._build_fn(n_pad)(
            put(xs), put(valid), self.params)
        live = np.concatenate([valid, np.zeros((S, 1), bool)], axis=1)
        self._main = {
            "x": put(xs), "ids": put(ext), "bucket_ids": bids,
            "perm": perm, "starts": starts, "registers": regs,
            "live": put(live),
            "tomb_counts": put(np.zeros(
                (S, self.family.L, self.num_buckets), np.int32))}

    def _build_fn(self, n_pad: int):
        """shard_map'd Algorithm 1 fusion over one padded row block."""
        key = ("build", n_pad)
        if key in self._fn_cache:
            return self._fn_cache[key]
        family, B, m = self.family, self.num_buckets, self.m
        axis = self.data_axis

        def _build(x, valid, params):
            x, valid = x[0], valid[0]
            shard = jax.lax.axis_index(axis)
            bids = family.bucket_ids(params, x, B).astype(jnp.int32)
            # pad rows hash to bucket B: dropped by the CSR segment_sum
            # and the HLL segment_max — invisible to every estimate.
            bids = jnp.where(valid[:, None], bids, B)
            gids = shard * n_pad + jnp.arange(n_pad, dtype=jnp.int32)
            t = build_tables(gids, bids, B, m)
            perm = t.perm - shard * n_pad
            return (bids[None], perm[None], t.starts[None],
                    t.registers[None])

        sh = P(axis)
        fn = jax.jit(shard_map(
            _build, mesh=self.mesh, in_specs=(sh, sh, P()),
            out_specs=(sh, sh, sh, sh), check_rep=False))
        self._fn_cache[key] = fn
        return fn

    def _reset_delta(self) -> None:
        S, C, L = self.shards, self.delta_capacity, self.family.L
        put = lambda a: jax.device_put(jnp.asarray(a), self._shard)
        self._delta = {
            "x": put(np.zeros((S, C + 1, self._d), self._dtype)),
            "bucket_ids": put(np.full((S, C + 1, L), -1, np.int32)),
            "ids": put(np.full((S, C + 1), -1, np.int32)),
            "live": put(np.zeros((S, C + 1), bool)),
            "count": put(np.zeros((S,), np.int32))}
        self._delta_count_s[:] = 0
        self._delta_live_s[:] = 0
        self._loc = {e: loc for e, loc in self._loc.items()
                     if loc[1] == "m"}

    def _ensure_init(self, rows: np.ndarray) -> None:
        """First contact without build(): empty main, delta-only shards."""
        if self._delta is not None:
            return
        self._d, self._dtype = int(rows.shape[1]), rows.dtype
        S, L, B, m = (self.shards, self.family.L, self.num_buckets, self.m)
        put = lambda a: jax.device_put(jnp.asarray(a), self._shard)
        self._n_pad = 0
        self._main = {
            "x": put(np.zeros((S, 0, self._d), self._dtype)),
            "ids": put(np.zeros((S, 0), np.int32)),
            "bucket_ids": put(np.zeros((S, 0, L), np.int32)),
            "perm": put(np.zeros((S, L, 0), np.int32)),
            "starts": put(np.zeros((S, L, B + 1), np.int32)),
            "registers": put(np.zeros((S, L, B, m), np.uint8)),
            "live": put(np.zeros((S, 1), bool)),
            "tomb_counts": put(np.zeros((S, L, B), np.int32))}
        self._reset_delta()

    # ------------------------------------------------------------ insert
    def insert(self, rows: jax.Array,
               ids: Optional[Sequence[int]] = None) -> np.ndarray:
        """Append documents to the least-loaded shard deltas.

        Splits the batch by remaining per-shard delta capacity,
        compacting between chunks when every delta fills.
        """
        rows = np.asarray(rows)
        if rows.shape[0] == 0:
            return np.zeros((0,), np.int64)
        self._ensure_init(rows)
        if ids is None:
            ids = np.arange(self._next_id, self._next_id + rows.shape[0],
                            dtype=np.int64)
        else:
            ids = np.asarray(ids, np.int64)
            if len(set(ids.tolist())) != len(ids):
                raise KeyError("duplicate ids within insert batch")
        for e in ids.tolist():
            if e in self._loc:
                raise KeyError(f"id {e} already indexed")
        lo = 0
        while lo < rows.shape[0]:
            free = self.delta_capacity - self._delta_count_s
            if free.sum() == 0:
                self.compact(reason="delta_full")
                free = self.delta_capacity - self._delta_count_s
            take = int(min(free.sum(), rows.shape[0] - lo))
            # round-robin water-fill over shards with free slots
            order = np.argsort(self._delta_count_s, kind="stable")
            assign: List[List[int]] = [[] for _ in range(self.shards)]
            left, cursor = take, 0
            free = free.copy()
            while left:
                s = int(order[cursor % self.shards])
                cursor += 1
                if free[s] > len(assign[s]):
                    assign[s].append(lo + take - left)
                    left -= 1
            self._insert_chunk(rows, ids, assign)
            lo += take
        self._next_id = max(self._next_id, int(ids.max()) + 1)
        self._maybe_compact()
        return ids

    def _insert_chunk(self, rows: np.ndarray, ids: np.ndarray,
                      assign: List[List[int]]) -> None:
        S = self.shards
        pk = _pad_size(max(max(len(a) for a in assign), 1))
        rows_p = np.zeros((S, pk, self._d), self._dtype)
        ids_p = np.zeros((S, pk), np.int32)
        valid = np.zeros((S, pk), bool)
        for s, idxs in enumerate(assign):
            k = len(idxs)
            rows_p[s, :k] = rows[idxs]
            ids_p[s, :k] = ids[idxs]
            valid[s, :k] = True
            base = int(self._delta_count_s[s])
            for i, j in enumerate(idxs):
                self._loc[int(ids[j])] = (s, "d", base + i)
            self._delta_count_s[s] += k
            self._delta_live_s[s] += k
            self._inserts += k
        d = self._delta
        out = self._insert_fn(pk)(
            (d["x"], d["bucket_ids"], d["ids"], d["live"], d["count"]),
            self.params, rows_p, ids_p, valid)
        self._delta = dict(zip(("x", "bucket_ids", "ids", "live", "count"),
                               out))

    def _insert_fn(self, pk: int):
        key = ("insert", pk)
        if key in self._fn_cache:
            return self._fn_cache[key]
        family, B = self.family, self.num_buckets
        axis = self.data_axis

        def _ins(leaves, params, rows, ext, valid):
            delta = delta_lib.DeltaSegment(*(l[0] for l in leaves))
            bids = family.bucket_ids(params, rows[0], B)
            nd = delta_lib.insert(delta, rows[0], bids, ext[0], valid[0])
            return (nd.x[None], nd.bucket_ids[None], nd.ids[None],
                    nd.live[None], nd.count[None])

        sh = P(axis)
        fn = jax.jit(shard_map(
            _ins, mesh=self.mesh,
            in_specs=((sh,) * 5, P(), sh, sh, sh),
            out_specs=(sh,) * 5, check_rep=False))
        self._fn_cache[key] = fn
        return fn

    # ------------------------------------------------------------ delete
    def delete(self, ids: Iterable[int], strict: bool = False) -> int:
        """Tombstone documents by external id; returns #removed."""
        S = self.shards
        main_rows: List[List[int]] = [[] for _ in range(S)]
        delta_slots: List[List[int]] = [[] for _ in range(S)]
        for e in ids:
            loc = self._loc.pop(int(e), None)
            if loc is None:
                if strict:
                    raise KeyError(e)
                continue
            s, kind, pos = loc
            (main_rows[s] if kind == "m" else delta_slots[s]).append(pos)
        removed = 0
        if any(main_rows):
            pk = _pad_size(max(max(len(a) for a in main_rows), 1))
            rows_p = np.zeros((S, pk), np.int32)
            valid = np.zeros((S, pk), bool)
            for s, rr in enumerate(main_rows):
                rows_p[s, :len(rr)] = rr
                valid[s, :len(rr)] = True
                self._main_live_s[s] -= len(rr)
                removed += len(rr)
            live, counts = self._delete_main_fn(pk)(
                (self._main["live"], self._main["tomb_counts"],
                 self._main["bucket_ids"]), rows_p, valid)
            self._main = {**self._main, "live": live, "tomb_counts": counts}
        if any(delta_slots):
            pk = _pad_size(max(max(len(a) for a in delta_slots), 1))
            slots_p = np.zeros((S, pk), np.int32)
            valid = np.zeros((S, pk), bool)
            for s, ss in enumerate(delta_slots):
                slots_p[s, :len(ss)] = ss
                valid[s, :len(ss)] = True
                self._delta_live_s[s] -= len(ss)
                removed += len(ss)
            dlive = self._delete_delta_fn(pk)(
                (self._delta["x"], self._delta["bucket_ids"],
                 self._delta["ids"], self._delta["live"],
                 self._delta["count"]), slots_p, valid)
            self._delta = {**self._delta, "live": dlive}
        self._deletes += removed
        self._maybe_compact()
        return removed

    def _delete_main_fn(self, pk: int):
        key = ("del_main", pk)
        if key in self._fn_cache:
            return self._fn_cache[key]
        axis = self.data_axis

        def _del(leaves, rows, valid):
            live, counts, bids = (l[0] for l in leaves)
            ts = tomb_lib.Tombstones(live=live, counts=counts)
            row_buckets = bids[rows[0]]   # pad lanes: row 0, add-count 0
            nts = tomb_lib.mark_dead(ts, rows[0], row_buckets, valid[0])
            return nts.live[None], nts.counts[None]

        sh = P(axis)
        fn = jax.jit(shard_map(_del, mesh=self.mesh,
                               in_specs=((sh,) * 3, sh, sh),
                               out_specs=(sh, sh), check_rep=False))
        self._fn_cache[key] = fn
        return fn

    def _delete_delta_fn(self, pk: int):
        key = ("del_delta", pk)
        if key in self._fn_cache:
            return self._fn_cache[key]
        axis = self.data_axis

        def _del(leaves, slots, valid):
            delta = delta_lib.DeltaSegment(*(l[0] for l in leaves))
            return delta_lib.kill(delta, slots[0], valid[0]).live[None]

        sh = P(axis)
        fn = jax.jit(shard_map(_del, mesh=self.mesh,
                               in_specs=((sh,) * 5, sh, sh),
                               out_specs=sh, check_rep=False))
        self._fn_cache[key] = fn
        return fn

    # --------------------------------------------------------- compaction
    def _maybe_compact(self) -> None:
        reason = self.policy.reason(
            delta_count=int(self._delta_count_s.max()) if self._delta is not
            None else 0,
            delta_capacity=self.delta_capacity,
            n_main=int(self._main_rows_s.sum()),
            n_dead=self.n_dead)
        if reason:
            self.compact(reason=reason)

    def compact(self, reason: str = "manual") -> None:
        """Fold each shard's delta + drop its tombstones, in place.

        Per-shard: live rows stay on their shard and go through the
        ``build_tables`` fusion again — no cross-shard movement.
        """
        t0 = time.perf_counter()
        if self._delta is None:
            return
        dropped = self.n_dead + int(
            (self._delta_count_s - self._delta_live_s).sum())
        m, d = self._main, self._delta
        mx = np.asarray(m["x"])
        mids = np.asarray(m["ids"])
        mlive = np.asarray(m["live"])[:, :self._n_pad]
        dx = np.asarray(d["x"])[:, :self.delta_capacity]
        dids = np.asarray(d["ids"])[:, :self.delta_capacity]
        dlive = np.asarray(d["live"])[:, :self.delta_capacity]
        parts = []
        for s in range(self.shards):
            xs = np.concatenate([mx[s][mlive[s]], dx[s][dlive[s]]], axis=0)
            es = np.concatenate([mids[s][mlive[s]].astype(np.int64),
                                 dids[s][dlive[s]].astype(np.int64)])
            parts.append((xs, es))
        self._set_main(parts)
        self._reset_delta()
        self.stats.record(reason, t0, dropped)

    # ------------------------------------------------------------- query
    def query(self, queries: jax.Array, r: float,
              force: Optional[str] = None) -> ShardedQueryResult:
        """Hybrid r-NN reporting, union over shards; ids are external."""
        assert self._delta is not None, "index is empty: build/insert first"
        queries = jnp.asarray(queries)
        m, d = self._main, self._delta
        out = self._query_fn(self._n_pad, force)(
            (m["x"], m["ids"], m["perm"], m["starts"], m["registers"],
             m["live"], m["tomb_counts"]),
            (d["x"], d["bucket_ids"], d["ids"], d["live"], d["count"]),
            self.params, queries, jnp.float32(r))
        ids, dists, mask, coll, cand, used = (np.asarray(o) for o in out)
        return ShardedQueryResult(ids=ids, dists=dists, mask=mask,
                                  collisions=coll, cand_est=cand,
                                  used_lsh=used,
                                  n_queries=int(queries.shape[0]))

    def _query_fn(self, n_pad: int, force: Optional[str]):
        key = ("query", n_pad, force)
        if key in self._fn_cache:
            return self._fn_cache[key]
        family, cm, B = self.family, self.cost_model, self.num_buckets
        metric = family.metric
        cap, C = self.cap, self.delta_capacity
        # both cond branches must agree on the output width, and top_k
        # cannot widen a buffer: clamp by the narrower strategy's width
        max_out = min(self.max_out, n_pad + C + 1,
                      family.L * cap + C + 1)
        routing, axis = self.routing, self.data_axis
        engine = self._engine

        def _query(main_leaves, delta_leaves, params, queries, r):
            (mx, mids, perm, starts, regs, live, tcounts) = (
                l[0] for l in main_leaves)
            delta = delta_lib.DeltaSegment(*(l[0] for l in delta_leaves))
            qb = family.bucket_ids(params, queries, B)

            dview = delta_lib.DeltaView(delta, metric)
            d_est = dview.estimate_terms(qb)
            n_live_local = jnp.sum(delta.live, dtype=jnp.int32)
            n_scan_local = delta.count + n_pad
            segments, local_terms = [dview], [d_est]
            coll_local = d_est.collisions
            if n_pad > 0:
                tables = LSHTables(perm, starts, regs)
                main = TableSegment(
                    tables=tables, x=mx, metric=metric, cap=cap,
                    live=live, tomb_counts=tcounts, ext_ids=mids,
                    q_chunk=queries.shape[0])
                m_est = main.estimate_terms(qb)
                merged_local = hll_lib.merge_registers(
                    m_est.registers.astype(jnp.int32), axis=1)   # (Q, m)
                local_terms = [dataclasses.replace(
                    m_est, registers=None,
                    merged_registers=merged_local), d_est]
                segments = [main, dview]
                coll_local = coll_local + m_est.collisions
                n_live_local = n_live_local + jnp.sum(live,
                                                      dtype=jnp.int32)

            # cross-shard SegmentEstimate merge: psum exact terms, pmax
            # the HLL registers (distinct union across disjoint shards).
            merged = SegmentEstimate(
                collisions=jax.lax.psum(coll_local, axis),
                dead_collisions=(jax.lax.psum(m_est.dead_collisions, axis)
                                 if n_pad > 0 else None),
                merged_registers=(jax.lax.pmax(merged_local, axis)
                                  if n_pad > 0 else None),
                cand_exact=jax.lax.psum(
                    d_est.cand_exact.astype(jnp.float32), axis))
            n_live_g = jax.lax.psum(n_live_local, axis)
            n_scan_g = jax.lax.psum(n_scan_local, axis)
            route_g = finalize_route([merged], cm, n_live=n_live_g,
                                     n_scan=n_scan_g)
            route_l = finalize_route(local_terms, cm, n_live=n_live_local,
                                     n_scan=n_scan_local)

            route = route_g if routing == "global" else route_l
            use_lsh = (jnp.sum(route.lsh_cost)
                       < route.linear_cost * queries.shape[0])
            if force == "lsh":
                use_lsh = jnp.bool_(True)
            elif force == "linear":
                use_lsh = jnp.bool_(False)

            def branch(lsh_route):
                def fn(_):
                    ids, dists, mask = engine.search_group(
                        segments, qb, queries, r, lsh_route=lsh_route)
                    return compact_results(ids, dists, mask, max_out)
                return fn

            ids, dists, mask = jax.lax.cond(use_lsh, branch(True),
                                            branch(False), operand=None)
            return (ids[None], dists[None], mask[None], route_g.collisions,
                    route_g.cand_est, use_lsh[None])

        sh, rep = P(axis), P()
        fn = jax.jit(shard_map(
            _query, mesh=self.mesh,
            in_specs=((sh,) * 7, (sh,) * 5, rep, rep, rep),
            out_specs=(sh, sh, sh, rep, rep, sh), check_rep=False))
        self._fn_cache[key] = fn
        return fn

    # ------------------------------------------------------ observability
    def index_stats(self) -> Dict[str, object]:
        out = {
            "n_live": self.n,
            "n_main": int(self._main_rows_s.sum()),
            "n_main_dead": self.n_dead,
            "delta_count": int(self._delta_count_s.sum()),
            "delta_live": int(self._delta_live_s.sum()),
            "delta_capacity": self.delta_capacity,
            "shards": self.shards,
            "n_pad_per_shard": self._n_pad,
            "live_per_shard": self._main_live_s.tolist(),
            "delta_per_shard": self._delta_count_s.tolist(),
            "routing": self.routing,
            "inserts": self._inserts,
            "deletes": self._deletes,
        }
        out.update(self.stats.as_dict())
        return out

    # -------------------------------------------------------- checkpoint
    def state_dict(self) -> Dict[str, Dict[str, np.ndarray]]:
        """Sharded segment leaves as a flat-array pytree.

        Leaves keep their leading shard axis; restore re-places them on
        the current mesh (same shard count) with ``device_put``.  The
        tree structure is state-independent so a fresh index serves as
        the restore template.
        """
        S, L, B, m = (self.shards, self.family.L, self.num_buckets, self.m)
        if self._delta is not None:
            main = {k: np.asarray(v) for k, v in self._main.items()}
            delta = {k: np.asarray(v) for k, v in self._delta.items()}
        else:
            main = {"x": np.zeros((S, 0, 0), np.float32),
                    "ids": np.zeros((S, 0), np.int32),
                    "bucket_ids": np.zeros((S, 0, L), np.int32),
                    "perm": np.zeros((S, L, 0), np.int32),
                    "starts": np.zeros((S, L, B + 1), np.int32),
                    "registers": np.zeros((S, L, B, m), np.uint8),
                    "live": np.zeros((S, 1), bool),
                    "tomb_counts": np.zeros((S, L, B), np.int32)}
            C = self.delta_capacity
            delta = {"x": np.zeros((S, C + 1, 0), np.float32),
                     "bucket_ids": np.full((S, C + 1, L), -1, np.int32),
                     "ids": np.full((S, C + 1), -1, np.int32),
                     "live": np.zeros((S, C + 1), bool),
                     "count": np.zeros((S,), np.int32)}
        return {
            "params": self.params,
            "main": main,
            "delta": delta,
            "meta": {"next_id": np.int64(self._next_id),
                     "built": np.int64(0 if self._delta is None else 1)},
        }

    def load_state_dict(self, state) -> "ShardedDynamicHybridIndex":
        """Restore sharded segment state saved by ``state_dict``."""
        self.params = jax.tree_util.tree_map(jnp.asarray, state["params"])
        # cached query fns bake in delta_capacity (the max_out clamp):
        # a restore may change it, so the cache cannot survive
        self._fn_cache = {}
        self._next_id = int(np.asarray(state["meta"]["next_id"]))
        if int(np.asarray(state["meta"]["built"])) == 0:
            self._main = self._delta = None
            return self
        ms, ds = state["main"], state["delta"]
        S = np.asarray(ms["live"]).shape[0]
        assert S == self.shards, (S, self.shards)
        put = lambda a: jax.device_put(jnp.asarray(a), self._shard)
        self._main = {k: put(v) for k, v in ms.items()}
        self._delta = {k: put(v) for k, v in ds.items()}
        self._n_pad = int(np.asarray(ms["x"]).shape[1])
        self._d = int(np.asarray(ms["x"]).shape[2])
        self._dtype = np.asarray(ms["x"]).dtype
        self.delta_capacity = int(np.asarray(ds["live"]).shape[1]) - 1
        # host bookkeeping from segment state
        self._loc = {}
        mids = np.asarray(ms["ids"])
        mlive = np.asarray(ms["live"])[:, :self._n_pad]
        real = mids != -1
        self._main_rows_s = real.sum(axis=1).astype(np.int64)
        self._main_live_s = mlive.sum(axis=1).astype(np.int64)
        self._delta_count_s = np.asarray(ds["count"]).astype(np.int64)
        dlive = np.asarray(ds["live"])[:, :self.delta_capacity]
        self._delta_live_s = dlive.sum(axis=1).astype(np.int64)
        dids = np.asarray(ds["ids"])
        for s in range(self.shards):
            for i in np.nonzero(mlive[s])[0]:
                self._loc[int(mids[s, i])] = (s, "m", int(i))
            for i in range(int(self._delta_count_s[s])):
                if dlive[s, i]:
                    self._loc[int(dids[s, i])] = (s, "d", int(i))
        return self
