"""ShardedDynamicHybridIndex — the streaming index over the mesh.

Every shard of the ``data`` axis owns a full level-stack worth of
segment state:

  * levels — a list of frozen segments shared *structurally* across
             shards: every shard holds its own rows for level entry k,
             padded to one common ``n_pad`` so the whole level is a
             stack of sharded leaves.  Pad rows are hashed to bucket
             ``B`` (one past the bucket space), which the CSR
             ``segment_sum`` and the HLL ``segment_max`` drop exactly:
             padding costs capacity, never correctness.  HLLs are keyed
             on per-level globally-unique internal ids
             (shard * n_pad + row), so a ``pmax`` of merged registers
             per level is the exact distinct-union sketch across
             shards; levels are disjoint document sets, so their
             estimates sum — the engine's N-segment combination.
  * tomb   — per-(shard, level) live bitmap + per-(table, bucket) dead
             counts (the engine's tombstone correction terms).
  * delta  — per-shard fixed-capacity delta segment; inserts/deletes
             are the same fused ``.at[]`` scatters as the single-host
             index, applied under ``shard_map``.

When the deltas fill, every shard's live delta rows freeze in place
into one new level-0 entry (no cross-shard movement, no rehash — the
delta carries its hashes).  A tiered ``CompactionPolicy`` merges a
level's entries into the next level; merges are staged in bounded
``compact_step(budget_rows)`` increments (host gather of at most
``budget_rows`` rows per step across shards) and the merged level
swaps in atomically — queries keep being served from the old level
list until then.

A merge is also the one point rows *move between shards*: the staged
survivors are host-side anyway, so at swap time a pluggable
``PlacementPolicy`` (``keep_local`` / ``round_robin`` /
``load_balance``; see ``streaming.compaction``) assigns each surviving
row a target shard, the staging buffers are re-partitioned
accordingly, and ``_make_level`` rewrites the ``_loc`` entry of every
placed row.  The mid-merge delete re-check runs *before* placement, so
a row deleted while staged is dropped, never moved.  Rebalancing is
what keeps a skewed insert stream (e.g. ``insert(..., shard=0)``) from
pinning one shard's row count — and with it the common per-level
``n_pad`` every shard pays for — permanently high.

Queries run one ``shard_map`` per level structure: each shard builds
its engine segments (one ``TableSegment`` per level + ``DeltaView``),
merges ``SegmentEstimate`` terms across shards (``psum`` exact terms,
``pmax`` registers, per level), finalizes global and local routes via
the shared ``finalize_route``, and picks a strategy per the routing
policy (``"global"`` or the density-adaptive ``"per_shard"``).
Reported ids are external; after any churn — including mid-merge —
the reported sets match a fresh single-host build on the surviving
corpus per route.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.cost_model import CostModel
from repro.core.engine import (QueryEngine, SegmentEstimate, TableSegment,
                               _pad_size, compact_results, finalize_route)
from repro.core.lsh.tables import LSHTables, build_tables
from repro.core import hll as hll_lib
from repro.checkpoint.manager import array_digest
from repro.obs import Observability
from repro.obs.metrics import WorkPhases, time_block
from repro.streaming import delta as delta_lib
from repro.streaming import tombstones as tomb_lib
from repro.streaming.compaction import (CompactionPolicy, CompactionStats,
                                        PlacementPolicy,
                                        make_placement_policy)

__all__ = ["ShardedDynamicHybridIndex", "ShardedQueryResult"]

_LEAVES = ("x", "ids", "bucket_ids", "perm", "starts", "registers",
           "live", "tomb_counts")


@dataclasses.dataclass
class ShardedQueryResult:
    """Union-over-shards reporting buffers + routing diagnostics."""

    ids: np.ndarray         # (S, Q, max_out) external doc ids
    dists: np.ndarray       # (S, Q, max_out)
    mask: np.ndarray        # (S, Q, max_out) reported r-near neighbors
    collisions: np.ndarray  # (Q,) global live collisions
    cand_est: np.ndarray    # (Q,) global corrected candSize estimate
    used_lsh: np.ndarray    # (S,) per-shard strategy decision
    n_queries: int

    def neighbors(self, i: int) -> np.ndarray:
        return self.ids[:, i][self.mask[:, i]]

    def reported(self, i: int):
        """(ids, dists) reported for query ``i``, flattened over shards."""
        m = self.mask[:, i]
        return self.ids[:, i][m], self.dists[:, i][m]

    def neighbor_sets(self):
        return {i: set(self.neighbors(i).tolist())
                for i in range(self.n_queries)}

    @property
    def frac_linear(self) -> float:
        return float((~self.used_lsh).mean())

    @property
    def n_linear(self) -> int:
        """Queries served by linear search, scaled by the shard vote.

        Sharded routing is per-(batch, shard), so the exact per-query
        count of the single-host index degenerates to the shard
        fraction here.
        """
        return round(self.n_queries * self.frac_linear)


@dataclasses.dataclass
class _ShardLevel:
    """One level entry: sharded leaves + host-side accounting."""

    uid: int
    level: int
    n_pad: int                      # per-shard padded rows
    leaves: Dict[str, jax.Array]    # _LEAVES, leading dim = shard axis
    rows_s: np.ndarray              # (S,) real rows (tombstoned included)
    live_s: np.ndarray              # (S,)
    # content addresses of the immutable leaves, cached lazily by
    # state_digests() — deletes rebind only live/tomb_counts, so these
    # stay valid for the level's lifetime
    digests: Optional[Dict[str, str]] = None

    @property
    def n_rows(self) -> int:
        return int(self.rows_s.sum())

    @property
    def n_live(self) -> int:
        return int(self.live_s.sum())


@dataclasses.dataclass
class _ShardMergeTask:
    """A scheduled levels merge with per-(uid, shard) staging state."""

    uids: List[int]
    target_level: int
    reason: str
    shards: int
    # staging chunks: (uid, shard, row indices), rows, ids, hashes
    src: List[Tuple[int, int, np.ndarray]] = dataclasses.field(
        default_factory=list)
    rows: List[np.ndarray] = dataclasses.field(default_factory=list)
    ids: List[np.ndarray] = dataclasses.field(default_factory=list)
    bids: List[np.ndarray] = dataclasses.field(default_factory=list)
    pair_idx: int = 0       # cursor over (uid, shard) pairs
    row_off: int = 0
    steps: int = 0
    work_seconds: float = 0.0   # sum of this task's compact_step durations

    @property
    def pairs(self) -> List[Tuple[int, int]]:
        return [(u, s) for u in self.uids for s in range(self.shards)]

    @property
    def staged_done(self) -> bool:
        return self.pair_idx >= len(self.uids) * self.shards


class ShardedDynamicHybridIndex:
    """Streaming Hybrid LSH index, row-sharded over a mesh axis."""

    def __init__(self, family, *, num_buckets: int, mesh: Mesh, m: int = 64,
                 cap: int = 64, delta_capacity: int = 1024,
                 cost_model: CostModel = CostModel(alpha=1.0, beta=10.0),
                 policy: CompactionPolicy = CompactionPolicy(),
                 placement: "str | PlacementPolicy" = "keep_local",
                 routing: str = "per_shard", max_out: int = 512,
                 data_axis: str = "data", key: jax.Array | int = 0,
                 impl: Optional[str] = None,
                 obs: Optional[Observability] = None,
                 engine: Optional[QueryEngine] = None):
        """Args:
          family: LSH family (``make_family``); owns metric + hashes.
          num_buckets: buckets per table B; rows hash into [0, B), pad
            rows to B (dropped exactly by the CSR/HLL segment reductions).
          mesh: jax mesh whose ``data_axis`` rows are sharded over.
          m: HLL registers per bucket.
          cap: LSH candidate verification cap per (query, table).
          delta_capacity: per-shard delta slots before a freeze.
          cost_model: Algorithm 2 cost constants (alpha, beta).
          policy: when to freeze/merge (``CompactionPolicy``).
          placement: merge-time row placement across shards —
            ``"keep_local"`` (default; rows never move),
            ``"round_robin"``, ``"load_balance"``, or any
            ``PlacementPolicy`` instance.
          routing: ``"global"`` (one strategy for the batch) or
            ``"per_shard"`` (each shard votes with its local estimate).
          max_out: reported neighbors per (shard, query).
          data_axis: mesh axis name to shard rows over.
          key: PRNG key (or int seed) for the family parameters.
          impl: kernel impl override (e.g. ``"pallas_interpret"``).
          obs: observability bundle — events + work phases only here;
            per-query tracing needs the host-side single-index path
            (routing runs inside ``shard_map`` on this index).
          engine: a shared ``QueryEngine`` (multi-tenant collections
            pass one); default builds a private one from
            ``cost_model``.
        """
        assert routing in ("global", "per_shard"), routing
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self.family = family
        self.params = family.init(key)
        self.num_buckets = int(num_buckets)
        self.m = int(m)
        self.cap = int(cap)
        self.delta_capacity = int(delta_capacity)
        self.cost_model = cost_model
        self.policy = policy
        self.placement = make_placement_policy(placement)
        self.routing = routing
        self.max_out = int(max_out)
        self.mesh = mesh
        self.data_axis = data_axis
        self.shards = int(mesh.shape[data_axis])
        self.impl = impl
        self._engine = engine if engine is not None else QueryEngine(
            cost_model, impl=impl)
        self._shard = NamedSharding(mesh, P(data_axis))
        self.stats = CompactionStats()
        self.obs = obs if obs is not None else Observability.disabled()
        self.phases = WorkPhases("stage", "build", "apply", "full")

        # Result-cache invalidation: monotonic mutation version, bumped
        # on every insert, delete, freeze, merge swap (rebalancing
        # included), full compaction, and restore.
        self._version = 0

        # device state; delta None until first use
        self._levels: List[_ShardLevel] = []
        self._delta = None    # dict: x, bucket_ids, ids, live, count
        self._tasks: List[_ShardMergeTask] = []
        self._next_uid = 0
        self._d = None        # row width
        self._dtype = None

        # host bookkeeping
        self._loc: Dict[int, tuple] = {}   # ext -> (shard, "m", uid, row)
        #                                         | (shard, "d", slot)
        self._next_id = 0
        S = self.shards
        self._delta_count_s = np.zeros(S, np.int64)
        self._delta_live_s = np.zeros(S, np.int64)
        self._inserts = 0
        self._deletes = 0
        self._fn_cache: Dict[tuple, object] = {}

    # ------------------------------------------------------------- sizes
    @property
    def n(self) -> int:
        return (sum(l.n_live for l in self._levels)
                + int(self._delta_live_s.sum()))

    @property
    def n_frozen_rows(self) -> int:
        return sum(l.n_rows for l in self._levels)

    @property
    def n_dead(self) -> int:
        return sum(l.n_rows - l.n_live for l in self._levels)

    @property
    def version(self) -> int:
        """Monotonic mutation version — the result-cache key component.

        Changes whenever a query could report differently: insert,
        delete, freeze, merge swap (placement moves included), full
        compaction, restore.
        """
        return self._version

    def _next_uid_(self) -> int:
        u = self._next_uid
        self._next_uid += 1
        return u

    # ------------------------------------------------------------- build
    def build(self, x: jax.Array,
              ids: Optional[Sequence[int]] = None
              ) -> "ShardedDynamicHybridIndex":
        """Initial batch build; returns self.

        Args: ``x`` (n, d) corpus rows, dealt round-robin over shards;
        ``ids`` optional (n,) unique external ids (default 0..n-1).
        Replaces any existing state.
        """
        x = np.asarray(x)
        n = x.shape[0]
        if ids is None:
            ids = np.arange(n, dtype=np.int64)
        else:
            ids = np.asarray(ids, np.int64)
            assert len(set(ids.tolist())) == len(ids), "duplicate ids"
        self._d, self._dtype = int(x.shape[1]), x.dtype
        S = self.shards
        self._levels = []
        self._tasks = []
        self._loc = {}
        self._version += 1
        if n:
            parts = [(x[s::S], ids[s::S]) for s in range(S)]
            self._make_level(parts, self.policy.level_for(
                n, self.delta_capacity))
        self._reset_delta()
        self._next_id = int(ids.max()) + 1 if n else 0
        return self

    def _make_level(self, parts: List[tuple], level: int) -> _ShardLevel:
        """Per-shard (rows, ext_ids[, bucket_rows]) -> one padded level.

        With ``bucket_rows`` supplied (freezes and merges) the fused
        build skips re-hashing and runs straight from the staged hashes.
        """
        S, L, B = self.shards, self.family.L, self.num_buckets
        ks = [int(p[0].shape[0]) for p in parts]
        n_pad = _pad_size(max(max(ks), 1))
        xs = np.zeros((S, n_pad, self._d), self._dtype)
        ext = np.full((S, n_pad), -1, np.int32)
        valid = np.zeros((S, n_pad), bool)
        with_bids = len(parts[0]) == 3
        bids_p = np.full((S, n_pad, L), B, np.int32) if with_bids else None
        for s, p in enumerate(parts):
            k = ks[s]
            xs[s, :k] = p[0]
            ext[s, :k] = p[1]
            valid[s, :k] = True
            if with_bids and k:
                bids_p[s, :k] = p[2]
        put = lambda a: jax.device_put(jnp.asarray(a), self._shard)
        if with_bids:
            bids = put(bids_p)
            perm, starts, regs = self._build_from_bids_fn(n_pad)(
                bids, put(valid))
        else:
            bids, perm, starts, regs = self._build_fn(n_pad)(
                put(xs), put(valid), self.params)
        live = np.concatenate([valid, np.zeros((S, 1), bool)], axis=1)
        lvl = _ShardLevel(
            uid=self._next_uid_(), level=int(level), n_pad=n_pad,
            leaves={"x": put(xs), "ids": put(ext), "bucket_ids": bids,
                    "perm": perm, "starts": starts, "registers": regs,
                    "live": put(live),
                    "tomb_counts": put(np.zeros((S, L, B), np.int32))},
            rows_s=np.asarray(ks, np.int64),
            live_s=np.asarray(ks, np.int64))
        self._levels.append(lvl)
        self._version += 1
        for s, p in enumerate(parts):
            for i, e in enumerate(np.asarray(p[1]).tolist()):
                self._loc[int(e)] = (s, "m", lvl.uid, i)
        self._evict_stale_query_fns()
        return lvl

    def _evict_stale_query_fns(self) -> None:
        """Drop query fns compiled for level structures that no longer
        exist.  The query fn is specialized per tuple of level pad
        sizes; under streaming that tuple changes on every freeze/merge,
        so without eviction a long-running index accumulates one
        compiled executable per structure ever seen."""
        cur = tuple(l.n_pad for l in self._levels)
        self._fn_cache = {k: v for k, v in self._fn_cache.items()
                          if k[0] != "query" or k[1] == cur}

    def _build_fn(self, n_pad: int):
        """shard_map'd Algorithm 1 fusion over one padded row block."""
        key = ("build", n_pad)
        if key in self._fn_cache:
            return self._fn_cache[key]
        family, B, m = self.family, self.num_buckets, self.m
        axis = self.data_axis

        def _build(x, valid, params):
            x, valid = x[0], valid[0]
            shard = jax.lax.axis_index(axis)
            bids = family.bucket_ids(params, x, B).astype(jnp.int32)
            # pad rows hash to bucket B: dropped by the CSR segment_sum
            # and the HLL segment_max — invisible to every estimate.
            bids = jnp.where(valid[:, None], bids, B)
            gids = shard * n_pad + jnp.arange(n_pad, dtype=jnp.int32)
            t = build_tables(gids, bids, B, m)
            perm = t.perm - shard * n_pad
            return (bids[None], perm[None], t.starts[None],
                    t.registers[None])

        sh = P(axis)
        fn = jax.jit(shard_map(
            _build, mesh=self.mesh, in_specs=(sh, sh, P()),
            out_specs=(sh, sh, sh, sh), check_rep=False))
        self._fn_cache[key] = fn
        return fn

    def _build_from_bids_fn(self, n_pad: int):
        """Same fusion, from staged hashes (freeze/merge path)."""
        key = ("build_bids", n_pad)
        if key in self._fn_cache:
            return self._fn_cache[key]
        B, m = self.num_buckets, self.m
        axis = self.data_axis

        def _build(bids, valid):
            bids, valid = bids[0], valid[0]
            shard = jax.lax.axis_index(axis)
            bids = jnp.where(valid[:, None], bids.astype(jnp.int32), B)
            gids = shard * n_pad + jnp.arange(n_pad, dtype=jnp.int32)
            t = build_tables(gids, bids, B, m)
            perm = t.perm - shard * n_pad
            return perm[None], t.starts[None], t.registers[None]

        sh = P(axis)
        fn = jax.jit(shard_map(
            _build, mesh=self.mesh, in_specs=(sh, sh),
            out_specs=(sh, sh, sh), check_rep=False))
        self._fn_cache[key] = fn
        return fn

    def _reset_delta(self) -> None:
        S, C, L = self.shards, self.delta_capacity, self.family.L
        put = lambda a: jax.device_put(jnp.asarray(a), self._shard)
        self._delta = {
            "x": put(np.zeros((S, C + 1, self._d), self._dtype)),
            "bucket_ids": put(np.full((S, C + 1, L), -1, np.int32)),
            "ids": put(np.full((S, C + 1), -1, np.int32)),
            "live": put(np.zeros((S, C + 1), bool)),
            "count": put(np.zeros((S,), np.int32))}
        self._delta_count_s[:] = 0
        self._delta_live_s[:] = 0

    def _ensure_init(self, rows: np.ndarray) -> None:
        """First contact without build(): no levels, delta-only shards."""
        if self._delta is not None:
            return
        self._d, self._dtype = int(rows.shape[1]), rows.dtype
        self._levels = []
        self._reset_delta()

    # ------------------------------------------------------------ insert
    def insert(self, rows: jax.Array, ids: Optional[Sequence[int]] = None,
               shard: Optional[int] = None) -> np.ndarray:
        """Append documents to the shard deltas; returns external ids.

        Args:
          rows: (k, d) new document rows.
          ids: optional (k,) external ids (must be unused); default
            continues from the running counter.
          shard: pin the whole batch to one shard's delta (models
            key-hash placement; how skewed streams arise).  Default
            None water-fills the least-loaded deltas.

        Splits the batch by remaining per-shard delta capacity, freezing
        every shard's delta into a new level-0 entry when the target
        shard(s) fill.  A pinned skewed stream piles rows onto one
        shard; merge-time rebalancing (``placement``) is what spreads
        them back out.
        """
        rows = np.asarray(rows)
        if rows.shape[0] == 0:
            return np.zeros((0,), np.int64)
        if shard is not None and not 0 <= int(shard) < self.shards:
            raise ValueError(f"shard {shard} not in [0, {self.shards})")
        self._ensure_init(rows)
        if ids is None:
            ids = np.arange(self._next_id, self._next_id + rows.shape[0],
                            dtype=np.int64)
        else:
            ids = np.asarray(ids, np.int64)
            if len(set(ids.tolist())) != len(ids):
                raise KeyError("duplicate ids within insert batch")
        for e in ids.tolist():
            if e in self._loc:
                raise KeyError(f"id {e} already indexed")
        lo = 0
        while lo < rows.shape[0]:
            free = self.delta_capacity - self._delta_count_s
            if shard is not None:
                # pinned: only the target shard's capacity counts
                pin = np.zeros_like(free)
                pin[int(shard)] = free[int(shard)]
                free = pin
            if free.sum() == 0:
                self._freeze("delta_full")
                continue
            take = int(min(free.sum(), rows.shape[0] - lo))
            # round-robin water-fill over shards with free slots
            order = np.argsort(self._delta_count_s, kind="stable")
            assign: List[List[int]] = [[] for _ in range(self.shards)]
            left, cursor = take, 0
            free = free.copy()
            while left:
                s = (int(shard) if shard is not None
                     else int(order[cursor % self.shards]))
                cursor += 1
                if free[s] > len(assign[s]):
                    assign[s].append(lo + take - left)
                    left -= 1
            self._insert_chunk(rows, ids, assign)
            lo += take
        self._next_id = max(self._next_id, int(ids.max()) + 1)
        self._maybe_compact()
        return ids

    def _insert_chunk(self, rows: np.ndarray, ids: np.ndarray,
                      assign: List[List[int]]) -> None:
        S = self.shards
        pk = _pad_size(max(max(len(a) for a in assign), 1))
        rows_p = np.zeros((S, pk, self._d), self._dtype)
        ids_p = np.zeros((S, pk), np.int32)
        valid = np.zeros((S, pk), bool)
        for s, idxs in enumerate(assign):
            k = len(idxs)
            rows_p[s, :k] = rows[idxs]
            ids_p[s, :k] = ids[idxs]
            valid[s, :k] = True
            base = int(self._delta_count_s[s])
            for i, j in enumerate(idxs):
                self._loc[int(ids[j])] = (s, "d", base + i)
            self._delta_count_s[s] += k
            self._delta_live_s[s] += k
            self._inserts += k
        d = self._delta
        out = self._insert_fn(pk)(
            (d["x"], d["bucket_ids"], d["ids"], d["live"], d["count"]),
            self.params, rows_p, ids_p, valid)
        self._delta = dict(zip(("x", "bucket_ids", "ids", "live", "count"),
                               out))
        self._version += 1

    def _insert_fn(self, pk: int):
        key = ("insert", pk)
        if key in self._fn_cache:
            return self._fn_cache[key]
        family, B = self.family, self.num_buckets
        axis = self.data_axis

        def _ins(leaves, params, rows, ext, valid):
            delta = delta_lib.DeltaSegment(*(l[0] for l in leaves))
            bids = family.bucket_ids(params, rows[0], B)
            nd = delta_lib.insert(delta, rows[0], bids, ext[0], valid[0])
            return (nd.x[None], nd.bucket_ids[None], nd.ids[None],
                    nd.live[None], nd.count[None])

        sh = P(axis)
        fn = jax.jit(shard_map(
            _ins, mesh=self.mesh,
            in_specs=((sh,) * 5, P(), sh, sh, sh),
            out_specs=(sh,) * 5, check_rep=False))
        self._fn_cache[key] = fn
        return fn

    # ------------------------------------------------------------ delete
    def delete(self, ids: Iterable[int], strict: bool = False) -> int:
        """Tombstone documents by external id; returns #removed.

        Unknown (or already-deleted) ids are skipped unless ``strict``
        (KeyError).  Deletes mark per-(shard, level) live bitmaps and
        bump per-bucket dead counts; tables are never mutated, and a
        row staged in a pending merge is dropped at swap time.
        """
        S = self.shards
        by_uid: Dict[int, List[List[int]]] = {}
        delta_slots: List[List[int]] = [[] for _ in range(S)]
        for e in ids:
            loc = self._loc.pop(int(e), None)
            if loc is None:
                if strict:
                    raise KeyError(e)
                continue
            s, kind = loc[0], loc[1]
            if kind == "d":
                delta_slots[s].append(loc[2])
            else:
                by_uid.setdefault(loc[2],
                                  [[] for _ in range(S)])[s].append(loc[3])
        removed = 0
        for uid, main_rows in by_uid.items():
            lvl = self._level_by_uid(uid)
            pk = _pad_size(max(max(len(a) for a in main_rows), 1))
            rows_p = np.zeros((S, pk), np.int32)
            valid = np.zeros((S, pk), bool)
            for s, rr in enumerate(main_rows):
                rows_p[s, :len(rr)] = rr
                valid[s, :len(rr)] = True
                lvl.live_s[s] -= len(rr)
                removed += len(rr)
            live, counts = self._delete_main_fn(pk)(
                (lvl.leaves["live"], lvl.leaves["tomb_counts"],
                 lvl.leaves["bucket_ids"]), rows_p, valid)
            lvl.leaves = {**lvl.leaves, "live": live, "tomb_counts": counts}
        if any(delta_slots):
            pk = _pad_size(max(max(len(a) for a in delta_slots), 1))
            slots_p = np.zeros((S, pk), np.int32)
            valid = np.zeros((S, pk), bool)
            for s, ss in enumerate(delta_slots):
                slots_p[s, :len(ss)] = ss
                valid[s, :len(ss)] = True
                self._delta_live_s[s] -= len(ss)
                removed += len(ss)
            dlive = self._delete_delta_fn(pk)(
                (self._delta["x"], self._delta["bucket_ids"],
                 self._delta["ids"], self._delta["live"],
                 self._delta["count"]), slots_p, valid)
            self._delta = {**self._delta, "live": dlive}
        self._deletes += removed
        if removed:
            self._version += 1
        self._maybe_compact()
        return removed

    def _level_by_uid(self, uid: int) -> _ShardLevel:
        for l in self._levels:
            if l.uid == uid:
                return l
        raise KeyError(uid)

    def _delete_main_fn(self, pk: int):
        key = ("del_main", pk)
        if key in self._fn_cache:
            return self._fn_cache[key]
        axis = self.data_axis

        def _del(leaves, rows, valid):
            live, counts, bids = (l[0] for l in leaves)
            ts = tomb_lib.Tombstones(live=live, counts=counts)
            row_buckets = bids[rows[0]]   # pad lanes: row 0, add-count 0
            nts = tomb_lib.mark_dead(ts, rows[0], row_buckets, valid[0])
            return nts.live[None], nts.counts[None]

        sh = P(axis)
        fn = jax.jit(shard_map(_del, mesh=self.mesh,
                               in_specs=((sh,) * 3, sh, sh),
                               out_specs=(sh, sh), check_rep=False))
        self._fn_cache[key] = fn
        return fn

    def _delete_delta_fn(self, pk: int):
        key = ("del_delta", pk)
        if key in self._fn_cache:
            return self._fn_cache[key]
        axis = self.data_axis

        def _del(leaves, slots, valid):
            delta = delta_lib.DeltaSegment(*(l[0] for l in leaves))
            return delta_lib.kill(delta, slots[0], valid[0]).live[None]

        sh = P(axis)
        fn = jax.jit(shard_map(_del, mesh=self.mesh,
                               in_specs=((sh,) * 5, sh, sh),
                               out_specs=sh, check_rep=False))
        self._fn_cache[key] = fn
        return fn

    # --------------------------------------------------------- compaction
    def _freeze(self, reason: str) -> None:
        """Seal every shard's live delta rows into one level-0 entry.

        Rows stay on their shard; the delta already carries its hashes,
        so the freeze is one fused from-hashes build over at most
        delta_capacity rows per shard.
        """
        if self._delta is None or self._delta_count_s.sum() == 0:
            return
        C = self.delta_capacity
        dx = np.asarray(self._delta["x"])[:, :C]
        dids = np.asarray(self._delta["ids"])[:, :C]
        dbids = np.asarray(self._delta["bucket_ids"])[:, :C]
        dlive = np.asarray(self._delta["live"])[:, :C]
        parts = []
        total = 0
        for s in range(self.shards):
            live = dlive[s]
            parts.append((dx[s][live], dids[s][live].astype(np.int64),
                          dbids[s][live]))
            total += int(live.sum())
        self._reset_delta()
        if total == 0:
            return
        self._make_level(parts, level=0)
        self.stats.record_freeze(total)
        self.obs.events.emit("freeze", rows=total, reason=reason)

    def _maybe_compact(self) -> None:
        if self._delta is not None:
            r = self.policy.freeze_reason(
                delta_count=int(self._delta_count_s.max()),
                delta_capacity=self.delta_capacity)
            if r:
                self._freeze(r)
        self._schedule_merges()
        if self.policy.step_rows is None:
            self._drain()

    def _pending_uids(self) -> set:
        return {u for t in self._tasks for u in t.uids}

    def _schedule_merges(self) -> None:
        if not self._levels:
            return
        pend = self._pending_uids()
        free = [l for l in self._levels if l.uid not in pend]
        counts: Dict[int, int] = {}
        for l in free:
            counts[l.level] = counts.get(l.level, 0) + 1
        for reason, src, target in self.policy.plan_merges(
                level_counts=counts, n_rows=self.n_frozen_rows,
                n_dead=self.n_dead,
                n_live=sum(l.n_live for l in self._levels),
                unit=self.delta_capacity, can_full=not pend):
            uids = [l.uid for l in free if src is None or l.level == src]
            if uids:
                self._tasks.append(_ShardMergeTask(
                    uids=uids, target_level=target,
                    reason=reason, shards=self.shards))
                self.obs.events.emit("merge_scheduled", uids=uids,
                                     target_level=target, reason=reason)

    @property
    def has_compaction_work(self) -> bool:
        return bool(self._tasks)

    @property
    def staged_ready(self) -> bool:
        """A fully-staged merge awaits a control-thread ``apply_staged``."""
        return bool(self._tasks) and self._tasks[0].staged_done

    @property
    def staged_rows(self) -> int:
        """Rows currently gathered into merge staging buffers."""
        return sum(sum(len(r) for r in t.rows) for t in self._tasks)

    @property
    def pending_merges(self) -> int:
        """Queued merge tasks (head may be partially staged)."""
        return len(self._tasks)

    def stage_step(self, budget_rows: Optional[int] = None) -> str:
        """Advance ONLY the staging half of the active merge.

        The worker-thread half of the ``CompactionDriver`` split: walks
        the head task's per-(segment, shard) staging cursors, gathering
        at most ``budget_rows`` live rows across shards into private
        host buffers.  The served level list is untouched, so this is
        safe concurrently with control-thread inserts/deletes/queries.
        Returns ``"idle"`` | ``"staging"`` | ``"ready"``; once
        ``"ready"``, only a control-thread ``apply_staged`` (the swap +
        placement + ``_loc`` rewrites) makes further progress.
        """
        if not self._tasks:
            return "idle"
        task = self._tasks[0]
        if task.staged_done:
            return "ready"
        budget = int(budget_rows or self.policy.step_rows
                     or max(self.delta_capacity, 1))
        task.steps += 1
        self.stats.record_step()
        with time_block(phases=self.phases, phase="stage") as tb:
            self._stage(task, budget)
        task.work_seconds += tb.elapsed
        return "ready" if task.staged_done else "staging"

    def prepare_staged(self) -> bool:
        """No-op on the sharded index (returns False).

        The single-host stack pre-builds a staged merge's output on the
        driver's worker (``DynamicHybridIndex.prepare_staged``); here
        the build cannot run early because the ``PlacementPolicy``
        partitions the staged rows using per-shard live loads *at swap
        time* — pre-building would bake in stale placement.  The swap
        (build included) therefore stays in ``apply_staged`` on the
        control thread; the staging gathers — the O(rows) churn-scaling
        half — still run on the worker.
        """
        return False

    def apply_staged(self) -> bool:
        """CONTROL-THREAD ONLY: swap a fully-staged merge in.

        Runs the mid-merge delete re-check, the ``PlacementPolicy``
        target assignment, the fused build of the new level, the atomic
        level-list swap with its ``_loc`` rewrites, and schedules
        cascaded merges.  Returns True when a merge was applied.
        """
        if not self._tasks or not self._tasks[0].staged_done:
            return False
        task = self._tasks[0]
        task.steps += 1
        self.stats.record_step()
        with time_block(phases=self.phases, phase="apply") as tb:
            total, dropped, moved = self._finalize_merge(task)
        task.work_seconds += tb.elapsed
        self.stats.record_merge(task.target_level, total, task.steps,
                                task.work_seconds, dropped,
                                reason=task.reason, moved=moved)
        self._emit_swap(task, total, dropped, moved)
        self._schedule_merges()       # cascade up the levels
        return True

    def compact_step(self, budget_rows: Optional[int] = None) -> bool:
        """Advance the active merge by one bounded step (gather + hash of
        at most ``budget_rows`` rows across shards, or — once staging is
        complete — the fused build + atomic level swap).  Returns True
        while more work remains."""
        if not self._tasks:
            return False
        budget = int(budget_rows or self.policy.step_rows
                     or max(self.delta_capacity, 1))
        task = self._tasks[0]
        task.steps += 1
        self.stats.record_step()
        if not task.staged_done:
            with time_block(phases=self.phases, phase="stage") as tb:
                self._stage(task, budget)
            task.work_seconds += tb.elapsed
            if not task.staged_done:
                return True
        with time_block(phases=self.phases, phase="apply") as tb:
            total, dropped, moved = self._finalize_merge(task)
        task.work_seconds += tb.elapsed
        self.stats.record_merge(task.target_level, total, task.steps,
                                task.work_seconds, dropped,
                                reason=task.reason, moved=moved)
        self._emit_swap(task, total, dropped, moved)
        self._schedule_merges()       # cascade up the levels
        return bool(self._tasks)

    def _emit_swap(self, task: "_ShardMergeTask", total: int, dropped: int,
                   moved: int) -> None:
        self.obs.events.emit("swap", target_level=task.target_level,
                             rows=total, dropped=dropped, steps=task.steps,
                             seconds=task.work_seconds, reason=task.reason)
        if moved:
            self.obs.events.emit("rebalance", rows_moved=moved,
                                 target_level=task.target_level,
                                 placement=self.placement.name)

    def _stage(self, task: _ShardMergeTask, budget: int) -> None:
        pairs = task.pairs
        left = max(budget, 1)
        while left > 0 and not task.staged_done:
            uid, s = pairs[task.pair_idx]
            lvl = self._level_by_uid(uid)
            n_rows = int(lvl.rows_s[s])
            if task.row_off >= n_rows:
                task.pair_idx += 1
                task.row_off = 0
                continue
            hi = min(n_rows, task.row_off + left)
            idx = np.arange(task.row_off, hi)
            live = np.asarray(lvl.leaves["live"][s, task.row_off:hi])
            idx = idx[live]
            if len(idx):
                task.src.append((uid, s, idx))
                task.rows.append(np.asarray(
                    lvl.leaves["x"][s, task.row_off:hi])[live])
                task.ids.append(np.asarray(
                    lvl.leaves["ids"][s, task.row_off:hi])[live])
                task.bids.append(np.asarray(
                    lvl.leaves["bucket_ids"][s, task.row_off:hi])[live])
            left -= hi - task.row_off
            task.row_off = hi

    def _finalize_merge(self, task: _ShardMergeTask) -> Tuple[int, int, int]:
        """Swap the staged merge in; returns (rows kept, dropped, moved).

        Order matters: (1) re-check every staged row against the
        *current* live bitmap — deletes that landed mid-merge must not
        resurrect; (2) hand the survivors (with their origin shards) to
        the placement policy; (3) re-partition the staging buffers by
        target shard and build the new level, whose ``_make_level``
        rewrites ``_loc`` for every row — moved rows included — before
        the old levels' entries are forgotten.
        """
        S = self.shards
        surv: List[tuple] = []   # (origin shard, rows, ids, bids)
        for (uid, s, idx), rows, ids, bids in zip(task.src, task.rows,
                                                  task.ids, task.bids):
            live = np.asarray(self._level_by_uid(uid).leaves["live"][s])[idx]
            if live.any():
                surv.append((s, rows[live], ids[live].astype(np.int64),
                             bids[live]))
        total_in = sum(self._level_by_uid(u).n_rows for u in task.uids)
        self._tasks.pop(0)
        self._levels = [l for l in self._levels if l.uid not in task.uids]
        self._version += 1
        if not surv:
            self._evict_stale_query_fns()
            return 0, total_in, 0
        origins = np.concatenate(
            [np.full(len(c[2]), c[0], np.int64) for c in surv])
        xs = np.concatenate([c[1] for c in surv], axis=0)
        es = np.concatenate([c[2] for c in surv])
        bs = np.concatenate([c[3] for c in surv], axis=0)
        # base load: live rows per shard outside this merge (surviving
        # levels — other pending merges' inputs included, they keep
        # their shard until their own swap — plus the delta); the merged
        # levels are already dropped from _levels, so shard_loads() is
        # exactly this base
        base = self.shard_loads()
        targets = np.asarray(
            self.placement.assign(origins, base, S), np.int64)
        # hard-validate the public extension point: a buggy custom
        # policy must fail the merge loudly, not silently drop rows
        # whose _loc entries would then dangle
        if targets.shape != origins.shape or not (
                (0 <= targets) & (targets < S)).all():
            raise ValueError(
                f"placement policy {self.placement.name!r} returned bad "
                f"targets (shape {targets.shape}, expected "
                f"{origins.shape}, values must be in [0, {S}))")
        moved = int((targets != origins).sum())
        parts = []
        for s in range(S):
            sel = targets == s
            parts.append((xs[sel], es[sel], bs[sel]))
        self._make_level(parts, level=task.target_level)
        return len(es), total_in - len(es), moved

    def _drain(self) -> None:
        while self._tasks:
            self.compact_step(budget_rows=max(self.n_frozen_rows, 1))

    def compact(self, reason: str = "manual") -> None:
        """Blocking full compaction: fold every level + the delta into
        one level per shard (drops tombstones).  Pending merge staging
        is discarded, not drained — the fold re-gathers everything, so
        finishing a partial merge first would build a level the fold
        immediately throws away."""
        t0 = time.perf_counter()
        if self._delta is None:
            return
        self._tasks = []
        dropped = self.n_dead + int(
            (self._delta_count_s - self._delta_live_s).sum())
        S, C = self.shards, self.delta_capacity
        dx = np.asarray(self._delta["x"])[:, :C]
        dids = np.asarray(self._delta["ids"])[:, :C]
        dbids = np.asarray(self._delta["bucket_ids"])[:, :C]
        dlive = np.asarray(self._delta["live"])[:, :C]
        parts, total = [], 0
        for s in range(S):
            xs, es, bs = [dx[s][dlive[s]]], \
                [dids[s][dlive[s]].astype(np.int64)], [dbids[s][dlive[s]]]
            for lvl in self._levels:
                live = np.asarray(lvl.leaves["live"][s, :lvl.n_pad])
                xs.append(np.asarray(lvl.leaves["x"][s])[live])
                es.append(np.asarray(
                    lvl.leaves["ids"][s])[live].astype(np.int64))
                bs.append(np.asarray(lvl.leaves["bucket_ids"][s])[live])
            x = np.concatenate(xs, axis=0)
            parts.append((x, np.concatenate(es), np.concatenate(bs, axis=0)))
            total += x.shape[0]
        self._levels = []
        self._version += 1
        self._reset_delta()
        if total:
            self._make_level(parts, self.policy.level_for(
                total, self.delta_capacity))
        self.stats.record(reason, t0, dropped)
        # record() measured the fold from t0; reuse its number — one
        # measurement, reported by both stats and the phase accumulator.
        self.phases.add("full", self.stats.last_seconds)
        self.obs.events.emit("full_compact", reason=reason, dropped=dropped,
                             seconds=self.stats.last_seconds)

    # ------------------------------------------------------------- query
    def query(self, queries: jax.Array, r: float,
              force: Optional[str] = None) -> ShardedQueryResult:
        """Hybrid r-NN reporting, union over shards; ids are external.

        Args:
          queries: (Q, d) rows, replicated to every shard.
          r: report radius — every returned neighbor has dist <= r.
          force: None (hybrid routing) | "lsh" | "linear" override.

        Returns a ``ShardedQueryResult`` with (S, Q, max_out) reporting
        buffers (union over the shard axis; ``neighbors(i)`` flattens
        it) plus global routing diagnostics.
        """
        assert self._delta is not None, "index is empty: build/insert first"
        queries = jnp.asarray(queries)
        d = self._delta
        n_pads = tuple(l.n_pad for l in self._levels)
        level_leaves = tuple(
            tuple(l.leaves[k] for k in _LEAVES) for l in self._levels)
        out = self._query_fn(n_pads, force)(
            level_leaves,
            (d["x"], d["bucket_ids"], d["ids"], d["live"], d["count"]),
            self.params, queries, jnp.float32(r))
        ids, dists, mask, coll, cand, used = (np.asarray(o) for o in out)
        return ShardedQueryResult(ids=ids, dists=dists, mask=mask,
                                  collisions=coll, cand_est=cand,
                                  used_lsh=used,
                                  n_queries=int(queries.shape[0]))

    def _query_fn(self, n_pads: Tuple[int, ...], force: Optional[str]):
        key = ("query", n_pads, force)
        if key in self._fn_cache:
            return self._fn_cache[key]
        family, cm, B = self.family, self.cost_model, self.num_buckets
        metric = family.metric
        cap, C = self.cap, self.delta_capacity
        # both cond branches must agree on the output width, and top_k
        # cannot widen a buffer: clamp by the narrower strategy's width
        max_out = min(self.max_out, sum(n_pads) + C + 1,
                      len(n_pads) * family.L * cap + C + 1)
        routing, axis = self.routing, self.data_axis
        engine, impl = self._engine, self.impl

        def _query(level_leaves, delta_leaves, params, queries, r):
            delta = delta_lib.DeltaSegment(*(l[0] for l in delta_leaves))
            qb = family.bucket_ids(params, queries, B)

            dview = delta_lib.DeltaView(delta, metric, impl=impl)
            d_est = dview.estimate_terms(qb)
            n_live_local = jnp.sum(delta.live, dtype=jnp.int32)
            n_scan_local = delta.count + sum(n_pads)
            segments, local_terms, global_terms = [], [], []
            for leaves, n_pad in zip(level_leaves, n_pads):
                (mx, mids, _bids, perm, starts, regs, live,
                 tcounts) = (l[0] for l in leaves)
                main = TableSegment(
                    tables=LSHTables(perm, starts, regs), x=mx,
                    metric=metric, cap=cap, live=live,
                    tomb_counts=tcounts, ext_ids=mids,
                    q_chunk=queries.shape[0], impl=impl)
                m_est = main.estimate_terms(qb)
                merged_local = hll_lib.merge_registers(
                    m_est.registers.astype(jnp.int32), axis=1)   # (Q, m)
                local_terms.append(dataclasses.replace(
                    m_est, registers=None,
                    merged_registers=merged_local))
                # cross-shard merge, per level: psum exact terms, pmax
                # the HLL registers (each level's internal ids are
                # globally unique, and levels are disjoint doc sets, so
                # pmax-per-level + sum-across-levels is exact).
                global_terms.append(SegmentEstimate(
                    collisions=jax.lax.psum(m_est.collisions, axis),
                    dead_collisions=jax.lax.psum(m_est.dead_collisions,
                                                 axis),
                    merged_registers=jax.lax.pmax(merged_local, axis)))
                segments.append(main)
                n_live_local = n_live_local + jnp.sum(live,
                                                      dtype=jnp.int32)
            segments.append(dview)
            local_terms.append(d_est)
            global_terms.append(SegmentEstimate(
                collisions=jax.lax.psum(d_est.collisions, axis),
                cand_exact=jax.lax.psum(
                    d_est.cand_exact.astype(jnp.float32), axis)))
            n_live_g = jax.lax.psum(n_live_local, axis)
            n_scan_g = jax.lax.psum(n_scan_local, axis)
            route_g = finalize_route(global_terms, cm, n_live=n_live_g,
                                     n_scan=n_scan_g)
            route_l = finalize_route(local_terms, cm, n_live=n_live_local,
                                     n_scan=n_scan_local)

            route = route_g if routing == "global" else route_l
            use_lsh = (jnp.sum(route.lsh_cost)
                       < route.linear_cost * queries.shape[0])
            if force == "lsh":
                use_lsh = jnp.bool_(True)
            elif force == "linear":
                use_lsh = jnp.bool_(False)

            def branch(lsh_route):
                def fn(_):
                    ids, dists, mask = engine.search_group(
                        segments, qb, queries, r, lsh_route=lsh_route)
                    return compact_results(ids, dists, mask, max_out)
                return fn

            ids, dists, mask = jax.lax.cond(use_lsh, branch(True),
                                            branch(False), operand=None)
            return (ids[None], dists[None], mask[None], route_g.collisions,
                    route_g.cand_est, use_lsh[None])

        sh, rep = P(axis), P()
        fn = jax.jit(shard_map(
            _query, mesh=self.mesh,
            in_specs=(tuple((sh,) * len(_LEAVES) for _ in n_pads),
                      (sh,) * 5, rep, rep, rep),
            out_specs=(sh, sh, sh, rep, rep, sh), check_rep=False))
        self._fn_cache[key] = fn
        return fn

    # ------------------------------------------------------ observability
    def shard_of(self, ext_id: int) -> int:
        """Shard currently holding a live document (KeyError if absent).

        The answer is only stable until the next merge: rebalancing may
        move the row at swap time.
        """
        return self._loc[int(ext_id)][0]

    def validate_locations(self) -> int:
        """Debug invariant check: every ``_loc`` entry resolves to a live
        row whose stored external id matches, and every live device row
        is reachable.  Returns the number of live rows checked; raises
        AssertionError on any inconsistency.  Host-side and O(n) — for
        tests and debugging, not the serving path."""
        n_checked = 0
        # snapshot device arrays once: per-element jax indexing would
        # pay one device round-trip per live row
        by_uid = {l.uid: (l, np.asarray(l.leaves["live"]),
                          np.asarray(l.leaves["ids"]))
                  for l in self._levels}
        d_live = np.asarray(self._delta["live"]) if self._delta else None
        d_ids = np.asarray(self._delta["ids"]) if self._delta else None
        for e, loc in self._loc.items():
            s, kind = loc[0], loc[1]
            if kind == "m":
                uid, row = loc[2], loc[3]
                entry = by_uid.get(uid)
                assert entry is not None, (e, loc, "level gone")
                lvl, live, ids = entry
                assert row < int(lvl.rows_s[s]), (e, loc, "row out of range")
                assert bool(live[s, row]), (e, loc, "dead row")
                assert int(ids[s, row]) == e, (e, loc, "id mismatch")
            else:
                slot = loc[2]
                assert bool(d_live[s, slot]), (e, loc, "dead")
                assert int(d_ids[s, slot]) == e, (e, loc, "id mismatch")
            n_checked += 1
        total_live = (sum(l.n_live for l in self._levels)
                      + int(self._delta_live_s.sum()))
        assert n_checked == total_live, (n_checked, total_live)
        return n_checked

    def shard_loads(self) -> np.ndarray:
        """(S,) live rows per shard (levels + delta)."""
        loads = self._delta_live_s.copy()
        for l in self._levels:
            loads += l.live_s
        return loads

    def index_stats(self) -> Dict[str, object]:
        """Size/level/compaction counters snapshot (host ints/lists).

        Adds the sharded extras to the single-host set: per-shard live
        and delta loads, ``placement``, ``rows_moved`` (cumulative rows
        rebalanced at merges) and ``shard_skew`` = max/mean live load —
        1.0 is perfectly balanced; keep_local under a skewed stream
        grows it toward S.
        """
        S = self.shards
        live_per_shard = np.zeros(S, np.int64)
        for l in self._levels:
            live_per_shard += l.live_s
        loads = live_per_shard + self._delta_live_s
        skew = (float(loads.max() / loads.mean())
                if loads.sum() else 1.0)
        levels: Dict[int, int] = {}
        for l in self._levels:
            levels[l.level] = levels.get(l.level, 0) + 1
        out = {
            "n_live": self.n,
            "n_main": self.n_frozen_rows,
            "n_main_dead": self.n_dead,
            "delta_count": int(self._delta_count_s.sum()),
            "delta_live": int(self._delta_live_s.sum()),
            "delta_capacity": self.delta_capacity,
            "shards": S,
            "segments": len(self._levels),
            "levels": levels,
            "level_n_pads": [l.n_pad for l in self._levels],
            "pending_merges": len(self._tasks),
            "live_per_shard": live_per_shard.tolist(),
            "delta_per_shard": self._delta_count_s.tolist(),
            "shard_skew": skew,
            "placement": self.placement.name,
            "routing": self.routing,
            "inserts": self._inserts,
            "deletes": self._deletes,
            "work_seconds": self.compaction_work_seconds,
        }
        out.update(self.stats.as_dict())
        return out

    @property
    def compaction_work_seconds(self) -> Dict[str, float]:
        """Per-phase compaction work (stage/build/apply/full + total) —
        the same accumulator the driver's ``stats()`` reports, so the
        two surfaces can never disagree or double-count."""
        return self.phases.as_dict()

    # -------------------------------------------------------- checkpoint
    def state_dict(self) -> Dict[str, Dict[str, np.ndarray]]:
        """Sharded level-stack leaves as a nested flat-array pytree.

        Leaves keep their leading shard axis; restore re-places them on
        the current mesh (same shard count) with ``device_put``.  The
        level list varies, so restore goes through the manifest-driven
        ``CheckpointManager.restore_index`` (no template).  Staged merge
        progress is volatile — inputs are still complete levels, so a
        restore loses no data and the policy re-schedules.  Rebalanced
        level layouts round-trip exactly (per-shard ``rows_s``/``live_s``
        ride in each level's meta), and the placement policy name rides
        in the top-level meta so a restored index keeps rebalancing.
        """
        S, L = self.shards, self.family.L
        levels: Dict[str, Dict] = {}
        for i, l in enumerate(self._levels):
            levels[f"{i:04d}"] = {
                **{k: np.asarray(v) for k, v in l.leaves.items()},
                "meta": {"uid": np.int64(l.uid),
                         "level": np.int64(l.level),
                         "rows_s": l.rows_s.astype(np.int64),
                         "live_s": l.live_s.astype(np.int64)},
            }
        if self._delta is not None:
            delta = {k: np.asarray(v) for k, v in self._delta.items()}
        else:
            C = self.delta_capacity
            delta = {"x": np.zeros((S, C + 1, 0), np.float32),
                     "bucket_ids": np.full((S, C + 1, L), -1, np.int32),
                     "ids": np.full((S, C + 1), -1, np.int32),
                     "live": np.zeros((S, C + 1), bool),
                     "count": np.zeros((S,), np.int32)}
        return {
            "params": self.params,
            "levels": levels,
            "delta": delta,
            "meta": {"next_id": np.int64(self._next_id),
                     "built": np.int64(0 if self._delta is None else 1),
                     "next_uid": np.int64(self._next_uid),
                     # 0-d unicode array: np.save round-trips it, and a
                     # restored index keeps rebalancing the same way
                     "placement": np.array(self.placement.name)},
        }

    # the six build-time leaves of a level; only live/tomb_counts
    # rebind after construction, so their digests can be cached
    _IMMUTABLE_LEAVES = ("x", "ids", "bucket_ids", "perm", "starts",
                         "registers")

    def state_digests(self) -> Dict[str, str]:
        """Content addresses for the immutable level leaves.

        Cached on each ``_ShardLevel``: deletes rebind only
        ``live``/``tomb_counts``, so the build-time leaves never change
        for the level's lifetime.  Feeding these hints to
        ``CheckpointManager.save_incremental`` makes snapshot hashing
        O(delta + tombstones) instead of O(corpus).
        """
        out: Dict[str, str] = {}
        for i, l in enumerate(self._levels):
            if l.digests is None:
                l.digests = {k: array_digest(np.asarray(l.leaves[k]))
                             for k in self._IMMUTABLE_LEAVES}
            for k, dg in l.digests.items():
                out[f"levels/{i:04d}/{k}"] = dg
        return out

    def load_state_dict(self, state) -> "ShardedDynamicHybridIndex":
        """Restore sharded level-stack state saved by ``state_dict``.

        The saved shard count may differ from the current mesh: leaves
        are mesh-agnostic host arrays with a leading shard axis, so a
        mismatch routes through ``_load_elastic`` which re-deals live
        rows onto the current shards.
        """
        self.params = jax.tree_util.tree_map(jnp.asarray, state["params"])
        # cached query fns bake in delta_capacity (the max_out clamp):
        # a restore may change it, so the cache cannot survive
        self._fn_cache = {}
        self._tasks = []
        self._version += 1
        self._next_id = int(np.asarray(state["meta"]["next_id"]))
        self._next_uid = int(np.asarray(state["meta"].get("next_uid", 0)))
        pl = state["meta"].get("placement")
        if pl is not None:      # pre-rebalancing checkpoints: keep ctor's
            try:
                self.placement = make_placement_policy(str(np.asarray(pl)))
            except ValueError:
                # custom PlacementPolicy subclass: only its name is
                # saved, so the restored index keeps the constructor's
                # policy (construct with the custom policy to restore it)
                pass
        if int(np.asarray(state["meta"]["built"])) == 0:
            self._levels, self._delta = [], None
            self._loc = {}
            return self
        ds = state["delta"]
        S = int(np.asarray(ds["live"]).shape[0])
        if S != self.shards:
            return self._load_elastic(state, ds, S)
        put = lambda a: jax.device_put(jnp.asarray(a), self._shard)
        self._delta = {k: put(v) for k, v in ds.items()}
        self.delta_capacity = int(np.asarray(ds["live"]).shape[1]) - 1
        self._d = int(np.asarray(ds["x"]).shape[2])
        self._dtype = np.asarray(ds["x"]).dtype
        self._loc = {}
        self._levels = []
        lvls = dict(state.get("levels") or {})
        ms = state.get("main")
        if ms is not None and np.asarray(ms["x"]).shape[1] > 0:
            # pre-stack checkpoint format (one sharded "main", no
            # meta): migrate to a single level — ignoring it would
            # silently restore an empty corpus
            mids = np.asarray(ms["ids"])
            n_pad = int(np.asarray(ms["x"]).shape[1])
            mlive = np.asarray(ms["live"])[:, :n_pad]
            rows_s = (mids != -1).sum(axis=1).astype(np.int64)
            lvls["main"] = {
                **ms,
                "meta": {"uid": np.int64(0),
                         "level": np.int64(self.policy.level_for(
                             int(rows_s.sum()), self.delta_capacity)),
                         "rows_s": rows_s,
                         "live_s": mlive.sum(axis=1).astype(np.int64)},
            }
        for key in sorted(lvls):
            s = dict(lvls[key])
            meta = s.pop("meta")
            leaves = {k: put(v) for k, v in s.items()}
            lvl = _ShardLevel(
                uid=int(np.asarray(meta["uid"])),
                level=int(np.asarray(meta["level"])),
                n_pad=int(np.asarray(s["x"]).shape[1]),
                leaves=leaves,
                rows_s=np.asarray(meta["rows_s"]).astype(np.int64),
                live_s=np.asarray(meta["live_s"]).astype(np.int64))
            self._levels.append(lvl)
            mids = np.asarray(s["ids"])
            mlive = np.asarray(s["live"])[:, :lvl.n_pad]
            for sh_i in range(self.shards):
                for i in np.nonzero(mlive[sh_i])[0]:
                    self._loc[int(mids[sh_i, i])] = (sh_i, "m", lvl.uid,
                                                     int(i))
        self._next_uid = max(self._next_uid,
                             max([l.uid for l in self._levels],
                                 default=-1) + 1)
        # delta host bookkeeping from segment state
        self._delta_count_s = np.asarray(ds["count"]).astype(np.int64)
        dlive = np.asarray(ds["live"])[:, :self.delta_capacity]
        self._delta_live_s = dlive.sum(axis=1).astype(np.int64)
        dids = np.asarray(ds["ids"])
        for s_i in range(self.shards):
            for i in range(int(self._delta_count_s[s_i])):
                if dlive[s_i, i]:
                    self._loc[int(dids[s_i, i])] = (s_i, "d", int(i))
        return self

    def _load_elastic(self, state, ds,
                      S_saved: int) -> "ShardedDynamicHybridIndex":
        """Restore a checkpoint saved on a different shard count.

        Live rows of each saved level are gathered host-side together
        with their staged hashes and dealt round-robin onto the current
        mesh through ``_make_level`` (no re-hash) — the same row
        movement the rebalancer performs, which preserves reported sets
        because placement never affects them.  Dead rows drop exactly
        as the next merge would have dropped them.  Delta rows re-deal
        the same way; if the new mesh's total delta capacity cannot
        hold them, they freeze into a level first, like an overflow
        flush.
        """
        S, L = self.shards, self.family.L
        self.delta_capacity = int(np.asarray(ds["live"]).shape[1]) - 1
        self._d = int(np.asarray(ds["x"]).shape[2])
        self._dtype = np.asarray(ds["x"]).dtype
        self._loc = {}
        self._levels = []
        lvls = dict(state.get("levels") or {})
        ms = state.get("main")
        if ms is not None and np.asarray(ms["x"]).shape[1] > 0:
            rows_s = (np.asarray(ms["ids"]) != -1).sum(axis=1)
            lvls["main"] = {**ms, "meta": {
                "level": np.int64(self.policy.level_for(
                    int(rows_s.sum()), self.delta_capacity))}}
        for key in sorted(lvls):
            s = dict(lvls[key])
            meta = s.pop("meta")
            n_pad = int(np.asarray(s["x"]).shape[1])
            xs, ids, bids = (np.asarray(s[k])
                             for k in ("x", "ids", "bucket_ids"))
            live = np.asarray(s["live"])[:, :n_pad]
            gx = np.concatenate([xs[sh][live[sh]]
                                 for sh in range(S_saved)])
            gi = np.concatenate([ids[sh][live[sh]]
                                 for sh in range(S_saved)])
            gb = np.concatenate([bids[sh][live[sh]]
                                 for sh in range(S_saved)])
            if gi.shape[0] == 0:
                continue        # fully-dead level: a merge drops it
            self._make_level(
                [(gx[sh::S], gi[sh::S], gb[sh::S]) for sh in range(S)],
                int(np.asarray(meta["level"])))
        # delta rows: gather live slots across saved shards, re-deal
        dx, dbid, did, dlive = (np.asarray(ds[k]) for k in
                                ("x", "bucket_ids", "ids", "live"))
        dcount = np.asarray(ds["count"]).astype(np.int64)
        masks = [dlive[sh, :int(dcount[sh])] for sh in range(S_saved)]
        rx = np.concatenate([dx[sh, :int(dcount[sh])][masks[sh]]
                             for sh in range(S_saved)])
        ri = np.concatenate([did[sh, :int(dcount[sh])][masks[sh]]
                             for sh in range(S_saved)])
        rb = np.concatenate([dbid[sh, :int(dcount[sh])][masks[sh]]
                             for sh in range(S_saved)])
        C = self.delta_capacity
        if rx.shape[0] > S * C:
            self._make_level([(rx[sh::S], ri[sh::S], rb[sh::S])
                              for sh in range(S)], 0)
            rx, ri, rb = rx[:0], ri[:0], rb[:0]
        put = lambda a: jax.device_put(jnp.asarray(a), self._shard)
        nx = np.zeros((S, C + 1, self._d), self._dtype)
        nb = np.full((S, C + 1, L), -1, np.int32)
        ni = np.full((S, C + 1), -1, np.int32)
        nl = np.zeros((S, C + 1), bool)
        nc = np.zeros((S,), np.int32)
        for sh in range(S):
            px, pi, pb = rx[sh::S], ri[sh::S], rb[sh::S]
            k = px.shape[0]
            nx[sh, :k], nb[sh, :k], ni[sh, :k] = px, pb, pi
            nl[sh, :k] = True
            nc[sh] = k
            for i, e in enumerate(pi.tolist()):
                self._loc[int(e)] = (sh, "d", int(i))
        self._delta = {"x": put(nx), "bucket_ids": put(nb),
                       "ids": put(ni), "live": put(nl), "count": put(nc)}
        self._delta_count_s = nc.astype(np.int64)
        self._delta_live_s = nc.astype(np.int64)
        return self
