"""Tombstones for the immutable main segment.

The main segment's CSR tables and per-bucket HyperLogLogs are immutable
(HLL registers are monotone — they cannot decrement), so deletes are
recorded on the side:

  live    (n + 1,)  bool   row liveness; the trash row at index n stays False
  counts  (L, B)    int32  dead entries per (table, bucket)

``counts`` is the exact correction term for the router: subtracting it
from the CSR bucket sizes gives exact *live* collisions, and subtracting
its per-query sum from the HLL union bounds the live candSize from below
(a dead point colliding in several tables is subtracted once per table).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["Tombstones", "make_tombstones", "mark_dead", "dead_in_buckets"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Tombstones:
    live: jax.Array     # (n + 1,) bool
    counts: jax.Array   # (L, B) int32

    @property
    def n(self) -> int:
        return self.live.shape[0] - 1

    def tree_flatten(self):
        return ((self.live, self.counts), None)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def make_tombstones(n: int, L: int, num_buckets: int) -> Tombstones:
    live = jnp.ones((int(n) + 1,), bool).at[int(n)].set(False)
    return Tombstones(live=live,
                      counts=jnp.zeros((int(L), int(num_buckets)),
                                       jnp.int32))


@jax.jit
def mark_dead(ts: Tombstones, rows: jax.Array, row_buckets: jax.Array,
              valid: jax.Array) -> Tombstones:
    """Tombstone main rows (padded batch).

    rows: (k,) main-internal row indices; row_buckets: (k, L) their
    bucket per table (callers pad invalid lanes with bucket 0 — the
    scatter-add contributes 0 there).
    """
    idx = jnp.where(valid, rows, ts.n)
    live = ts.live.at[idx].set(False)
    L = ts.counts.shape[0]
    lidx = jnp.broadcast_to(jnp.arange(L)[None, :], row_buckets.shape)
    counts = ts.counts.at[lidx, row_buckets].add(
        jnp.broadcast_to(valid[:, None], row_buckets.shape)
        .astype(jnp.int32))
    return Tombstones(live=live, counts=counts)


def dead_in_buckets(ts: Tombstones, qbuckets: jax.Array) -> jax.Array:
    """(Q, L) query buckets -> (Q, L) exact dead-entry counts."""
    lidx = jnp.arange(ts.counts.shape[0])[None, :]
    return ts.counts[lidx, qbuckets.astype(jnp.int32)]
