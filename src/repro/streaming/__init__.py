"""Streaming index subsystem: incremental inserts/deletes over the
static Hybrid LSH core.

  * ``DynamicHybridIndex``  — main segment + delta segment + tombstones,
                              with HLL-aware compaction
  * ``ShardedDynamicHybridIndex`` — the same segment state per mesh
                              shard, pmax-merged HLL routing estimates,
                              per-shard compaction (streaming.sharded)
  * ``streaming.delta``     — fixed-capacity append-only delta segment
                              (+ its engine ``DeltaView`` adapter)
  * ``streaming.tombstones``— main-segment tombstone bitmap + per-bucket
                              dead counts (the engine's correction term)
  * ``streaming.segment``   — immutable main segment (Algorithm 1 build)
  * ``streaming.compaction``— trigger policy + compaction stats
"""
from repro.streaming.compaction import CompactionPolicy, CompactionStats
from repro.streaming.delta import DeltaSegment, DeltaView, make_delta
from repro.streaming.index import DynamicHybridIndex
from repro.streaming.segment import MainSegment, build_main
from repro.streaming.sharded import (ShardedDynamicHybridIndex,
                                     ShardedQueryResult)
from repro.streaming.tombstones import Tombstones, make_tombstones

__all__ = ["DynamicHybridIndex", "ShardedDynamicHybridIndex",
           "ShardedQueryResult", "CompactionPolicy", "CompactionStats",
           "DeltaSegment", "DeltaView", "make_delta", "MainSegment",
           "build_main", "Tombstones", "make_tombstones"]
