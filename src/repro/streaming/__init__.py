"""Streaming index subsystem: incremental inserts/deletes over the
static Hybrid LSH core.

  * ``DynamicHybridIndex``  — delta segment + multi-level LSM segment
                              stack + tombstones, with tiered, budgeted
                              off-query-path compaction
  * ``ShardedDynamicHybridIndex`` — the same level-stack state per mesh
                              shard, pmax-merged HLL routing estimates,
                              per-shard freeze/merge (streaming.sharded)
  * ``streaming.delta``     — fixed-capacity append-only delta segment
                              (+ its engine ``DeltaView`` adapter)
  * ``streaming.tombstones``— per-segment tombstone bitmap + per-bucket
                              dead counts (the engine's correction term)
  * ``streaming.segment``   — frozen segments, freeze (Algorithm 1 over
                              a padded block) and the ``SegmentStack``
                              with incremental ``compact_step`` merges
  * ``streaming.compaction``— tiered trigger policy + per-level stats
  * ``streaming.driver``    — ``CompactionDriver``: merge staging on a
                              background worker thread, swaps handed
                              back to the control thread via ``drain()``
"""
from repro.streaming.compaction import (CompactionPolicy, CompactionStats,
                                        KeepLocalPlacement,
                                        LoadBalancePlacement,
                                        PlacementPolicy,
                                        RoundRobinPlacement,
                                        make_placement_policy)
from repro.streaming.delta import DeltaSegment, DeltaView, make_delta
from repro.streaming.driver import CompactionDriver
from repro.streaming.index import DynamicHybridIndex
from repro.streaming.segment import (FrozenSegment, MainSegment,
                                     SegmentStack, build_main,
                                     freeze_segment)
from repro.streaming.sharded import (ShardedDynamicHybridIndex,
                                     ShardedQueryResult)
from repro.streaming.tombstones import Tombstones, make_tombstones

__all__ = ["DynamicHybridIndex", "ShardedDynamicHybridIndex",
           "ShardedQueryResult", "CompactionDriver",
           "CompactionPolicy", "CompactionStats",
           "PlacementPolicy", "KeepLocalPlacement", "RoundRobinPlacement",
           "LoadBalancePlacement", "make_placement_policy",
           "DeltaSegment", "DeltaView", "make_delta", "MainSegment",
           "FrozenSegment", "SegmentStack", "build_main", "freeze_segment",
           "Tombstones", "make_tombstones"]
