"""CompactionDriver — merge staging on a background worker thread.

PR 3/4 moved merge work off the *query path*: merges advance in bounded
``compact_step(budget_rows)`` increments that the serving layer ticks
between batches.  The tick itself, though, still runs on the serving
thread — every gather of ``budget_rows`` rows is serving-thread time a
query batch could have had.  This module removes even that: a
``CompactionDriver`` owns a daemon worker thread that runs the staging
gathers (``stage_step``) continuously, while the parts that mutate
served state stay on the control thread behind a tiny handoff:

  worker thread                      control (serving) thread
  ─────────────                      ────────────────────────
  stage_step(budget) → "staging"     insert / delete / query
  stage_step(budget) → "staging"     drain()  → nothing ready, ~free
  stage_step(budget) → "ready"       insert / delete / query
  prepare_staged()  (pre-build) ──►  drain()  → apply_staged():
  (waits for the swap)                 mid-merge delete re-check,
                                       atomic level swap,
                                       PlacementPolicy + _loc rewrites,
                                       cascade scheduling
  stage_step(...)  (next merge)      drain()  → nothing ready, ~free

On the single-host index the worker also *pre-builds* the merged
segment from its immutable staging buffers (``prepare_staged``), so
the control-thread swap runs no fused build at all — rows deleted
after staging are carried as tombstones in the new segment (the same
mask a normal delete leaves) instead of forcing a rebuild.  The
sharded index cannot pre-build (its ``PlacementPolicy`` partitions
rows against swap-time live loads), so its drain pays the build; the
staging gathers — the churn-proportional half — are off-thread either
way.

The swap MUST stay on the control thread: it re-checks staged rows
against tombstones that the control thread owns, rewrites the host-side
``_loc`` map that inserts/deletes read, and (sharded) runs the
``PlacementPolicy`` against live per-shard loads — none of which can
race a mutation.  Staging, by contrast, only *reads* immutable frozen
rows into the task's private host buffers (on the worker's own stream,
where the platform has one), so it can overlap serving freely; churn
that lands mid-stage is caught by the swap-time re-check.

Thread-safety contract (who may call what):

  * worker thread (internal): ``index.stage_step`` under the driver
    lock.
  * control thread: ``drain`` (between batches — the scheduler's
    ``background_tick``), ``flush`` (checkpoint barrier), ``start`` /
    ``stop`` / ``notify`` / ``stats``.
  * anything that resets index state wholesale (``compact()``,
    ``build()``, ``load_state_dict()``) must not run while the worker
    is live: ``stop()`` or ``flush()`` first.  ``RetrievalService``
    does this around checkpoints and restores.

The driver works with both streaming indexes — ``DynamicHybridIndex``
and ``ShardedDynamicHybridIndex`` expose the same
``stage_step`` / ``apply_staged`` / ``has_compaction_work`` surface.
For the sharded index one worker stages all shards' chunks of the
active merge: a sharded level swap is a single cross-shard atomic
operation, so per-shard swap serialization on the control thread falls
out of the same ``drain()``.

Multi-tenant serving (docs/serving.md "Collections"): ONE driver —
one worker thread, one lock — owns every collection's index.
``attach(name, index)`` / ``detach(name)`` manage the pool; the
worker round-robins over the attached indexes that have pending merge
work, servicing ONE bounded op (a staging gather or a pre-build) per
index per turn, so a churn-heavy tenant cannot monopolize the worker
while another tenant's merge starves.  Per-collection worker-op
counts are reported as ``stats()["fairness"]``.  ``drain``/``flush``
sweep all attached indexes.  The single-index constructor form
(``CompactionDriver(index)``) attaches it under the reserved default
name ``""`` — bit-identical to the pre-collections behavior.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

__all__ = ["CompactionDriver"]


class CompactionDriver:
    """Background staging worker + control-thread swap handoff.

    Args:
      index: a streaming index (``DynamicHybridIndex`` or
        ``ShardedDynamicHybridIndex``).  The driver never outlives the
        index's state: stop/flush it before ``build``/``compact``/
        ``load_state_dict``.
      budget_rows: rows per worker staging gather (None: the index
        policy's ``step_rows``, else its delta capacity) — bounds the
        lock hold time per gather, which is the longest a control-thread
        ``drain`` can be made to wait.
      poll_s: worker sleep between idle polls; mutations can cut the
        latency with ``notify()``.

    Lifecycle: ``start()`` → serve (… ``drain()`` between batches …) →
    ``flush()`` at checkpoints → ``stop(flush=True)`` at shutdown.
    """

    def __init__(self, index=None, *, budget_rows: Optional[int] = None,
                 poll_s: float = 0.02, name: str = "compaction-driver",
                 obs=None):
        # name -> index; insertion-ordered, which the round-robin
        # cursor walks.  "" is the default (single-tenant) slot.
        self._indexes: "Dict[str, object]" = {}
        if index is not None:
            self._indexes[""] = index
        self._rr = 0                # round-robin cursor over attachments
        self._fairness: Dict[str, int] = {}  # name -> worker ops run
        self.budget_rows = budget_rows
        self.poll_s = float(poll_s)
        self.name = name
        # share the index's event log by default so driver lifecycle
        # interleaves with freeze/swap events in one stream
        if obs is None:
            obs = getattr(index, "obs", None)
        if obs is None:
            from repro.obs import Observability
            obs = Observability.disabled()
        self.obs = obs
        # one lock excludes worker staging from control-thread swaps;
        # staging never blocks serving for longer than one budgeted
        # gather because the worker re-acquires per stage_step call
        self._mu = threading.Lock()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._stage_calls = 0       # worker gathers that ran
        self._prepares = 0          # worker speculative segment builds
        self._drains = 0            # control-thread drain() calls
        self._applied = 0           # merges swapped in via drain/flush
        self._flushes = 0
        self._cuts = 0              # consistent-cut snapshot captures
        self._errors: List[str] = []

    # ----------------------------------------------------------- index pool
    @property
    def index(self):
        """The default (single-tenant) index, else the first attached —
        the pre-collections single-index view.  None when empty."""
        if "" in self._indexes:
            return self._indexes[""]
        return next(iter(self._indexes.values()), None)

    def indexes(self) -> Dict[str, object]:
        """Snapshot of the attached pool (name -> index)."""
        with self._mu:
            return dict(self._indexes)

    def attach(self, name: str, index) -> None:
        """CONTROL-THREAD ONLY: add (or replace) a collection's index
        in the pool.  The lock excludes the worker, so the new index is
        visible to its next round-robin turn."""
        with self._mu:
            self._indexes[str(name)] = index
        self.obs.events.emit("driver_attach", name=self.name,
                             collection=str(name))
        self._wake.set()

    def detach(self, name: str):
        """CONTROL-THREAD ONLY: remove a collection's index from the
        pool (idempotent).  Under the lock the worker is never
        mid-stage on it; any staged-but-unapplied work is simply
        abandoned with the index (staging is volatile by contract).
        Returns the detached index, or None."""
        with self._mu:
            idx = self._indexes.pop(str(name), None)
        if idx is not None:
            self.obs.events.emit("driver_detach", name=self.name,
                                 collection=str(name))
        return idx

    # ------------------------------------------------------------ lifecycle
    @property
    def running(self) -> bool:
        """True while the worker thread is alive."""
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "CompactionDriver":
        """Start (or restart) the daemon worker; returns self."""
        if self.running:
            return self
        self._stop.clear()
        self._wake.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=self.name)
        self._thread.start()
        self.obs.events.emit("driver_start", name=self.name,
                             budget_rows=self.budget_rows)
        return self

    def stop(self, flush: bool = False) -> None:
        """CONTROL-THREAD ONLY: join the worker; optionally finish all
        pending merge work inline afterwards (``flush=True``) so no
        staging is left orphaned.  Idempotent; ``start()`` restarts."""
        was_running = self.running
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=60.0)
            if self._thread.is_alive():       # pragma: no cover
                self._errors.append("stop: worker join timed out")
            self._thread = None
        if was_running:
            self.obs.events.emit("driver_stop", name=self.name, flush=flush)
        if flush:
            self.flush()

    def notify(self) -> None:
        """Hint the worker that new merge work may exist (cheap; any
        thread).  Without it the worker still finds work within
        ``poll_s``."""
        self._wake.set()

    # ------------------------------------------------- control-thread ops
    def drain(self) -> int:
        """CONTROL-THREAD ONLY: apply any fully-staged merge swaps.

        The serving loop's between-batches hook (replaces the budgeted
        ``compact_step`` tick): when nothing is staged-ready this is one
        flag check under the lock — the gathers themselves live on the
        worker.  Applies cascaded-ready heads in a loop and returns the
        number of merges swapped in.
        """
        self._drains += 1
        applied = 0
        with self._mu:
            for idx in self._indexes.values():
                while idx.apply_staged():
                    applied += 1
        if applied:
            self._applied += applied
            self._wake.set()          # the worker can stage the next merge
        return applied

    def flush(self) -> int:
        """CONTROL-THREAD ONLY: run every pending merge to completion
        inline (stage remainder + swap), returning merges applied.

        The checkpoint barrier: after a flush there is no staged or
        queued merge, so a snapshot can never capture a half-staged
        state and restores re-derive a clean schedule.  The worker (if
        running) is simply excluded by the lock for the duration.
        """
        self._flushes += 1
        applied = 0
        with self._mu:
            for idx in self._indexes.values():
                while idx.has_compaction_work:
                    if idx.apply_staged():
                        applied += 1
                    else:
                        idx.stage_step(1 << 30)   # stage the remainder
        if applied:
            self._applied += applied
        self.obs.events.emit("flush_barrier", name=self.name,
                             applied=applied)
        return applied

    def consistent_cut(self, capture):
        """CONTROL-THREAD ONLY: run ``capture()`` under the driver lock
        and return its result — a consistent-cut snapshot barrier.

        Unlike ``flush`` this does NOT drain queued merges: the lock
        alone excludes the worker, so the callback sees the index
        between bounded staging gathers.  That is a valid checkpoint
        state because staged merge progress is volatile by contract
        (its inputs are still complete segments on disk; a restore
        re-derives the schedule and restages).  Cost is therefore
        O(capture) — for an incremental snapshot, O(delta + manifest) —
        instead of O(pending compaction), regardless of how much merge
        work is queued.
        """
        self._cuts += 1
        with self._mu:
            out = capture()
        self.obs.events.emit("snapshot_cut", name=self.name)
        return out

    # ------------------------------------------------------------- worker
    def _service_one(self, name: str, idx) -> bool:
        """One bounded worker op on one index (under the lock): a
        pre-build when its head is staged-ready, else a staging
        gather.  Returns True when work ran."""
        if idx.staged_ready:
            # pre-build the merged segment so the control thread's
            # swap is re-check + rewire only.  Once prepared (or on
            # the sharded index, which never pre-builds), the head
            # just waits on a drain — re-polling would spin on the
            # lock.
            if idx.prepare_staged():
                self._prepares += 1
                self._fairness[name] = self._fairness.get(name, 0) + 1
                return True
            return False
        status = idx.stage_step(self.budget_rows)
        if status == "ready":
            self.obs.events.emit("stage_ready", collection=name,
                                 staged_rows=int(idx.staged_rows))
        if status != "idle":
            self._stage_calls += 1
            self._fairness[name] = self._fairness.get(name, 0) + 1
            return True
        return False

    def _run(self) -> None:
        while not self._stop.is_set():
            did_work = False
            try:
                with self._mu:
                    # round-robin: start one past the last serviced
                    # collection, take ONE bounded op from the first
                    # that has work — a churny tenant advances one op
                    # per turn, not until done.
                    names = list(self._indexes)
                    n = len(names)
                    for k in range(n):
                        i = (self._rr + 1 + k) % n
                        if self._service_one(names[i], self._indexes[names[i]]):
                            self._rr = i
                            did_work = True
                            break
            except Exception as e:    # control reset state mid-stage
                # (compact()/restore without stop(): defensive — abandon
                # the gather, the re-derived schedule restages)
                if len(self._errors) < 64:      # bounded: a wedged
                    self._errors.append(repr(e))  # worker must not grow
                did_work = False
            if did_work:
                continue              # more to do right away
            self._wake.wait(self.poll_s)
            self._wake.clear()

    # ------------------------------------------------------ observability
    def stats(self) -> Dict[str, object]:
        """Driver-state snapshot (host ints/bools; any thread).

        ``pending_gathers`` queued merge tasks, ``staged_rows`` rows in
        staging buffers, ``staged_ready`` head-awaiting-swap,
        ``worker_alive``, plus cumulative ``stage_calls`` / ``prepares``
        (worker gathers and pre-builds), ``drains`` / ``applied`` /
        ``flushes`` / ``cuts`` (control-thread side; ``cuts`` counts
        consistent-cut snapshot captures), and ``worker_errors``.
        ``work_seconds`` is the index's per-phase compaction-work
        accumulator — the same dict ``index_stats()`` reports, never a
        second measurement.  With multiple attached collections the
        index-derived fields aggregate over the pool
        (``pending_gathers``/``staged_rows`` sum; ``staged_ready`` =
        any; ``work_seconds`` sums per phase), ``collections`` counts
        attachments, and ``fairness`` maps each collection to the
        worker ops (gathers + pre-builds) it has received — the
        round-robin audit trail.
        """
        with self._mu:
            indexes = dict(self._indexes)
        pending = sum(int(i.pending_merges) for i in indexes.values())
        staged = sum(int(i.staged_rows) for i in indexes.values())
        ready = any(bool(i.staged_ready) for i in indexes.values())
        work: Dict[str, float] = {}
        for i in indexes.values():
            for phase, secs in dict(
                    getattr(i, "compaction_work_seconds", None) or {}).items():
                work[phase] = work.get(phase, 0.0) + secs
        return {
            "worker_alive": self.running,
            "pending_gathers": pending,
            "staged_rows": staged,
            "staged_ready": ready,
            "budget_rows": self.budget_rows,
            "stage_calls": self._stage_calls,
            "prepares": self._prepares,
            "drains": self._drains,
            "applied": self._applied,
            "flushes": self._flushes,
            "cuts": self._cuts,
            "worker_errors": len(self._errors),
            "collections": len(indexes),
            "fairness": dict(self._fairness),
            "work_seconds": work,
        }

    def __repr__(self) -> str:
        pending = sum(int(i.pending_merges)
                      for i in self._indexes.values())
        return (f"CompactionDriver({self.name!r}, "
                f"alive={self.running}, "
                f"collections={len(self._indexes)}, "
                f"pending={pending})")
