"""Fixed-capacity delta segment — the mutable half of the streaming index.

Layout (capacity ``C``; one trash row at index ``C`` absorbs masked
scatter lanes so every update stays fixed-shape):

  x          (C + 1, d)   inserted rows (same dtype as the main corpus;
                          packed uint32 codes for the hamming metric)
  bucket_ids (C + 1, L)   per-table bucket of each row
  ids        (C + 1,)     external document ids
  live       (C + 1,)     False = empty slot or tombstoned; live[C] stays False
  count      ()           rows ever written (monotone until compaction reset)

Inserts are one fused ``.at[]`` scatter over a padded batch: ``count`` is
a traced scalar, so repeated same-size inserts hit the same jit cache
entry (no retrace).  Queries treat the delta as a small exact segment:
per-table equality against ``bucket_ids`` replaces the CSR walk, and the
counts are exact — unlike the main segment's HyperLogLogs they decrement
for free when ``live`` flips off, which is why the delta needs no sketch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.engine import EXT_SENTINEL, SegmentEstimate
from repro.kernels import ops

__all__ = ["DeltaSegment", "DeltaView", "make_delta", "insert", "kill",
           "collision_stats", "search"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeltaSegment:
    x: jax.Array            # (C + 1, d)
    bucket_ids: jax.Array   # (C + 1, L) int32
    ids: jax.Array          # (C + 1,) int32 external doc ids
    live: jax.Array         # (C + 1,) bool
    count: jax.Array        # () int32

    @property
    def capacity(self) -> int:
        return self.x.shape[0] - 1

    def tree_flatten(self):
        return ((self.x, self.bucket_ids, self.ids, self.live, self.count),
                None)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def make_delta(capacity: int, d: int, L: int,
               dtype=jnp.float32) -> DeltaSegment:
    c = int(capacity)
    return DeltaSegment(
        x=jnp.zeros((c + 1, d), dtype),
        bucket_ids=jnp.full((c + 1, L), -1, jnp.int32),
        ids=jnp.full((c + 1,), -1, jnp.int32),
        live=jnp.zeros((c + 1,), bool),
        count=jnp.zeros((), jnp.int32))


@jax.jit
def insert(delta: DeltaSegment, rows: jax.Array, bids: jax.Array,
           ext_ids: jax.Array, valid: jax.Array) -> DeltaSegment:
    """Append a padded batch; invalid lanes land on the trash row."""
    k = valid.shape[0]
    slot = delta.count + jnp.arange(k, dtype=jnp.int32)
    idx = jnp.where(valid, slot, delta.capacity)
    return DeltaSegment(
        x=delta.x.at[idx].set(rows),
        bucket_ids=delta.bucket_ids.at[idx].set(bids.astype(jnp.int32)),
        ids=delta.ids.at[idx].set(ext_ids.astype(jnp.int32)),
        live=delta.live.at[idx].set(valid).at[delta.capacity].set(False),
        count=delta.count + jnp.sum(valid, dtype=jnp.int32))


@jax.jit
def kill(delta: DeltaSegment, slots: jax.Array,
         valid: jax.Array) -> DeltaSegment:
    """Tombstone delta slots (padded batch; trash row absorbs padding)."""
    idx = jnp.where(valid, slots, delta.capacity)
    return dataclasses.replace(delta, live=delta.live.at[idx].set(False))


@dataclasses.dataclass
class DeltaView:
    """Engine ``Segment`` adapter for the exact, sketch-free delta.

    Counts are exact (no HLL, no dead-count correction), so its
    ``SegmentEstimate`` carries ``cand_exact`` only.  ``n_live``/
    ``n_scan`` are supplied by the owner: host ints for the single-host
    index, traced scalars inside ``shard_map``.
    """

    delta: DeltaSegment
    metric: str
    impl: Optional[str] = None
    n_live: Union[int, jax.Array] = 0
    n_scan: Union[int, jax.Array] = 0
    tidx: Optional[jax.Array] = None   # (V,) multi-probe column->table map

    def estimate_terms(self, qbuckets: jax.Array) -> SegmentEstimate:
        coll, dist = collision_stats(self.delta, qbuckets, tidx=self.tidx)
        return SegmentEstimate(collisions=coll, cand_exact=dist,
                               n_live=self.n_live, n_scan=self.n_scan)

    def search(self, qbuckets: jax.Array, q: jax.Array, r, *,
               lsh_route: bool):
        ids, dists, mask = search(self.delta, qbuckets, q, r, self.metric,
                                  require_collision=lsh_route,
                                  impl=self.impl, tidx=self.tidx)
        return jnp.where(mask, ids, EXT_SENTINEL), dists, mask

    def count_candidates(self, qbuckets: jax.Array) -> jax.Array:
        """(Q,) distinct colliding delta rows — exact, the delta keeps
        no sketches and its LSH route has no gather cap."""
        return collision_stats(self.delta, qbuckets, tidx=self.tidx)[1]


def _row_buckets(delta: DeltaSegment,
                 tidx: jax.Array | None) -> jax.Array:
    """(C + 1, V) per-row buckets aligned with the qbuckets columns.

    Identity for single-probe; under multi-probe each physical table's
    column repeats T times (``tidx``), so a probed query bucket compares
    against the row's bucket in the *same* physical table.
    """
    if tidx is None:
        return delta.bucket_ids
    return delta.bucket_ids[:, tidx.astype(jnp.int32)]


@jax.jit
def collision_stats(delta: DeltaSegment, qbuckets: jax.Array,
                    tidx: jax.Array | None = None):
    """Exact per-query delta counts: (collisions, distinct), both (Q,).

    The streaming analogue of ``bucket_counts`` + the HLL candSize term,
    except both are exact (and already tombstone-aware via ``live``).
    """
    hit = (qbuckets[:, None, :].astype(jnp.int32)
           == _row_buckets(delta, tidx)[None, :, :])   # (Q, C + 1, V)
    hit = hit & delta.live[None, :, None]
    collisions = jnp.sum(hit, axis=(1, 2), dtype=jnp.int32)
    distinct = jnp.sum(jnp.any(hit, axis=-1), axis=1, dtype=jnp.int32)
    return collisions, distinct


@functools.partial(jax.jit,
                   static_argnames=("metric", "require_collision", "impl"))
def search(delta: DeltaSegment, qbuckets: jax.Array, q: jax.Array, r: float,
           metric: str, require_collision: bool = True,
           impl: str | None = None, tidx: jax.Array | None = None):
    """Exact scan of the delta segment -> (ext_ids, dists, mask), (Q, C+1).

    ``require_collision=True`` mirrors LSH-route semantics (a delta row
    is a candidate only if it collides in >= 1 probed bucket); ``False``
    mirrors the linear route (every live row is checked).

    The distance + threshold pass is the fused linear-route kernel
    (``ops.fused_linear_scan``) — the delta is small, but it sits in
    *every* query's segment list, so its scan rides the same one-pass
    path as the frozen levels; the live/collision masks compose on top.
    """
    _, dists, in_radius = ops.fused_linear_scan(q, delta.x, r, metric,
                                                impl=impl)
    mask = in_radius & delta.live[None, :]
    if require_collision:
        hit = jnp.any(qbuckets[:, None, :].astype(jnp.int32)
                      == _row_buckets(delta, tidx)[None, :, :], axis=-1)
        mask = mask & hit
    ids = jnp.broadcast_to(delta.ids[None, :], dists.shape)
    return ids, dists, mask
