"""Version-keyed LRU result cache for the serving path.

Keys are ``(collection, index_version, radius, query_fingerprint)``:
the index bumps its monotonic ``version`` on every mutation that could
change a reported set (insert, delete, freeze, merge swap, sharded
rebalance, restore), so a repeated query hits only while the index is
bit-for-bit the one the cached result was computed against.  Staleness
is therefore impossible by construction — no TTLs, no invalidation
callbacks; a mutation simply makes every old key unreachable.  Dead
entries are reclaimed two ways: ``purge_stale`` drops them eagerly the
first time a new version is seen, and the byte-budget LRU sweep evicts
whatever survives.

Multi-tenant serving (docs/serving.md "Collections") shares ONE cache
across every collection: the collection name leads the key, versions
are tracked per collection (each tenant's index has its own monotonic
counter), and ``drop_collection`` purges a dropped tenant eagerly —
required for correctness, since a re-created collection's fresh index
restarts at version 0 and would otherwise alias the old corpus.  The
default (single-tenant) corpus uses the reserved empty name ``""``.

Values are per-query-row ``(ids, dists)`` numpy pairs — exactly what
``QueryResult.reported`` / ``ShardedQueryResult.reported`` return —
stored read-only so hits can be served zero-copy.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.metrics import NULL_REGISTRY

__all__ = ["ResultCache"]

# accounting overhead per entry (key tuple, OrderedDict node, list
# headers) — keeps many tiny results from reading as "free"
_ENTRY_OVERHEAD = 256


class ResultCache:
    """Byte-budgeted LRU over ``(collection, version, radius,
    fingerprint)`` keys.

    ``max_bytes <= 0`` disables caching entirely: ``get`` always
    misses and ``put`` is a no-op, so callers never need a second code
    path.  Not thread-safe by itself — the serving contract is
    control-thread-only, same as the index.
    """

    def __init__(self, max_bytes: int, registry=None):
        self.max_bytes = int(max_bytes)
        self._entries: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._nbytes: Dict[tuple, int] = {}
        self._bytes = 0
        # per-collection: each tenant's index versions independently
        self._version_seen: Dict[str, int] = {}
        self._hits = 0
        self._misses = 0
        self._puts = 0
        self._evictions = 0
        self._stale_drops = 0
        reg = registry if registry is not None else NULL_REGISTRY
        self._m_hits = reg.counter(
            "repro_cache_hits_total", help="Result-cache hits")
        self._m_misses = reg.counter(
            "repro_cache_misses_total", help="Result-cache misses")
        self._m_evictions = reg.counter(
            "repro_cache_evictions_total",
            help="Entries evicted by the byte-budget LRU sweep")
        self._m_stale = reg.counter(
            "repro_cache_stale_drops_total",
            help="Entries dropped because the index version moved on")
        self._g_bytes = reg.gauge(
            "repro_cache_bytes", help="Bytes held by the result cache")

    # --------------------------------------------------------------- keys
    @staticmethod
    def fingerprint(tokens: np.ndarray) -> str:
        """Content hash of one request's token rows (shape + dtype
        salted: a (1, 8) int32 row and its int64 twin must not
        collide)."""
        a = np.ascontiguousarray(tokens)
        h = hashlib.blake2b(digest_size=16)
        h.update(str((a.shape, a.dtype.str)).encode())
        h.update(a.tobytes())
        return h.hexdigest()

    def key(self, version: int, radius: float, tokens: np.ndarray,
            collection: str = "") -> tuple:
        """``(collection, version, radius, fingerprint)`` — the
        collection leads so a tenant's entries are a contiguous notion,
        never shared across names; ``""`` is the default corpus."""
        return (str(collection), int(version), float(radius),
                self.fingerprint(tokens))

    # ------------------------------------------------------------ get/put
    def get(self, key: tuple):
        """The cached (ids_list, dists_list) for ``key``, or None."""
        entry = self._entries.get(key)
        if entry is None:
            self._misses += 1
            self._m_misses.inc()
            return None
        self._entries.move_to_end(key)
        self._hits += 1
        self._m_hits.inc()
        return entry

    def put(self, key: tuple, ids: List[np.ndarray],
            dists: List[np.ndarray]) -> bool:
        """Insert a result; returns False when it cannot fit (cache
        disabled, or the single entry exceeds the whole budget)."""
        nbytes = _ENTRY_OVERHEAD + sum(
            a.nbytes for a in ids) + sum(a.nbytes for a in dists)
        if self.max_bytes <= 0 or nbytes > self.max_bytes:
            return False
        if key in self._entries:        # same version+query resubmitted
            self._drop(key, stale=False, count_evict=False)
        for a in ids:
            a.flags.writeable = False   # zero-copy hits stay immutable
        for a in dists:
            a.flags.writeable = False
        self._entries[key] = (ids, dists)
        self._nbytes[key] = nbytes
        self._bytes += nbytes
        self._puts += 1
        while self._bytes > self.max_bytes:
            old = next(iter(self._entries))
            self._drop(old, stale=False, count_evict=True)
        self._g_bytes.set(self._bytes)
        return True

    def purge_stale(self, version: int, collection: str = "") -> int:
        """Drop every entry of ``collection`` keyed to an older index
        version.

        O(entries), but only does work the first time each new version
        is seen per collection — the usual call site (once per served
        batch) is a single dict lookup + int compare.  Returns the
        number dropped.
        """
        collection = str(collection)
        if self._version_seen.get(collection) == version:
            return 0
        self._version_seen[collection] = version
        stale = [k for k in self._entries
                 if k[0] == collection and k[1] != version]
        for k in stale:
            self._drop(k, stale=True, count_evict=False)
        self._g_bytes.set(self._bytes)
        return len(stale)

    def drop_collection(self, collection: str) -> int:
        """Drop ALL of one collection's entries (counted as stale
        drops) and forget its version watermark.  MUST run when a
        collection is dropped: a later re-create restarts the index
        version at 0, and surviving entries would alias the old corpus
        bit-for-bit.  Returns the number dropped."""
        collection = str(collection)
        self._version_seen.pop(collection, None)
        dead = [k for k in self._entries if k[0] == collection]
        for k in dead:
            self._drop(k, stale=True, count_evict=False)
        self._g_bytes.set(self._bytes)
        return len(dead)

    def _drop(self, key: tuple, *, stale: bool, count_evict: bool) -> None:
        del self._entries[key]
        self._bytes -= self._nbytes.pop(key)
        if stale:
            self._stale_drops += 1
            self._m_stale.inc()
        if count_evict:
            self._evictions += 1
            self._m_evictions.inc()

    # --------------------------------------------------------------- view
    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> Dict[str, float]:
        """Host-side counters snapshot (schema: CACHE_STATS_KEYS)."""
        lookups = self._hits + self._misses
        return {
            "hits": self._hits,
            "misses": self._misses,
            "puts": self._puts,
            "evictions": self._evictions,
            "stale_drops": self._stale_drops,
            "entries": len(self._entries),
            "bytes": self._bytes,
            "max_bytes": self.max_bytes,
            "hit_rate": self._hits / lookups if lookups else 0.0,
        }
