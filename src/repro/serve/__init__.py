from repro.serve.cache import ResultCache
from repro.serve.engine import generate, make_serve_prefill, make_serve_step
from repro.serve.retrieval import (RequestResult, RetrievalConfig,
                                   RetrievalService)
from repro.serve.scheduler import ShapeBucketScheduler, route_and_group

__all__ = ["generate", "make_serve_prefill", "make_serve_step",
           "RequestResult", "ResultCache", "RetrievalConfig",
           "RetrievalService", "ShapeBucketScheduler", "route_and_group"]
