from repro.serve.engine import generate, make_serve_prefill, make_serve_step
from repro.serve.retrieval import RetrievalConfig, RetrievalService
from repro.serve.scheduler import ShapeBucketScheduler, route_and_group

__all__ = ["generate", "make_serve_prefill", "make_serve_step",
           "RetrievalConfig", "RetrievalService", "ShapeBucketScheduler",
           "route_and_group"]
