from repro.serve.cache import ResultCache
from repro.serve.collections import Collection, CollectionManager
from repro.serve.engine import generate, make_serve_prefill, make_serve_step
from repro.serve.retrieval import (RequestResult, RetrievalConfig,
                                   RetrievalService)
from repro.serve.scheduler import (ShapeBucketScheduler, TenantQuota,
                                   route_and_group)

__all__ = ["generate", "make_serve_prefill", "make_serve_step",
           "Collection", "CollectionManager", "RequestResult",
           "ResultCache", "RetrievalConfig", "RetrievalService",
           "ShapeBucketScheduler", "TenantQuota", "route_and_group"]
