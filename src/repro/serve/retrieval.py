"""Retrieval-augmented serving: the paper's index as a first-class
feature of the serving path.

An LM (any of the 10 archs) encodes requests to normalized embeddings
(models.transformer.forward_embed); the corpus embeddings live in a
streaming index (cosine/SimHash by default), so a serving corpus
mutates live via ``add_documents`` / ``remove_documents`` instead of
full rebuilds.  With ``RetrievalConfig.mesh`` set, the corpus is
row-sharded over the mesh's data axis (``ShardedDynamicHybridIndex``);
otherwise the single-host ``DynamicHybridIndex`` serves.  Either way
every retrieval request goes through the paper's Algorithm 2 via the
shared segment engine, with the tombstone-corrected estimate.
``stats`` exposes routing decisions and compaction counters.

Compaction modes (docs/compaction.md): synchronous drain (default),
budgeted ticks (``compact_step_rows`` set; ``compaction_tick`` between
batches), or fully async (``async_compaction=True``; the service owns
a ``CompactionDriver`` whose worker thread stages merges while the
serving thread only drains staged swaps).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.core import CostModel
from repro.core.lsh import make_family
from repro.models.parallel import ParallelConfig
from repro.models.transformer import forward_embed
from repro.streaming import (CompactionDriver, CompactionPolicy,
                             DynamicHybridIndex,
                             ShardedDynamicHybridIndex)


@dataclasses.dataclass
class RetrievalConfig:
    radius: float = 0.3            # cosine distance threshold
    tables: int = 20               # L
    num_buckets: int = 4096
    hll_m: int = 64
    cap: int = 128
    beta_over_alpha: float = 10.0
    delta: float = 0.1
    # Streaming-index knobs.
    delta_capacity: int = 4096
    compact_delta_fill: float = 1.0
    compact_tombstone_ratio: float = 0.25
    # LSM level-stack knobs: fanout bounds segments per level; step_rows
    # switches merges from synchronous drain to bounded off-query-path
    # steps (RetrievalService ticks them between batches).
    compact_fanout: int = 4
    compact_step_rows: Optional[int] = None
    # Async compaction: a CompactionDriver worker thread runs the merge
    # staging gathers continuously; the serving thread's tick becomes a
    # cheap drain() that only applies fully-staged atomic swaps (plus
    # their loc rewrites), so no gather ever lands on the serving
    # thread.  compact_step_rows doubles as the worker's per-gather
    # budget (default delta_capacity // 2 when unset and async is on).
    async_compaction: bool = False
    # Mesh sharding: set to shard the corpus over `mesh_axis`.
    mesh: Optional[Mesh] = None
    mesh_axis: str = "data"
    shard_routing: str = "global"  # or "per_shard" (density-adaptive)
    shard_max_out: int = 512       # reported neighbors per (shard, query)
    # Merge-time rebalancing: placement of surviving merge rows across
    # shards — "keep_local" (never move), "round_robin", or
    # "load_balance" (water-fill per-shard live counts).  `stats` then
    # reports `shard_skew` (max/mean live load) and cumulative
    # `rows_moved` so skewed streams are visible and correctable.
    shard_placement: str = "keep_local"


class RetrievalService:
    """Embed-and-report-near-neighbors service.

    Wraps an LM encoder (any arch config) over a streaming index:
    ``index_corpus`` builds, ``add_documents``/``remove_documents``
    mutate live, ``query`` reports r-near neighbors for an embedded
    request batch, ``compaction_tick`` advances merge work off the
    query path, and ``stats`` exposes routing + compaction +
    rebalancing counters.

    With ``RetrievalConfig.async_compaction`` the service owns a
    ``CompactionDriver``: merge staging runs on the driver's worker
    thread, ``compaction_tick`` degenerates to the driver's cheap
    ``drain()`` (apply any fully-staged atomic swap), and
    ``checkpoint`` flushes the driver first so a snapshot never
    captures a half-staged merge.  All ``RetrievalService`` methods are
    control-thread-only — the only concurrency is the driver's worker,
    which the service manages (``shutdown`` stops it).
    """

    def __init__(self, cfg: ArchConfig, par: ParallelConfig, params,
                 rcfg: RetrievalConfig = RetrievalConfig()):
        self.cfg, self.par, self.params, self.rcfg = cfg, par, params, rcfg
        self._embed = jax.jit(
            lambda p, b: forward_embed(p, b, cfg, par))
        self.index: Optional[Union[DynamicHybridIndex,
                                   ShardedDynamicHybridIndex]] = None
        self.driver: Optional[CompactionDriver] = None
        self._queries_served = 0
        self._linear_served = 0
        self._compaction_ticks = 0
        self._idle_ticks = 0

    def embed(self, batch: Dict[str, jax.Array]) -> jax.Array:
        """Normalized (B, d_model) embeddings for one token batch."""
        return self._embed(self.params, batch)

    def _embed_corpus(self, batches: Iterable[Dict[str, jax.Array]]):
        embs = [np.asarray(self.embed(b)) for b in batches]
        return jnp.asarray(np.concatenate(embs, axis=0))

    def _step_rows(self) -> Optional[int]:
        """Merge-step budget: the configured step_rows; async mode must
        not fall back to the synchronous drain (step_rows=None), so it
        defaults to half the delta capacity."""
        r = self.rcfg
        if r.compact_step_rows is None and r.async_compaction:
            return max(r.delta_capacity // 2, 1)
        return r.compact_step_rows

    def index_corpus(self, batches: Iterable[Dict[str, jax.Array]]):
        """Embed + build the corpus index per ``RetrievalConfig`` (mesh
        set -> sharded index with the configured routing/placement);
        returns the corpus size.  With ``async_compaction`` a
        ``CompactionDriver`` is started on the new index (any previous
        driver is stopped first)."""
        if self.driver is not None:
            self.driver.stop()
            self.driver = None
        corpus = self._embed_corpus(batches)
        r = self.rcfg
        fam = make_family("cosine", d=corpus.shape[1], L=r.tables,
                          r=r.radius, delta=r.delta)
        common = dict(
            num_buckets=r.num_buckets, m=r.hll_m, cap=r.cap,
            delta_capacity=r.delta_capacity,
            cost_model=CostModel(alpha=1.0, beta=r.beta_over_alpha),
            policy=CompactionPolicy(
                delta_fill=r.compact_delta_fill,
                tombstone_ratio=r.compact_tombstone_ratio,
                fanout=r.compact_fanout,
                step_rows=self._step_rows()))
        if r.mesh is not None:
            self.index = ShardedDynamicHybridIndex(
                fam, mesh=r.mesh, data_axis=r.mesh_axis,
                routing=r.shard_routing, max_out=r.shard_max_out,
                placement=r.shard_placement, **common)
        else:
            self.index = DynamicHybridIndex(fam, **common)
        self.index.build(corpus)
        if r.async_compaction:
            self.driver = CompactionDriver(
                self.index, budget_rows=self._step_rows()).start()
        return corpus.shape[0]

    # ------------------------------------------------------- live mutation
    def add_documents(self,
                      batches: Iterable[Dict[str, jax.Array]]) -> np.ndarray:
        """Embed + insert new documents; returns their doc ids.

        Inserts land in the delta segment(s) (no rebuild); compaction
        folds them into the main segment per the configured policy.
        """
        assert self.index is not None, "call index_corpus first"
        ids = self.index.insert(self._embed_corpus(batches))
        if self.driver is not None:
            self.driver.notify()      # a freeze may have queued a merge
        return ids

    def remove_documents(self, doc_ids: Sequence[int]) -> int:
        """Tombstone documents by id; returns #removed."""
        assert self.index is not None, "call index_corpus first"
        removed = self.index.delete(doc_ids)
        if self.driver is not None:
            self.driver.notify()      # tombstone pressure may queue work
        return removed

    def query(self, batch: Dict[str, jax.Array],
              radius: Optional[float] = None):
        """Returns (QueryResult | ShardedQueryResult, embeddings).

        Deliberately does NOT advance compaction: with
        ``compact_step_rows`` set, merge steps belong between batches —
        wire ``compaction_tick`` as the scheduler's ``background_tick``
        (or call it from the serving loop), never inside a request.
        """
        assert self.index is not None, "call index_corpus first"
        q = self.embed(batch)
        res = self.index.query(q, radius or self.rcfg.radius)
        self._queries_served += res.n_queries
        # exact per-query linear count from the route partition (the
        # frac_linear*n round-trip drifts under rounding)
        self._linear_served += res.n_linear
        return res, q

    def compaction_tick(self) -> bool:
        """The between-batches maintenance hook (wire it as
        ``ShapeBucketScheduler``'s ``background_tick``).  Budgeted mode:
        advance pending merge work by one bounded ``compact_step``.
        Async mode: the driver's cheap ``drain()`` — apply any
        fully-staged atomic swap; the gathers live on the worker.
        Returns True while more compaction work remains.

        ``stats["compaction_ticks"]`` counts only ticks that actually
        ran work (a step that advanced a merge, or a drain that applied
        a swap); no-op ticks land in ``stats["idle_ticks"]``.
        """
        if self.index is None:
            return False
        if self.driver is not None:
            if self.driver.drain() > 0:
                self._compaction_ticks += 1
            else:
                self._idle_ticks += 1
            return bool(self.index.has_compaction_work)
        if self.index.has_compaction_work:
            self._compaction_ticks += 1
        else:
            self._idle_ticks += 1
        return bool(self.index.compact_step(self._step_rows()))

    # ------------------------------------------------- driver lifecycle
    def checkpoint(self, manager, step: int) -> None:
        """Flush pending merge work, then snapshot the index.

        The flush is the async-mode checkpoint barrier: every queued
        merge finishes (stage remainder + swap) before ``save_index``
        runs, so the snapshot never captures a half-staged merge and
        the saved level structure is exactly what queries will see
        after a restore.  ``manager`` is a ``CheckpointManager``.
        """
        assert self.index is not None, "call index_corpus first"
        if self.driver is not None:
            self.driver.flush()
        manager.save_index(step, self.index)

    def restore(self, manager, step: Optional[int] = None):
        """Restore index state from a committed checkpoint (the index
        must have been built with the same config).  The driver worker
        is stopped around the state swap — staging must never run
        against a stack being replaced — and restarted after; staged
        progress is volatile by contract, so nothing is lost.  Returns
        the restored step (None: no committed checkpoint)."""
        assert self.index is not None, "call index_corpus first"
        if self.driver is not None:
            self.driver.stop()
        restored = manager.restore_index(self.index, step=step)
        if self.driver is not None:
            self.driver.start()
        return restored

    def shutdown(self, flush: bool = True) -> None:
        """Stop the driver worker; ``flush=True`` (default) completes
        pending merges inline first so no staging is orphaned.  Safe to
        call with no driver or repeatedly."""
        if self.driver is not None:
            self.driver.stop(flush=flush)

    @property
    def stats(self) -> Dict[str, float]:
        """Serving counters merged with the index's ``index_stats()``.

        Includes the per-level LSM counters (segments, levels,
        pending_merges, merges_per_level, compact_steps, freezes, ...)
        and — when the corpus is mesh-sharded — the rebalancing view:
        ``live_per_shard``/``delta_per_shard`` loads, ``shard_skew``
        (max/mean live load; 1.0 = balanced), the active ``placement``
        policy, and cumulative ``rows_moved`` across shards.

        ``compaction_ticks`` counts only ticks that ran work;
        ``idle_ticks`` the no-ops.  In async mode a ``driver`` sub-dict
        carries the ``CompactionDriver`` state (``worker_alive``,
        ``pending_gathers``, ``staged_rows``, ``stage_calls``,
        ``drains``/``applied``, ...).
        """
        served = max(self._queries_served, 1)
        out = {"queries": self._queries_served,
               "linear_served": self._linear_served,
               "frac_linear": self._linear_served / served,
               "compaction_ticks": self._compaction_ticks,
               "idle_ticks": self._idle_ticks,
               "index_size": self.index.n if self.index else 0}
        if self.index is not None:
            out.update(self.index.index_stats())
        if self.driver is not None:
            out["driver"] = self.driver.stats()
        return out
