"""Retrieval-augmented serving: the paper's index as a first-class
feature of the serving path.

An LM (any of the 10 archs) encodes requests to normalized embeddings
(models.transformer.forward_embed); the corpus embeddings live in a
HybridLSHIndex (cosine/SimHash by default).  Every retrieval request
goes through the paper's Algorithm 2: estimate LSHCost from bucket
sizes + merged HLLs, then run LSH-based or linear search per query
group.  ``stats`` exposes the routing decisions for observability.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import CostModel, HybridLSHIndex
from repro.core.lsh import make_family
from repro.models.parallel import ParallelConfig
from repro.models.transformer import forward_embed


@dataclasses.dataclass
class RetrievalConfig:
    radius: float = 0.3            # cosine distance threshold
    tables: int = 20               # L
    num_buckets: int = 4096
    hll_m: int = 64
    cap: int = 128
    beta_over_alpha: float = 10.0
    delta: float = 0.1


class RetrievalService:
    """Embed-and-report-near-neighbors service."""

    def __init__(self, cfg: ArchConfig, par: ParallelConfig, params,
                 rcfg: RetrievalConfig = RetrievalConfig()):
        self.cfg, self.par, self.params, self.rcfg = cfg, par, params, rcfg
        self._embed = jax.jit(
            lambda p, b: forward_embed(p, b, cfg, par))
        self.index: Optional[HybridLSHIndex] = None
        self._queries_served = 0
        self._linear_served = 0

    def embed(self, batch: Dict[str, jax.Array]) -> jax.Array:
        return self._embed(self.params, batch)

    def index_corpus(self, batches: Iterable[Dict[str, jax.Array]]):
        embs = [np.asarray(self.embed(b)) for b in batches]
        corpus = jnp.asarray(np.concatenate(embs, axis=0))
        r = self.rcfg
        fam = make_family("cosine", d=corpus.shape[1], L=r.tables,
                          r=r.radius, delta=r.delta)
        self.index = HybridLSHIndex(
            fam, num_buckets=r.num_buckets, m=r.hll_m, cap=r.cap,
            cost_model=CostModel(alpha=1.0, beta=r.beta_over_alpha))
        self.index.build(corpus)
        return corpus.shape[0]

    def query(self, batch: Dict[str, jax.Array],
              radius: Optional[float] = None):
        """Returns (QueryResult, embeddings)."""
        assert self.index is not None, "call index_corpus first"
        q = self.embed(batch)
        res = self.index.query(q, radius or self.rcfg.radius)
        self._queries_served += res.n_queries
        self._linear_served += int(res.frac_linear * res.n_queries)
        return res, q

    @property
    def stats(self) -> Dict[str, float]:
        served = max(self._queries_served, 1)
        return {"queries": self._queries_served,
                "frac_linear": self._linear_served / served,
                "index_size": self.index.n if self.index else 0}
