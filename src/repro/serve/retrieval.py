"""Retrieval-augmented serving: the paper's index as a first-class
feature of the serving path.

An LM (any of the 10 archs) encodes requests to normalized embeddings
(models.transformer.forward_embed); the corpus embeddings live in a
streaming index (cosine/SimHash by default), so a serving corpus
mutates live via ``add_documents`` / ``remove_documents`` instead of
full rebuilds.  With ``RetrievalConfig.mesh`` set, the corpus is
row-sharded over the mesh's data axis (``ShardedDynamicHybridIndex``);
otherwise the single-host ``DynamicHybridIndex`` serves.  Either way
every retrieval request goes through the paper's Algorithm 2 via the
shared segment engine, with the tombstone-corrected estimate.
``stats`` exposes routing decisions and compaction counters.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.core import CostModel
from repro.core.lsh import make_family
from repro.models.parallel import ParallelConfig
from repro.models.transformer import forward_embed
from repro.streaming import (CompactionPolicy, DynamicHybridIndex,
                             ShardedDynamicHybridIndex)


@dataclasses.dataclass
class RetrievalConfig:
    radius: float = 0.3            # cosine distance threshold
    tables: int = 20               # L
    num_buckets: int = 4096
    hll_m: int = 64
    cap: int = 128
    beta_over_alpha: float = 10.0
    delta: float = 0.1
    # Streaming-index knobs.
    delta_capacity: int = 4096
    compact_delta_fill: float = 1.0
    compact_tombstone_ratio: float = 0.25
    # LSM level-stack knobs: fanout bounds segments per level; step_rows
    # switches merges from synchronous drain to bounded off-query-path
    # steps (RetrievalService ticks them between batches).
    compact_fanout: int = 4
    compact_step_rows: Optional[int] = None
    # Mesh sharding: set to shard the corpus over `mesh_axis`.
    mesh: Optional[Mesh] = None
    mesh_axis: str = "data"
    shard_routing: str = "global"  # or "per_shard" (density-adaptive)
    shard_max_out: int = 512       # reported neighbors per (shard, query)
    # Merge-time rebalancing: placement of surviving merge rows across
    # shards — "keep_local" (never move), "round_robin", or
    # "load_balance" (water-fill per-shard live counts).  `stats` then
    # reports `shard_skew` (max/mean live load) and cumulative
    # `rows_moved` so skewed streams are visible and correctable.
    shard_placement: str = "keep_local"


class RetrievalService:
    """Embed-and-report-near-neighbors service.

    Wraps an LM encoder (any arch config) over a streaming index:
    ``index_corpus`` builds, ``add_documents``/``remove_documents``
    mutate live, ``query`` reports r-near neighbors for an embedded
    request batch, ``compaction_tick`` advances merge work off the
    query path, and ``stats`` exposes routing + compaction +
    rebalancing counters.
    """

    def __init__(self, cfg: ArchConfig, par: ParallelConfig, params,
                 rcfg: RetrievalConfig = RetrievalConfig()):
        self.cfg, self.par, self.params, self.rcfg = cfg, par, params, rcfg
        self._embed = jax.jit(
            lambda p, b: forward_embed(p, b, cfg, par))
        self.index: Optional[Union[DynamicHybridIndex,
                                   ShardedDynamicHybridIndex]] = None
        self._queries_served = 0
        self._linear_served = 0
        self._compaction_ticks = 0

    def embed(self, batch: Dict[str, jax.Array]) -> jax.Array:
        """Normalized (B, d_model) embeddings for one token batch."""
        return self._embed(self.params, batch)

    def _embed_corpus(self, batches: Iterable[Dict[str, jax.Array]]):
        embs = [np.asarray(self.embed(b)) for b in batches]
        return jnp.asarray(np.concatenate(embs, axis=0))

    def index_corpus(self, batches: Iterable[Dict[str, jax.Array]]):
        """Embed + build the corpus index per ``RetrievalConfig`` (mesh
        set -> sharded index with the configured routing/placement);
        returns the corpus size."""
        corpus = self._embed_corpus(batches)
        r = self.rcfg
        fam = make_family("cosine", d=corpus.shape[1], L=r.tables,
                          r=r.radius, delta=r.delta)
        common = dict(
            num_buckets=r.num_buckets, m=r.hll_m, cap=r.cap,
            delta_capacity=r.delta_capacity,
            cost_model=CostModel(alpha=1.0, beta=r.beta_over_alpha),
            policy=CompactionPolicy(
                delta_fill=r.compact_delta_fill,
                tombstone_ratio=r.compact_tombstone_ratio,
                fanout=r.compact_fanout,
                step_rows=r.compact_step_rows))
        if r.mesh is not None:
            self.index = ShardedDynamicHybridIndex(
                fam, mesh=r.mesh, data_axis=r.mesh_axis,
                routing=r.shard_routing, max_out=r.shard_max_out,
                placement=r.shard_placement, **common)
        else:
            self.index = DynamicHybridIndex(fam, **common)
        self.index.build(corpus)
        return corpus.shape[0]

    # ------------------------------------------------------- live mutation
    def add_documents(self,
                      batches: Iterable[Dict[str, jax.Array]]) -> np.ndarray:
        """Embed + insert new documents; returns their doc ids.

        Inserts land in the delta segment(s) (no rebuild); compaction
        folds them into the main segment per the configured policy.
        """
        assert self.index is not None, "call index_corpus first"
        return self.index.insert(self._embed_corpus(batches))

    def remove_documents(self, doc_ids: Sequence[int]) -> int:
        """Tombstone documents by id; returns #removed."""
        assert self.index is not None, "call index_corpus first"
        return self.index.delete(doc_ids)

    def query(self, batch: Dict[str, jax.Array],
              radius: Optional[float] = None):
        """Returns (QueryResult | ShardedQueryResult, embeddings).

        Deliberately does NOT advance compaction: with
        ``compact_step_rows`` set, merge steps belong between batches —
        wire ``compaction_tick`` as the scheduler's ``background_tick``
        (or call it from the serving loop), never inside a request.
        """
        assert self.index is not None, "call index_corpus first"
        q = self.embed(batch)
        res = self.index.query(q, radius or self.rcfg.radius)
        self._queries_served += res.n_queries
        # exact per-query linear count from the route partition (the
        # frac_linear*n round-trip drifts under rounding)
        self._linear_served += res.n_linear
        return res, q

    def compaction_tick(self) -> bool:
        """Advance pending LSM merge work by one bounded step (the
        off-query-path hook: wire it as ``ShapeBucketScheduler``'s
        ``background_tick``, or call it between batches).  Returns True
        while more compaction work remains."""
        if self.index is None:
            return False
        self._compaction_ticks += 1
        return bool(self.index.compact_step(self.rcfg.compact_step_rows))

    @property
    def stats(self) -> Dict[str, float]:
        """Serving counters merged with the index's ``index_stats()``.

        Includes the per-level LSM counters (segments, levels,
        pending_merges, merges_per_level, compact_steps, freezes, ...)
        and — when the corpus is mesh-sharded — the rebalancing view:
        ``live_per_shard``/``delta_per_shard`` loads, ``shard_skew``
        (max/mean live load; 1.0 = balanced), the active ``placement``
        policy, and cumulative ``rows_moved`` across shards.
        """
        served = max(self._queries_served, 1)
        out = {"queries": self._queries_served,
               "linear_served": self._linear_served,
               "frac_linear": self._linear_served / served,
               "compaction_ticks": self._compaction_ticks,
               "index_size": self.index.n if self.index else 0}
        if self.index is not None:
            out.update(self.index.index_stats())
        return out
