"""Retrieval-augmented serving: the paper's index as a first-class
feature of the serving path.

An LM (any of the 10 archs) encodes requests to normalized embeddings
(models.transformer.forward_embed); the corpus embeddings live in a
streaming index (cosine/SimHash by default), so a serving corpus
mutates live via ``add_documents`` / ``remove_documents`` instead of
full rebuilds.  With ``RetrievalConfig.mesh`` set, the corpus is
row-sharded over the mesh's data axis (``ShardedDynamicHybridIndex``);
otherwise the single-host ``DynamicHybridIndex`` serves.  Either way
every retrieval request goes through the paper's Algorithm 2 via the
shared segment engine, with the tombstone-corrected estimate.
``stats`` exposes routing decisions and compaction counters.

Compaction modes (docs/compaction.md): synchronous drain (default),
budgeted ticks (``compact_step_rows`` set; ``compaction_tick`` between
batches), or fully async (``async_compaction=True``; the service owns
a ``CompactionDriver`` whose worker thread stages merges while the
serving thread only drains staged swaps).

The closed-loop fast path (docs/serving.md): ``submit`` enqueues
requests on the service's coalescing ``ShapeBucketScheduler``;
``drain_batches`` forms pow2 shape buckets across requests, serves
repeats straight from the version-keyed ``ResultCache``, embeds the
misses ONCE per formed bucket, runs the paper's cost estimate over the
whole coalesced batch, splits by route, and scatters per-request
``RequestResult``s back by uid.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, Iterable, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.core import CostModel
from repro.core.engine import QueryEngine, _pad_size
from repro.core.lsh import make_family
from repro.models.parallel import ParallelConfig
from repro.models.transformer import forward_embed
from repro.obs import Observability, to_prometheus
from repro.serve.cache import ResultCache
from repro.serve.collections import Collection, CollectionManager
from repro.serve.scheduler import ShapeBucketScheduler, TenantQuota
from repro.streaming import (CompactionDriver, CompactionPolicy,
                             DynamicHybridIndex,
                             ShardedDynamicHybridIndex)


@dataclasses.dataclass
class RetrievalConfig:
    radius: float = 0.3            # cosine distance threshold
    tables: int = 20               # L
    num_buckets: int = 4096
    hll_m: int = 64
    cap: int = 128
    beta_over_alpha: float = 10.0
    delta: float = 0.1
    # Streaming-index knobs.
    delta_capacity: int = 4096
    compact_delta_fill: float = 1.0
    compact_tombstone_ratio: float = 0.25
    # LSM level-stack knobs: fanout bounds segments per level; step_rows
    # switches merges from synchronous drain to bounded off-query-path
    # steps (RetrievalService ticks them between batches).
    compact_fanout: int = 4
    compact_step_rows: Optional[int] = None
    # Async compaction: a CompactionDriver worker thread runs the merge
    # staging gathers continuously; the serving thread's tick becomes a
    # cheap drain() that only applies fully-staged atomic swaps (plus
    # their loc rewrites), so no gather ever lands on the serving
    # thread.  compact_step_rows doubles as the worker's per-gather
    # budget (default delta_capacity // 2 when unset and async is on).
    async_compaction: bool = False
    # Mesh sharding: set to shard the corpus over `mesh_axis`.
    mesh: Optional[Mesh] = None
    mesh_axis: str = "data"
    shard_routing: str = "global"  # or "per_shard" (density-adaptive)
    shard_max_out: int = 512       # reported neighbors per (shard, query)
    # Merge-time rebalancing: placement of surviving merge rows across
    # shards — "keep_local" (never move), "round_robin", or
    # "load_balance" (water-fill per-shard live counts).  `stats` then
    # reports `shard_skew` (max/mean live load) and cumulative
    # `rows_moved` so skewed streams are visible and correctable.
    shard_placement: str = "keep_local"
    # Closed-loop serving (docs/serving.md): the submit/drain_batches
    # path coalesces cross-request queries into pow2 shape buckets.
    # max_wait_s is the coalescing deadline (0 drains greedily);
    # max_queue bounds admission (None = unbounded; beyond it submit
    # returns None and counts a reject); result_cache_bytes budgets the
    # version-keyed query result cache (0 disables it).
    coalesce_max_batch: int = 64
    coalesce_min_bucket: int = 8
    coalesce_max_wait_s: float = 0.0
    max_queue: Optional[int] = 4096
    result_cache_bytes: int = 8 << 20
    # Observability (repro.obs; docs/observability.md): one bundle —
    # metrics registry + per-query route tracer + compaction event log —
    # shared by the service, the index, and the driver.  obs_enabled
    # False builds the no-op variant (the query path short-circuits on
    # it).  Per-query spans need the single-host index; the sharded
    # index routes inside shard_map and gets events + phases only.
    obs_enabled: bool = True
    obs_trace_capacity: int = 256       # retained per-query spans
    obs_events_capacity: int = 512      # event-log ring size
    obs_trace_sample_every: int = 16    # trace every Nth batch (1 = all)
    obs_per_segment_timing: bool = False
    obs_dump_path: Optional[str] = None  # shutdown() metrics dump target


@dataclasses.dataclass
class RequestResult:
    """One request's scattered share of a coalesced batch.

    ``ids[i]`` / ``dists[i]`` are the reported r-near neighbors of the
    request's i-th query row (external doc ids; arrays are read-only
    when served from the cache).  ``cached`` marks a cache hit;
    ``queue_wait_s`` is the scheduler queue time (0 for hits served at
    submit-batch formation).
    """

    uid: int
    ids: List[np.ndarray]
    dists: List[np.ndarray]
    n_queries: int
    cached: bool
    queue_wait_s: float

    def neighbor_sets(self):
        return {i: set(self.ids[i].tolist())
                for i in range(self.n_queries)}


class RetrievalService:
    """Embed-and-report-near-neighbors service.

    Wraps an LM encoder (any arch config) over a streaming index:
    ``index_corpus`` builds, ``add_documents``/``remove_documents``
    mutate live, ``query`` reports r-near neighbors for an embedded
    request batch, ``compaction_tick`` advances merge work off the
    query path, and ``stats`` exposes routing + compaction +
    rebalancing counters.

    With ``RetrievalConfig.async_compaction`` the service owns a
    ``CompactionDriver``: merge staging runs on the driver's worker
    thread, ``compaction_tick`` degenerates to the driver's cheap
    ``drain()`` (apply any fully-staged atomic swap), and
    ``checkpoint`` flushes the driver first so a snapshot never
    captures a half-staged merge.  All ``RetrievalService`` methods are
    control-thread-only — the only concurrency is the driver's worker,
    which the service manages (``shutdown`` stops it).
    """

    def __init__(self, cfg: ArchConfig, par: ParallelConfig, params,
                 rcfg: Optional[RetrievalConfig] = None):
        # default must be constructed per instance: a dataclass default
        # in the signature is ONE shared object, and anything mutating
        # it (tests tweaking radius, a caller setting mesh) would leak
        # into every service built afterwards
        rcfg = rcfg if rcfg is not None else RetrievalConfig()
        self.cfg, self.par, self.params, self.rcfg = cfg, par, params, rcfg
        self._embed = jax.jit(
            lambda p, b: forward_embed(p, b, cfg, par))
        self.index: Optional[Union[DynamicHybridIndex,
                                   ShardedDynamicHybridIndex]] = None
        self.driver: Optional[CompactionDriver] = None
        self._queries_served = 0
        self._linear_served = 0
        self._compaction_ticks = 0
        self._idle_ticks = 0
        self.obs = Observability.create(
            enabled=rcfg.obs_enabled,
            trace_capacity=rcfg.obs_trace_capacity,
            events_capacity=rcfg.obs_events_capacity,
            per_segment_timing=rcfg.obs_per_segment_timing,
            trace_sample_every=rcfg.obs_trace_sample_every)
        reg = self.obs.registry
        self._m_queries = reg.counter(
            "repro_service_queries_total", help="Queries served")
        self._m_linear = reg.counter(
            "repro_service_linear_total",
            help="Queries served by the linear route")
        self._m_ticks = reg.counter(
            "repro_service_compaction_ticks_total",
            help="Maintenance ticks that ran compaction work")
        self._m_idle = reg.counter(
            "repro_service_idle_ticks_total",
            help="Maintenance ticks with nothing to do")
        self._g_size = reg.gauge(
            "repro_index_live_docs", help="Live documents in the index")
        # The closed-loop fast path: one coalescing scheduler + one
        # version-keyed result cache per service, built unconditionally
        # so the stats schema never varies with traffic shape.  The
        # scheduler's background tick is the compaction hook — every
        # drain advances merge work between batches.
        self.scheduler = ShapeBucketScheduler(
            max_batch=rcfg.coalesce_max_batch,
            min_bucket=rcfg.coalesce_min_bucket,
            background_tick=self.compaction_tick,
            registry=reg,
            max_wait_s=rcfg.coalesce_max_wait_s,
            max_queue=rcfg.max_queue)
        self.cache = ResultCache(rcfg.result_cache_bytes, registry=reg)
        # Multi-tenant collections (docs/serving.md "Collections"):
        # named per-tenant indexes built through one factory that
        # shares the family (one lru-cached jitted hash), one
        # QueryEngine, the scheduler's per-tenant token buckets, the
        # collection-keyed cache, and — in async mode — one
        # CompactionDriver pool.  The default corpus (index_corpus)
        # keeps the reserved name "" and never lives in the manager.
        self._family = None             # shared LSH family, built lazily
        self._shared_engine: Optional[QueryEngine] = None
        self._tick_rr = 0               # budgeted-tick round-robin cursor
        self.collections = CollectionManager(
            index_factory=self._make_index,
            obs=self.obs, scheduler=self.scheduler, cache=self.cache)

    def embed(self, batch: Dict[str, jax.Array]) -> jax.Array:
        """Normalized (B, d_model) embeddings for one token batch."""
        return self._embed(self.params, batch)

    def _embed_corpus(self, batches: Iterable[Dict[str, jax.Array]]):
        embs = [np.asarray(self.embed(b)) for b in batches]
        return jnp.asarray(np.concatenate(embs, axis=0))

    def _step_rows(self) -> Optional[int]:
        """Merge-step budget: the configured step_rows; async mode must
        not fall back to the synchronous drain (step_rows=None), so it
        defaults to half the delta capacity."""
        r = self.rcfg
        if r.compact_step_rows is None and r.async_compaction:
            return max(r.delta_capacity // 2, 1)
        return r.compact_step_rows

    def _lsh_family(self, d: int):
        """The ONE LSH family (and shared ``QueryEngine``) every index
        this service builds is constructed around — frozen + hashable,
        so ``bucket_fn_for``'s lru cache hands all collections the same
        jitted hash."""
        if self._family is None or self._family.d != d:
            r = self.rcfg
            self._family = make_family("cosine", d=d, L=r.tables,
                                       r=r.radius, delta=r.delta)
            self._shared_engine = QueryEngine(
                CostModel(alpha=1.0, beta=r.beta_over_alpha),
                tracer=self.obs.tracer)
        return self._family

    def _make_index(self, obs: Optional[Observability] = None,
                    d: Optional[int] = None):
        """Build one fresh, empty streaming index per ``RetrievalConfig``
        (the collection factory; ``index_corpus`` reuses it for the
        default corpus).  All indexes share the family, the engine, and
        the service's obs bundle (the manager passes a per-collection
        event facade as ``obs``)."""
        r = self.rcfg
        d = int(d) if d is not None else int(self.cfg.d_model)
        fam = self._lsh_family(d)
        common = dict(
            num_buckets=r.num_buckets, m=r.hll_m, cap=r.cap,
            delta_capacity=r.delta_capacity,
            cost_model=CostModel(alpha=1.0, beta=r.beta_over_alpha),
            policy=CompactionPolicy(
                delta_fill=r.compact_delta_fill,
                tombstone_ratio=r.compact_tombstone_ratio,
                fanout=r.compact_fanout,
                step_rows=self._step_rows()),
            obs=obs if obs is not None else self.obs,
            engine=self._shared_engine)
        if r.mesh is not None:
            index = ShardedDynamicHybridIndex(
                fam, mesh=r.mesh, data_axis=r.mesh_axis,
                routing=r.shard_routing, max_out=r.shard_max_out,
                placement=r.shard_placement, **common)
        else:
            index = DynamicHybridIndex(fam, **common)
        index.build(np.zeros((0, d), np.float32))
        return index

    def _ensure_driver(self) -> CompactionDriver:
        """The ONE async-compaction driver (created + started on first
        need); its worker round-robins over every attached index —
        default corpus and collections alike."""
        if self.driver is None:
            self.driver = CompactionDriver(
                budget_rows=self._step_rows(), obs=self.obs).start()
            self.collections.driver = self.driver
        return self.driver

    def index_corpus(self, batches: Iterable[Dict[str, jax.Array]]):
        """Embed + build the default corpus index per
        ``RetrievalConfig`` (mesh set -> sharded index with the
        configured routing/placement); returns the corpus size.  With
        ``async_compaction`` the index is attached to the service's
        shared ``CompactionDriver`` under the reserved name ``""``
        (detached first on a rebuild — collections stay attached)."""
        if self.driver is not None:
            self.driver.detach("")
        corpus = self._embed_corpus(batches)
        self.index = self._make_index(d=corpus.shape[1])
        self.index.build(corpus)
        if self.rcfg.async_compaction:
            self._ensure_driver().attach("", self.index)
        return corpus.shape[0]

    # ------------------------------------------------- collection lifecycle
    def create_collection(self, name: str,
                          batches: Optional[Iterable] = None, *,
                          quota: Optional[TenantQuota] = None) -> int:
        """Create a named collection (docs/serving.md "Collections");
        returns its initial corpus size.

        ``batches`` (optional) embeds + builds the tenant's initial
        corpus exactly like ``index_corpus`` does for the default one;
        omitted = empty collection, ready for ``add_documents``.
        ``quota`` installs the tenant's scheduler token bucket + drain
        weight.  In async mode the new index attaches to the shared
        driver — after the build, so the worker never races it.
        """
        if self.rcfg.async_compaction:
            self.collections.driver = self._ensure_driver()
        col = self.collections.create(name, quota=quota, attach=False)
        n = 0
        if batches is not None:
            corpus = self._embed_corpus(batches)
            col.index.build(corpus)
            n = int(corpus.shape[0])
        self.collections.attach_driver(name)
        if self.driver is not None:
            self.driver.notify()
        return n

    def drop_collection(self, name: str) -> "Collection":
        """Drop a named collection: detached from the driver, queued
        requests discarded, cache entries purged.  Returns the removed
        ``Collection`` (its index is still queryable by the caller)."""
        return self.collections.drop(name)

    def _index_for(self, collection: str):
        """Resolve a collection id to its index ("" = default corpus)."""
        if not collection:
            assert self.index is not None, "call index_corpus first"
            return self.index
        return self.collections.get(collection).index

    # ------------------------------------------------------- live mutation
    def add_documents(self, batches: Iterable[Dict[str, jax.Array]],
                      collection: str = "") -> np.ndarray:
        """Embed + insert new documents; returns their doc ids.

        Inserts land in the delta segment(s) (no rebuild); compaction
        folds them into the main segment per the configured policy.
        ``collection`` targets a named collection ("" = default corpus).
        """
        ids = self._index_for(collection).insert(
            self._embed_corpus(batches))
        if self.driver is not None:
            self.driver.notify()      # a freeze may have queued a merge
        return ids

    def remove_documents(self, doc_ids: Sequence[int],
                         collection: str = "") -> int:
        """Tombstone documents by id; returns #removed."""
        removed = self._index_for(collection).delete(doc_ids)
        if self.driver is not None:
            self.driver.notify()      # tombstone pressure may queue work
        return removed

    def query(self, batch: Dict[str, jax.Array],
              radius: Optional[float] = None, collection: str = ""):
        """Returns (QueryResult | ShardedQueryResult, embeddings).

        Deliberately does NOT advance compaction: with
        ``compact_step_rows`` set, merge steps belong between batches —
        wire ``compaction_tick`` as the scheduler's ``background_tick``
        (or call it from the serving loop), never inside a request.
        """
        index = self._index_for(collection)
        q = self.embed(batch)
        res = self._routed_query(index, q, radius or self.rcfg.radius,
                                 collection)
        return res, q

    def _routed_query(self, index, emb, radius: float, collection: str):
        """One index query with per-tenant attribution: spans recorded
        while this runs carry the collection (shared tracer context),
        and counts land in both the service-wide totals and — for named
        collections — the per-tenant labeled series."""
        tracer = self.obs.tracer
        tracer.set_context(collection=collection or None)
        try:
            res = index.query(emb, radius)
        finally:
            tracer.set_context()
        self._queries_served += res.n_queries
        # exact per-query linear count from the route partition (the
        # frac_linear*n round-trip drifts under rounding)
        self._linear_served += res.n_linear
        self._m_queries.inc(res.n_queries)
        self._m_linear.inc(res.n_linear)
        if collection:
            self.collections.note_query(collection, res.n_queries,
                                        res.n_linear)
        return res

    # ------------------------------------------- coalesced serving path
    def submit(self, batch, radius: Optional[float] = None,
               collection: str = "") -> Optional[int]:
        """Enqueue one retrieval request for coalesced dispatch.

        ``batch`` is a token batch dict (or a bare token array); a 1-D
        row is treated as a single query.  ``collection`` routes to a
        named collection ("" = default corpus; unknown names raise at
        the door, not at drain time).  Returns the request uid, or
        None when admission control sheds it — the tenant's own token
        bucket, or the global queue bound (both counted in
        ``repro_scheduler_rejects_total``, per-collection labeled).
        Results come back from ``drain_batches`` keyed by this uid.
        """
        collection = str(collection)
        if collection:
            self.collections.get(collection)   # raise early on unknown
        tokens = batch["tokens"] if isinstance(batch, dict) else batch
        tokens = np.asarray(tokens)
        if tokens.ndim == 1:
            tokens = tokens[None, :]
        r = float(radius if radius is not None else self.rcfg.radius)
        return self.scheduler.submit({"tokens": tokens, "radius": r},
                                     collection=collection)

    def drain_batches(self, max_batches: Optional[int] = None,
                      force: bool = False) -> Dict[int, "RequestResult"]:
        """Form and serve coalesced batches until the scheduler yields
        nothing (deadline not reached, or queue empty).

        ``force=True`` flushes requests still inside the coalescing
        deadline (shutdown, test barriers); ``max_batches`` bounds the
        work per call so a serving loop can interleave drains with
        other duties.  Returns uid -> ``RequestResult`` for every
        request served this call.
        """
        assert self.index is not None or len(self.collections), \
            "call index_corpus or create_collection first"
        out: Dict[int, RequestResult] = {}
        served = 0
        while max_batches is None or served < max_batches:
            reqs, _bucket = self.scheduler.next_batch(force=force)
            if not reqs:
                break
            out.update(self._serve_batch(reqs))
            served += 1
        return out

    def _serve_batch(self, reqs) -> Dict[int, "RequestResult"]:
        """Serve one formed batch: cache lookups first, then one embed +
        one routed index query per (collection, radius, seq) miss
        group, scattered back per request by uid.  A formed batch may
        span tenants (the scheduler drains weighted-fair across them);
        each tenant's requests dispatch against its own index at its
        own version."""
        versions: Dict[str, int] = {}
        out: Dict[int, RequestResult] = {}
        # (collection, radius, seq_len) -> [(req, key)]; rows of one
        # group share one index, one compiled embed + query shape, so
        # they coalesce into one dense pow2 dispatch through the PR 7
        # fused kernels
        groups: Dict[tuple, list] = {}
        for req in reqs:
            col = req.collection
            version = versions.get(col)
            if version is None:
                version = self._index_for(col).version
                self.cache.purge_stale(version, collection=col)
                versions[col] = version
            tokens = req.payload["tokens"]
            radius = req.payload["radius"]
            key = self.cache.key(version, radius, tokens, collection=col)
            hit = self.cache.get(key)
            if hit is not None:
                ids, dists = hit
                out[req.uid] = RequestResult(
                    uid=req.uid, ids=list(ids), dists=list(dists),
                    n_queries=len(ids), cached=True,
                    queue_wait_s=req.wait_s)
                continue
            groups.setdefault((col, radius, tokens.shape[1]), []).append(
                (req, key))
        for (col, radius, _seq), members in groups.items():
            self._serve_miss_group(col, radius, members, out)
        return out

    def _serve_miss_group(self, collection: str, radius: float,
                          members, out) -> None:
        index = self._index_for(collection)
        rows = np.concatenate([req.payload["tokens"]
                               for req, _ in members], axis=0)
        nq = rows.shape[0]
        n_pad = _pad_size(nq, minimum=self.rcfg.coalesce_min_bucket)
        if n_pad > nq:      # repeat the last row; pad results dropped
            rows = np.concatenate(
                [rows, np.repeat(rows[-1:], n_pad - nq, axis=0)], axis=0)
        emb = self.embed({"tokens": jnp.asarray(rows)})
        tracer = self.obs.tracer
        tracer.set_context(collection=collection or None)
        try:
            res = index.query(emb, radius)
        finally:
            tracer.set_context()
        self._queries_served += nq
        n_linear = self._count_linear(res, nq)
        self._linear_served += n_linear
        self._m_queries.inc(nq)
        self._m_linear.inc(n_linear)
        if collection:
            self.collections.note_query(collection, nq, n_linear)
        off = 0
        for req, key in members:
            k = req.payload["tokens"].shape[0]
            pairs = [res.reported(off + j) for j in range(k)]
            ids = [np.asarray(p[0]) for p in pairs]
            dists = [np.asarray(p[1]) for p in pairs]
            self.cache.put(key, ids, dists)
            out[req.uid] = RequestResult(
                uid=req.uid, ids=ids, dists=dists, n_queries=k,
                cached=False, queue_wait_s=req.wait_s)
            off += k

    @staticmethod
    def _count_linear(res, nq: int) -> int:
        """Linear-route count over the REAL rows of a padded batch.

        Single-host results carry the route partition (pad rows land at
        indices >= nq and are excluded exactly); the sharded per-batch
        vote only supports the fractional reconstruction.
        """
        if hasattr(res, "lin_idx"):
            return len({int(i) for i in np.asarray(res.lin_idx).tolist()
                        if i < nq})
        return round(nq * res.frac_linear)

    def compaction_tick(self) -> bool:
        """The between-batches maintenance hook (wire it as
        ``ShapeBucketScheduler``'s ``background_tick``).  Budgeted mode:
        advance pending merge work by one bounded ``compact_step``.
        Async mode: the driver's cheap ``drain()`` — apply any
        fully-staged atomic swap; the gathers live on the worker.
        Returns True while more compaction work remains.

        ``stats["compaction_ticks"]`` counts only ticks that actually
        ran work (a step that advanced a merge, or a drain that applied
        a swap); no-op ticks land in ``stats["idle_ticks"]``.

        Multi-tenant: the driver's ``drain`` sweeps every attached
        collection; in budgeted mode each tick advances ONE collection
        with pending work, round-robin — the inline mirror of the
        driver worker's fairness.
        """
        indexes = self._all_indexes()
        if not indexes:
            return False
        if self.driver is not None:
            if self.driver.drain() > 0:
                self._compaction_ticks += 1
                self._m_ticks.inc()
            else:
                self._idle_ticks += 1
                self._m_idle.inc()
            return any(bool(i.has_compaction_work) for i in indexes)
        pending = [i for i in indexes if i.has_compaction_work]
        if not pending:
            self._idle_ticks += 1
            self._m_idle.inc()
            return False
        self._compaction_ticks += 1
        self._m_ticks.inc()
        self._tick_rr += 1
        index = pending[self._tick_rr % len(pending)]
        more = bool(index.compact_step(self._step_rows()))
        return more or len(pending) > 1

    def _all_indexes(self) -> List:
        """Default index (if built) + every collection's, in order."""
        out = [self.index] if self.index is not None else []
        out.extend(self.collections.get(n).index
                   for n in self.collections.names())
        return out

    # ------------------------------------------------- driver lifecycle
    def checkpoint(self, manager, step: int,
                   barrier: str = "cut") -> None:
        """Snapshot the FULL collection tree: the default corpus index
        at the top level (the pre-collections layout, so old
        checkpoints stay readable) plus every named collection — index
        state and quota — nested under ``collections/<name>/...`` (a
        per-collection manifest subtree;
        ``CheckpointManager.collection_names`` lists them).

        ``barrier`` selects the async-mode consistency barrier:

        * ``"cut"`` (default): a consistent-cut snapshot — state is
          captured under the driver lock WITHOUT draining queued
          merges (``CompactionDriver.consistent_cut``), and saved
          incrementally: frozen levels are content-addressed via the
          index's cached ``state_digests`` hints, so the snapshot
          writes only the delta, tombstones, and manifest.  Valid
          because staged merge progress is volatile by contract.
          Checkpoint stall is O(delta + manifest), not O(pending
          compaction), in all three compaction modes.
        * ``"flush"``: the legacy barrier — every queued merge
          finishes inline (stage remainder + swap) across ALL attached
          collections, then a full (non-incremental) save runs.

        ``manager`` is a ``CheckpointManager``.
        """
        assert self.index is not None or len(self.collections), \
            "call index_corpus or create_collection first"
        assert barrier in ("cut", "flush"), barrier
        t0 = time.perf_counter()

        def _capture():
            st: Dict[str, object] = {}
            dg: Dict[str, str] = {}
            if self.index is not None:
                st = self.index.state_dict()
                sd = getattr(self.index, "state_digests", None)
                if sd is not None:
                    dg.update(sd())
            cols = self.collections.state_dict()
            if cols:
                st = {**st, "collections": cols}
                dg.update({f"collections/{p}": d for p, d in
                           self.collections.state_digests().items()})
            return st, dg

        if barrier == "flush":
            if self.driver is not None:
                self.driver.flush()
            state, _ = _capture()
            manager.save(step, state, blocking=True)
        else:
            if self.driver is not None:
                state, digests = self.driver.consistent_cut(_capture)
            else:
                state, digests = _capture()
            manager.save_incremental(step, state, digests=digests,
                                     blocking=True)
        self.obs.events.emit(
            "snapshot", step=int(step), barrier=barrier,
            seconds=time.perf_counter() - t0)

    def restore(self, manager, step: Optional[int] = None):
        """Restore the full collection tree from a committed checkpoint
        (the service must be configured the same as the one that
        saved).  The driver worker is stopped around the state swap —
        staging must never run against a stack being replaced — and
        restarted after; staged progress is volatile by contract, so
        nothing is lost.  Named collections are rebuilt exactly:
        current ones dropped, saved ones re-created (with their saved
        quotas) through the shared factory and loaded.  A fresh service
        may restore directly — the default index is built on demand
        when the checkpoint carries top-level corpus state.  Returns
        the restored step (None: no committed checkpoint)."""
        t0 = time.perf_counter()
        if self.driver is not None:
            self.driver.stop()
        state, restored = manager.restore_tree(step=step)
        if state is None:
            if self.driver is not None:
                self.driver.start()
            return None
        cols = state.pop("collections", None) or {}
        if self.rcfg.async_compaction:
            self._ensure_driver()
            self.driver.stop()
        if state:
            if self.index is None:
                self.index = self._make_index()
            self.index.load_state_dict(state)
        self.collections.load_state_dict(cols)
        if self.driver is not None:
            self.driver.start()
            if self.index is not None and "" not in self.driver.indexes():
                self.driver.attach("", self.index)
        self.obs.events.emit(
            "restore", step=int(restored),
            collections=len(cols),
            seconds=time.perf_counter() - t0)
        return restored

    def shutdown(self, flush: bool = True,
                 dump_path: Optional[str] = None) -> None:
        """Stop the driver worker; ``flush=True`` (default) completes
        pending merges inline first so no staging is orphaned.  Safe to
        call with no driver or repeatedly.

        When ``dump_path`` (or ``RetrievalConfig.obs_dump_path``) is
        set and observability is enabled, the final ``metrics()``
        snapshot is written there as JSON — the post-mortem record of
        a serving run.
        """
        if self.driver is not None:
            self.driver.stop(flush=flush)
        self.obs.events.emit("shutdown", flush=flush,
                             queries=self._queries_served)
        path = dump_path or self.rcfg.obs_dump_path
        if path and self.obs.enabled:
            with open(path, "w") as f:
                json.dump(self.metrics(), f, indent=2, sort_keys=True)

    # --------------------------------------------------- export surfaces
    def _sync_gauges(self) -> None:
        self._g_size.set(self.index.n if self.index else 0)

    def metrics(self) -> Dict[str, object]:
        """One JSON-ready observability snapshot: the registry dump,
        the tracer's routing/misroute summary, the event-log tail +
        per-kind counts, and the ``stats`` dict — everything a scrape
        or a shutdown dump needs in one call."""
        self._sync_gauges()
        return _jsonable({
            "registry": self.obs.registry.snapshot(),
            "tracing": self.obs.tracer.summary(),
            "events": {
                "counts_by_kind": self.obs.events.counts_by_kind(),
                "dropped": self.obs.events.dropped,
                "tail": self.obs.events.events(limit=50),
            },
            "stats": self.stats,
        })

    def metrics_text(self) -> str:
        """The registry in Prometheus text exposition format."""
        self._sync_gauges()
        return to_prometheus(self.obs.registry)

    @property
    def stats(self) -> Dict[str, float]:
        """Serving counters merged with the index's ``index_stats()``.

        Includes the per-level LSM counters (segments, levels,
        pending_merges, merges_per_level, compact_steps, freezes, ...)
        and — when the corpus is mesh-sharded — the rebalancing view:
        ``live_per_shard``/``delta_per_shard`` loads, ``shard_skew``
        (max/mean live load; 1.0 = balanced), the active ``placement``
        policy, and cumulative ``rows_moved`` across shards.

        The coalesced serving path adds three pinned sub-dicts:
        ``scheduler`` (queue depth, submits/rejects/batches, queue-wait
        aggregates, per-tenant quota views — SCHEDULER_STATS_KEYS /
        SCHEDULER_TENANT_KEYS), ``cache`` (hit/miss/evict/stale
        counters + byte budget — CACHE_STATS_KEYS), and
        ``collections`` (the multi-tenant view —
        COLLECTION_MANAGER_KEYS / COLLECTION_STATS_KEYS per tenant;
        empty manager when only the default corpus is in use).

        ``compaction_ticks`` counts only ticks that ran work;
        ``idle_ticks`` the no-ops.  In async mode a ``driver`` sub-dict
        carries the ``CompactionDriver`` state (``worker_alive``,
        ``pending_gathers``, ``staged_rows``, ``stage_calls``,
        ``drains``/``applied``, ...).
        """
        served = max(self._queries_served, 1)
        out = {"queries": self._queries_served,
               "linear_served": self._linear_served,
               "frac_linear": self._linear_served / served,
               "compaction_ticks": self._compaction_ticks,
               "idle_ticks": self._idle_ticks,
               "index_size": self.index.n if self.index else 0,
               "scheduler": self.scheduler.stats(),
               "cache": self.cache.stats(),
               "collections": self.collections.stats()}
        if self.index is not None:
            out.update(self.index.index_stats())
        if self.driver is not None:
            out["driver"] = self.driver.stats()
        return out


def _jsonable(obj):
    """Recursively coerce numpy scalars/arrays (and tuple/dict-int keys)
    to plain JSON types so ``json.dumps`` round-trips a metrics dump."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return _jsonable(obj.tolist())
    if isinstance(obj, (np.bool_, bool)):
        return bool(obj)
    if isinstance(obj, (np.integer, int)):
        return int(obj)
    if isinstance(obj, (np.floating, float)):
        return float(obj)
    return obj
