"""Request scheduler: shape-bucketed batching with hybrid routing.

TPU serving wants a small set of compiled shapes.  The scheduler
accumulates requests, forms batches padded to power-of-two sizes
(bounded jit-cache churn), and — for retrieval requests — consults the
paper's cost estimator FIRST so that a micro-batch executes a single
strategy (per-query lax.cond would run both branches densely on TPU;
see DESIGN.md §2).

Cross-request coalescing (docs/serving.md): with ``max_wait_s > 0``
``next_batch`` holds small queues back until either the queue can fill
a whole ``max_batch`` or the *oldest* request has waited out the
deadline, so many single-query submits merge into one dense pow2
bucket instead of draining as singleton batches.  ``max_wait_s = 0``
(default) drains greedily — exactly the pre-coalescing behavior.
Admission control: with ``max_queue`` set, submits beyond the bound
are rejected (``submit`` returns None, counted in
``repro_scheduler_rejects_total``) instead of growing the queue — and
the latency SLO — without bound.

Multi-tenant serving (docs/serving.md "Collections"): every request
carries a collection id (the default corpus is the reserved empty name
``""``).  ``set_quota`` attaches a per-tenant token bucket
(``rate`` tokens/s refill, ``burst`` capacity) so a flooding tenant is
rejected at ITS OWN bucket — before the global queue bound — and a
quiet tenant keeps being admitted; rejects are counted per collection
(``repro_scheduler_rejects_total{collection=...}``) on top of the
unlabeled aggregate.  ``next_batch`` drains *weighted-fair* across the
tenants present in the queue: batch slots are allocated proportionally
to quota weights (largest-remainder, leftover filled in global FIFO
age order), so a backlogged tenant cannot starve another's queue-wait
even when both are inside their buckets.  Single-tenant queues drain
pure FIFO — bit-identical to the pre-collections behavior.

The scheduler is also the natural interleaving point for *off-query-
path* index maintenance: register a ``background_tick`` (typically
``RetrievalService.compaction_tick``) and it runs once per
``next_batch`` call — empty and not-yet-ready drains included, so a
quiet serving loop still advances merges.  What a tick costs depends
on the service's compaction mode (docs/compaction.md):

  * budgeted — the tick runs one bounded LSM merge step (a gather of
    ``compact_step_rows`` rows) on this thread, between batches
    instead of inside one;
  * async    — the gathers live on the ``CompactionDriver``'s worker
    thread and the tick degenerates to the driver's ``drain()``: a
    flag check, plus the atomic level swap when one is staged-ready.
    The serving thread never pays for staging at all.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import partition_indices
from repro.obs.metrics import DEFAULT_TIME_BUCKETS, NULL_REGISTRY


@dataclasses.dataclass
class Request:
    uid: int
    payload: Any
    t_submit: float = 0.0       # scheduler clock at submit
    wait_s: float = 0.0         # queue wait, stamped when the batch forms
    collection: str = ""        # tenant id; "" = the default corpus


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Per-collection admission quota + drain weight.

    ``rate`` tokens/s refill a bucket of ``burst`` capacity; each
    admitted submit spends one token, an empty bucket rejects.  The
    defaults (inf/inf) never reject — a tenant with no quota set is
    limited only by the global ``max_queue``.  ``weight`` scales the
    tenant's share of batch slots under weighted drain.
    """
    rate: float = math.inf
    burst: float = math.inf
    weight: float = 1.0


class _TenantState:
    """One collection's token bucket + serving counters."""

    __slots__ = ("quota", "tokens", "t_refill", "submits", "rejects",
                 "batched", "wait_max")

    def __init__(self, quota: TenantQuota, now: float):
        self.quota = quota
        self.tokens = quota.burst
        self.t_refill = now
        self.submits = 0
        self.rejects = 0
        self.batched = 0
        self.wait_max = 0.0

    def try_take(self, now: float) -> bool:
        """Refill by elapsed time, then spend one token if available."""
        q = self.quota
        if math.isinf(q.rate) and math.isinf(q.burst):
            return True
        self.tokens = min(q.burst,
                          self.tokens + (now - self.t_refill) * q.rate)
        self.t_refill = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


class ShapeBucketScheduler:
    def __init__(self, max_batch: int = 64, min_bucket: int = 8,
                 background_tick: Optional[Callable[[], Any]] = None,
                 registry=None, max_wait_s: float = 0.0,
                 max_queue: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        """``registry`` — optional ``repro.obs.MetricsRegistry``; the
        default null registry makes every instrument a no-op.

        ``max_wait_s`` — coalescing deadline: ``next_batch`` returns an
        empty batch (without counting a phantom batch) until the queue
        holds ``max_batch`` requests or the oldest has waited this
        long.  0 (default) drains greedily.
        ``max_queue`` — admission bound: ``submit`` beyond it returns
        None and counts a reject.  None (default) = unbounded.
        ``clock`` — monotonic time source (injectable for tests).
        """
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        self.background_tick = background_tick
        self.max_wait_s = float(max_wait_s)
        self.max_queue = max_queue
        self.clock = clock
        self.queue: List[Request] = []
        self._tenants: Dict[str, _TenantState] = {}
        self._uid = 0
        self._ticks = 0
        self._submits = 0
        self._rejects = 0
        self._batches = 0
        self._requests_batched = 0
        self._wait_sum = 0.0
        self._wait_max = 0.0
        reg = registry if registry is not None else NULL_REGISTRY
        self._m_submits = reg.counter(
            "repro_scheduler_submits_total", help="Requests submitted")
        self._m_rejects = reg.counter(
            "repro_scheduler_rejects_total",
            help="Requests rejected by admission control (queue full)")
        self._m_batches = reg.counter(
            "repro_scheduler_batches_total", help="Batches formed")
        self._m_batch_size = reg.histogram(
            "repro_scheduler_batch_size",
            buckets=(1, 2, 4, 8, 16, 32, 64, 128),
            help="Requests per formed batch (pre-padding)")
        self._m_queue_wait = reg.histogram(
            "repro_scheduler_queue_wait_seconds",
            buckets=DEFAULT_TIME_BUCKETS,
            help="Per-request queue wait (submit -> batch formed)")
        self._m_ticks = reg.counter(
            "repro_scheduler_ticks_total", help="Background ticks run")
        self._registry = reg

    # ------------------------------------------------------------ tenants
    def _tenant(self, collection: str) -> _TenantState:
        st = self._tenants.get(collection)
        if st is None:
            st = _TenantState(TenantQuota(), self.clock())
            self._tenants[collection] = st
        return st

    def set_quota(self, collection: str, *, rate: float = math.inf,
                  burst: Optional[float] = None,
                  weight: float = 1.0) -> None:
        """Attach (or replace) a tenant's token-bucket quota.

        ``rate`` tokens/s, ``burst`` bucket capacity (default: ``rate``,
        so one second of headroom), ``weight`` the drain share.  The
        bucket starts full; replacing a quota refills it.
        """
        if burst is None:
            burst = rate
        q = TenantQuota(rate=float(rate), burst=float(burst),
                        weight=float(weight))
        self._tenants[str(collection)] = _TenantState(q, self.clock())

    def drop_collection(self, collection: str) -> int:
        """Remove a tenant: its queued requests are discarded (they
        will never be served — callers drop the uids) and its quota and
        counters are forgotten.  Returns the number of requests
        dropped from the queue."""
        collection = str(collection)
        n0 = len(self.queue)
        self.queue = [r for r in self.queue if r.collection != collection]
        self._tenants.pop(collection, None)
        return n0 - len(self.queue)

    def _reject(self, collection: str, st: _TenantState,
                reason: str) -> None:
        self._rejects += 1
        st.rejects += 1
        self._m_rejects.inc()
        self._registry.counter(
            "repro_scheduler_rejects_total",
            help="Requests rejected by admission control (queue full)",
            labels={"collection": collection, "reason": reason}).inc()

    def submit(self, payload, collection: str = "") -> Optional[int]:
        """Enqueue a request; returns its uid, or None when admission
        control sheds it — either the tenant's own token bucket is
        empty (``reason="quota"``) or the global queue already holds
        ``max_queue`` requests (``reason="queue_full"``)."""
        collection = str(collection)
        st = self._tenant(collection)
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self._reject(collection, st, "queue_full")
            return None
        if not st.try_take(self.clock()):
            self._reject(collection, st, "quota")
            return None
        st.submits += 1
        self._uid += 1
        self.queue.append(Request(self._uid, payload,
                                  t_submit=self.clock(),
                                  collection=collection))
        self._submits += 1
        self._m_submits.inc()
        return self._uid

    def _bucket(self, k: int) -> int:
        if k == 0:
            return 0
        return min(self.max_batch,
                   max(self.min_bucket, 1 << (k - 1).bit_length()))

    def _ready(self, now: float) -> bool:
        if not self.queue:
            return False
        if len(self.queue) >= self.max_batch or self.max_wait_s <= 0.0:
            return True
        return (now - self.queue[0].t_submit) >= self.max_wait_s

    def _select(self, k: int) -> List[Request]:
        """Pop up to ``k`` requests, weighted-fair across tenants.

        When everything fits (or one tenant owns the queue) this is the
        plain FIFO pop.  Otherwise batch slots are allocated to tenants
        in proportion to their quota weights (floor), the remainder
        filled one slot at a time by global FIFO age — so a backlogged
        tenant gets its share, never the whole batch.  The popped batch
        preserves submit order (uid order) regardless of allocation.
        """
        if len(self.queue) <= k:
            take, self.queue = self.queue, []
            return take
        by_col: Dict[str, List[Request]] = {}
        for req in self.queue:
            by_col.setdefault(req.collection, []).append(req)
        if len(by_col) == 1:
            take = self.queue[:k]
            self.queue = self.queue[k:]
            return take
        weights = {c: self._tenant(c).quota.weight for c in by_col}
        total_w = sum(weights.values()) or 1.0
        alloc = {c: min(len(by_col[c]), int(k * weights[c] / total_w))
                 for c in by_col}
        rem = k - sum(alloc.values())
        while rem > 0:
            live = [c for c in by_col if alloc[c] < len(by_col[c])]
            if not live:
                break
            oldest = min(live, key=lambda c: by_col[c][alloc[c]].uid)
            alloc[oldest] += 1
            rem -= 1
        chosen = {req.uid for c, reqs in by_col.items()
                  for req in reqs[:alloc[c]]}
        take = [r for r in self.queue if r.uid in chosen]
        self.queue = [r for r in self.queue if r.uid not in chosen]
        return take

    def next_batch(self, force: bool = False) -> Tuple[List[Request], int]:
        """Pop up to max_batch requests; returns (requests, padded_size).

        Padded size is the pow2 bucket: the runner repeats the last
        payload to fill and drops the padded results.  Under a
        coalescing deadline (``max_wait_s > 0``) a short queue whose
        oldest request is still inside the deadline returns ``([], 0)``
        — pass ``force=True`` to flush it anyway (shutdown, test
        barriers).  Empty and not-ready drains count NO batch and
        record nothing in the batch-size histogram (a phantom
        zero-size batch would drag the occupancy stats); the
        registered ``background_tick`` still runs every call, so
        maintenance work (a bounded LSM ``compact_step``, or in
        async-compaction mode the driver's cheap ``drain()``)
        interleaves between query batches even when traffic pauses.
        """
        now = self.clock()
        if force and self.queue or self._ready(now):
            take = self._select(self.max_batch)
            self._batches += 1
            self._m_batches.inc()
            self._m_batch_size.observe(len(take))
            for req in take:
                req.wait_s = max(now - req.t_submit, 0.0)
                self._m_queue_wait.observe(req.wait_s)
                self._wait_sum += req.wait_s
                self._wait_max = max(self._wait_max, req.wait_s)
                st = self._tenant(req.collection)
                st.batched += 1
                st.wait_max = max(st.wait_max, req.wait_s)
            self._requests_batched += len(take)
        else:
            take = []
        if self.background_tick is not None:
            self._ticks += 1
            self._m_ticks.inc()
            self.background_tick()
        return take, self._bucket(len(take))

    @property
    def ticks(self) -> int:
        return self._ticks

    def stats(self) -> Dict[str, float]:
        """Host-side counters snapshot (schema: SCHEDULER_STATS_KEYS).

        ``tenants`` maps each collection seen (submitted to, or given a
        quota) to its per-tenant view, pinned by
        ``SCHEDULER_TENANT_KEYS``: admitted ``submits``, ``rejects``
        (quota + queue-full), ``batched``, live ``queue_depth``,
        current bucket ``tokens``, the quota (``rate``/``burst``/
        ``weight``), and ``queue_wait_max_s``.
        """
        depth: Dict[str, int] = {}
        for req in self.queue:
            depth[req.collection] = depth.get(req.collection, 0) + 1
        tenants = {}
        for name, st in self._tenants.items():
            tenants[name] = {
                "submits": st.submits,
                "rejects": st.rejects,
                "batched": st.batched,
                "queue_depth": depth.get(name, 0),
                "tokens": st.tokens,
                "rate": st.quota.rate,
                "burst": st.quota.burst,
                "weight": st.quota.weight,
                "queue_wait_max_s": st.wait_max,
            }
        return {
            "queue_depth": len(self.queue),
            "submits": self._submits,
            "rejects": self._rejects,
            "batches": self._batches,
            "requests_batched": self._requests_batched,
            "ticks": self._ticks,
            "queue_wait_sum_s": self._wait_sum,
            "queue_wait_max_s": self._wait_max,
            "max_batch": self.max_batch,
            "max_wait_s": self.max_wait_s,
            "max_queue": self.max_queue,
            "tenants": tenants,
        }


def route_and_group(estimates_use_lsh: np.ndarray, min_bucket: int = 8):
    """Split a retrieval batch into per-strategy index groups (padded)."""
    return partition_indices(estimates_use_lsh, minimum=min_bucket)
