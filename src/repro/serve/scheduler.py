"""Request scheduler: shape-bucketed batching with hybrid routing.

TPU serving wants a small set of compiled shapes.  The scheduler
accumulates requests, forms batches padded to power-of-two sizes
(bounded jit-cache churn), and — for retrieval requests — consults the
paper's cost estimator FIRST so that a micro-batch executes a single
strategy (per-query lax.cond would run both branches densely on TPU;
see DESIGN.md §2).

The scheduler is also the natural interleaving point for *off-query-
path* index maintenance: register a ``background_tick`` (typically
``RetrievalService.compaction_tick``) and it runs once per formed
batch, between query batches.  What a tick costs depends on the
service's compaction mode (docs/compaction.md):

  * budgeted — the tick runs one bounded LSM merge step (a gather of
    ``compact_step_rows`` rows) on this thread, between batches
    instead of inside one;
  * async    — the gathers live on the ``CompactionDriver``'s worker
    thread and the tick degenerates to the driver's ``drain()``: a
    flag check, plus the atomic level swap when one is staged-ready.
    The serving thread never pays for staging at all.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import partition_indices
from repro.obs.metrics import NULL_REGISTRY


@dataclasses.dataclass
class Request:
    uid: int
    payload: Any


class ShapeBucketScheduler:
    def __init__(self, max_batch: int = 64, min_bucket: int = 8,
                 background_tick: Optional[Callable[[], Any]] = None,
                 registry=None):
        """``registry`` — optional ``repro.obs.MetricsRegistry``; the
        default null registry makes every instrument a no-op."""
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        self.background_tick = background_tick
        self.queue: List[Request] = []
        self._uid = 0
        self._ticks = 0
        reg = registry if registry is not None else NULL_REGISTRY
        self._m_submits = reg.counter(
            "repro_scheduler_submits_total", help="Requests submitted")
        self._m_batches = reg.counter(
            "repro_scheduler_batches_total", help="Batches formed")
        self._m_batch_size = reg.histogram(
            "repro_scheduler_batch_size",
            buckets=(1, 2, 4, 8, 16, 32, 64, 128),
            help="Requests per formed batch (pre-padding)")
        self._m_ticks = reg.counter(
            "repro_scheduler_ticks_total", help="Background ticks run")

    def submit(self, payload) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, payload))
        self._m_submits.inc()
        return self._uid

    def _bucket(self, k: int) -> int:
        if k == 0:
            return 0
        return min(self.max_batch,
                   max(self.min_bucket, 1 << (k - 1).bit_length()))

    def next_batch(self) -> Tuple[List[Request], int]:
        """Pop up to max_batch requests; returns (requests, padded_size).

        Padded size is the pow2 bucket: the runner repeats the last
        payload to fill and drops the padded results.  A registered
        ``background_tick`` runs here — after the batch is formed,
        before the runner executes it — so maintenance work (a bounded
        LSM ``compact_step``, or in async-compaction mode the driver's
        cheap ``drain()``) interleaves between query batches instead of
        stalling one.
        """
        take = self.queue[:self.max_batch]
        self.queue = self.queue[len(take):]
        self._m_batches.inc()
        self._m_batch_size.observe(len(take))
        if self.background_tick is not None:
            self._ticks += 1
            self._m_ticks.inc()
            self.background_tick()
        return take, self._bucket(len(take))

    @property
    def ticks(self) -> int:
        return self._ticks


def route_and_group(estimates_use_lsh: np.ndarray, min_bucket: int = 8):
    """Split a retrieval batch into per-strategy index groups (padded)."""
    return partition_indices(estimates_use_lsh, minimum=min_bucket)
