"""Request scheduler: shape-bucketed batching with hybrid routing.

TPU serving wants a small set of compiled shapes.  The scheduler
accumulates requests, forms batches padded to power-of-two sizes
(bounded jit-cache churn), and — for retrieval requests — consults the
paper's cost estimator FIRST so that a micro-batch executes a single
strategy (per-query lax.cond would run both branches densely on TPU;
see DESIGN.md §2).

Cross-request coalescing (docs/serving.md): with ``max_wait_s > 0``
``next_batch`` holds small queues back until either the queue can fill
a whole ``max_batch`` or the *oldest* request has waited out the
deadline, so many single-query submits merge into one dense pow2
bucket instead of draining as singleton batches.  ``max_wait_s = 0``
(default) drains greedily — exactly the pre-coalescing behavior.
Admission control: with ``max_queue`` set, submits beyond the bound
are rejected (``submit`` returns None, counted in
``repro_scheduler_rejects_total``) instead of growing the queue — and
the latency SLO — without bound.

The scheduler is also the natural interleaving point for *off-query-
path* index maintenance: register a ``background_tick`` (typically
``RetrievalService.compaction_tick``) and it runs once per
``next_batch`` call — empty and not-yet-ready drains included, so a
quiet serving loop still advances merges.  What a tick costs depends
on the service's compaction mode (docs/compaction.md):

  * budgeted — the tick runs one bounded LSM merge step (a gather of
    ``compact_step_rows`` rows) on this thread, between batches
    instead of inside one;
  * async    — the gathers live on the ``CompactionDriver``'s worker
    thread and the tick degenerates to the driver's ``drain()``: a
    flag check, plus the atomic level swap when one is staged-ready.
    The serving thread never pays for staging at all.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import partition_indices
from repro.obs.metrics import DEFAULT_TIME_BUCKETS, NULL_REGISTRY


@dataclasses.dataclass
class Request:
    uid: int
    payload: Any
    t_submit: float = 0.0       # scheduler clock at submit
    wait_s: float = 0.0         # queue wait, stamped when the batch forms


class ShapeBucketScheduler:
    def __init__(self, max_batch: int = 64, min_bucket: int = 8,
                 background_tick: Optional[Callable[[], Any]] = None,
                 registry=None, max_wait_s: float = 0.0,
                 max_queue: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        """``registry`` — optional ``repro.obs.MetricsRegistry``; the
        default null registry makes every instrument a no-op.

        ``max_wait_s`` — coalescing deadline: ``next_batch`` returns an
        empty batch (without counting a phantom batch) until the queue
        holds ``max_batch`` requests or the oldest has waited this
        long.  0 (default) drains greedily.
        ``max_queue`` — admission bound: ``submit`` beyond it returns
        None and counts a reject.  None (default) = unbounded.
        ``clock`` — monotonic time source (injectable for tests).
        """
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        self.background_tick = background_tick
        self.max_wait_s = float(max_wait_s)
        self.max_queue = max_queue
        self.clock = clock
        self.queue: List[Request] = []
        self._uid = 0
        self._ticks = 0
        self._submits = 0
        self._rejects = 0
        self._batches = 0
        self._requests_batched = 0
        self._wait_sum = 0.0
        self._wait_max = 0.0
        reg = registry if registry is not None else NULL_REGISTRY
        self._m_submits = reg.counter(
            "repro_scheduler_submits_total", help="Requests submitted")
        self._m_rejects = reg.counter(
            "repro_scheduler_rejects_total",
            help="Requests rejected by admission control (queue full)")
        self._m_batches = reg.counter(
            "repro_scheduler_batches_total", help="Batches formed")
        self._m_batch_size = reg.histogram(
            "repro_scheduler_batch_size",
            buckets=(1, 2, 4, 8, 16, 32, 64, 128),
            help="Requests per formed batch (pre-padding)")
        self._m_queue_wait = reg.histogram(
            "repro_scheduler_queue_wait_seconds",
            buckets=DEFAULT_TIME_BUCKETS,
            help="Per-request queue wait (submit -> batch formed)")
        self._m_ticks = reg.counter(
            "repro_scheduler_ticks_total", help="Background ticks run")

    def submit(self, payload) -> Optional[int]:
        """Enqueue a request; returns its uid, or None when admission
        control sheds it (queue already holds ``max_queue`` requests)."""
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self._rejects += 1
            self._m_rejects.inc()
            return None
        self._uid += 1
        self.queue.append(Request(self._uid, payload,
                                  t_submit=self.clock()))
        self._submits += 1
        self._m_submits.inc()
        return self._uid

    def _bucket(self, k: int) -> int:
        if k == 0:
            return 0
        return min(self.max_batch,
                   max(self.min_bucket, 1 << (k - 1).bit_length()))

    def _ready(self, now: float) -> bool:
        if not self.queue:
            return False
        if len(self.queue) >= self.max_batch or self.max_wait_s <= 0.0:
            return True
        return (now - self.queue[0].t_submit) >= self.max_wait_s

    def next_batch(self, force: bool = False) -> Tuple[List[Request], int]:
        """Pop up to max_batch requests; returns (requests, padded_size).

        Padded size is the pow2 bucket: the runner repeats the last
        payload to fill and drops the padded results.  Under a
        coalescing deadline (``max_wait_s > 0``) a short queue whose
        oldest request is still inside the deadline returns ``([], 0)``
        — pass ``force=True`` to flush it anyway (shutdown, test
        barriers).  Empty and not-ready drains count NO batch and
        record nothing in the batch-size histogram (a phantom
        zero-size batch would drag the occupancy stats); the
        registered ``background_tick`` still runs every call, so
        maintenance work (a bounded LSM ``compact_step``, or in
        async-compaction mode the driver's cheap ``drain()``)
        interleaves between query batches even when traffic pauses.
        """
        now = self.clock()
        if force and self.queue or self._ready(now):
            take = self.queue[:self.max_batch]
            self.queue = self.queue[len(take):]
            self._batches += 1
            self._m_batches.inc()
            self._m_batch_size.observe(len(take))
            for req in take:
                req.wait_s = max(now - req.t_submit, 0.0)
                self._m_queue_wait.observe(req.wait_s)
                self._wait_sum += req.wait_s
                self._wait_max = max(self._wait_max, req.wait_s)
            self._requests_batched += len(take)
        else:
            take = []
        if self.background_tick is not None:
            self._ticks += 1
            self._m_ticks.inc()
            self.background_tick()
        return take, self._bucket(len(take))

    @property
    def ticks(self) -> int:
        return self._ticks

    def stats(self) -> Dict[str, float]:
        """Host-side counters snapshot (schema: SCHEDULER_STATS_KEYS)."""
        return {
            "queue_depth": len(self.queue),
            "submits": self._submits,
            "rejects": self._rejects,
            "batches": self._batches,
            "requests_batched": self._requests_batched,
            "ticks": self._ticks,
            "queue_wait_sum_s": self._wait_sum,
            "queue_wait_max_s": self._wait_max,
            "max_batch": self.max_batch,
            "max_wait_s": self.max_wait_s,
            "max_queue": self.max_queue,
        }


def route_and_group(estimates_use_lsh: np.ndarray, min_bucket: int = 8):
    """Split a retrieval batch into per-strategy index groups (padded)."""
    return partition_indices(estimates_use_lsh, minimum=min_bucket)
