"""Multi-tenant collections: one engine, many corpora.

A ``CollectionManager`` maps *named collections* — independent tenant
corpora — onto per-tenant streaming indexes while everything expensive
stays shared, once per process:

  * **one QueryEngine + jit cache** — the service builds a single
    ``QueryEngine`` and a single LSH family; every collection's index
    is constructed around them, so Algorithm-2 routing, the fused PR 7
    kernels, and the ``bucket_fn_for`` jitted hash (lru-cached on the
    hashable family) compile once no matter how many tenants exist;
  * **one CompactionDriver worker pool** — each created collection is
    ``attach``-ed to the service's driver, whose worker round-robins
    one bounded op at a time over the collections with pending merge
    work (fairness counters in ``driver.stats()["fairness"]``);
  * **one Observability bundle** — collection lifecycle and index
    events carry a ``collection`` field (the manager wraps the shared
    ``EventLog`` per tenant), per-collection serving counters are
    labeled registry series (``repro_collection_*{collection=...}``),
    and the shared tracer's spans are stamped via
    ``tracer.set_context(collection=...)`` around each tenant's query;
  * **one ResultCache / one ShapeBucketScheduler** — keys and requests
    carry the collection id; the manager wires per-tenant token-bucket
    quotas into the scheduler and purges a dropped tenant's cache
    entries (required: a re-created collection restarts at version 0).

The default (single-tenant) corpus keeps the reserved empty name
``""`` and does NOT live in the manager — ``RetrievalService``'s
pre-collections surface is untouched.

Checkpointing: ``state_dict()`` nests every tenant under
``collections/<name>/...`` (index state + quota), which the
``CheckpointManager`` flattens into per-collection manifest subtrees
(``CheckpointManager.collection_names`` lists them without loading
arrays); ``load_state_dict`` rebuilds the full tree through the same
index factory.  See docs/serving.md "Collections".
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.obs import Observability
from repro.serve.scheduler import TenantQuota

__all__ = ["Collection", "CollectionManager"]

# names become event labels, metric label values, and checkpoint leaf
# path segments — so no "/", no whitespace, never empty ("" is the
# reserved default-corpus id)
_NAME_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9_.\-]*$")


class _CollectionEvents:
    """EventLog facade that stamps ``collection=<name>`` on every
    event an index emits (freeze, merge_scheduled, swap, ...), so one
    shared ring buffer stays attributable per tenant."""

    __slots__ = ("_log", "_name")

    def __init__(self, log, name: str):
        self._log = log
        self._name = name

    def emit(self, kind: str, **fields) -> None:
        self._log.emit(kind, collection=self._name, **fields)

    def __getattr__(self, attr):
        return getattr(self._log, attr)


@dataclasses.dataclass
class Collection:
    """One tenant: a name, its index, its quota, and serving counters."""

    name: str
    index: object
    quota: TenantQuota
    queries: int = 0
    linear_served: int = 0

    def stats(self) -> Dict[str, object]:
        """This collection's view (schema: COLLECTION_STATS_KEYS)."""
        ist = self.index.index_stats()
        return {
            "n_live": ist["n_live"],
            "version": int(self.index.version),
            "segments": ist["segments"],
            "pending_merges": ist["pending_merges"],
            "delta_live": ist["delta_live"],
            "queries": self.queries,
            "linear_served": self.linear_served,
            "inserts": ist["inserts"],
            "deletes": ist["deletes"],
            "quota_rate": self.quota.rate,
            "quota_burst": self.quota.burst,
            "quota_weight": self.quota.weight,
        }


class CollectionManager:
    """Named tenant corpora over shared serving machinery.

    Args:
      index_factory: ``(obs) -> index`` — builds one fresh, empty
        streaming index wired to the given observability bundle (the
        manager passes a per-collection event facade).  The service
        supplies a factory that closes over the shared family, engine,
        and config, so tenants share every compiled artifact.
      obs: the shared ``Observability`` bundle.
      scheduler: the service's ``ShapeBucketScheduler`` (quota wiring
        + request dropping on ``drop``); optional for bare use.
      cache: the service's ``ResultCache`` (purged on ``drop``);
        optional.
      driver: the shared ``CompactionDriver`` — may also be set later
        via the ``driver`` attribute (the service creates it lazily);
        created collections attach to it, dropped ones detach.

    Control-thread-only, like the service that owns it.
    """

    def __init__(self, index_factory: Callable[[Observability], object],
                 *, obs: Optional[Observability] = None,
                 scheduler=None, cache=None, driver=None):
        self._factory = index_factory
        self.obs = obs if obs is not None else Observability.disabled()
        self.scheduler = scheduler
        self.cache = cache
        self.driver = driver
        self._collections: Dict[str, Collection] = {}
        self._created = 0
        self._dropped = 0
        reg = self.obs.registry
        self._m_created = reg.counter(
            "repro_collections_created_total", help="Collections created")
        self._m_dropped = reg.counter(
            "repro_collections_dropped_total", help="Collections dropped")

    # -------------------------------------------------------------- views
    def __len__(self) -> int:
        return len(self._collections)

    def __contains__(self, name: str) -> bool:
        return str(name) in self._collections

    def names(self) -> List[str]:
        """Creation-ordered collection names."""
        return list(self._collections)

    def get(self, name: str) -> Collection:
        col = self._collections.get(str(name))
        if col is None:
            raise KeyError(
                f"no collection {name!r} (have: {self.names()})")
        return col

    # ---------------------------------------------------------- lifecycle
    def create(self, name: str,
               quota: Optional[TenantQuota] = None,
               attach: bool = True) -> Collection:
        """Create an empty named collection; raises on duplicates and
        invalid names.  ``quota`` (a ``TenantQuota``) installs the
        tenant's token bucket + drain weight on the shared scheduler;
        omitted = unlimited, weight 1.  ``attach=False`` defers the
        driver attach (``attach_driver``) — callers that seed the new
        index with a wholesale ``build`` must do so before the worker
        can see it."""
        name = str(name)
        if not _NAME_RE.match(name):
            raise ValueError(
                f"invalid collection name {name!r} (want "
                f"{_NAME_RE.pattern}; '' is the default corpus)")
        if name in self._collections:
            raise ValueError(f"collection {name!r} already exists")
        quota = quota if quota is not None else TenantQuota()
        col_obs = dataclasses.replace(
            self.obs, events=_CollectionEvents(self.obs.events, name))
        index = self._factory(col_obs)
        col = Collection(name=name, index=index, quota=quota)
        self._collections[name] = col
        self._created += 1
        self._m_created.inc()
        if self.scheduler is not None:
            self.scheduler.set_quota(name, rate=quota.rate,
                                     burst=quota.burst,
                                     weight=quota.weight)
        if attach and self.driver is not None:
            self.driver.attach(name, index)
        self.obs.events.emit("collection_create", collection=name,
                             quota_rate=quota.rate,
                             quota_weight=quota.weight)
        return col

    def attach_driver(self, name: str) -> None:
        """Attach an existing collection's index to the shared driver
        (no-op without one) — the deferred half of
        ``create(attach=False)``."""
        if self.driver is not None:
            self.driver.attach(str(name), self.get(name).index)

    def drop(self, name: str) -> Collection:
        """Drop a collection: detach it from the driver, discard its
        queued requests, purge its cache entries (a re-created name
        restarts at version 0 — stale hits must be impossible), and
        forget it.  Returns the removed ``Collection``."""
        col = self.get(name)
        name = col.name
        if self.driver is not None:
            self.driver.detach(name)
        dropped_reqs = 0
        if self.scheduler is not None:
            dropped_reqs = self.scheduler.drop_collection(name)
        purged = 0
        if self.cache is not None:
            purged = self.cache.drop_collection(name)
        del self._collections[name]
        self._dropped += 1
        self._m_dropped.inc()
        self.obs.events.emit("collection_drop", collection=name,
                             n_live=int(col.index.n),
                             dropped_requests=dropped_reqs,
                             purged_cache_entries=purged)
        return col

    def note_query(self, name: str, n_queries: int, n_linear: int) -> None:
        """Fold one served batch into the tenant's counters + labeled
        registry series."""
        col = self.get(name)
        col.queries += n_queries
        col.linear_served += n_linear
        reg = self.obs.registry
        reg.counter("repro_collection_queries_total",
                    help="Queries served, by collection",
                    labels={"collection": col.name}).inc(n_queries)
        reg.counter("repro_collection_linear_total",
                    help="Linear-route queries, by collection",
                    labels={"collection": col.name}).inc(n_linear)

    # -------------------------------------------------------------- stats
    def stats(self) -> Dict[str, object]:
        """Pinned snapshot (COLLECTION_MANAGER_KEYS at the top level,
        COLLECTION_STATS_KEYS per collection)."""
        reg = self.obs.registry
        for col in self._collections.values():
            reg.gauge("repro_collection_live_docs",
                      help="Live documents, by collection",
                      labels={"collection": col.name}).set(int(col.index.n))
        return {
            "n_collections": len(self._collections),
            "created_total": self._created,
            "dropped_total": self._dropped,
            "collections": {name: col.stats()
                            for name, col in self._collections.items()},
        }

    # --------------------------------------------------------- checkpoint
    def state_dict(self) -> Dict[str, Dict[str, object]]:
        """``{name: {"index": <index state>, "quota": {...}}}`` — the
        subtree ``RetrievalService.checkpoint`` nests under
        ``"collections"``, giving each tenant its own manifest
        namespace (``collections/<name>/...``)."""
        out = {}
        for name, col in self._collections.items():
            out[name] = {
                "index": col.index.state_dict(),
                "quota": {
                    "rate": np.float64(col.quota.rate),
                    "burst": np.float64(col.quota.burst),
                    "weight": np.float64(col.quota.weight),
                },
            }
        return out

    def state_digests(self) -> Dict[str, str]:
        """Content-address hints for every tenant's immutable leaves,
        namespaced to match the ``state_dict`` layout — lets
        ``save_incremental`` skip re-hashing frozen segments across the
        whole collection tree."""
        out: Dict[str, str] = {}
        for name, col in self._collections.items():
            digests = getattr(col.index, "state_digests", None)
            if digests is None:
                continue
            for path, dg in digests().items():
                out[f"{name}/index/{path}"] = dg
        return out

    def load_state_dict(self, state: Dict[str, Dict[str, object]]) -> None:
        """Rebuild the full collection tree from a checkpoint subtree:
        existing collections are dropped, each saved tenant is
        re-created through the factory (same shared family/engine) with
        its saved quota, and its index state is restored."""
        for name in list(self._collections):
            self.drop(name)
        for name, sub in state.items():
            q = sub["quota"]
            quota = TenantQuota(rate=float(q["rate"]),
                                burst=float(q["burst"]),
                                weight=float(q["weight"]))
            # attach only after the state lands: a wholesale
            # load_state_dict must never race the driver worker
            col = self.create(name, quota=quota, attach=False)
            col.index.load_state_dict(sub["index"])
            self.attach_driver(name)
