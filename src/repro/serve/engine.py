"""Serving engine: prefill/decode step factories + generation loop.

``make_serve_prefill`` / ``make_serve_step`` produce the pure functions
the dry-run lowers for the inference cells (prefill_32k lowers the
prefill; decode_32k / long_500k lower one serve_step = one new token
for the whole batch against the KV caches).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import decode_step, prefill
from repro.models.embedding import greedy_sample
from repro.models.parallel import ParallelConfig


def make_serve_prefill(cfg: ArchConfig, par: ParallelConfig,
                       cache_len: int):
    def serve_prefill(params, batch):
        h_last, caches, lengths = prefill(params, batch, cfg, par,
                                          cache_len)
        token = greedy_sample(params["lm_head"], h_last, par)
        return token, caches, lengths
    return serve_prefill


def make_serve_step(cfg: ArchConfig, par: ParallelConfig):
    def serve_step(params, caches, token, lengths):
        h_last, caches = decode_step(params, caches, token, lengths, cfg,
                                     par)
        nxt = greedy_sample(params["lm_head"], h_last, par)
        return nxt, caches, lengths + 1
    return serve_step


def generate(params, batch, cfg: ArchConfig, par: ParallelConfig, *,
             cache_len: int, max_new_tokens: int,
             eos_id: Optional[int] = None) -> jax.Array:
    """Greedy generation for a batch of equal-length prompts.

    Returns (B, max_new_tokens) int32.
    """
    pre = jax.jit(make_serve_prefill(cfg, par, cache_len))
    step = jax.jit(make_serve_step(cfg, par), donate_argnums=1)
    token, caches, lengths = pre(params, batch)
    out = [token]
    for _ in range(max_new_tokens - 1):
        token, caches, lengths = step(params, caches, token, lengths)
        out.append(token)
        if eos_id is not None and bool(jnp.all(token == eos_id)):
            break
    return jnp.stack(out, axis=1)
