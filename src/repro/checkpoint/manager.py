"""Sharded, atomic, async checkpointing with cross-mesh elastic restore.

Full-snapshot layout:  <dir>/step_<N>/
            manifest.json      tree structure, shapes, dtypes, step
            <leafpath>.npy     one file per leaf
            COMMITTED          empty marker written LAST (atomicity)

Incremental (content-addressed) layout, used by the streaming index
snapshots (``save_incremental``):

    <dir>/chunks/<digest>.npy  immutable leaf payloads, keyed by a
                               blake2b content address and shared by
                               every step that references them
    <dir>/step_<N>/
            manifest.json      leaf path -> {chunk, shape, dtype}
            COMMITTED          same atomicity marker

A frozen LSM level never changes after it is built, so consecutive
snapshots reference the same chunks and write only the delta, the
tombstone bitmaps, and the manifest — checkpoint write cost is
O(changed bytes), not O(index).  Chunk files are published with an
atomic rename, and a reference-counting GC removes chunks no committed
step references once ``keep``-pruning drops their last step.

Fault-tolerance contract used by the train loop and the serving path:
  * a crash mid-save leaves no COMMITTED marker -> restore skips it;
  * restore() picks the newest committed step;
  * manager init sweeps torn-write litter: ``step_*.tmp`` dirs,
    uncommitted ``step_*`` dirs, half-written chunk tmp files, and
    orphaned chunks (keep-pruning never counts any of these, so
    without the sweep a crashing process leaks disk forever);
  * restore(target_shardings=...) device_puts each leaf with the NEW
    mesh's NamedSharding — this is the elastic-scaling path (a 16x16
    checkpoint restores onto 2x16x16 and vice versa, since the on-disk
    format is mesh-agnostic full arrays per host shard);
  * saves run on a background thread (training continues), joined
    before the next save or shutdown.

``fault_hook`` is the crash-fault-injection seam: tests pass a callable
that raises at named points ("leaf" after each leaf/chunk write,
"pre_commit" before the marker, "post_commit" after the publish) to
prove restores are bit-exact at every torn-write boundary
(tests/test_recovery.py).

Multi-host note: in a real cluster each process writes only
``addressable_shards`` under a per-host subdir and host 0 commits; in
this single-process container that degenerates to full arrays.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

_COMMIT = "COMMITTED"
_CHUNKS = "chunks"


def array_digest(arr) -> str:
    """Content address of one stored leaf: blake2b over dtype + shape +
    raw bytes.  bfloat16 hashes as its stored uint16 view so the digest
    always matches the bytes on disk."""
    arr = np.asarray(arr)
    if str(arr.dtype) == "bfloat16":
        arr = arr.view(np.uint16)
    h = hashlib.blake2b(digest_size=16)
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any], template):
    if isinstance(template, dict):
        return {k: _unflatten(
            {p[len(k) + 1:]: v for p, v in flat.items()
             if p.split("/")[0] == k}, template[k]) for k in template}
    if isinstance(template, (list, tuple)):
        typ = type(template)
        vals = [
            _unflatten({p[len(str(i)) + 1:]: v for p, v in flat.items()
                        if p.split("/")[0] == str(i)}, template[i])
            for i in range(len(template))]
        return typ(vals)
    assert len(flat) == 1 and "" in flat, list(flat)
    return flat[""]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 fault_hook: Optional[Callable[..., None]] = None):
        self.dir = directory
        self.keep = keep
        self._fault_hook = fault_hook
        self._thread: Optional[threading.Thread] = None
        self._saves = 0
        self._incremental_saves = 0
        self._chunks_written = 0
        self._chunks_reused = 0
        self._bytes_written = 0
        self._bytes_reused = 0
        self._chunks_gced = 0
        self._litter_swept = 0
        self._last_save_seconds = 0.0
        self._last_restore_seconds = 0.0
        os.makedirs(directory, exist_ok=True)
        self._sweep_litter()

    def _fault(self, point: str, **info) -> None:
        """Crash-fault-injection seam: tests install a hook that raises
        at a named save-path point (see module docstring)."""
        if self._fault_hook is not None:
            self._fault_hook(point, **info)

    # --------------------------------------------------------------- save
    def save(self, step: int, state, blocking: bool = False):
        """Full (self-contained) snapshot: every leaf written under the
        step dir.  ``save_incremental`` is the content-addressed
        variant the streaming snapshots use."""
        self.wait()
        flat = {p: np.asarray(jax.device_get(v))
                for p, v in _flatten(state).items()}

        def _write():
            t0 = time.perf_counter()
            final = os.path.join(self.dir, f"step_{step:010d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": {}}
            for i, (path, arr) in enumerate(flat.items()):
                fn = path.replace("/", "__") + ".npy"
                logical = str(arr.dtype)
                if logical == "bfloat16":  # numpy can't serialize bf16
                    arr = arr.view(np.uint16)
                np.save(os.path.join(tmp, fn), arr)
                manifest["leaves"][path] = {
                    "file": fn, "shape": list(arr.shape),
                    "dtype": logical}
                self._fault("leaf", path=path, index=i)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            self._fault("pre_commit", step=step)
            with open(os.path.join(tmp, _COMMIT), "w"):
                pass
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
            self._fault("post_commit", step=step)
            self._saves += 1
            self._last_save_seconds = time.perf_counter() - t0
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def save_incremental(self, step: int, state,
                         digests: Optional[Dict[str, str]] = None,
                         blocking: bool = False):
        """Content-addressed snapshot: write only chunks the store does
        not already hold; the step dir carries just the manifest and
        the COMMITTED marker, so consecutive snapshots of a streaming
        index cost O(delta + tombstones + manifest) bytes.

        ``digests``: optional {leaf path: content address} hints for
        leaves the caller knows are immutable (frozen-level arrays,
        cached by ``streaming.segment.frozen_digests``); a hinted leaf
        whose chunk already exists is referenced without re-hashing.
        Hints must only ever be supplied for truly immutable arrays —
        the crash-fault differential tests are the check that holds
        producers to that.
        """
        self.wait()
        digests = dict(digests or {})
        flat = {p: np.asarray(jax.device_get(v))
                for p, v in _flatten(state).items()}

        def _write():
            t0 = time.perf_counter()
            cdir = os.path.join(self.dir, _CHUNKS)
            os.makedirs(cdir, exist_ok=True)
            final = os.path.join(self.dir, f"step_{step:010d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "format": "chunks", "leaves": {}}
            for i, (path, arr) in enumerate(flat.items()):
                logical = str(arr.dtype)
                stored = (arr.view(np.uint16) if logical == "bfloat16"
                          else arr)
                dg = digests.get(path)
                if dg is not None and not os.path.exists(
                        os.path.join(cdir, dg + ".npy")):
                    dg = None      # first sighting: hash + write below
                if dg is None:
                    dg = array_digest(stored)
                cfn = os.path.join(cdir, dg + ".npy")
                if os.path.exists(cfn):
                    self._chunks_reused += 1
                    self._bytes_reused += stored.nbytes
                else:
                    ctmp = cfn + ".tmp"
                    with open(ctmp, "wb") as f:
                        np.save(f, stored)
                    os.replace(ctmp, cfn)   # atomic chunk publish
                    self._chunks_written += 1
                    self._bytes_written += stored.nbytes
                manifest["leaves"][path] = {
                    "chunk": dg, "shape": list(stored.shape),
                    "dtype": logical}
                self._fault("leaf", path=path, index=i)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            self._fault("pre_commit", step=step)
            with open(os.path.join(tmp, _COMMIT), "w"):
                pass
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
            self._fault("post_commit", step=step)
            self._incremental_saves += 1
            self._last_save_seconds = time.perf_counter() - t0
            self._gc()
            self._gc_chunks()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    def _gc_chunks(self):
        """Drop chunks no committed step references (runs after every
        incremental save and at init, so keep-pruning a step also frees
        the chunk bytes only it referenced)."""
        cdir = os.path.join(self.dir, _CHUNKS)
        if not os.path.isdir(cdir):
            return
        referenced = set()
        for s in self.committed_steps():
            d = os.path.join(self.dir, f"step_{s:010d}")
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            for meta in manifest["leaves"].values():
                if "chunk" in meta:
                    referenced.add(meta["chunk"] + ".npy")
        for name in os.listdir(cdir):
            if name not in referenced:
                os.remove(os.path.join(cdir, name))
                self._chunks_gced += 1

    def _sweep_litter(self):
        """Torn-write hygiene at startup: a crash mid-save leaves
        ``step_*.tmp`` dirs, uncommitted ``step_*`` dirs, and chunk
        ``*.tmp`` files that ``keep``-pruning never counts; a crash
        between chunk writes and the commit leaves orphaned chunks.
        All are swept here so a restart converges to exactly the
        committed steps plus the chunks they reference."""
        for name in os.listdir(self.dir):
            p = os.path.join(self.dir, name)
            if name.startswith("step_") and name.endswith(".tmp"):
                shutil.rmtree(p, ignore_errors=True)
                self._litter_swept += 1
            elif (name.startswith("step_") and os.path.isdir(p)
                  and not os.path.exists(os.path.join(p, _COMMIT))):
                shutil.rmtree(p, ignore_errors=True)
                self._litter_swept += 1
        cdir = os.path.join(self.dir, _CHUNKS)
        if os.path.isdir(cdir):
            for name in os.listdir(cdir):
                if ".tmp" in name:
                    os.remove(os.path.join(cdir, name))
                    self._litter_swept += 1
            self._gc_chunks()

    # ------------------------------------------------------ observability
    def stats(self) -> Dict[str, object]:
        """Snapshot-cost counters (pinned: obs/schema.py
        ``CHECKPOINT_STATS_KEYS``).  ``bytes_written``/``bytes_reused``
        split each incremental save into new chunk bytes vs bytes
        referenced from the store — the incremental-vs-full headline
        ``BENCH_recovery.json`` asserts in CI."""
        return {
            "saves": self._saves,
            "incremental_saves": self._incremental_saves,
            "chunks_written": self._chunks_written,
            "chunks_reused": self._chunks_reused,
            "bytes_written": self._bytes_written,
            "bytes_reused": self._bytes_reused,
            "chunks_gced": self._chunks_gced,
            "litter_swept": self._litter_swept,
            "steps_kept": len(self.committed_steps()),
            "last_save_seconds": self._last_save_seconds,
            "last_restore_seconds": self._last_restore_seconds,
        }

    # ------------------------------------------------------------ restore
    def committed_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, name, _COMMIT)):
                out.append(int(name[5:]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    # ---------------------------------------------------- streaming index
    def save_index(self, step: int, index, blocking: bool = True,
                   incremental: bool = False):
        """Snapshot a streaming index's segment state.

        ``index`` is any object with a ``state_dict()`` returning an
        array pytree (``DynamicHybridIndex`` or the mesh-sharded
        ``ShardedDynamicHybridIndex``); every level of the segment
        stack, the delta, and the tombstone buffers land as one leaf
        file each under the usual atomic COMMITTED protocol.  Sharded
        segment leaves are gathered to full host arrays (leading shard
        axis kept), so the on-disk format is mesh-agnostic.  The
        sharded index's placement policy name and per-shard level
        layouts (``rows_s``/``live_s`` meta) ride along, so rebalanced
        states round-trip exactly (docs/streaming.md has the manifest
        layout).

        ``incremental=True`` uses the content-addressed layout and the
        index's ``state_digests()`` hints (when it has them), so
        unchanged frozen levels are referenced, not rewritten
        (docs/recovery.md).
        """
        if incremental:
            hints = getattr(index, "state_digests", None)
            self.save_incremental(step, index.state_dict(),
                                  digests=hints() if hints else None,
                                  blocking=blocking)
        else:
            self.save(step, index.state_dict(), blocking=blocking)

    def restore_index(self, index, step: Optional[int] = None):
        """Restore segment state into ``index`` (constructed with the
        same family/config as the one that saved; ``load_state_dict``
        re-places sharded leaves on the index's current mesh — a
        DIFFERENT shard count re-partitions the saved rows, the elastic
        restore path).  Returns the step, or None when no committed
        checkpoint exists.

        The restore is manifest-driven (``restore_tree``), not
        template-driven: a streaming index's level stack is a variable
        number of frozen segments, so the saved structure — however many
        levels, mid-merge or not — is reconstructed from leaf paths
        rather than matched against the fresh index's (usually empty)
        state."""
        state, step = self.restore_tree(step=step)
        if state is None:
            return None
        index.load_state_dict(state)
        return step

    def collection_names(self, step: Optional[int] = None):
        """Collections present in a committed step's manifest.

        Multi-tenant snapshots (``RetrievalService.checkpoint`` with
        collections) nest every tenant under ``collections/<name>/...``
        leaf paths — one per-collection manifest subtree.  This reads
        JUST the manifest (no array loads), so callers can inspect or
        selectively restore tenants.  Returns sorted names; [] when the
        step predates collections or nothing is committed.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            return []
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        names = {path.split("/")[1] for path in manifest["leaves"]
                 if path.startswith("collections/")}
        return sorted(names)

    def restore_tree(self, step: Optional[int] = None):
        """Load a committed step as nested dicts rebuilt from leaf paths.

        No template needed: ``a/b/c`` becomes ``{"a": {"b": {"c": arr}}}``
        with host numpy leaves.  This is how variable-structure states
        (the streaming indexes' level lists) round-trip.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        t0 = time.perf_counter()
        state: Dict[str, Any] = {}
        for path, arr in self._load_leaves(step):
            node = state
            parts = path.split("/")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = arr
        self._last_restore_seconds = time.perf_counter() - t0
        return state, step

    def _load_leaves(self, step: int):
        """Yield (leaf path, host array) pairs of a committed step —
        the one place that knows the on-disk leaf formats (per-step
        files and content-addressed chunks)."""
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        for path, meta in manifest["leaves"].items():
            if "chunk" in meta:
                fn = os.path.join(self.dir, _CHUNKS,
                                  meta["chunk"] + ".npy")
            else:
                fn = os.path.join(d, meta["file"])
            arr = np.load(fn)
            if meta["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            yield path, arr

    def restore(self, template, step: Optional[int] = None,
                target_shardings=None):
        """Load into the structure of ``template``.

        target_shardings: optional matching pytree of NamedSharding —
        pass the shardings of the CURRENT mesh to restore elastically
        onto a different topology than the one that saved.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        flat = dict(self._load_leaves(step))
        state = _unflatten(flat, template)
        if target_shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, target_shardings)
        else:
            state = jax.tree_util.tree_map(jax.numpy.asarray, state)
        return state, step
