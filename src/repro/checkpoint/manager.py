"""Sharded, atomic, async checkpointing with cross-mesh elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json      tree structure, shapes, dtypes, step
            <leafpath>.npy     one file per leaf
            COMMITTED          empty marker written LAST (atomicity)

Fault-tolerance contract used by the train loop:
  * a crash mid-save leaves no COMMITTED marker -> restore skips it;
  * restore() picks the newest committed step;
  * restore(target_shardings=...) device_puts each leaf with the NEW
    mesh's NamedSharding — this is the elastic-scaling path (a 16x16
    checkpoint restores onto 2x16x16 and vice versa, since the on-disk
    format is mesh-agnostic full arrays per host shard);
  * saves run on a background thread (training continues), joined
    before the next save or shutdown.

Multi-host note: in a real cluster each process writes only
``addressable_shards`` under a per-host subdir and host 0 commits; in
this single-process container that degenerates to full arrays.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_COMMIT = "COMMITTED"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any], template):
    if isinstance(template, dict):
        return {k: _unflatten(
            {p[len(k) + 1:]: v for p, v in flat.items()
             if p.split("/")[0] == k}, template[k]) for k in template}
    if isinstance(template, (list, tuple)):
        typ = type(template)
        vals = [
            _unflatten({p[len(str(i)) + 1:]: v for p, v in flat.items()
                        if p.split("/")[0] == str(i)}, template[i])
            for i in range(len(template))]
        return typ(vals)
    assert len(flat) == 1 and "" in flat, list(flat)
    return flat[""]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # --------------------------------------------------------------- save
    def save(self, step: int, state, blocking: bool = False):
        self.wait()
        flat = {p: np.asarray(jax.device_get(v))
                for p, v in _flatten(state).items()}

        def _write():
            final = os.path.join(self.dir, f"step_{step:010d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": {}}
            for path, arr in flat.items():
                fn = path.replace("/", "__") + ".npy"
                logical = str(arr.dtype)
                if logical == "bfloat16":  # numpy can't serialize bf16
                    arr = arr.view(np.uint16)
                np.save(os.path.join(tmp, fn), arr)
                manifest["leaves"][path] = {
                    "file": fn, "shape": list(arr.shape),
                    "dtype": logical}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, _COMMIT), "w"):
                pass
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------ restore
    def committed_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and not name.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, name, _COMMIT)):
                out.append(int(name[5:]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    # ---------------------------------------------------- streaming index
    def save_index(self, step: int, index, blocking: bool = True):
        """Snapshot a streaming index's segment state.

        ``index`` is any object with a ``state_dict()`` returning an
        array pytree (``DynamicHybridIndex`` or the mesh-sharded
        ``ShardedDynamicHybridIndex``); every level of the segment
        stack, the delta, and the tombstone buffers land as one leaf
        file each under the usual atomic COMMITTED protocol.  Sharded
        segment leaves are gathered to full host arrays (leading shard
        axis kept), so the on-disk format is mesh-agnostic.  The
        sharded index's placement policy name and per-shard level
        layouts (``rows_s``/``live_s`` meta) ride along, so rebalanced
        states round-trip exactly (docs/streaming.md has the manifest
        layout).
        """
        self.save(step, index.state_dict(), blocking=blocking)

    def restore_index(self, index, step: Optional[int] = None):
        """Restore segment state into ``index`` (constructed with the
        same family/config — and, for the sharded index, the same shard
        count — as the one that saved; ``load_state_dict`` re-places
        sharded leaves on the index's current mesh).  Returns the step,
        or None when no committed checkpoint exists.

        The restore is manifest-driven (``restore_tree``), not
        template-driven: a streaming index's level stack is a variable
        number of frozen segments, so the saved structure — however many
        levels, mid-merge or not — is reconstructed from leaf paths
        rather than matched against the fresh index's (usually empty)
        state."""
        state, step = self.restore_tree(step=step)
        if state is None:
            return None
        index.load_state_dict(state)
        return step

    def collection_names(self, step: Optional[int] = None):
        """Collections present in a committed step's manifest.

        Multi-tenant snapshots (``RetrievalService.checkpoint`` with
        collections) nest every tenant under ``collections/<name>/...``
        leaf paths — one per-collection manifest subtree.  This reads
        JUST the manifest (no array loads), so callers can inspect or
        selectively restore tenants.  Returns sorted names; [] when the
        step predates collections or nothing is committed.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            return []
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        names = {path.split("/")[1] for path in manifest["leaves"]
                 if path.startswith("collections/")}
        return sorted(names)

    def restore_tree(self, step: Optional[int] = None):
        """Load a committed step as nested dicts rebuilt from leaf paths.

        No template needed: ``a/b/c`` becomes ``{"a": {"b": {"c": arr}}}``
        with host numpy leaves.  This is how variable-structure states
        (the streaming indexes' level lists) round-trip.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        state: Dict[str, Any] = {}
        for path, arr in self._load_leaves(step):
            node = state
            parts = path.split("/")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = arr
        return state, step

    def _load_leaves(self, step: int):
        """Yield (leaf path, host array) pairs of a committed step —
        the one place that knows the on-disk leaf format."""
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        for path, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(d, meta["file"]))
            if meta["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            yield path, arr

    def restore(self, template, step: Optional[int] = None,
                target_shardings=None):
        """Load into the structure of ``template``.

        target_shardings: optional matching pytree of NamedSharding —
        pass the shardings of the CURRENT mesh to restore elastically
        onto a different topology than the one that saved.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        flat = dict(self._load_leaves(step))
        state = _unflatten(flat, template)
        if target_shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, target_shardings)
        else:
            state = jax.tree_util.tree_map(jax.numpy.asarray, state)
        return state, step
