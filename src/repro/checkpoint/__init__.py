from repro.checkpoint.manager import CheckpointManager, array_digest

__all__ = ["CheckpointManager", "array_digest"]
