"""GPipe-style pipeline parallelism over a mesh axis via ppermute.

Each device along ``axis`` owns one STAGE (a slice of layer repeats);
microbatch activations circulate stage-to-stage with
``lax.ppermute`` inside a shard_map, using the classic rotating-buffer
schedule: step t runs stage s on microbatch (t - s); the pipeline
drains after n_micro + n_stages - 1 steps.  Bubble fraction =
(n_stages - 1) / (n_micro + n_stages - 1).

This is the composable runtime primitive (correctness-tested on an
8-device debug mesh in tests/test_pipeline.py); the 40 dry-run cells
use the pod axis for data parallelism by default (DESIGN.md §5), with
PP available for depth-dominated models via this module.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def gpipe(stage_fn: Callable, stage_params, xs: jax.Array, *, mesh: Mesh,
          axis: str):
    """Run a pipelined stack.

    stage_fn(params_one_stage, h) -> h     (same shape in/out)
    stage_params: pytree with a leading stage dim == mesh.shape[axis]
    xs: (n_micro, mb, ...) microbatched inputs (replicated).
    Returns (n_micro, mb, ...) outputs (replicated).
    """
    n_stages = mesh.shape[axis]
    n_micro = xs.shape[0]
    steps = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local(params_loc, xs_loc):
        params_one = jax.tree_util.tree_map(lambda a: a[0], params_loc)
        sid = jax.lax.axis_index(axis)

        def body(h, t):
            inject = xs_loc[jnp.minimum(t, n_micro - 1)]
            h = jnp.where((sid == 0) & (t < n_micro), inject, h)
            y = stage_fn(params_one, h)
            out = jnp.where(sid == n_stages - 1, y, jnp.zeros_like(y))
            h_next = jax.lax.ppermute(y, axis, perm)
            return h_next, out

        _, outs = jax.lax.scan(body, jnp.zeros_like(xs_loc[0]),
                               jnp.arange(steps))
        # Only the last stage produced nonzero outputs; psum replicates
        # them to every stage.  Valid rows are the last n_micro steps.
        outs = jax.lax.psum(outs[n_stages - 1:], axis)
        return outs

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P(*([None] * xs.ndim))),
        out_specs=P(*([None] * xs.ndim)),
        check_rep=False)
    return fn(stage_params, xs)


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
