from repro.distributed.pipeline import bubble_fraction, gpipe

__all__ = ["bubble_fraction", "gpipe"]
