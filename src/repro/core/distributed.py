"""Mesh-sharded Hybrid LSH index (beyond the paper: multi-pod scale).

The database is row-sharded over the mesh's ``data`` axis.  Each shard
builds *local* CSR tables over its rows with globally-unique ids.  At
query time (queries replicated), every shard wraps its tables in the
engine's ``TableSegment`` and the collectives merge the per-shard
``SegmentEstimate`` terms:

  * global #collisions      = psum of local live collisions
  * global candSize         = HLL estimate of pmax-merged registers —
    HLL mergeability, which the paper uses across L tables, extends
    verbatim across shards: one (Q, m) pmax is the whole estimate.
  * routing policies:
      - "global":    one decision from the global Eq.(1)/(2) costs
      - "per_shard": each shard compares ITS local costs and picks its
        own strategy.  Correct because r-NN reporting is a union over
        disjoint shards; strictly better under local density skew (the
        shard holding a dense cluster scans linearly while others use
        LSH).  This is our main distributed extension of Algorithm 2.

Estimate math and both search strategies come from ``core.engine``
(``finalize_route`` / ``TableSegment.search``); only the collectives
and the per-shard ``lax.cond`` routing live here.  All collectives are
jax.lax primitives inside shard_map; the same code lowers for the
512-chip production mesh (see launch/dryrun.py).  The streaming
(sharded dynamic) variant lives in ``streaming.sharded``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.cost_model import CostModel
from repro.core.engine import (SegmentEstimate, TableSegment,
                               compact_results, finalize_route)
from repro.core.lsh.tables import LSHTables, build_tables
from repro.core import hll as hll_lib

__all__ = ["ShardedIndexState", "build_sharded", "make_query_fn"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ShardedIndexState:
    """Sharded leaves; first axis of every leaf is the shard axis."""

    x: jax.Array           # (n, d)          rows sharded over 'data'
    perm: jax.Array        # (S, L, n/S)     sharded over dim 0
    starts: jax.Array      # (S, L, B+1)
    registers: jax.Array   # (S, L, B, m)

    def tree_flatten(self):
        return (self.x, self.perm, self.starts, self.registers), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    def local_tables(self) -> LSHTables:
        """Inside shard_map: leaves arrive with S == 1."""
        return LSHTables(self.perm[0], self.starts[0], self.registers[0])


def build_sharded(family, params, x: jax.Array, *, num_buckets: int, m: int,
                  mesh: Mesh, data_axis: str = "data") -> ShardedIndexState:
    """Build per-shard tables; ids are globally unique (offset + local)."""
    n = x.shape[0]
    shards = mesh.shape[data_axis]
    assert n % shards == 0, (n, shards)
    n_local = n // shards

    def _build(x_local):
        shard_id = jax.lax.axis_index(data_axis)
        # HLLs must hash GLOBAL ids (cross-shard distinct-union
        # semantics); the CSR perm stores LOCAL row indices so the
        # search path can gather local rows — reporting re-offsets.
        ids = shard_id * n_local + jnp.arange(n_local, dtype=jnp.int32)
        bids = family.bucket_ids(params, x_local, num_buckets)
        t = build_tables(ids, bids, num_buckets, m)
        perm_local = t.perm - shard_id * n_local
        return (perm_local[None], t.starts[None], t.registers[None])

    spec_x = P(data_axis)
    fn = shard_map(_build, mesh=mesh, in_specs=(spec_x,),
                   out_specs=(P(data_axis), P(data_axis), P(data_axis)),
                   check_rep=False)
    x = jax.device_put(x, NamedSharding(mesh, P(data_axis)))
    perm, starts, registers = jax.jit(fn)(x)
    return ShardedIndexState(x=x, perm=perm, starts=starts,
                             registers=registers)


def make_query_fn(family, *, num_buckets: int, mesh: Mesh, n_total: int,
                  cost_model: CostModel, metric: str, cap: int, max_out: int,
                  policy: str = "per_shard", data_axis: str = "data"):
    """Build the jitted distributed hybrid query function.

    Returns fn(state, params, queries, r) ->
      dict(ids (S, Q, max_out), dists, mask, collisions (Q,),
           cand_est (Q,), used_lsh (S,)).
    Queries are replicated; outputs stay sharded over the data axis
    (union of per-shard reports).
    """
    shards = mesh.shape[data_axis]
    n_local = n_total // shards

    def _query(state_leaves, params, queries, r):
        x_local, perm, starts, registers = state_leaves
        tables = LSHTables(perm[0], starts[0], registers[0])
        qb = family.bucket_ids(params, queries, num_buckets)   # (Q, L)
        seg = TableSegment(tables=tables, x=x_local, metric=metric,
                           cap=cap, q_chunk=queries.shape[0],
                           n_live=n_local, n_scan=n_local)
        est = seg.estimate_terms(qb)            # collisions + (Q, L, m) regs
        merged_local = hll_lib.merge_registers(
            est.registers.astype(jnp.int32), axis=1)           # (Q, m)
        local = dataclasses.replace(est, registers=None,
                                    merged_registers=merged_local)

        merged = SegmentEstimate(
            collisions=jax.lax.psum(est.collisions, data_axis),
            merged_registers=jax.lax.pmax(merged_local, data_axis),
            n_live=n_total, n_scan=n_total)
        route_g = finalize_route([merged], cost_model)
        route_l = finalize_route([local], cost_model)

        route = route_g if policy == "global" else route_l
        lsh_cost = jnp.sum(route.lsh_cost)
        lin_cost = route.linear_cost * queries.shape[0]
        use_lsh = lsh_cost < lin_cost                          # scalar/shard

        def branch(lsh_route):
            def fn(_):
                ids, dists, mask = seg.search(qb, queries, r,
                                              lsh_route=lsh_route)
                ids, dists, valid = compact_results(ids, dists, mask,
                                                    max_out)
                shard_id = jax.lax.axis_index(data_axis)
                return ids + shard_id * n_local, dists, valid
            return fn

        ids, dists, mask = jax.lax.cond(use_lsh, branch(True), branch(False),
                                        operand=None)
        return (ids[None], dists[None], mask[None], route_g.collisions,
                route_g.cand_est, use_lsh[None])

    rep = P()
    sharded = P(data_axis)
    fn = shard_map(
        _query, mesh=mesh,
        in_specs=((sharded, sharded, sharded, sharded), rep, rep, rep),
        out_specs=(sharded, sharded, sharded, rep, rep, sharded),
        check_rep=False)

    @jax.jit
    def query(state, params, queries, r):
        ids, dists, mask, coll, cand, used = fn(
            (state.x, state.perm, state.starts, state.registers),
            params, queries, r)
        return {"ids": ids, "dists": dists, "mask": mask,
                "collisions": coll, "cand_est": cand, "used_lsh": used}

    def query_wrapper(state: ShardedIndexState, params, queries, r):
        return query(state, params, queries, jnp.float32(r))

    return query_wrapper
