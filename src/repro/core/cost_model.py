"""The paper's computational cost model (Sec. 3.1, Eq. 1-2).

  LSHCost    = alpha * #collisions + beta * candSize        (1)
  LinearCost = beta * n                                     (2)

alpha = average cost of processing one colliding entry (bucket lookup +
duplicate removal), beta = cost of one distance computation.  Only the
ratio beta/alpha matters for routing; the paper sets it per dataset
(10, 10, 6, 1 for Webspam/CoverType/Corel/MNIST).  ``calibrate`` measures
both on the current backend with the same kernels the search paths use.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["CostModel", "PAPER_PRESETS", "calibrate"]


@dataclasses.dataclass(frozen=True)
class CostModel:
    alpha: float = 1.0
    beta: float = 10.0

    def lsh_cost(self, collisions, cand_size):
        return self.alpha * collisions + self.beta * cand_size

    def linear_cost(self, n):
        return self.beta * n

    def use_lsh(self, collisions, cand_size, n):
        """Algorithm 2 line 4: True -> LSH-based search."""
        return self.lsh_cost(collisions, cand_size) < self.linear_cost(n)


# beta/alpha presets from the paper's experiments (alpha normalized to 1).
PAPER_PRESETS = {
    "webspam": CostModel(alpha=1.0, beta=10.0),
    "covertype": CostModel(alpha=1.0, beta=10.0),
    "corel": CostModel(alpha=1.0, beta=6.0),
    "mnist": CostModel(alpha=1.0, beta=1.0),
}


def _time_fn(fn, *args, iters: int = 5) -> float:
    jax.block_until_ready(fn(*args))  # single warmup call (compile)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def calibrate(d: int, metric: str = "l2", n_probe: int = 4096,
              seed: int = 0) -> CostModel:
    """Measure (alpha, beta) with the production kernels on this backend.

    beta: per-point cost of a distance scan; alpha: per-entry cost of the
    sort-based duplicate-removal path.  Returns a CostModel with
    alpha normalized to 1 (matching how the paper reports beta/alpha).
    """
    from repro.core import search as search_lib
    from repro.kernels import ops

    key = jax.random.PRNGKey(seed)
    kq, kx, ki = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n_probe, d), jnp.float32)
    q = jax.random.normal(kq, (64, d), jnp.float32)
    ids = jax.random.randint(ki, (64, n_probe), 0, n_probe, jnp.int32)

    dist = jax.jit(lambda a, b: ops.pairwise_dist(a, b, metric))
    beta_t = _time_fn(dist, q, x) / (64 * n_probe)

    def dedupe(c):
        # ids < n_probe, so sentinel=n_probe keeps every unique id.
        _, uniq = search_lib.dedupe_sorted(c, sentinel=n_probe)
        return jnp.sum(uniq, axis=-1)

    alpha_t = _time_fn(jax.jit(dedupe), ids) / (64 * n_probe)
    alpha_t = max(alpha_t, 1e-12)
    return CostModel(alpha=1.0, beta=max(beta_t / alpha_t, 1e-3))
