"""Segment engine — the one estimate→route→partition→search pipeline.

Every index in the repo (static ``HybridLSHIndex``, mesh-sharded
``core.distributed``, streaming ``DynamicHybridIndex``, and the sharded
streaming ``streaming.sharded``) is a composition over two concepts:

  * ``Segment``     — a searchable unit exposing its routing terms
                      (exact collisions, HLL registers or exact distinct
                      counts, tombstone dead counts, live/scan sizes)
                      and a fixed-shape search over its rows.
  * ``QueryEngine`` — owns Algorithm 2 once: gather per-segment terms,
                      combine them into a ``RouteEstimate``
                      (``finalize_route``), partition the query batch,
                      and run both strategies over every segment.

The old static/dynamic estimator split collapses here: a static segment
is simply one whose dead counts are zero and whose scan size equals its
live size, so ``finalize_route`` serves both.  The segment list is
arbitrary-length: the streaming index hands over its whole LSM level
stack (every frozen level + the delta) and the per-segment dead-count
correction composes term-by-term — Algorithm 2 stays a single path no
matter how many levels exist.  Multi-probe composes the same way: a
``tidx`` column→table map turns (Q, L*T) probed buckets into virtual
tables that every segment adapter understands.  The distributed indexes
reuse the traceable pieces (``Segment.estimate_terms`` +
``finalize_route`` + ``Segment.search``) inside ``shard_map``, merging
``SegmentEstimate`` fields across shards with ``psum``/``pmax`` before
finalizing — host-side partitioning only happens in the single-host
``QueryEngine.query``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Protocol, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hll as hll_lib
from repro.core import search as search_lib
from repro.core.cost_model import CostModel
from repro.core.lsh.tables import (LSHTables, bucket_counts,
                                   gather_registers, table_index)
from repro.kernels import ops

__all__ = ["RouteEstimate", "SegmentEstimate", "Segment", "TableSegment",
           "QueryEngine", "QueryResult", "finalize_route",
           "partition_indices", "compact_results", "EXT_SENTINEL"]

Scalar = Union[int, float, jax.Array]

EXT_SENTINEL = np.int32(2**31 - 1)   # masked-out slots in reported buffers


# ---------------------------------------------------------------------------
# Route estimate (Algorithm 2 lines 1-4, vectorized over the query batch)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RouteEstimate:
    """Vectorized output of Algorithm 2 lines 1-4."""

    collisions: jax.Array   # (Q,) int32   exact live sum of bucket sizes
    cand_est: jax.Array     # (Q,) float32 HLL union estimate of candSize
    lsh_cost: jax.Array     # (Q,) float32 Eq. (1)
    linear_cost: Scalar     # scalar       Eq. (2) (traced under shard_map)
    use_lsh: jax.Array      # (Q,) bool    Algorithm 2 line 4


@dataclasses.dataclass
class SegmentEstimate:
    """One segment's contribution to the routing estimate.

    Exactly one of ``registers`` / ``merged_registers`` / ``cand_exact``
    normally carries the candSize term: CSR+HLL segments report raw
    ``(Q, L, m)`` registers (so the fused merge+estimate kernel applies),
    cross-shard merges report pre-merged ``(Q, m)`` registers, and
    sketch-free segments (the delta) report an exact distinct count.  A
    merged cross-shard estimate may carry both a sketch and an exact
    term; they are summed.
    """

    collisions: jax.Array                          # (Q,) exact live
    dead_collisions: Optional[jax.Array] = None    # (Q,) or None (static)
    registers: Optional[jax.Array] = None          # (Q, L, m) uint8
    merged_registers: Optional[jax.Array] = None   # (Q, m)
    cand_exact: Optional[jax.Array] = None         # (Q,) exact distinct
    n_live: Scalar = 0    # live rows this segment contributes
    n_scan: Scalar = 0    # rows its linear scan computes distances over


class Segment(Protocol):
    """Anything the engine can route over (duck-typed; no inheritance)."""

    def estimate_terms(self, qbuckets: jax.Array) -> SegmentEstimate:
        """(Q, L) query buckets -> this segment's routing terms."""
        ...

    def search(self, qbuckets: jax.Array, q: jax.Array, r, *,
               lsh_route: bool) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Fixed-shape search -> sentinel-padded ``(ids, dists, mask)``."""
        ...

    # Traced queries (``QueryEngine`` with a tracer) additionally call
    # ``count_candidates(qbuckets) -> (Q,)``: the distinct candidates
    # this segment's LSH route gathers (cap-truncated).  Both in-repo
    # adapters implement it; a custom segment only needs it when
    # tracing is enabled.


def finalize_route(terms: Sequence[SegmentEstimate], cost_model: CostModel,
                   *, impl: Optional[str] = None,
                   n_live: Optional[Scalar] = None,
                   n_scan: Optional[Scalar] = None) -> RouteEstimate:
    """Combine per-segment terms into the tombstone-aware RouteEstimate.

    collisions = sum of exact live collisions; candSize = sum over
    segments of (HLL estimate - dead collisions, clamped at 0) plus the
    exact distinct counts, clamped by the structural bounds (candSize is
    a distinct count, <= live #collisions and <= n_live).  Static
    segments simply have zero dead counts.  HLL registers are monotone
    (they never decrement), so the dead-count subtraction over-corrects
    slightly — a dead point colliding in several tables is subtracted
    once per table — making the churned estimate a mild under-estimate,
    biased toward the LSH route, whose verification step masks dead
    rows cheaply.  LinearCost is priced at ``n_scan``: the rows the
    linear route actually computes distances over (tombstoned or padded
    rows included — masking happens after the scan).
    """
    assert terms, "finalize_route needs at least one segment"
    collisions = terms[0].collisions
    for t in terms[1:]:
        collisions = collisions + t.collisions
    if n_live is None:
        n_live = sum(t.n_live for t in terms)
    if n_scan is None:
        n_scan = sum(t.n_scan for t in terms)

    cand = jnp.zeros_like(collisions, dtype=jnp.float32)
    for t in terms:
        if t.registers is not None:
            est = ops.hll_merge_estimate(t.registers, impl=impl)
        elif t.merged_registers is not None:
            est = hll_lib.estimate_from_registers(t.merged_registers)
        else:
            est = None
        if est is not None:
            if t.dead_collisions is not None:
                est = jnp.maximum(
                    est - t.dead_collisions.astype(jnp.float32), 0.0)
            cand = cand + est
        if t.cand_exact is not None:
            cand = cand + t.cand_exact.astype(jnp.float32)
    n_live_f = (float(n_live) if isinstance(n_live, (int, float))
                else n_live.astype(jnp.float32))
    cand = jnp.minimum(cand, jnp.minimum(
        collisions.astype(jnp.float32), n_live_f))
    lsh_cost = cost_model.lsh_cost(collisions.astype(jnp.float32), cand)
    linear_cost = cost_model.linear_cost(n_scan)
    return RouteEstimate(collisions=collisions, cand_est=cand,
                         lsh_cost=lsh_cost, linear_cost=linear_cost,
                         use_lsh=lsh_cost < linear_cost)


# ---------------------------------------------------------------------------
# The CSR+HLL segment (static core and the streaming main segment)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TableSegment:
    """CSR tables + per-bucket HLLs, with optional tombstones/external ids.

    With the defaults this is the static core's segment: no dead counts,
    internal ids reported raw.  The streaming main segment supplies
    ``live``/``tomb_counts`` (tombstone-corrected estimates, dead rows
    masked after search) and ``ext_ids`` (external ids reported, with
    ``EXT_SENTINEL`` in masked slots).
    """

    tables: LSHTables
    x: Optional[jax.Array] = None       # (n, d) rows; None = estimate-only
    metric: str = "l2"
    cap: int = 64
    live: Optional[jax.Array] = None         # (n + 1,) bool
    tomb_counts: Optional[jax.Array] = None  # (L, B) int32
    ext_ids: Optional[jax.Array] = None      # (n,) int32
    n_live: Optional[Scalar] = None          # defaults to tables.n
    n_scan: Optional[Scalar] = None          # defaults to #rows scanned
    impl: Optional[str] = None
    q_chunk: Optional[int] = None            # None -> min(32, Q)
    tidx: Optional[jax.Array] = None         # (V,) multi-probe column->table

    def estimate_terms(self, qbuckets: jax.Array) -> SegmentEstimate:
        counts = bucket_counts(self.tables, qbuckets, tidx=self.tidx)
        regs = gather_registers(self.tables, qbuckets, tidx=self.tidx)
        if self.tomb_counts is None:
            collisions = jnp.sum(counts, axis=-1)
            dead = None
        else:
            lidx = table_index(self.tables, self.tidx)
            d = self.tomb_counts[lidx, qbuckets.astype(jnp.int32)]
            collisions = jnp.sum(counts - d, axis=-1)
            dead = jnp.sum(d, axis=-1)
        n_rows = self.tables.n if self.x is None else self.x.shape[0]
        n_live = self.tables.n if self.n_live is None else self.n_live
        n_scan = n_rows if self.n_scan is None else self.n_scan
        return SegmentEstimate(collisions=collisions, dead_collisions=dead,
                               registers=regs, n_live=n_live, n_scan=n_scan)

    def search(self, qbuckets: jax.Array, q: jax.Array, r, *,
               lsh_route: bool):
        assert self.x is not None, "estimate-only segment has no rows"
        n = self.x.shape[0]
        if lsh_route:
            qc = self.q_chunk or min(32, q.shape[0])
            ids, dists, mask = search_lib.lsh_search(
                self.x, self.tables, qbuckets, q, r, self.metric, self.cap,
                q_chunk=qc, tidx=self.tidx, impl=self.impl)
        else:
            ids, dists, mask = search_lib.linear_search(
                self.x, q, r, self.metric, impl=self.impl)
        if self.live is not None or self.ext_ids is not None:
            safe = jnp.clip(ids, 0, n - 1)
            if self.live is not None:
                mask = mask & self.live[safe]
            if self.ext_ids is not None:
                ids = jnp.where(mask, self.ext_ids[safe], EXT_SENTINEL)
        return ids, dists, mask

    def count_candidates(self, qbuckets: jax.Array) -> jax.Array:
        """(Q,) distinct candidates the LSH route gathers (cap-truncated,
        tombstoned rows included — they cost gather + verification)."""
        return search_lib.lsh_candidate_counts(self.tables, qbuckets,
                                               self.cap, tidx=self.tidx)


# ---------------------------------------------------------------------------
# Query result + host-side partitioning helpers
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class QueryResult:
    """Per-strategy buffers + per-query bookkeeping.

    ``neighbors(i)`` extracts the reported ids for query i regardless of
    which strategy served it.
    """

    route: RouteEstimate
    lsh_idx: np.ndarray          # query indices served by LSH search
    lin_idx: np.ndarray          # query indices served by linear search
    lsh_out: Optional[tuple]     # (ids, dists, mask) for the LSH group
    lin_out: Optional[tuple]     # (ids, dists, mask) for the linear group
    n_queries: int

    def neighbors(self, i: int) -> np.ndarray:
        for idx, out in ((self.lsh_idx, self.lsh_out),
                         (self.lin_idx, self.lin_out)):
            if out is None:
                continue
            pos = np.nonzero(np.asarray(idx) == i)[0]
            if len(pos):
                ids, _, mask = out
                row = pos[0]
                return np.asarray(ids[row])[np.asarray(mask[row])]
        raise KeyError(i)

    def reported(self, i: int):
        """(ids, dists) reported for query ``i`` — ``neighbors`` plus
        the distances, the pair the serving result cache stores."""
        for idx, out in ((self.lsh_idx, self.lsh_out),
                         (self.lin_idx, self.lin_out)):
            if out is None:
                continue
            pos = np.nonzero(np.asarray(idx) == i)[0]
            if len(pos):
                ids, dists, mask = out
                row = pos[0]
                m = np.asarray(mask[row])
                return np.asarray(ids[row])[m], np.asarray(dists[row])[m]
        raise KeyError(i)

    def neighbor_sets(self):
        return {i: set(self.neighbors(i).tolist())
                for i in range(self.n_queries)}

    @property
    def n_linear(self) -> int:
        """Exact count of queries served by linear search.

        ``lin_idx`` is power-of-two padded by repeating its last entry,
        so the raw length over-counts — dedup gives the true count.
        """
        return len(set(np.asarray(self.lin_idx).tolist()))

    @property
    def frac_linear(self) -> float:
        return self.n_linear / max(self.n_queries, 1)


def _pad_size(k: int, minimum: int = 8) -> int:
    """Round group sizes up to powers of two: bounded jit-cache churn."""
    if k == 0:
        return 0
    return max(minimum, 1 << (k - 1).bit_length())


def partition_indices(use_lsh: np.ndarray,
                      minimum: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """Split query indices into (lsh_idx, linear_idx), each padded to a
    power-of-two length by repeating the last index (results for padded
    slots are discarded by the caller)."""
    use_lsh = np.asarray(use_lsh)
    lsh_idx = np.nonzero(use_lsh)[0]
    lin_idx = np.nonzero(~use_lsh)[0]

    def pad(idx):
        tgt = _pad_size(len(idx), minimum)
        if tgt == 0:
            return idx.astype(np.int32)
        out = np.full(tgt, idx[-1] if len(idx) else 0, np.int32)
        out[:len(idx)] = idx
        return out

    return pad(lsh_idx), pad(lin_idx)


def compact_results(ids: jax.Array, dists: jax.Array, mask: jax.Array,
                    max_out: int):
    """Compact sentinel-padded (Q, C) results to fixed (Q, max_out).

    Keeps the ``max_out`` nearest reported neighbors per query (exact
    whenever the true output size <= max_out).
    """
    key = jnp.where(mask, dists, jnp.inf)
    neg, pos = jax.lax.top_k(-key, max_out)
    take = jnp.take_along_axis
    return (take(ids, pos, axis=-1), -neg,
            take(mask, pos, axis=-1) & jnp.isfinite(-neg))


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------
class QueryEngine:
    """Owns the hybrid pipeline once, for any list of segments.

    ``estimate``/``search_group`` are pure traced functions — the
    sharded indexes call them inside ``shard_map`` and merge the terms
    across shards themselves; ``query`` is the host-side single-host
    pipeline that additionally partitions the batch.
    """

    def __init__(self, cost_model: CostModel, impl: Optional[str] = None,
                 tracer=None):
        """Args: ``cost_model`` — Algorithm 2 constants (alpha, beta);
        ``impl`` — kernel impl override (e.g. ``"pallas_interpret"``);
        ``tracer`` — optional ``repro.obs.QueryTracer`` (duck-typed, the
        engine never imports obs).  ``query`` takes the traced path only
        while ``tracer.enabled`` is true."""
        self.cost_model = cost_model
        self.impl = impl
        self.tracer = tracer

    # traceable pieces (also used inside shard_map by the sharded paths)
    def estimate(self, segments: Sequence[Segment],
                 qbuckets: jax.Array) -> RouteEstimate:
        """Algorithm 2 lines 1-4 over the whole segment list.

        Args:
          segments: engine segments (frozen levels + delta, any length).
          qbuckets: (Q, L) int query buckets — or (Q, V) virtual-table
            columns under multi-probe.

        Returns the vectorized ``RouteEstimate`` (all fields (Q,) except
        the scalar ``linear_cost``)."""
        return finalize_route([s.estimate_terms(qbuckets) for s in segments],
                              self.cost_model, impl=self.impl)

    def search_group(self, segments: Sequence[Segment], qbuckets: jax.Array,
                     q: jax.Array, r, *, lsh_route: bool):
        """Search every segment for one routed group; concat the buffers.

        Args:
          qbuckets/q: (G, L) buckets and (G, d) rows of the group.
          r: report radius; ``lsh_route`` picks the strategy.

        Returns sentinel-padded ``(ids, dists, mask)``, each (G, C) with
        C the concatenation of the per-segment output widths."""
        parts = [s.search(qbuckets, q, r, lsh_route=lsh_route)
                 for s in segments]
        if len(parts) == 1:
            return parts[0]
        return tuple(jnp.concatenate([p[i] for p in parts], axis=-1)
                     for i in range(3))

    # host-side pipeline (single-host indexes)
    def query(self, segments: Sequence[Segment], queries: jax.Array,
              qbuckets: jax.Array, r: float,
              force: Optional[str] = None) -> QueryResult:
        """Hybrid r-NN reporting over the segments.

        Args:
          segments: engine segments, any length.
          queries: (Q, d) rows; ``qbuckets``: (Q, L) their buckets.
          r: report radius (every returned neighbor has dist <= r).
          force: None (hybrid routing) | "lsh" | "linear" — the two
            baselines of the paper's Figure 2.

        Returns a ``QueryResult``; ``neighbors(i)``/``neighbor_sets()``
        extract reported ids regardless of which strategy served each
        query.
        """
        tracer = self.tracer
        if tracer is None or not tracer.enabled or not tracer.sample():
            nq = queries.shape[0]
            route = self.estimate(segments, qbuckets)
            if force == "lsh":
                use = np.ones(nq, bool)
            elif force == "linear":
                use = np.zeros(nq, bool)
            else:
                use = np.asarray(route.use_lsh)
            lsh_idx, lin_idx = partition_indices(use)

            lsh_out = lin_out = None
            if len(lsh_idx):
                lsh_out = self.search_group(segments, qbuckets[lsh_idx],
                                            queries[lsh_idx], float(r),
                                            lsh_route=True)
            if len(lin_idx):
                lin_out = self.search_group(segments, qbuckets[lin_idx],
                                            queries[lin_idx], float(r),
                                            lsh_route=False)
            return QueryResult(route=route, lsh_idx=lsh_idx, lin_idx=lin_idx,
                               lsh_out=lsh_out, lin_out=lin_out, n_queries=nq)
        return self._query_traced(segments, queries, qbuckets, r, force)

    def count_candidates(self, segments: Sequence[Segment],
                         qbuckets: jax.Array) -> jax.Array:
        """(Q,) distinct candidates the LSH route gathers, summed over
        segments (segments hold disjoint docs, so the sum is exact)."""
        total = segments[0].count_candidates(qbuckets)
        for s in segments[1:]:
            total = total + s.count_candidates(qbuckets)
        return total

    def _query_traced(self, segments: Sequence[Segment], queries: jax.Array,
                      qbuckets: jax.Array, r: float,
                      force: Optional[str]) -> QueryResult:
        """``query`` with phase timing + span recording (same result).

        Phase boundaries are ``block_until_ready``-synced so the timings
        attribute device work to the phase that issued it — the reason
        this is a separate method instead of timers in the fast path.
        """
        tracer = self.tracer
        timings = {}
        seg_seconds = None

        t0 = time.perf_counter()
        route = self.estimate(segments, qbuckets)
        jax.block_until_ready(route.lsh_cost)
        timings["estimate"] = time.perf_counter() - t0

        nq = queries.shape[0]
        if force == "lsh":
            use = np.ones(nq, bool)
        elif force == "linear":
            use = np.zeros(nq, bool)
        else:
            use = np.asarray(route.use_lsh)
        lsh_idx, lin_idx = partition_indices(use)

        per_segment = (getattr(tracer, "per_segment_timing", False)
                       and len(segments) > 1)

        def timed_group(idx, lsh_route, label):
            t0 = time.perf_counter()
            if per_segment:
                parts, seg_t = [], []
                for si, s in enumerate(segments):
                    ts = time.perf_counter()
                    p = s.search(qbuckets[idx], queries[idx], float(r),
                                 lsh_route=lsh_route)
                    jax.block_until_ready(p[2])
                    seg_t.append((f"seg{si}", time.perf_counter() - ts))
                    parts.append(p)
                if len(parts) == 1:
                    out = parts[0]
                else:
                    out = tuple(jnp.concatenate([p[i] for p in parts],
                                                axis=-1) for i in range(3))
                seg_seconds[label] = seg_t
            else:
                out = self.search_group(segments, qbuckets[idx],
                                        queries[idx], float(r),
                                        lsh_route=lsh_route)
            jax.block_until_ready(out[2])
            timings[label] = time.perf_counter() - t0
            return out

        if per_segment:
            seg_seconds = {}
        lsh_out = lin_out = None
        if len(lsh_idx):
            lsh_out = timed_group(lsh_idx, True, "search_lsh")
        if len(lin_idx):
            lin_out = timed_group(lin_idx, False, "search_linear")

        t0 = time.perf_counter()
        cand_actual = np.asarray(self.count_candidates(segments, qbuckets))
        timings["count_actual"] = time.perf_counter() - t0

        coll = np.asarray(route.collisions).astype(np.float64)
        lsh_cost_actual = np.asarray(self.cost_model.lsh_cost(
            coll, cand_actual.astype(np.float64)))
        tracer.record_batch(
            use_lsh=use,
            collisions=coll,
            cand_est=np.asarray(route.cand_est).astype(np.float64),
            cand_actual=cand_actual,
            lsh_cost_est=np.asarray(route.lsh_cost).astype(np.float64),
            lsh_cost_actual=lsh_cost_actual,
            linear_cost=float(np.asarray(route.linear_cost)),
            probes=int(qbuckets.shape[1]),
            forced=force,
            phase_seconds=timings,
            segment_seconds=seg_seconds,
            kernel_impl=ops.resolve_impl(self.impl))
        return QueryResult(route=route, lsh_idx=lsh_idx, lin_idx=lin_idx,
                           lsh_out=lsh_out, lin_out=lin_out, n_queries=nq)


# ---------------------------------------------------------------------------
# Compatibility wrappers (the pre-engine estimator entry points)
# ---------------------------------------------------------------------------
def estimate_routes(tables: LSHTables, qbuckets: jax.Array,
                    cost_model: CostModel, n: int,
                    impl: Optional[str] = None) -> RouteEstimate:
    """O(m*L) per query, independent of bucket sizes (the paper's point)."""
    seg = TableSegment(tables=tables, n_live=n, n_scan=n)
    return finalize_route([seg.estimate_terms(qbuckets)], cost_model,
                          impl=impl)


def estimate_routes_dynamic(tables: LSHTables, qbuckets: jax.Array,
                            cost_model: CostModel, n_live: int, *,
                            tomb_counts: jax.Array,
                            delta_collisions: jax.Array,
                            delta_distinct: jax.Array,
                            n_scan: Optional[int] = None,
                            impl: Optional[str] = None) -> RouteEstimate:
    """Tombstone-corrected Algorithm 2 for a main+delta segment pair."""
    main = TableSegment(tables=tables, tomb_counts=tomb_counts)
    delta = SegmentEstimate(collisions=delta_collisions,
                            cand_exact=delta_distinct)
    return finalize_route([main.estimate_terms(qbuckets), delta], cost_model,
                          impl=impl, n_live=n_live,
                          n_scan=n_live if n_scan is None else n_scan)
