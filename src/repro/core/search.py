"""The two search strategies the hybrid router chooses between.

Both are batched, fixed-shape, jittable functions (TPU execution model),
and both now run through the fused Pallas scan kernels
(``kernels/fused_scan.py``) behind the ``ops`` dispatch:

  * ``linear_search``     — fused brute-force scan (Eq. 2 cost):
                            distance + threshold + report mask + ids in
                            one kernel pass over (Q, N) tiles.
  * ``lsh_search``        — fixed-capacity bucket gather, then the fused
                            verification kernel: sorted-run dedup +
                            row gather + rowwise distance + threshold
                            over (Q, C) candidate tiles (Eq. 1 cost:
                            alpha-term = gather+dedup, beta-term =
                            verification).

On non-TPU backends (and under ``impl="ref"``) both dispatch to the
composed jnp oracles in ``kernels/ref.py`` — same results, bit-exact.

Reporting semantics: every function returns ``(ids, dists, mask)`` where
``mask[q, i]`` marks a reported r-near neighbor of query q.  Buffers are
sentinel-padded; ``mask`` already excludes padding.

Query batches are processed in fixed ``q_chunk`` slices so the
per-chunk working set stays bounded; batches that are not a chunk
multiple are padded up and the results sliced back (a 33-query batch
runs as two 32-query chunks, never as one (33, n) buffer).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.lsh.tables import LSHTables, gather_candidates
from repro.kernels import ops
from repro.kernels import ref as _ref

__all__ = ["linear_search", "lsh_search", "lsh_candidate_counts",
           "dedupe_sorted", "rowwise_dist"]


def rowwise_dist(rows: jax.Array, q: jax.Array, metric: str) -> jax.Array:
    """rows: (..., C, d) candidates vs q: (..., d) -> (..., C) distances.

    Used for candidate verification (gather-bound, so plain VPU math;
    the full-scan MXU kernel wouldn't help on already-gathered rows).
    L2 returns squared distance, consistent with ops.pairwise_dist.
    Delegates to ``kernels.ref.rowwise_dist`` — the expression the fused
    LSH-route kernel replicates tile-by-tile.
    """
    return _ref.rowwise_dist(rows, q, metric)


def dedupe_sorted(cands: jax.Array, sentinel: int) -> Tuple[jax.Array, jax.Array]:
    """Sort candidate ids and mask duplicates / sentinels.

    cands: (Q, C) int32 with sentinel padding.  Returns (sorted_ids,
    first_occurrence_mask).  This is the TPU replacement for the paper's
    hash-set duplicate removal; its cost is the alpha-term of Eq. (1).
    The fused LSH kernel applies the same run-boundary mask in-kernel
    (``ids != prev``); this helper remains the counting path
    (``lsh_candidate_counts``) and the oracle's reference.
    """
    s = jnp.sort(cands, axis=-1)
    first = jnp.concatenate(
        [jnp.ones(s.shape[:-1] + (1,), bool), s[..., 1:] != s[..., :-1]],
        axis=-1)
    return s, first & (s < sentinel)


def _chunked(chunk_fn, args, nq: int, q_chunk: int, pad_values):
    """Run ``chunk_fn`` over fixed q_chunk slices of per-query arrays.

    Pads every array in ``args`` up to the next chunk multiple (with its
    entry in ``pad_values``) so *no* batch size falls back to the
    full-materialization path, then slices the (nq, ...) results back.
    """
    padded = tuple(ops.pad_to(a, q_chunk, 0, value=v)
                   for a, v in zip(args, pad_values))
    nb = padded[0].shape[0] // q_chunk
    reshaped = tuple(a.reshape(nb, q_chunk, *a.shape[1:]) for a in padded)
    ids, dists, mask = jax.lax.map(
        chunk_fn, reshaped if len(reshaped) > 1 else reshaped[0])
    flat = lambda a: a.reshape(nb * q_chunk, -1)[:nq]
    return flat(ids), flat(dists), flat(mask)


@functools.partial(jax.jit, static_argnames=("metric", "impl", "q_chunk"))
def linear_search(x: jax.Array, q: jax.Array, r: float, metric: str,
                  impl: str | None = None, q_chunk: int = 32):
    """Brute-force scan. Returns (ids (Q,n), dists (Q,n), mask (Q,n)).

    One fused kernel per chunk: distances, threshold compare, report
    mask, and candidate ids leave the kernel together (``ops.
    fused_linear_scan``); the composed pipeline never materializes.
    Queries are processed in chunks of ``q_chunk`` (padded up to a chunk
    multiple when needed) so the kernel's working set stays bounded on
    large corpora; the (Q, n) result buffers are the reporting contract
    and are unchanged.
    """
    def chunk_fn(qq):
        return ops.fused_linear_scan(qq, x, r, metric, impl=impl)

    nq = q.shape[0]
    if q_chunk and nq > q_chunk:
        return _chunked(chunk_fn, (q,), nq, q_chunk, (0,))
    return chunk_fn(q)


@functools.partial(jax.jit, static_argnames=("cap",))
def lsh_candidate_counts(tables: LSHTables, qbuckets: jax.Array, cap: int,
                         tidx: jax.Array | None = None) -> jax.Array:
    """(Q,) distinct candidates ``lsh_search`` would gather per query.

    The observability counterpart of the alpha-term: the same
    fixed-capacity gather + sort-dedup as ``lsh_search``, counting
    instead of verifying — ids only, no row gather, no distance math —
    so a traced query batch can compare the HLL candSize *estimate*
    against the candidates actually scanned (cap-truncated, exactly
    like the search; tombstoned rows included — they are gathered and
    verified, so they are real work).  Per-route *kernel time* for the
    verification itself is recorded by the tracer's phase histograms,
    labeled with the backend that served it (``ops.resolve_impl``).
    """
    sentinel = tables.n
    cands = gather_candidates(tables, qbuckets, cap, sentinel, tidx=tidx)
    _, uniq = dedupe_sorted(cands, sentinel)
    return jnp.sum(uniq, axis=-1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("metric", "cap", "q_chunk",
                                             "impl"))
def lsh_search(x: jax.Array, tables: LSHTables, qbuckets: jax.Array,
               q: jax.Array, r: float, metric: str, cap: int,
               q_chunk: int = 32, tidx: jax.Array | None = None,
               impl: str | None = None):
    """LSH-based search (steps S2+S3).

    x: (n, d) database rows (or (n, W) packed codes for hamming);
    qbuckets: (Q, V) bucket of each query per probed table (V = L, or
    L*T under multi-probe with ``tidx`` mapping probe columns to
    physical tables); q: (Q, d) queries.
    Returns (ids (Q, V*cap), dists, mask) — deduped, verified.

    Per chunk the candidate ids are sorted (int32, d-independent) and
    handed to the fused verification kernel (``ops.fused_lsh_scan``):
    run-dedup, row gather, rowwise distance, and threshold run in one
    pass over (Q, V*cap) candidate tiles, so the gathered (qc, C, d)
    rows stream through VMEM instead of materializing.  Queries are
    processed in chunks of ``q_chunk`` (padded up to a chunk multiple —
    pad rows carry all-sentinel candidates, so they self-mask).
    """
    n = x.shape[0]
    sentinel = n
    cands = gather_candidates(tables, qbuckets, cap, sentinel,
                              tidx=tidx)                        # (Q, C)

    def chunk_fn(args):
        c, qq = args                                   # (qc, C), (qc, d)
        ids = jnp.sort(c, axis=-1)
        return ops.fused_lsh_scan(x, ids, qq, r, metric, impl=impl)

    nq = q.shape[0]
    if q_chunk and nq > q_chunk:
        return _chunked(chunk_fn, (cands, q), nq, q_chunk, (sentinel, 0))
    return chunk_fn((cands, q))
