"""The two search strategies the hybrid router chooses between.

Both are batched, fixed-shape, jittable functions (TPU execution model):

  * ``linear_search``     — Pallas-blocked brute-force scan (Eq. 2 cost).
  * ``lsh_search``        — fixed-capacity bucket gather, sort-based
                            dedup, rowwise candidate verification
                            (Eq. 1 cost: alpha-term = gather+dedup,
                            beta-term = verification).

Reporting semantics: every function returns ``(ids, dists, mask)`` where
``mask[q, i]`` marks a reported r-near neighbor of query q.  Buffers are
sentinel-padded; ``mask`` already excludes padding.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.lsh.tables import LSHTables, gather_candidates
from repro.kernels import ops

__all__ = ["linear_search", "lsh_search", "lsh_candidate_counts",
           "dedupe_sorted", "rowwise_dist"]


def rowwise_dist(rows: jax.Array, q: jax.Array, metric: str) -> jax.Array:
    """rows: (..., C, d) candidates vs q: (..., d) -> (..., C) distances.

    Used for candidate verification (gather-bound, so plain VPU math;
    the full-scan MXU kernel wouldn't help on already-gathered rows).
    L2 returns squared distance, consistent with ops.pairwise_dist.
    """
    if metric == "hamming":
        from repro.kernels.ref import popcount_u32
        x = rows.astype(jnp.uint32) ^ q[..., None, :].astype(jnp.uint32)
        return jnp.sum(popcount_u32(x), axis=-1).astype(jnp.float32)
    rows = rows.astype(jnp.float32)
    q = q.astype(jnp.float32)[..., None, :]
    if metric == "l2":
        d = rows - q
        return jnp.sum(d * d, axis=-1)
    if metric == "l1":
        return jnp.sum(jnp.abs(rows - q), axis=-1)
    if metric == "cosine":
        rn = rows / jnp.maximum(
            jnp.linalg.norm(rows, axis=-1, keepdims=True), 1e-12)
        qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True),
                             1e-12)
        return 1.0 - jnp.sum(rn * qn, axis=-1)
    raise ValueError(metric)


def dedupe_sorted(cands: jax.Array, sentinel: int) -> Tuple[jax.Array, jax.Array]:
    """Sort candidate ids and mask duplicates / sentinels.

    cands: (Q, C) int32 with sentinel padding.  Returns (sorted_ids,
    first_occurrence_mask).  This is the TPU replacement for the paper's
    hash-set duplicate removal; its cost is the alpha-term of Eq. (1).
    """
    s = jnp.sort(cands, axis=-1)
    first = jnp.concatenate(
        [jnp.ones(s.shape[:-1] + (1,), bool), s[..., 1:] != s[..., :-1]],
        axis=-1)
    return s, first & (s < sentinel)


@functools.partial(jax.jit, static_argnames=("metric", "impl", "q_chunk"))
def linear_search(x: jax.Array, q: jax.Array, r: float, metric: str,
                  impl: str | None = None, q_chunk: int = 32):
    """Brute-force scan. Returns (ids (Q,n), dists (Q,n), mask (Q,n)).

    Queries are processed in chunks of ``q_chunk`` (mirroring
    ``lsh_search``) so the kernel's intermediate working set stays
    bounded on large corpora; the (Q, n) result buffers are the
    reporting contract and are unchanged.
    """
    thresh = ops.metric_radius_transform(metric, r)
    n = x.shape[0]

    def chunk_fn(qq):
        if metric == "hamming":
            dists = ops.hamming_dist(qq, x, impl=impl).astype(jnp.float32)
        else:
            dists = ops.pairwise_dist(qq, x, metric, impl=impl)
        mask = dists <= thresh
        ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), dists.shape)
        return ids, dists, mask

    nq = q.shape[0]
    if q_chunk and nq % q_chunk == 0 and nq > q_chunk:
        q_r = q.reshape(nq // q_chunk, q_chunk, *q.shape[1:])
        ids, dists, mask = jax.lax.map(chunk_fn, q_r)
        flat = lambda a: a.reshape(nq, -1)
        return flat(ids), flat(dists), flat(mask)
    return chunk_fn(q)


@functools.partial(jax.jit, static_argnames=("cap",))
def lsh_candidate_counts(tables: LSHTables, qbuckets: jax.Array, cap: int,
                         tidx: jax.Array | None = None) -> jax.Array:
    """(Q,) distinct candidates ``lsh_search`` would gather per query.

    The observability counterpart of the alpha-term: the same
    fixed-capacity gather + sort-dedup as ``lsh_search``, counting
    instead of verifying — ids only, no row gather, no distance math —
    so a traced query batch can compare the HLL candSize *estimate*
    against the candidates actually scanned (cap-truncated, exactly
    like the search; tombstoned rows included — they are gathered and
    verified, so they are real work).
    """
    sentinel = tables.n
    cands = gather_candidates(tables, qbuckets, cap, sentinel, tidx=tidx)
    _, uniq = dedupe_sorted(cands, sentinel)
    return jnp.sum(uniq, axis=-1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("metric", "cap", "q_chunk"))
def lsh_search(x: jax.Array, tables: LSHTables, qbuckets: jax.Array,
               q: jax.Array, r: float, metric: str, cap: int,
               q_chunk: int = 32, tidx: jax.Array | None = None):
    """LSH-based search (steps S2+S3).

    x: (n, d) database rows (or (n, W) packed codes for hamming);
    qbuckets: (Q, V) bucket of each query per probed table (V = L, or
    L*T under multi-probe with ``tidx`` mapping probe columns to
    physical tables); q: (Q, d) queries.
    Returns (ids (Q, V*cap), dists, mask) — deduped, verified.
    Processes queries in chunks of ``q_chunk`` to bound the gathered
    candidate working set (V*cap rows of d floats per query).
    """
    n = x.shape[0]
    sentinel = n
    cands = gather_candidates(tables, qbuckets, cap, sentinel,
                              tidx=tidx)                        # (Q, C)
    thresh = ops.metric_radius_transform(metric, r)

    def chunk_fn(args):
        c, qq = args                                   # (qc, C), (qc, d)
        ids, uniq = dedupe_sorted(c, sentinel)
        rows = x[jnp.clip(ids, 0, n - 1)]              # (qc, C, d)
        dists = rowwise_dist(rows, qq, metric)
        mask = uniq & (dists <= thresh)
        return ids, dists, mask

    nq = q.shape[0]
    if nq % q_chunk == 0 and nq > q_chunk:
        c_r = cands.reshape(nq // q_chunk, q_chunk, -1)
        q_r = q.reshape(nq // q_chunk, q_chunk, -1)
        ids, dists, mask = jax.lax.map(chunk_fn, (c_r, q_r))
        flat = lambda a: a.reshape(nq, -1)
        return flat(ids), flat(dists), flat(mask)
    return chunk_fn((cands, q))
