"""Query-directed multi-probe on top of the Hybrid LSH index.

The paper's Sec. 5 names multi-probe LSH (Lv et al. '07) as the natural
next target for HLL-based cost estimation, because multi-probe examines
many buckets per table and therefore aggravates the duplicate-removal
bottleneck.  We implement it for SimHash: per table, probe the base
bucket plus the buckets reached by flipping the T-1 bits with the
smallest projection margin |a.x| (those are the likeliest sign errors).

The cost model extends verbatim: #collisions sums over the L*T probed
buckets and candSize merges their L*T HLLs — the estimate stays O(m*L*T)
and the hybrid routing decision covers the whole probe set.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lsh.families import SimHash, _mix_words_to_bucket
from repro.core.lsh.tables import (LSHTables, bucket_counts,
                                   gather_candidates, gather_registers)

__all__ = ["probe_codes", "probe_buckets", "flatten_probes",
           "multiprobe_counts", "multiprobe_registers",
           "multiprobe_candidates"]

_U = jnp.uint32


def probe_codes(fam: SimHash, params, queries: jax.Array,
                num_probes: int) -> jax.Array:
    """(Q, d) -> probe fingerprints (Q, L, T, W) uint32.

    Probe 0 is the base code; probe t>0 flips the t-th smallest-margin
    bit of that table's code (single-bit perturbations, the dominant
    terms of the Lv et al. probing sequence).
    """
    assert num_probes - 1 <= fam.k, (num_probes, fam.k)
    codes = fam.codes(params, queries)                 # (Q, L, W)
    margins = fam.margins(params, queries)             # (Q, L, k)
    order = jnp.argsort(margins, axis=-1)              # ascending margin
    flip_pos = order[..., :max(num_probes - 1, 0)]     # (Q, L, T-1)

    w = codes.shape[-1]
    word = flip_pos // 32                              # (Q, L, T-1)
    bit = (flip_pos % 32).astype(_U)
    onehot_word = jax.nn.one_hot(word, w, dtype=_U)    # (Q, L, T-1, W)
    flip_mask = onehot_word * (jnp.asarray(np.uint32(1), _U)
                               << bit)[..., None]      # (Q, L, T-1, W)
    flipped = codes[:, :, None, :] ^ flip_mask         # (Q, L, T-1, W)
    return jnp.concatenate([codes[:, :, None, :], flipped], axis=2)


def probe_buckets(fam: SimHash, params, queries: jax.Array,
                  num_probes: int, num_buckets: int) -> jax.Array:
    """(Q, d) -> probed bucket ids (Q, L, T) int32."""
    pcodes = probe_codes(fam, params, queries, num_probes)
    return _mix_words_to_bucket(pcodes, num_buckets)


def flatten_probes(qbuckets_probe: jax.Array):
    """(Q, L, T) probe set -> ((Q, L*T) qbuckets, (L*T,) table map).

    Treat (table, probe) pairs as L*T virtual tables hitting the SAME
    physical table — repeat the table index per probe.  The returned
    pair plugs straight into the engine segments: pass the flat buckets
    as ``qbuckets`` and the map as each segment's ``tidx``, and the
    whole pipeline (estimate terms, dead-count correction, candidate
    gather, delta equality scan) runs over the probed bucket set —
    multi-probe is delta/level-aware for free.
    """
    q, L, t = qbuckets_probe.shape
    return qbuckets_probe.reshape(q, L * t), jnp.repeat(
        jnp.arange(L, dtype=jnp.int32), t)


_flat = flatten_probes


def multiprobe_counts(tables: LSHTables, qb_probe: jax.Array) -> jax.Array:
    """(Q, L, T) probed buckets -> (Q, L*T) bucket sizes."""
    flatb, tidx = flatten_probes(qb_probe)
    return bucket_counts(tables, flatb, tidx=tidx)


def multiprobe_registers(tables: LSHTables, qb_probe: jax.Array) -> jax.Array:
    """(Q, L, T) probed buckets -> (Q, L*T, m) HLL registers."""
    flatb, tidx = flatten_probes(qb_probe)
    return gather_registers(tables, flatb, tidx=tidx)


def multiprobe_candidates(tables: LSHTables, qb_probe: jax.Array, cap: int,
                          sentinel: int) -> jax.Array:
    """(Q, L, T) probed buckets -> (Q, L*T*cap) candidate ids."""
    flatb, tidx = flatten_probes(qb_probe)
    return gather_candidates(tables, flatb, cap, sentinel, tidx=tidx)
