"""Query-directed multi-probe on top of the Hybrid LSH index.

The paper's Sec. 5 names multi-probe LSH (Lv et al. '07) as the natural
next target for HLL-based cost estimation, because multi-probe examines
many buckets per table and therefore aggravates the duplicate-removal
bottleneck.  We implement it for SimHash: per table, probe the base
bucket plus the buckets reached by flipping the T-1 bits with the
smallest projection margin |a.x| (those are the likeliest sign errors).

The cost model extends verbatim: #collisions sums over the L*T probed
buckets and candSize merges their L*T HLLs — the estimate stays O(m*L*T)
and the hybrid routing decision covers the whole probe set.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hll import hash32
from repro.core.lsh.families import SimHash, _mix_words_to_bucket
from repro.core.lsh.tables import LSHTables

__all__ = ["probe_codes", "probe_buckets", "multiprobe_counts",
           "multiprobe_registers", "multiprobe_candidates"]

_U = jnp.uint32


def probe_codes(fam: SimHash, params, queries: jax.Array,
                num_probes: int) -> jax.Array:
    """(Q, d) -> probe fingerprints (Q, L, T, W) uint32.

    Probe 0 is the base code; probe t>0 flips the t-th smallest-margin
    bit of that table's code (single-bit perturbations, the dominant
    terms of the Lv et al. probing sequence).
    """
    assert num_probes - 1 <= fam.k, (num_probes, fam.k)
    codes = fam.codes(params, queries)                 # (Q, L, W)
    margins = fam.margins(params, queries)             # (Q, L, k)
    order = jnp.argsort(margins, axis=-1)              # ascending margin
    flip_pos = order[..., :max(num_probes - 1, 0)]     # (Q, L, T-1)

    w = codes.shape[-1]
    word = flip_pos // 32                              # (Q, L, T-1)
    bit = (flip_pos % 32).astype(_U)
    onehot_word = jax.nn.one_hot(word, w, dtype=_U)    # (Q, L, T-1, W)
    flip_mask = onehot_word * (jnp.asarray(np.uint32(1), _U)
                               << bit)[..., None]      # (Q, L, T-1, W)
    flipped = codes[:, :, None, :] ^ flip_mask         # (Q, L, T-1, W)
    return jnp.concatenate([codes[:, :, None, :], flipped], axis=2)


def probe_buckets(fam: SimHash, params, queries: jax.Array,
                  num_probes: int, num_buckets: int) -> jax.Array:
    """(Q, d) -> probed bucket ids (Q, L, T) int32."""
    pcodes = probe_codes(fam, params, queries, num_probes)
    return _mix_words_to_bucket(pcodes, num_buckets)


def _flat(qbuckets_probe: jax.Array) -> jax.Array:
    q, L, t = qbuckets_probe.shape
    # Treat (table, probe) pairs as L*T virtual tables hitting the SAME
    # physical table — repeat the table index per probe.
    return qbuckets_probe.reshape(q, L * t), jnp.repeat(
        jnp.arange(L, dtype=jnp.int32), t)


def multiprobe_counts(tables: LSHTables, qb_probe: jax.Array) -> jax.Array:
    """(Q, L, T) probed buckets -> (Q, L*T) bucket sizes."""
    flatb, tidx = _flat(qb_probe)
    lo = tables.starts[tidx[None, :], flatb]
    hi = tables.starts[tidx[None, :], flatb + 1]
    return hi - lo


def multiprobe_registers(tables: LSHTables, qb_probe: jax.Array) -> jax.Array:
    """(Q, L, T) probed buckets -> (Q, L*T, m) HLL registers."""
    flatb, tidx = _flat(qb_probe)
    return tables.registers[tidx[None, :], flatb]


def multiprobe_candidates(tables: LSHTables, qb_probe: jax.Array, cap: int,
                          sentinel: int) -> jax.Array:
    """(Q, L, T) probed buckets -> (Q, L*T*cap) candidate ids."""
    flatb, tidx = _flat(qb_probe)
    lo = tables.starts[tidx[None, :], flatb]            # (Q, L*T)
    size = tables.starts[tidx[None, :], flatb + 1] - lo
    offs = jnp.arange(cap, dtype=jnp.int32)
    idx = lo[..., None] + offs
    valid = offs[None, None, :] < size[..., None]
    n = tables.n
    gathered = tables.perm[tidx[None, :, None],
                           jnp.clip(idx, 0, n - 1)]
    cands = jnp.where(valid, gathered, jnp.int32(sentinel))
    return cands.reshape(qb_probe.shape[0], -1)
