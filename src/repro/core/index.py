"""HybridLSHIndex — the paper's data structure as a single-host module.

Build (Algorithm 1): hash all points into L CSR tables, fusing the
per-bucket HyperLogLog build.  Query (Algorithm 2): one static
``TableSegment`` handed to the shared ``QueryEngine``, which estimates
per-query LSHCost from bucket sizes + merged HLLs, routes each query to
LSH-based or linear search, and executes both groups as fixed-shape
batches.

The distributed (mesh-sharded) variant lives in ``core.distributed``;
the streaming variant in ``streaming.index``; the serving integration
in ``serve.retrieval``.  All of them compose the same engine.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.cost_model import CostModel
from repro.core.engine import (QueryEngine, QueryResult, RouteEstimate,
                               TableSegment)
from repro.core.lsh.families import bucket_fn_for
from repro.core.lsh.tables import LSHTables, build_tables

__all__ = ["HybridLSHIndex", "QueryResult"]


class HybridLSHIndex:
    """Hybrid LSH/linear r-NN reporting index (the paper's contribution)."""

    def __init__(self, family, *, num_buckets: int, m: int = 64,
                 cap: int = 64,
                 cost_model: CostModel = CostModel(alpha=1.0, beta=10.0),
                 key: jax.Array | int = 0,
                 impl: Optional[str] = None):
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self.family = family
        self.params = family.init(key)
        self.num_buckets = int(num_buckets)
        self.m = int(m)
        self.cap = int(cap)
        self.cost_model = cost_model
        self.impl = impl
        self.x: Optional[jax.Array] = None
        self.tables: Optional[LSHTables] = None
        self._engine = QueryEngine(cost_model, impl=impl)
        self._bucket_fn = bucket_fn_for(self.family, self.num_buckets)

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return 0 if self.x is None else int(self.x.shape[0])

    def build(self, x: jax.Array, chunk: int = 65536) -> "HybridLSHIndex":
        """Algorithm 1: hash + CSR sort + fused per-bucket HLL build."""
        self.x = jnp.asarray(x)
        n = self.x.shape[0]
        bids = []
        for lo in range(0, n, chunk):
            bids.append(self._bucket_fn(self.params, self.x[lo:lo + chunk]))
        bucket_ids = jnp.concatenate(bids, axis=0)      # (n, L)
        ids = jnp.arange(n, dtype=jnp.int32)
        self.tables = build_tables(ids, bucket_ids, self.num_buckets, self.m)
        return self

    # ------------------------------------------------------------------
    def _segment(self) -> TableSegment:
        assert self.tables is not None, "index is empty: build first"
        return TableSegment(tables=self.tables, x=self.x,
                            metric=self.family.metric, cap=self.cap,
                            impl=self.impl, n_live=self.n, n_scan=self.n)

    def estimate(self, queries: jax.Array) -> RouteEstimate:
        """Algorithm 2 lines 1-4, vectorized over the query batch."""
        qb = self._bucket_fn(self.params, queries)
        return self._engine.estimate([self._segment()], qb)

    def query(self, queries: jax.Array, r: float,
              force: Optional[str] = None) -> QueryResult:
        """Hybrid r-NN reporting.

        force: None (hybrid routing) | "lsh" | "linear" — the two
        baselines of the paper's Figure 2.
        """
        queries = jnp.asarray(queries)
        qb = self._bucket_fn(self.params, queries)
        return self._engine.query([self._segment()], queries, qb, float(r),
                                  force=force)

    # ------------------------------------------------------------------
    def memory_stats(self) -> Dict[str, Any]:
        t = self.tables
        if t is None:   # not built yet: report an empty footprint
            return {"perm_bytes": 0, "starts_bytes": 0, "hll_bytes": 0,
                    "hll_overhead_vs_data": 0.0}
        return {
            "perm_bytes": t.perm.size * 4,
            "starts_bytes": t.starts.size * 4,
            "hll_bytes": t.registers.size,
            "hll_overhead_vs_data": t.registers.size / max(
                1, self.x.size * self.x.dtype.itemsize),
        }
