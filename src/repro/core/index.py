"""HybridLSHIndex — the paper's data structure as a single-host module.

Build (Algorithm 1): hash all points into L CSR tables, fusing the
per-bucket HyperLogLog build.  Query (Algorithm 2): estimate per-query
LSHCost from bucket sizes + merged HLLs, route each query to LSH-based
or linear search, execute both groups as fixed-shape batches.

The distributed (mesh-sharded) variant lives in ``core.distributed``;
the serving integration in ``serve.retrieval``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search as search_lib
from repro.core.cost_model import CostModel
from repro.core.lsh import families as fam_lib
from repro.core.lsh.tables import LSHTables, build_tables
from repro.core.router import (RouteEstimate, estimate_routes,
                               partition_indices)

__all__ = ["HybridLSHIndex", "QueryResult"]


@dataclasses.dataclass
class QueryResult:
    """Per-strategy buffers + per-query bookkeeping.

    ``neighbors(i)`` extracts the reported ids for query i regardless of
    which strategy served it.
    """

    route: RouteEstimate
    lsh_idx: np.ndarray          # query indices served by LSH search
    lin_idx: np.ndarray          # query indices served by linear search
    lsh_out: Optional[tuple]     # (ids, dists, mask) for the LSH group
    lin_out: Optional[tuple]     # (ids, dists, mask) for the linear group
    n_queries: int

    def neighbors(self, i: int) -> np.ndarray:
        for idx, out in ((self.lsh_idx, self.lsh_out),
                         (self.lin_idx, self.lin_out)):
            if out is None:
                continue
            pos = np.nonzero(np.asarray(idx) == i)[0]
            if len(pos):
                ids, _, mask = out
                row = pos[0]
                return np.asarray(ids[row])[np.asarray(mask[row])]
        raise KeyError(i)

    def neighbor_sets(self):
        return {i: set(self.neighbors(i).tolist())
                for i in range(self.n_queries)}

    @property
    def frac_linear(self) -> float:
        served_lin = len(set(np.asarray(self.lin_idx).tolist()))
        return served_lin / max(self.n_queries, 1)


class HybridLSHIndex:
    """Hybrid LSH/linear r-NN reporting index (the paper's contribution)."""

    def __init__(self, family, *, num_buckets: int, m: int = 64,
                 cap: int = 64,
                 cost_model: CostModel = CostModel(alpha=1.0, beta=10.0),
                 key: jax.Array | int = 0,
                 impl: Optional[str] = None):
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self.family = family
        self.params = family.init(key)
        self.num_buckets = int(num_buckets)
        self.m = int(m)
        self.cap = int(cap)
        self.cost_model = cost_model
        self.impl = impl
        self.x: Optional[jax.Array] = None
        self.tables: Optional[LSHTables] = None
        self._bucket_fn = jax.jit(functools.partial(
            self.family.bucket_ids, num_buckets=self.num_buckets))

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return 0 if self.x is None else int(self.x.shape[0])

    def build(self, x: jax.Array, chunk: int = 65536) -> "HybridLSHIndex":
        """Algorithm 1: hash + CSR sort + fused per-bucket HLL build."""
        self.x = jnp.asarray(x)
        n = self.x.shape[0]
        bids = []
        for lo in range(0, n, chunk):
            bids.append(self._bucket_fn(self.params, self.x[lo:lo + chunk]))
        bucket_ids = jnp.concatenate(bids, axis=0)      # (n, L)
        ids = jnp.arange(n, dtype=jnp.int32)
        self.tables = build_tables(ids, bucket_ids, self.num_buckets, self.m)
        return self

    # ------------------------------------------------------------------
    def estimate(self, queries: jax.Array) -> RouteEstimate:
        """Algorithm 2 lines 1-4, vectorized over the query batch."""
        qb = self._bucket_fn(self.params, queries)
        return estimate_routes(self.tables, qb, self.cost_model, self.n,
                               impl=self.impl)

    def query(self, queries: jax.Array, r: float,
              force: Optional[str] = None) -> QueryResult:
        """Hybrid r-NN reporting.

        force: None (hybrid routing) | "lsh" | "linear" — the two
        baselines of the paper's Figure 2.
        """
        queries = jnp.asarray(queries)
        nq = queries.shape[0]
        route = self.estimate(queries)
        if force == "lsh":
            use = np.ones(nq, bool)
        elif force == "linear":
            use = np.zeros(nq, bool)
        else:
            use = np.asarray(route.use_lsh)
        lsh_idx, lin_idx = partition_indices(use)

        lsh_out = lin_out = None
        if len(lsh_idx):
            sub = queries[lsh_idx]
            qb = self._bucket_fn(self.params, sub)
            lsh_out = search_lib.lsh_search(
                self.x, self.tables, qb, sub, float(r),
                self.family.metric, self.cap,
                q_chunk=min(32, len(lsh_idx)))
        if len(lin_idx):
            lin_out = search_lib.linear_search(
                self.x, queries[lin_idx], float(r), self.family.metric,
                impl=self.impl)
        return QueryResult(route=route, lsh_idx=lsh_idx, lin_idx=lin_idx,
                           lsh_out=lsh_out, lin_out=lin_out, n_queries=nq)

    # ------------------------------------------------------------------
    def memory_stats(self) -> Dict[str, Any]:
        t = self.tables
        return {
            "perm_bytes": t.perm.size * 4,
            "starts_bytes": t.starts.size * 4,
            "hll_bytes": t.registers.size,
            "hll_overhead_vs_data": t.registers.size / max(
                1, self.x.size * self.x.dtype.itemsize),
        }
