"""Hybrid router (Algorithm 2) — compatibility surface.

The actual pipeline lives in ``repro.core.engine`` since the
segment-engine refactor: ``finalize_route`` is the one tombstone-aware
estimate path (dead counts zero for static segments), and
``QueryEngine`` owns estimate→route→partition→search.  This module
re-exports the public names so existing imports keep working.

On TPU the per-query ``if`` of Algorithm 2 becomes *batch partitioning*:
the estimator runs vectorized over the query batch, then the batch is
split into an LSH group and a linear group (padded to bounded static
sizes so jit caches stay small).  A vmapped ``lax.cond`` would execute
both branches densely — partitioning is the performance-correct port.
"""
from __future__ import annotations

import warnings

from repro.core.engine import (RouteEstimate, _pad_size, compact_results,
                               estimate_routes, estimate_routes_dynamic,
                               finalize_route, partition_indices)

warnings.warn(
    "repro.core.router is a compatibility shim and will be removed in the "
    "next release; import from repro.core.engine instead",
    DeprecationWarning, stacklevel=2)

__all__ = ["RouteEstimate", "estimate_routes", "estimate_routes_dynamic",
           "finalize_route", "partition_indices", "compact_results"]
