"""Hybrid router (Algorithm 2): estimate LSHCost, compare to LinearCost,
pick the strategy.

On TPU the per-query ``if`` of Algorithm 2 becomes *batch partitioning*:
the estimator runs vectorized over the query batch, then the batch is
split into an LSH group and a linear group (padded to bounded static
sizes so jit caches stay small).  A vmapped ``lax.cond`` would execute
both branches densely — partitioning is the performance-correct port.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import CostModel
from repro.core.lsh.tables import LSHTables, bucket_counts, gather_registers
from repro.kernels import ops

__all__ = ["RouteEstimate", "estimate_routes", "estimate_routes_dynamic",
           "partition_indices", "compact_results"]


@dataclasses.dataclass
class RouteEstimate:
    """Vectorized output of Algorithm 2 lines 1-4."""

    collisions: jax.Array   # (Q,) int32   exact  sum of bucket sizes
    cand_est: jax.Array     # (Q,) float32 HLL union estimate of candSize
    lsh_cost: jax.Array     # (Q,) float32 Eq. (1)
    linear_cost: float      # scalar       Eq. (2)
    use_lsh: jax.Array      # (Q,) bool    Algorithm 2 line 4


def estimate_routes(tables: LSHTables, qbuckets: jax.Array,
                    cost_model: CostModel, n: int,
                    impl: Optional[str] = None) -> RouteEstimate:
    """O(m*L) per query, independent of bucket sizes (the paper's point)."""
    counts = bucket_counts(tables, qbuckets)            # (Q, L)
    collisions = jnp.sum(counts, axis=-1)
    regs = gather_registers(tables, qbuckets)           # (Q, L, m)
    cand_est = ops.hll_merge_estimate(regs, impl=impl)  # (Q,)
    # candSize can never exceed #collisions (it's the distinct count)
    # nor n — clamp the estimator with both structural bounds.
    cand_est = jnp.minimum(cand_est, jnp.minimum(
        collisions.astype(jnp.float32), float(n)))
    lsh_cost = cost_model.lsh_cost(collisions.astype(jnp.float32), cand_est)
    linear_cost = float(cost_model.linear_cost(n))
    return RouteEstimate(collisions=collisions, cand_est=cand_est,
                         lsh_cost=lsh_cost, linear_cost=linear_cost,
                         use_lsh=lsh_cost < linear_cost)


def estimate_routes_dynamic(tables: LSHTables, qbuckets: jax.Array,
                            cost_model: CostModel, n_live: int, *,
                            tomb_counts: jax.Array,
                            delta_collisions: jax.Array,
                            delta_distinct: jax.Array,
                            n_scan: Optional[int] = None,
                            impl: Optional[str] = None) -> RouteEstimate:
    """Tombstone-corrected Algorithm 2 for the streaming index.

    The main segment's CSR sizes and HLLs still include tombstoned rows
    (both are immutable), so the estimate is corrected on the fly:

      collisions = (CSR sizes - per-bucket dead counts)  [exact, main]
                   + delta collisions                    [exact, delta]
      candSize   = max(HLL union - dead collisions, 0)   [see CostModel
                   + exact delta distinct                 .corrected_cand_size]

    LinearCost is priced at ``n_scan`` — the rows the linear route
    actually computes distances over (all main rows, tombstoned or not,
    plus occupied delta slots; masking happens after the scan).  It
    defaults to ``n_live``, which under-prices linear under heavy
    un-compacted churn — pass the true scan size when available.
    """
    counts = bucket_counts(tables, qbuckets)            # (Q, L)
    lidx = jnp.arange(tables.L)[None, :]
    dead = tomb_counts[lidx, qbuckets.astype(jnp.int32)]  # (Q, L)
    collisions = jnp.sum(counts - dead, axis=-1) + delta_collisions
    regs = gather_registers(tables, qbuckets)           # (Q, L, m)
    cand_main = ops.hll_merge_estimate(regs, impl=impl)  # (Q,)
    cand_est = cost_model.corrected_cand_size(
        cand_main, jnp.sum(dead, axis=-1), delta_distinct, collisions,
        n_live)
    lsh_cost = cost_model.lsh_cost(collisions.astype(jnp.float32), cand_est)
    linear_cost = float(cost_model.linear_cost(
        n_live if n_scan is None else n_scan))
    return RouteEstimate(collisions=collisions, cand_est=cand_est,
                         lsh_cost=lsh_cost, linear_cost=linear_cost,
                         use_lsh=lsh_cost < linear_cost)


def _pad_size(k: int, minimum: int = 8) -> int:
    """Round group sizes up to powers of two: bounded jit-cache churn."""
    if k == 0:
        return 0
    return max(minimum, 1 << (k - 1).bit_length())


def partition_indices(use_lsh: np.ndarray,
                      minimum: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """Split query indices into (lsh_idx, linear_idx), each padded to a
    power-of-two length by repeating the last index (results for padded
    slots are discarded by the caller)."""
    use_lsh = np.asarray(use_lsh)
    lsh_idx = np.nonzero(use_lsh)[0]
    lin_idx = np.nonzero(~use_lsh)[0]

    def pad(idx):
        tgt = _pad_size(len(idx), minimum)
        if tgt == 0:
            return idx.astype(np.int32)
        out = np.full(tgt, idx[-1] if len(idx) else 0, np.int32)
        out[:len(idx)] = idx
        return out

    return pad(lsh_idx), pad(lin_idx)


def compact_results(ids: jax.Array, dists: jax.Array, mask: jax.Array,
                    max_out: int):
    """Compact sentinel-padded (Q, C) results to fixed (Q, max_out).

    Keeps the ``max_out`` nearest reported neighbors per query (exact
    whenever the true output size <= max_out).
    """
    key = jnp.where(mask, dists, jnp.inf)
    neg, pos = jax.lax.top_k(-key, max_out)
    take = jnp.take_along_axis
    return (take(ids, pos, axis=-1), -neg,
            take(mask, pos, axis=-1) & jnp.isfinite(-neg))
