"""DEPRECATED compatibility shim for the pre-engine router module.

Everything here is a re-export from ``repro.core.engine``, where the
pipeline has lived since the segment-engine refactor (PR 2).  Update
imports symbol-for-symbol — the names are identical:

  =================================  =====================================
  old import                         replacement
  =================================  =====================================
  ``router.RouteEstimate``           ``engine.RouteEstimate``
  ``router.estimate_routes``         ``engine.estimate_routes``
  ``router.estimate_routes_dynamic`` ``engine.estimate_routes_dynamic``
  ``router.finalize_route``          ``engine.finalize_route``
  ``router.partition_indices``       ``engine.partition_indices``
  ``router.compact_results``         ``engine.compact_results``
  =================================  =====================================

Deprecation window: this shim survives two more PRs after PR 4 and is
then deleted (see docs/architecture.md, "Deprecations").  New code
should also prefer the higher-level ``engine.QueryEngine`` /
``engine.TableSegment`` composition over calling these directly.

On TPU the per-query ``if`` of Algorithm 2 becomes *batch partitioning*:
the estimator runs vectorized over the query batch, then the batch is
split into an LSH group and a linear group (padded to bounded static
sizes so jit caches stay small).  A vmapped ``lax.cond`` would execute
both branches densely — partitioning is the performance-correct port.
"""
from __future__ import annotations

import warnings

from repro.core.engine import (RouteEstimate, _pad_size, compact_results,
                               estimate_routes, estimate_routes_dynamic,
                               finalize_route, partition_indices)

warnings.warn(
    "repro.core.router is a deprecated re-export shim (removal: two PRs "
    "after PR 4; see docs/architecture.md 'Deprecations'). Replace "
    "repro.core.router.{RouteEstimate, estimate_routes, "
    "estimate_routes_dynamic, finalize_route, partition_indices, "
    "compact_results} with the identically-named symbols in "
    "repro.core.engine",
    DeprecationWarning, stacklevel=2)

__all__ = ["RouteEstimate", "estimate_routes", "estimate_routes_dynamic",
           "finalize_route", "partition_indices", "compact_results"]
