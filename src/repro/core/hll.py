"""HyperLogLog sketches in JAX (Flajolet et al., AofA'07).

The paper attaches one HLL to every LSH bucket so that the union
cardinality of the L buckets colliding with a query (= ``candSize`` in
Eq. (1)) can be estimated in O(m*L) time, independent of bucket sizes.

TPU adaptation: buckets are dense CSR ranges, so per-bucket HLLs are a
dense ``(num_buckets, m)`` register array built in one fused
``segment_max`` pass.  Register updates are keyed on the *global* point
id, so the same point produces the same ``(register, rank)`` pair in
every table and every shard — merging registers with ``max`` therefore
computes the exact HLL of the *distinct* union, which is what makes the
candSize estimate correct across tables (paper, Sec. 3.2) and across
mesh shards (our distributed extension; merge = ``pmax``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "hash32",
    "clz32",
    "point_register_rank",
    "build_bucket_hlls",
    "merge_registers",
    "estimate_cardinality",
    "estimate_from_registers",
    "relative_error",
]

_UINT = jnp.uint32

# Murmur3-style 32-bit finalizer constants.
_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)


def hash32(x: jax.Array, seed: int = 0) -> jax.Array:
    """Murmur3 fmix32 of ``x`` (any integer dtype), returns uint32.

    Good avalanche behaviour; used both for HLL register/rank derivation
    and for bucket-id mixing in the LSH tables.
    """
    h = x.astype(_UINT) + jnp.asarray(
        np.uint32((int(seed) * 0x9E3779B9) & 0xFFFFFFFF), _UINT)
    h = h ^ (h >> 16)
    h = h * _C1
    h = h ^ (h >> 13)
    h = h * _C2
    h = h ^ (h >> 16)
    return h


def clz32(x: jax.Array) -> jax.Array:
    """Branchless count-leading-zeros for uint32 (returns 32 for x == 0).

    Bit-exact (no float log tricks, which mis-round near powers of two).
    """
    x = x.astype(_UINT)
    n = jnp.zeros_like(x, dtype=jnp.int32)
    for shift, mask in ((16, 0x0000FFFF), (8, 0x00FFFFFF), (4, 0x0FFFFFFF),
                        (2, 0x3FFFFFFF), (1, 0x7FFFFFFF)):
        small = x <= jnp.asarray(np.uint32(mask), _UINT)
        n = jnp.where(small, n + shift, n)
        x = jnp.where(small, x << shift, x)
    return jnp.where(x == 0, jnp.int32(32), n)


def point_register_rank(ids: jax.Array, m: int, seed: int = 0):
    """Derive the HLL ``(register, rank)`` update pair for point ids.

    Standard single-hash construction: the top ``p = log2(m)`` bits of the
    32-bit hash select the register, the rank is the number of leading
    zeros of the remaining ``32 - p`` bits plus one (capped there by an
    implicit sentinel bit, as in the reference algorithm).
    """
    p = int(m).bit_length() - 1
    assert (1 << p) == m, f"m must be a power of two, got {m}"
    h = hash32(ids, seed)
    reg = (h >> np.uint32(32 - p)).astype(jnp.int32)
    rest = (h << np.uint32(p)) | jnp.asarray(np.uint32(1) << np.uint32(p - 1), _UINT)
    rank = clz32(rest) + 1
    return reg, rank


def build_bucket_hlls(ids: jax.Array, bucket_ids: jax.Array, num_buckets: int,
                      m: int, seed: int = 0) -> jax.Array:
    """One fused pass: per-bucket HLL registers as ``(num_buckets, m)`` int32.

    ``segment_max`` over the flattened key ``bucket * m + register`` — this
    is Algorithm 1 line 4 of the paper, vectorized.
    """
    reg, rank = point_register_rank(ids, m, seed)
    seg = bucket_ids.astype(jnp.int32) * m + reg
    flat = jax.ops.segment_max(rank, seg, num_segments=num_buckets * m,
                               indices_are_sorted=False)
    flat = jnp.maximum(flat, 0)  # empty segments come back as dtype-min
    return flat.reshape(num_buckets, m)


def merge_registers(registers: jax.Array, axis=0) -> jax.Array:
    """Merge HLLs (component-wise max) along ``axis`` — Algorithm 2 line 2."""
    return jnp.max(registers, axis=axis)


def _alpha(m: int) -> float:
    if m <= 16:
        return 0.673
    if m <= 32:
        return 0.697
    if m <= 64:
        return 0.709
    return 0.7213 / (1.0 + 1.079 / m)


@functools.partial(jax.jit, static_argnames=("m",))
def estimate_cardinality(registers: jax.Array, m: int) -> jax.Array:
    """HLL estimator with small/large-range corrections.

    ``registers``: (..., m) int32.  Returns float32 estimates shaped (...,).
    """
    regs = registers.astype(jnp.float32)
    raw = _alpha(m) * m * m / jnp.sum(jnp.exp2(-regs), axis=-1)
    zeros = jnp.sum((registers == 0).astype(jnp.float32), axis=-1)
    # Small-range (linear counting) correction.
    small = m * jnp.log(m / jnp.maximum(zeros, 1e-9))
    est = jnp.where((raw <= 2.5 * m) & (zeros > 0), small, raw)
    # Large-range correction for the 32-bit hash space.
    two32 = jnp.float32(2.0**32)
    est = jnp.where(est > two32 / 30.0,
                    -two32 * jnp.log1p(-est / two32), est)
    return est


def estimate_from_registers(registers: jax.Array) -> jax.Array:
    """Convenience wrapper inferring m from the trailing dim."""
    return estimate_cardinality(registers, int(registers.shape[-1]))


def relative_error(m: int) -> float:
    """Theoretical standard relative error, 1.04 / sqrt(m) (paper Sec. 2)."""
    return 1.04 / float(np.sqrt(m))
