from repro.core.lsh.families import (BitSampling, PStableL1, PStableL2,
                                     SimHash, bucket_fn_for, k_from_delta,
                                     make_family)
from repro.core.lsh.tables import (LSHTables, bucket_counts, build_tables,
                                   gather_candidates, gather_registers)

__all__ = ["BitSampling", "PStableL1", "PStableL2", "SimHash",
           "bucket_fn_for", "k_from_delta", "make_family", "LSHTables", "bucket_counts",
           "build_tables", "gather_candidates", "gather_registers"]
