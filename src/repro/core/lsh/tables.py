"""CSR bucket tables with per-bucket HyperLogLogs (Algorithm 1, TPU-native).

A classic LSH hash table is a pointer-chasing dict; on TPU we store each
table as a CSR layout over a dense power-of-two bucket space:

  perm      (L, n)        point ids, sorted by bucket id, per table
  starts    (L, B + 1)    bucket offsets into ``perm``
  registers (L, B, m)     per-bucket HLL registers (uint8)

Build is one ``argsort`` + one ``segment_sum`` + one ``segment_max`` per
table (vmapped over L).  Bucket *sizes* give the exact ``#collisions``
term of Eq. (1); the registers give the mergeable candSize estimator.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import hll as hll_lib

__all__ = ["LSHTables", "build_tables", "table_index", "bucket_counts",
           "gather_registers", "gather_candidates"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LSHTables:
    """Stacked CSR tables (a pytree; leaves are the three arrays)."""

    perm: jax.Array        # (L, n) int32
    starts: jax.Array      # (L, B + 1) int32
    registers: jax.Array   # (L, B, m) uint8

    @property
    def L(self) -> int:
        return self.perm.shape[0]

    @property
    def n(self) -> int:
        return self.perm.shape[1]

    @property
    def num_buckets(self) -> int:
        return self.registers.shape[1]

    @property
    def m(self) -> int:
        return self.registers.shape[2]

    def tree_flatten(self):
        return (self.perm, self.starts, self.registers), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def _build_one_table(ids: jax.Array, bucket_ids: jax.Array, num_buckets: int,
                     m: int) -> Dict[str, jax.Array]:
    order = jnp.argsort(bucket_ids)
    perm = ids[order].astype(jnp.int32)
    counts = jax.ops.segment_sum(jnp.ones_like(bucket_ids, jnp.int32),
                                 bucket_ids, num_segments=num_buckets)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts).astype(jnp.int32)])
    regs = hll_lib.build_bucket_hlls(ids, bucket_ids, num_buckets, m)
    return {"perm": perm, "starts": starts,
            "registers": regs.astype(jnp.uint8)}


@functools.partial(jax.jit, static_argnames=("num_buckets", "m"))
def build_tables(ids: jax.Array, bucket_ids: jax.Array, num_buckets: int,
                 m: int) -> LSHTables:
    """ids: (n,) global point ids; bucket_ids: (n, L) per-table buckets."""
    out = jax.vmap(lambda b: _build_one_table(ids, b, num_buckets, m),
                   in_axes=1)(bucket_ids)
    return LSHTables(out["perm"], out["starts"], out["registers"])


def table_index(tables: LSHTables,
                tidx: jax.Array | None) -> jax.Array:
    """Virtual-table map, shaped (1, V): column j of a qbuckets array
    probes physical table ``tidx[j]`` (identity when tidx is None).
    Multi-probe flattens its (Q, L, T) probe set to (Q, L*T) columns
    with ``tidx`` repeating each table T times — every gather below
    (and the engine's tombstone lookup) then works unchanged."""
    if tidx is None:
        return jnp.arange(tables.L, dtype=jnp.int32)[None, :]
    return tidx.astype(jnp.int32)[None, :]


def bucket_counts(tables: LSHTables, qbuckets: jax.Array,
                  tidx: jax.Array | None = None) -> jax.Array:
    """qbuckets: (Q, V) -> per-(query, probed bucket) sizes (Q, V) int32.

    V = L (one probe per table) or L*T under multi-probe (``tidx``).
    ``sum(axis=-1)`` of the result is the exact #collisions of Eq. (1).
    """
    b = qbuckets.astype(jnp.int32)                      # (Q, V)
    lidx = table_index(tables, tidx)                    # (1, V)
    lo = tables.starts[lidx, b]
    hi = tables.starts[lidx, b + 1]
    return hi - lo


def gather_registers(tables: LSHTables, qbuckets: jax.Array,
                     tidx: jax.Array | None = None) -> jax.Array:
    """(Q, V) bucket ids -> (Q, V, m) HLL registers of the hit buckets."""
    lidx = table_index(tables, tidx)
    return tables.registers[lidx, qbuckets.astype(jnp.int32)]


def gather_candidates(tables: LSHTables, qbuckets: jax.Array, cap: int,
                      sentinel: int,
                      tidx: jax.Array | None = None) -> jax.Array:
    """Fixed-capacity candidate gather: (Q, V) buckets -> (Q, V*cap) ids.

    Each probed bucket contributes up to ``cap`` ids; slots beyond the
    bucket size are filled with ``sentinel`` (an id == n, sorting after
    every real id).  Truncation beyond ``cap`` is a recall risk only for
    buckets the cost model routes to linear search anyway (big buckets
    => big #collisions => LSHCost > LinearCost).
    """
    b = qbuckets.astype(jnp.int32)                      # (Q, V)
    lidx = table_index(tables, tidx)
    lo = tables.starts[lidx, b]                          # (Q, V)
    size = tables.starts[lidx, b + 1] - lo               # (Q, V)
    offs = jnp.arange(cap, dtype=jnp.int32)              # (cap,)
    idx = lo[..., None] + offs                           # (Q, V, cap)
    valid = offs[None, None, :] < size[..., None]
    n = tables.n
    gathered = tables.perm[lidx[..., None], jnp.clip(idx, 0, n - 1)]
    cands = jnp.where(valid, gathered, jnp.int32(sentinel))
    q = qbuckets.shape[0]
    return cands.reshape(q, qbuckets.shape[1] * cap)
