"""LSH families used by the paper, in JAX.

The paper evaluates four (metric, family) pairs:

  * cosine   -> SimHash (Charikar'02)            [Webspam]
  * L2       -> p-stable Gaussian (Datar+'04)    [Corel]
  * L1       -> p-stable Cauchy (Datar+'04)      [CoverType]
  * Hamming  -> bit sampling (Indyk-Motwani'98)  [MNIST via 64-bit SimHash]

Each family produces, for every point, L table codes.  Codes are packed
into ``(…, L, W)`` uint32 words (W = ceil(bits_per_code / 32)), then mixed
into a bucket id in ``[0, num_buckets)``.  All functions are pure and
jittable; parameters are plain pytrees created from a PRNG key.

Parameterization follows the paper: L is fixed, and
``k = ceil(log(1 - delta**(1/L)) / log(p1))`` for SimHash / bit sampling
(footnote 1, also used by E2LSH); for the p-stable families the paper
fixes (k, w) = (8, 4r) for L1 and (7, 2r) for L2 to reach delta = 10%.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hll import hash32


@functools.lru_cache(maxsize=128)
def bucket_fn_for(family, num_buckets: int):
    """Shared jitted ``(params, x) -> bucket ids`` per (family, B).

    Families are frozen dataclasses (hashable), so the compiled hash
    survives index reconstruction — restores, benchmark reruns, and
    serving restarts reuse it instead of re-tracing per instance.
    """
    return jax.jit(functools.partial(family.bucket_ids,
                                     num_buckets=num_buckets))

__all__ = [
    "SimHash", "PStableL2", "PStableL1", "BitSampling",
    "k_from_delta", "make_family",
]

_UINT = jnp.uint32


def _pack_bits(bits: jax.Array) -> jax.Array:
    """Pack boolean bits (..., k) into (..., ceil(k/32)) uint32 words."""
    k = bits.shape[-1]
    w = (k + 31) // 32
    pad = w * 32 - k
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    bits = bits.reshape(bits.shape[:-1] + (w, 32)).astype(_UINT)
    powers = (jnp.asarray(np.uint32(1), _UINT) << jnp.arange(32, dtype=_UINT))
    return jnp.sum(bits * powers, axis=-1, dtype=_UINT)


def _mix_words_to_bucket(words: jax.Array, num_buckets: int,
                         seed: int = 17) -> jax.Array:
    """Mix (..., W) uint32 words into a bucket id in [0, num_buckets).

    num_buckets must be a power of two.  Boost-style hash combining with a
    murmur finalizer per word gives well-spread buckets even for k < 32.
    """
    assert num_buckets & (num_buckets - 1) == 0, "num_buckets must be 2^t"
    acc = jnp.full(words.shape[:-1], np.uint32(seed), _UINT)
    for j in range(words.shape[-1]):
        acc = hash32(acc ^ words[..., j], seed=seed + j)
    return (acc & jnp.asarray(np.uint32(num_buckets - 1), _UINT)).astype(jnp.int32)


def k_from_delta(p1: float, L: int, delta: float) -> int:
    """Paper footnote 1: smallest k with (1 - p1^k)^L <= delta."""
    if not (0.0 < p1 < 1.0):
        raise ValueError(f"p1 must be in (0,1), got {p1}")
    return max(1, math.ceil(math.log(1.0 - delta ** (1.0 / L)) / math.log(p1)))


def _norm_cdf(x: float) -> float:
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


@dataclasses.dataclass(frozen=True)
class SimHash:
    """Random-hyperplane LSH for cosine distance (1 - cos theta)."""

    d: int
    L: int
    k: int
    metric: str = "cosine"

    def init(self, key: jax.Array) -> Dict[str, Any]:
        r = jax.random.normal(key, (self.d, self.L * self.k), jnp.float32)
        return {"R": r}

    def codes(self, params, x: jax.Array) -> jax.Array:
        """x: (n, d) -> packed codes (n, L, W) uint32."""
        proj = x.astype(jnp.float32) @ params["R"]
        bits = (proj > 0).reshape(x.shape[0], self.L, self.k)
        return _pack_bits(bits)

    def margins(self, params, x: jax.Array) -> jax.Array:
        """|projection| per bit — used by query-directed multiprobe."""
        proj = x.astype(jnp.float32) @ params["R"]
        return jnp.abs(proj).reshape(x.shape[0], self.L, self.k)

    def bucket_ids(self, params, x: jax.Array, num_buckets: int) -> jax.Array:
        return _mix_words_to_bucket(self.codes(params, x), num_buckets)

    def p1(self, r: float) -> float:
        """Collision prob of ONE bit for points at cosine distance r."""
        theta = math.acos(max(-1.0, min(1.0, 1.0 - r)))
        return 1.0 - theta / math.pi

    def p1_code(self, r: float) -> float:
        return self.p1(r) ** self.k


@dataclasses.dataclass(frozen=True)
class _PStableBase:
    """floor((a.x + b) / w) family (Datar et al. '04)."""

    d: int
    L: int
    k: int
    w: float
    metric: str = "l2"

    def _draw_a(self, key):  # overridden: gaussian vs cauchy
        raise NotImplementedError

    def init(self, key: jax.Array) -> Dict[str, Any]:
        ka, kb = jax.random.split(key)
        a = self._draw_a(ka)
        b = jax.random.uniform(kb, (self.L * self.k,), jnp.float32,
                               0.0, self.w)
        return {"a": a, "b": b}

    def codes(self, params, x: jax.Array) -> jax.Array:
        """x: (n, d) -> (n, L, k) int32 lattice coordinates as uint32 words."""
        proj = (x.astype(jnp.float32) @ params["a"] + params["b"]) / self.w
        h = jnp.floor(proj).astype(jnp.int32)
        return h.reshape(x.shape[0], self.L, self.k).astype(_UINT)

    def bucket_ids(self, params, x: jax.Array, num_buckets: int) -> jax.Array:
        return _mix_words_to_bucket(self.codes(params, x), num_buckets)

    def p1_code(self, r: float) -> float:
        return self.p1(r) ** self.k


@dataclasses.dataclass(frozen=True)
class PStableL2(_PStableBase):
    metric: str = "l2"

    def _draw_a(self, key):
        return jax.random.normal(key, (self.d, self.L * self.k), jnp.float32)

    def p1(self, r: float) -> float:
        """Datar et al. Eq. for Gaussian p-stable at distance c=r."""
        t = self.w / max(r, 1e-12)
        return (1.0 - 2.0 * _norm_cdf(-t)
                - 2.0 / (math.sqrt(2.0 * math.pi) * t)
                * (1.0 - math.exp(-t * t / 2.0)))


@dataclasses.dataclass(frozen=True)
class PStableL1(_PStableBase):
    metric: str = "l1"

    def _draw_a(self, key):
        # Standard Cauchy via tan of uniform.
        u = jax.random.uniform(key, (self.d, self.L * self.k), jnp.float32,
                               1e-6, 1.0 - 1e-6)
        return jnp.tan(math.pi * (u - 0.5))

    def p1(self, r: float) -> float:
        t = self.w / max(r, 1e-12)
        return (2.0 * math.atan(t) / math.pi
                - math.log1p(t * t) / (math.pi * t))


@dataclasses.dataclass(frozen=True)
class BitSampling:
    """Bit sampling LSH for Hamming distance over packed binary codes.

    Input points are (n, W_in) uint32 fingerprints of ``dim_bits`` bits
    (the paper uses 64-bit SimHash fingerprints of MNIST).
    """

    dim_bits: int
    L: int
    k: int
    metric: str = "hamming"

    def init(self, key: jax.Array) -> Dict[str, Any]:
        pos = jax.random.randint(key, (self.L * self.k,), 0, self.dim_bits,
                                 jnp.int32)
        return {"pos": pos}

    def codes(self, params, x: jax.Array) -> jax.Array:
        """x: (n, W_in) uint32 -> (n, L, W) uint32 sampled-bit codes."""
        pos = params["pos"]
        word, bit = pos // 32, (pos % 32).astype(_UINT)
        bits = (x[:, word] >> bit) & jnp.asarray(np.uint32(1), _UINT)
        bits = bits.reshape(x.shape[0], self.L, self.k).astype(bool)
        return _pack_bits(bits)

    def bucket_ids(self, params, x: jax.Array, num_buckets: int) -> jax.Array:
        return _mix_words_to_bucket(self.codes(params, x), num_buckets)

    def p1(self, r: float) -> float:
        return 1.0 - float(r) / float(self.dim_bits)

    def p1_code(self, r: float) -> float:
        return self.p1(r) ** self.k


def make_family(metric: str, *, d: int, L: int, r: float, delta: float = 0.1,
                k: int | None = None, w: float | None = None):
    """Build the family the paper pairs with ``metric`` at radius ``r``.

    Mirrors the paper's experiment section: SimHash / bit sampling derive k
    from (L, delta, p1(r)); the p-stable families use the paper's fixed
    (k, w) presets unless overridden.
    """
    if metric == "cosine":
        fam = SimHash(d=d, L=L, k=1)
        kk = k or k_from_delta(fam.p1(r), L, delta)
        return SimHash(d=d, L=L, k=kk)
    if metric == "hamming":
        fam = BitSampling(dim_bits=d, L=L, k=1)
        kk = k or k_from_delta(fam.p1(r), L, delta)
        return BitSampling(dim_bits=d, L=L, k=kk)
    if metric == "l2":
        return PStableL2(d=d, L=L, k=k or 7, w=w or 2.0 * r)
    if metric == "l1":
        return PStableL1(d=d, L=L, k=k or 8, w=w or 4.0 * r)
    raise ValueError(f"unknown metric {metric!r}")
