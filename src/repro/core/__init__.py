"""Core library: the paper's Hybrid LSH r-NN reporting data structure.

Public surface:
  * ``HybridLSHIndex``  — single-host build/query (Algorithms 1 + 2)
  * ``core.engine``     — the segment engine every index composes:
                          ``QueryEngine`` + ``Segment`` implementations
  * ``core.distributed`` — mesh-sharded index with pmax-merged HLLs
  * ``core.lsh``        — LSH families + CSR tables
  * ``core.hll``        — HyperLogLog sketches
  * ``core.cost_model`` — Eq. (1)/(2) + calibration
  * ``core.multiprobe`` — query-directed multi-probe extension
"""
from repro.core.cost_model import CostModel, PAPER_PRESETS, calibrate
from repro.core.engine import (QueryEngine, RouteEstimate, SegmentEstimate,
                               TableSegment, estimate_routes,
                               finalize_route)
from repro.core.index import HybridLSHIndex, QueryResult

__all__ = ["CostModel", "PAPER_PRESETS", "calibrate", "HybridLSHIndex",
           "QueryResult", "RouteEstimate", "estimate_routes",
           "QueryEngine", "SegmentEstimate", "TableSegment",
           "finalize_route"]
