"""repro.obs — the observability substrate (docs/observability.md).

Three surfaces, bundled by ``Observability``:

  * ``MetricsRegistry`` — thread-safe counters / gauges / fixed-bucket
    histograms with a near-zero-cost disabled mode (``metrics``).
  * ``QueryTracer``     — per-query route spans: estimated vs actual
    candSize, chosen strategy, probes, and the derived misroute rate —
    the paper's Eq. (1)/(2) cost model as a live calibration signal
    (``trace``).
  * ``EventLog``        — bounded ring buffer of compaction/driver
    lifecycle events: freeze, merge_scheduled, swap, rebalance,
    flush_barrier, ... (``events``).

Export helpers: ``to_prometheus`` text exposition (``export``) and the
documented stats-key schemas (``schema``).

Ownership: ``RetrievalService`` creates one enabled bundle and hands
it to its index + driver; indexes built directly default to a fresh
*disabled* bundle, so nothing pays for observability unless asked.
The query fast path additionally short-circuits on ``tracer.enabled``
— toggling that flag flips tracing at runtime without a rebuild.
"""
from __future__ import annotations

import dataclasses

from repro.obs.events import EventLog, NULL_EVENTS
from repro.obs.export import to_prometheus
from repro.obs.metrics import (DEFAULT_TIME_BUCKETS, Counter, Gauge,
                               Histogram, MetricsRegistry, NULL_REGISTRY,
                               WorkPhases, time_block)
from repro.obs.trace import SPAN_FIELDS, QueryTracer

__all__ = ["Observability", "MetricsRegistry", "NULL_REGISTRY", "Counter",
           "Gauge", "Histogram", "WorkPhases", "time_block",
           "DEFAULT_TIME_BUCKETS", "QueryTracer", "SPAN_FIELDS",
           "EventLog", "NULL_EVENTS", "to_prometheus"]


@dataclasses.dataclass
class Observability:
    """One bundle of the three surfaces, shared index ↔ driver ↔ service."""

    registry: MetricsRegistry
    tracer: QueryTracer
    events: EventLog
    enabled: bool = True

    @classmethod
    def create(cls, enabled: bool = True, *, trace_capacity: int = 256,
               events_capacity: int = 512,
               per_segment_timing: bool = False,
               trace_sample_every: int = 16) -> "Observability":
        """Build a bundle; ``enabled=False`` builds the no-op variant
        (null registry instruments, tracer/events short-circuit).
        ``trace_sample_every`` — trace every Nth query batch (1 traces
        all; see QueryTracer's docstring for the cost model)."""
        registry = MetricsRegistry(enabled=enabled)
        return cls(
            registry=registry,
            tracer=QueryTracer(registry, capacity=trace_capacity,
                               per_segment_timing=per_segment_timing,
                               enabled=enabled,
                               sample_every=trace_sample_every),
            events=EventLog(capacity=events_capacity, enabled=enabled),
            enabled=enabled)

    @classmethod
    def disabled(cls) -> "Observability":
        """A fresh no-op bundle (the default for bare indexes).

        Fresh — not a shared singleton — so enabling one index's
        tracer later (``obs.tracer.enabled = True``) can never
        silently enable another's.
        """
        return cls.create(enabled=False)
