"""The documented stats schemas — the contract dashboards build on.

``RetrievalService.stats`` / ``index_stats()`` / ``CompactionDriver.
stats()`` are consumed by the BENCH emitters, the CI assert blocks,
and any scraping dashboard; a silently renamed key breaks all of them
after merge instead of in review.  These frozensets are asserted
exact (``==``, not ``<=``) by ``tests/test_obs.py`` /
``tests/test_serve.py``: adding a key is a deliberate, reviewed edit
here, in the producer, and in docs/observability.md together.
"""
from __future__ import annotations

__all__ = ["RETRIEVAL_SERVICE_KEYS", "COMPACTION_STATS_KEYS",
           "INDEX_STATS_KEYS", "SHARDED_INDEX_EXTRA_KEYS",
           "DRIVER_STATS_KEYS", "SCHEDULER_STATS_KEYS",
           "SCHEDULER_TENANT_KEYS", "CACHE_STATS_KEYS",
           "COLLECTION_STATS_KEYS", "COLLECTION_MANAGER_KEYS",
           "CHECKPOINT_STATS_KEYS", "WORK_PHASE_KEYS",
           "EVENT_BASE_FIELDS", "retrieval_stats_keys"]

# RetrievalService's own serving counters (before the index_stats
# merge); "scheduler", "cache", and "collections" are sub-dicts pinned
# below (the collections sub-dict is present unconditionally — empty
# manager, stable schema)
RETRIEVAL_SERVICE_KEYS = frozenset({
    "queries", "linear_served", "frac_linear",
    "compaction_ticks", "idle_ticks", "index_size",
    "scheduler", "cache", "collections"})

# ShapeBucketScheduler.stats() — the coalescing/admission view;
# "tenants" is the per-collection sub-dict pinned below
SCHEDULER_STATS_KEYS = frozenset({
    "queue_depth", "submits", "rejects", "batches", "requests_batched",
    "ticks", "queue_wait_sum_s", "queue_wait_max_s",
    "max_batch", "max_wait_s", "max_queue", "tenants"})

# stats["scheduler"]["tenants"][<collection>] — one tenant's
# token-bucket + drain view
SCHEDULER_TENANT_KEYS = frozenset({
    "submits", "rejects", "batched", "queue_depth", "tokens",
    "rate", "burst", "weight", "queue_wait_max_s"})

# ResultCache.stats() — the version-keyed result cache view
CACHE_STATS_KEYS = frozenset({
    "hits", "misses", "puts", "evictions", "stale_drops",
    "entries", "bytes", "max_bytes", "hit_rate"})

# CompactionStats.as_dict() — shared by both streaming indexes
COMPACTION_STATS_KEYS = frozenset({
    "compactions", "freezes", "last_reason", "last_seconds",
    "total_seconds", "rows_dropped", "rows_frozen", "rows_moved",
    "compact_steps", "last_merge_steps", "merges_per_level",
    "rows_merged_per_level"})

# DynamicHybridIndex.index_stats() (sharded adds the extras below)
INDEX_STATS_KEYS = frozenset({
    "n_live", "n_main", "n_main_dead", "delta_count", "delta_live",
    "delta_capacity", "segments", "levels", "pending_merges",
    "inserts", "deletes", "work_seconds"}) | COMPACTION_STATS_KEYS

SHARDED_INDEX_EXTRA_KEYS = frozenset({
    "shards", "level_n_pads", "live_per_shard", "delta_per_shard",
    "shard_skew", "placement", "routing"})

# CompactionDriver.stats() — index-derived fields aggregate over the
# attached collection pool; "fairness" maps collection -> worker ops
DRIVER_STATS_KEYS = frozenset({
    "worker_alive", "pending_gathers", "staged_rows", "staged_ready",
    "budget_rows", "stage_calls", "prepares", "drains", "applied",
    "flushes", "cuts", "worker_errors", "collections", "fairness",
    "work_seconds"})

# CheckpointManager.stats() — the incremental-snapshot ledger:
# chunks/bytes written vs reused (content-address hit rate), GC and
# litter-sweep counts, and the last save/restore wall times
CHECKPOINT_STATS_KEYS = frozenset({
    "saves", "incremental_saves", "chunks_written", "chunks_reused",
    "bytes_written", "bytes_reused", "chunks_gced", "litter_swept",
    "steps_kept", "last_save_seconds", "last_restore_seconds"})

# CollectionManager.stats()["collections"][<name>] — one tenant's view
COLLECTION_STATS_KEYS = frozenset({
    "n_live", "version", "segments", "pending_merges", "delta_live",
    "queries", "linear_served", "inserts", "deletes",
    "quota_rate", "quota_burst", "quota_weight"})

# CollectionManager.stats() top level
COLLECTION_MANAGER_KEYS = frozenset({
    "n_collections", "created_total", "dropped_total", "collections"})

# WorkPhases.as_dict() — the compaction work-seconds sub-dict
WORK_PHASE_KEYS = frozenset({"stage", "build", "apply", "full", "total"})

# every EventLog entry carries at least these
EVENT_BASE_FIELDS = frozenset({"seq", "ts", "kind"})


def retrieval_stats_keys(*, sharded: bool = False,
                         driver: bool = False) -> frozenset:
    """Exact key set of ``RetrievalService.stats`` for a configuration."""
    keys = RETRIEVAL_SERVICE_KEYS | INDEX_STATS_KEYS
    if sharded:
        keys |= SHARDED_INDEX_EXTRA_KEYS
    if driver:
        keys |= {"driver"}
    return keys
