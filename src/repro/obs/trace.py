"""Per-query trace spans through the route decision + misroute rate.

The paper's Algorithm 2 picks LSH-probing or a linear scan per query
from an *estimated* candSize (the per-bucket HyperLogLogs).  This
module turns that choice into a live calibration signal: for every
traced query the engine records what the estimator said (``cand_est``,
``lsh_cost_est``) and what actually happened (``cand_actual`` — the
distinct candidates the LSH route's gather produces, cap-truncated,
exact in the delta), then re-prices Eq. (1) with the actual candSize:

  lsh_cost_actual = alpha * collisions + beta * cand_actual

A query is a **misroute** when the chosen strategy did more work than
the alternative would have cost under actual terms:

  * routed LSH     and  lsh_cost_actual > linear_cost  (should've scanned)
  * routed linear  and  lsh_cost_actual < linear_cost  (should've probed)

with a tiny relative margin so exact cost ties never flag.
``linear_cost`` needs no "actual" counterpart — Eq. (2) is
deterministic in ``n_scan``.  Force-overridden queries
(``force="lsh"|"linear"``) get spans but are excluded from the
misroute rate: the router didn't choose, so the rate would not be
measuring the estimator.  The misroute rate is therefore exactly the
fraction of routed queries whose HLL estimate crossed the Eq. (1)/(2)
boundary in the wrong direction — nonzero on any mixed-density corpus
with borderline queries, and the first thing to watch when tuning
``beta_over_alpha`` or the HLL register count ``m``.

Span fields (``SPAN_FIELDS``; docs/observability.md has the schema):
``strategy``, ``forced``, ``collisions``, ``cand_est``,
``cand_actual``, ``lsh_cost_est``, ``lsh_cost_actual``,
``linear_cost``, ``probes``, ``misroute``.

Granularity: spans are per query; wall-time *phase* timings
(``estimate`` / ``search_lsh`` / ``search_linear`` / ``count_actual``)
are per batch (the engine executes routed groups batched, so per-query
wall time does not exist), as are the optional per-segment timings
(``per_segment_timing=True`` — searches each segment separately with
device syncs; measurably slower, debug only).  Per-level merge/freeze
timings live in the event log, not here.

Cost: a *traced* batch is not free — the ``count_candidates`` pass
that prices the actual candidate set is real device work (roughly the
gather+dedupe half of an LSH search), and the phase timings insert
device syncs that cost pipelining.  The tracer therefore **samples**:
with ``sample_every=N`` only every Nth query batch takes the traced
path; the other N-1 run the byte-identical fast path (results never
differ — tracing is observation only).  The default ``N=16`` keeps the
steady-state overhead of an *enabled* tracer under the 5% budget
(benchmarks/obs_bench.py measures both the sampled and the
every-batch figure); ``sample_every=1`` traces everything, for debug
sessions and for the benchmark's misroute measurement.  Calibration
aggregates (misroute rate, rel-error) are computed over traced batches
only — an unbiased sample, since sampling is by arrival order, not by
content.

Thread safety: ``record_batch`` takes the tracer lock once per batch;
registry instruments carry their own locks.  The engine's untraced
path never calls in (it short-circuits on ``enabled``).
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry

__all__ = ["QueryTracer", "SPAN_FIELDS"]

SPAN_FIELDS = ("strategy", "forced", "collisions", "cand_est",
               "cand_actual", "lsh_cost_est", "lsh_cost_actual",
               "linear_cost", "probes", "misroute")

# relative slack: an actual cost within this of the alternative is a
# tie, not a misroute (exact equality happens on integer-valued costs)
_TIE_MARGIN = 1e-6


class QueryTracer:
    """Ring buffer of per-query route spans + calibration aggregates."""

    def __init__(self, registry: MetricsRegistry, capacity: int = 256,
                 per_segment_timing: bool = False, enabled: bool = True,
                 sample_every: int = 16):
        self.enabled = bool(enabled)
        self.per_segment_timing = bool(per_segment_timing)
        self.capacity = max(int(capacity), 1)
        self.sample_every = max(int(sample_every), 1)
        self._lock = threading.Lock()
        self._calls = 0            # query batches seen while enabled
        self._sampled = 0          # of those, batches actually traced
        self._spans: deque = deque(maxlen=self.capacity)
        self._batches: deque = deque(maxlen=64)   # batch-level phase info
        # cumulative aggregates (never ring-evicted)
        self._queries = 0          # routed (non-forced) queries
        self._misroutes = 0
        self._forced = 0
        self._by_route = {"lsh": {"queries": 0, "misroutes": 0,
                                  "rel_err_sum": 0.0},
                          "linear": {"queries": 0, "misroutes": 0,
                                     "rel_err_sum": 0.0}}
        # registry series (null instruments when the registry is off)
        self._m_queries = {
            s: registry.counter("repro_queries_total",
                                help="queries served, by chosen route",
                                labels={"route": s})
            for s in ("lsh", "linear")}
        self._m_misroutes = {
            s: registry.counter(
                "repro_misroutes_total",
                help="queries whose chosen route cost more than the "
                     "alternative under actual candSize",
                labels={"route": s})
            for s in ("lsh", "linear")}
        self._m_rel_err = {
            s: registry.histogram(
                "repro_cand_rel_error",
                buckets=(0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.0, 10.0),
                help="|cand_est - cand_actual| / max(cand_actual, 1)",
                labels={"route": s})
            for s in ("lsh", "linear")}
        # phase histograms are labeled (phase, impl) so the exposition
        # shows which kernel backend served each route (the fused Pallas
        # path on TPU, the jnp oracles elsewhere); series are created
        # lazily per observed backend (get-or-create is cheap)
        self._registry = registry
        self._m_phase: Dict[tuple, object] = {}
        self._last_impl: Optional[str] = None
        # multi-tenant context: extra fields stamped on every span
        # recorded while set (e.g. {"collection": name}); the serving
        # layer sets it around a collection's query — control-thread
        # only, like the query path itself
        self._context: Dict[str, object] = {}

    def set_context(self, **fields) -> None:
        """Stamp ``fields`` on subsequently recorded spans (pass
        nothing to clear).  ``RetrievalService`` brackets each
        collection's index query with
        ``set_context(collection=name)`` so one shared tracer's spans
        stay attributable per tenant."""
        self._context = {k: v for k, v in fields.items() if v is not None}

    def _phase_hist(self, phase: str, impl: str):
        key = (phase, impl)
        h = self._m_phase.get(key)
        if h is None:
            h = self._registry.histogram(
                "repro_query_phase_seconds",
                help="wall seconds per traced query batch, by phase and "
                     "kernel impl",
                labels={"phase": phase, "impl": impl})
            self._m_phase[key] = h
        return h

    # ------------------------------------------------------------ sample
    def sample(self) -> bool:
        """One call per query batch: True → the engine takes the traced
        path for this batch.  Every ``sample_every``-th call samples
        (the first always does, so short-lived tracers still trace)."""
        with self._lock:
            hit = (self._calls % self.sample_every) == 0
            self._calls += 1
            if hit:
                self._sampled += 1
        return hit

    # ------------------------------------------------------------ record
    def record_batch(self, *, use_lsh: np.ndarray, collisions: np.ndarray,
                     cand_est: np.ndarray, cand_actual: np.ndarray,
                     lsh_cost_est: np.ndarray, lsh_cost_actual: np.ndarray,
                     linear_cost: float, probes: int,
                     forced: Optional[str],
                     phase_seconds: Dict[str, float],
                     segment_seconds: Optional[Dict[str, list]] = None,
                     kernel_impl: Optional[str] = None
                     ) -> None:
        """Fold one engine batch into spans + aggregates.

        All per-query arrays are (Q,) host numpy; ``linear_cost`` is
        the batch's scalar Eq. (2) cost; ``forced`` is the engine's
        strategy override (those queries get spans but do not count
        toward the misroute rate); ``kernel_impl`` is the resolved
        kernel backend (``ops.resolve_impl``) that served the search
        phases — it labels the phase histograms.
        """
        use = np.asarray(use_lsh, bool)
        nq = int(use.shape[0])
        lin = float(linear_cost)
        margin = _TIE_MARGIN * max(abs(lin), 1.0)
        lsh_act = np.asarray(lsh_cost_actual, np.float64)
        # chosen-lsh misroute: did more work than the known linear cost;
        # chosen-linear misroute: probing would have been cheaper
        mis = np.where(use, lsh_act > lin + margin, lsh_act < lin - margin)
        rel_err = (np.abs(np.asarray(cand_est, np.float64)
                          - np.asarray(cand_actual, np.float64))
                   / np.maximum(np.asarray(cand_actual, np.float64), 1.0))

        spans = []
        ctx = dict(self._context)
        for i in range(nq):
            strat = "lsh" if use[i] else "linear"
            spans.append({
                **ctx,
                "strategy": strat,
                "forced": forced is not None,
                "collisions": int(collisions[i]),
                "cand_est": float(cand_est[i]),
                "cand_actual": int(cand_actual[i]),
                "lsh_cost_est": float(lsh_cost_est[i]),
                "lsh_cost_actual": float(lsh_act[i]),
                "linear_cost": lin,
                "probes": int(probes),
                "misroute": bool(mis[i]),
            })

        with self._lock:
            self._spans.extend(spans)
            self._last_impl = kernel_impl
            self._batches.append({
                "n_queries": nq, "forced": forced,
                "phase_seconds": dict(phase_seconds),
                "segment_seconds": segment_seconds,
                "kernel_impl": kernel_impl,
            })
            if forced is None:
                self._queries += nq
                self._misroutes += int(mis.sum())
                for s in ("lsh", "linear"):
                    sel = use if s == "lsh" else ~use
                    agg = self._by_route[s]
                    agg["queries"] += int(sel.sum())
                    agg["misroutes"] += int(mis[sel].sum())
                    agg["rel_err_sum"] += float(rel_err[sel].sum())
            else:
                self._forced += nq

        for s in ("lsh", "linear"):
            sel = use if s == "lsh" else ~use
            k = int(sel.sum())
            if k and forced is None:
                self._m_queries[s].inc(k)
                self._m_misroutes[s].inc(int(mis[sel].sum()))
                for e in rel_err[sel]:
                    self._m_rel_err[s].observe(float(e))
        impl_label = kernel_impl or "auto"
        for p, sec in phase_seconds.items():
            self._phase_hist(p, impl_label).observe(float(sec))

    # ----------------------------------------------------------- readout
    @property
    def misroute_rate(self) -> float:
        with self._lock:
            return self._misroutes / max(self._queries, 1)

    def spans(self, limit: Optional[int] = None,
              strategy: Optional[str] = None) -> List[Dict[str, object]]:
        """Newest-last copies of retained spans."""
        with self._lock:
            out = list(self._spans)
        if strategy is not None:
            out = [s for s in out if s["strategy"] == strategy]
        if limit is not None:
            out = out[-int(limit):]
        return [dict(s) for s in out]

    def summary(self) -> Dict[str, object]:
        """Cumulative calibration aggregates (JSON-serializable)."""
        with self._lock:
            by_route = {}
            for s, agg in self._by_route.items():
                q = agg["queries"]
                by_route[s] = {
                    "queries": q,
                    "misroutes": agg["misroutes"],
                    "misroute_rate": agg["misroutes"] / max(q, 1),
                    "cand_rel_err_mean": agg["rel_err_sum"] / max(q, 1),
                }
            last = self._batches[-1] if self._batches else None
            return {
                "sample_every": self.sample_every,
                "batches_seen": self._calls,
                "batches_traced": self._sampled,
                "queries": self._queries,
                "misroutes": self._misroutes,
                "misroute_rate": self._misroutes / max(self._queries, 1),
                "forced_queries": self._forced,
                "frac_lsh": (by_route["lsh"]["queries"]
                             / max(self._queries, 1)),
                "kernel_impl": self._last_impl,
                "by_route": by_route,
                "spans_retained": len(self._spans),
                "last_batch": dict(last) if last else None,
            }
