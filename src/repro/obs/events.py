"""Bounded ring-buffer event log for compaction/driver lifecycle.

Replaces ad-hoc timing plumbing as the *narrative* record of index
maintenance: each entry is one structured lifecycle event —

  kind               emitted by                        payload fields
  ────────────────── ───────────────────────────────── ─────────────────
  freeze             index ``_freeze``                 rows, reason
  merge_scheduled    index ``_schedule_merges``        uids, target_level,
                                                       reason
  swap               index merge absorption            target_level, rows,
                                                       dropped, steps,
                                                       seconds, reason
  rebalance          sharded merge swap (moved > 0)    rows_moved,
                                                       target_level
  full_compact       index ``compact()``               reason, dropped,
                                                       seconds
  stage_ready        driver worker (staging complete)  staged_rows
  flush_barrier      driver ``flush()``                applied
  driver_start /     driver lifecycle                  name, budget_rows /
  driver_stop                                          name, flush
  shutdown           ``RetrievalService.shutdown``     flush

Every event additionally carries ``seq`` (monotone, counts *all*
events ever emitted — so ``seq - len(log)`` is the number evicted by
the ring bound) and ``ts`` (``time.time()`` wall clock).

Thread safety: ``emit`` may be called from the serving thread and the
``CompactionDriver`` worker concurrently; a single lock guards the
deque and the sequence counter.  Disabled logs short-circuit before
taking the lock.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

__all__ = ["EventLog", "NULL_EVENTS"]


class EventLog:
    def __init__(self, capacity: int = 512, enabled: bool = True):
        self.capacity = max(int(capacity), 1)
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._events: List[Dict[str, object]] = []
        self._seq = 0

    def emit(self, kind: str, **fields) -> None:
        """Append one event; O(1), bounded by ``capacity``."""
        if not self.enabled:
            return
        ev: Dict[str, object] = {"seq": 0, "ts": time.time(), "kind": kind}
        ev.update(fields)
        with self._lock:
            ev["seq"] = self._seq
            self._seq += 1
            self._events.append(ev)
            if len(self._events) > self.capacity:
                del self._events[:len(self._events) - self.capacity]

    def events(self, kind: Optional[str] = None,
               limit: Optional[int] = None) -> List[Dict[str, object]]:
        """Newest-last copies of retained events, optionally filtered by
        ``kind`` and truncated to the most recent ``limit``."""
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e["kind"] == kind]
        if limit is not None:
            evs = evs[-int(limit):]
        return [dict(e) for e in evs]

    def counts_by_kind(self) -> Dict[str, int]:
        """kind -> count over *retained* events (ring-bounded)."""
        out: Dict[str, int] = {}
        with self._lock:
            for e in self._events:
                k = str(e["kind"])
                out[k] = out.get(k, 0) + 1
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    @property
    def seq(self) -> int:
        """Total events ever emitted (evicted ones included)."""
        with self._lock:
            return self._seq

    @property
    def dropped(self) -> int:
        """Events evicted by the ring bound."""
        with self._lock:
            return self._seq - len(self._events)


NULL_EVENTS = EventLog(capacity=1, enabled=False)
