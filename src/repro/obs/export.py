"""Prometheus-style text exposition for a ``MetricsRegistry``.

Implements the text format subset dashboards actually scrape: one
``# HELP`` / ``# TYPE`` header per metric name, one sample line per
series, histograms expanded to cumulative ``_bucket{le=...}`` +
``_sum`` + ``_count``.  No external client library — the format is
five line templates, and the CI image must not grow a dependency for
them (see docs/observability.md#exposition-format).
"""
from __future__ import annotations

from typing import Dict, List

from repro.obs.metrics import MetricsRegistry

__all__ = ["to_prometheus"]


def _fmt_labels(labels, extra: Dict[str, str] = ()) -> str:
    pairs = list(labels) + list(dict(extra).items() if extra else [])
    if not pairs:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in pairs)
    return "{" + inner + "}"


def _escape(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r"\"").replace(
        "\n", r"\n")


def _fmt_value(v: float) -> str:
    f = float(v)
    if f == float("inf"):
        return "+Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def to_prometheus(registry: MetricsRegistry) -> str:
    """Render every registered series in exposition text format."""
    lines: List[str] = []
    seen_header = set()
    for inst in registry.collect():
        if inst.name not in seen_header:
            seen_header.add(inst.name)
            if inst.help:
                lines.append(f"# HELP {inst.name} {_escape(inst.help)}")
            lines.append(f"# TYPE {inst.name} {inst.kind}")
        if inst.kind in ("counter", "gauge"):
            lines.append(
                f"{inst.name}{_fmt_labels(inst.labels)} "
                f"{_fmt_value(inst.value)}")
        else:   # histogram
            for le, c in inst.cumulative():
                lines.append(
                    f"{inst.name}_bucket"
                    f"{_fmt_labels(inst.labels, {'le': _fmt_value(le)})} "
                    f"{c}")
            lines.append(f"{inst.name}_sum{_fmt_labels(inst.labels)} "
                         f"{_fmt_value(inst.sum)}")
            lines.append(f"{inst.name}_count{_fmt_labels(inst.labels)} "
                         f"{inst.count}")
    return "\n".join(lines) + ("\n" if lines else "")
