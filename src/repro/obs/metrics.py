"""Thread-safe metrics registry: counters, gauges, fixed-bucket histograms.

Design constraints (docs/observability.md):

  * Thread safety — instruments are written from the serving thread
    AND the ``CompactionDriver`` worker.  Every mutable instrument
    carries its own small lock; reads (``snapshot``/``collect``) take
    the same locks per instrument, so a snapshot is per-series
    coherent without a global pause.
  * Near-zero-cost disabled mode — a registry built with
    ``enabled=False`` hands out shared *null* instruments whose
    mutators are empty methods.  Callers keep unconditional
    ``counter.inc()`` call sites; the disabled cost is one no-op
    method call, and the hot query path (``core.engine``) additionally
    short-circuits on ``tracer.enabled`` so it pays nothing at all.
  * Fixed buckets — histograms take their upper bounds at creation
    (Prometheus-style cumulative ``le`` buckets with an implicit
    ``+Inf``); no dynamic resizing, so ``observe`` is O(#buckets).

``WorkPhases`` is the timer-accumulator the streaming stack uses for
merge work: one named phase per half of the compaction pipeline
(stage / build / apply / full), accumulated via ``time_block`` so each
interval is measured exactly once and reported identically wherever it
surfaces (``index_stats()["work_seconds"]`` and the driver ``stats()``
sub-dict read the same accumulator).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "NULL_REGISTRY", "WorkPhases", "time_block",
           "DEFAULT_TIME_BUCKETS"]

# decade ladder for wall-time histograms (seconds)
DEFAULT_TIME_BUCKETS: Tuple[float, ...] = (
    1e-5, 1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0)

Labels = Tuple[Tuple[str, str], ...]


def _series_key(name: str, labels: Labels) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone float counter (inc-only)."""

    kind = "counter"
    __slots__ = ("name", "labels", "help", "_lock", "_value")

    def __init__(self, name: str, labels: Labels = (), help: str = ""):
        self.name, self.labels, self.help = name, labels, help
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only increase")
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Set/add float gauge."""

    kind = "gauge"
    __slots__ = ("name", "labels", "help", "_lock", "_value")

    def __init__(self, name: str, labels: Labels = (), help: str = ""):
        self.name, self.labels, self.help = name, labels, help
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, v: float) -> None:
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Fixed-bucket cumulative histogram (Prometheus ``le`` semantics).

    ``buckets`` are sorted upper bounds; an implicit ``+Inf`` bucket
    catches the rest.  ``counts[i]`` is *non*-cumulative per bucket
    internally; ``cumulative()`` folds them for exposition.
    """

    kind = "histogram"
    __slots__ = ("name", "labels", "help", "buckets", "_lock", "_counts",
                 "_sum", "_count")

    def __init__(self, name: str, buckets: Sequence[float],
                 labels: Labels = (), help: str = ""):
        self.name, self.labels, self.help = name, labels, help
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.buckets) + 1)   # +Inf tail
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for b in self.buckets:          # few fixed buckets: linear scan
            if v <= b:
                break
            i += 1
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    def time(self) -> "time_block":
        """Context manager observing the block's wall time."""
        return time_block(histogram=self)

    def cumulative(self) -> List[Tuple[float, int]]:
        """[(le, cumulative count)] including the +Inf bucket."""
        with self._lock:
            counts = list(self._counts)
        out, running = [], 0
        for b, c in zip(self.buckets + (float("inf"),), counts):
            running += c
            out.append((b, running))
        return out

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def count(self) -> int:
        with self._lock:
            return self._count


class _NullCounter:
    kind = "counter"
    name, labels, help, value = "null", (), "", 0.0

    def inc(self, v: float = 1.0) -> None:
        pass


class _NullGauge:
    kind = "gauge"
    name, labels, help, value = "null", (), "", 0.0

    def set(self, v: float) -> None:
        pass

    def add(self, v: float) -> None:
        pass


class _NullHistogram:
    kind = "histogram"
    name, labels, help = "null", (), ""
    buckets: Tuple[float, ...] = (1.0,)
    sum, count = 0.0, 0

    def observe(self, v: float) -> None:
        pass

    def time(self) -> "time_block":
        return time_block()

    def cumulative(self) -> List[Tuple[float, int]]:
        return []


_NULL_COUNTER = _NullCounter()
_NULL_GAUGE = _NullGauge()
_NULL_HISTOGRAM = _NullHistogram()


class MetricsRegistry:
    """Named instrument factory + snapshot/collect surface.

    ``counter``/``gauge``/``histogram`` are get-or-create: the same
    (name, labels) pair always returns the same instrument, so call
    sites can re-resolve by name instead of threading objects around.
    Re-requesting a name as a different kind raises.  Disabled
    registries return the shared null instruments (no allocation, no
    state, no locks).
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._instruments: Dict[Tuple[str, Labels], object] = {}

    @staticmethod
    def _labels(labels: Optional[Dict[str, str]]) -> Labels:
        if not labels:
            return ()
        return tuple(sorted((str(k), str(v)) for k, v in labels.items()))

    def _get(self, name: str, labels: Labels, kind: str, factory):
        with self._lock:
            inst = self._instruments.get((name, labels))
            if inst is None:
                inst = factory()
                self._instruments[(name, labels)] = inst
            elif inst.kind != kind:
                raise TypeError(
                    f"{name!r} already registered as {inst.kind}, "
                    f"requested {kind}")
            return inst

    def counter(self, name: str, help: str = "",
                labels: Optional[Dict[str, str]] = None) -> Counter:
        if not self.enabled:
            return _NULL_COUNTER
        lb = self._labels(labels)
        return self._get(name, lb, "counter",
                         lambda: Counter(name, lb, help))

    def gauge(self, name: str, help: str = "",
              labels: Optional[Dict[str, str]] = None) -> Gauge:
        if not self.enabled:
            return _NULL_GAUGE
        lb = self._labels(labels)
        return self._get(name, lb, "gauge", lambda: Gauge(name, lb, help))

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
                  help: str = "",
                  labels: Optional[Dict[str, str]] = None) -> Histogram:
        if not self.enabled:
            return _NULL_HISTOGRAM
        lb = self._labels(labels)
        return self._get(name, lb, "histogram",
                         lambda: Histogram(name, buckets, lb, help))

    def collect(self) -> List[object]:
        """Instruments sorted by (name, labels) — the exposition order."""
        with self._lock:
            items = sorted(self._instruments.items())
        return [inst for _, inst in items]

    def snapshot(self) -> Dict[str, object]:
        """JSON-serializable dump of every series."""
        out: Dict[str, object] = {"enabled": self.enabled,
                                  "counters": {}, "gauges": {},
                                  "histograms": {}}
        for inst in self.collect():
            key = _series_key(inst.name, inst.labels)
            if inst.kind == "counter":
                out["counters"][key] = inst.value
            elif inst.kind == "gauge":
                out["gauges"][key] = inst.value
            else:
                out["histograms"][key] = {
                    "buckets": [[le, c] for le, c in inst.cumulative()],
                    "sum": inst.sum, "count": inst.count}
        return out


NULL_REGISTRY = MetricsRegistry(enabled=False)


class WorkPhases:
    """Thread-safe named wall-time accumulators (seconds per phase).

    The one home of compaction work-seconds: ``SegmentStack`` /
    ``ShardedDynamicHybridIndex`` add each measured interval exactly
    once (via ``time_block``), and every reporting surface —
    ``index_stats()``, the driver ``stats()`` sub-dict — reads the
    same accumulator, so staged (worker) and control-thread halves can
    never double-count.
    """

    def __init__(self, *phases: str):
        self._lock = threading.Lock()
        self._seconds: Dict[str, float] = {p: 0.0 for p in phases}

    def add(self, phase: str, seconds: float) -> None:
        with self._lock:
            self._seconds[phase] = self._seconds.get(phase, 0.0) + seconds

    def as_dict(self) -> Dict[str, float]:
        with self._lock:
            out = dict(self._seconds)
        out["total"] = sum(out.values())
        return out

    @property
    def total(self) -> float:
        with self._lock:
            return sum(self._seconds.values())


class time_block:
    """Measure a block's wall time once; fan the interval out.

    ``elapsed`` is set on exit; optional sinks: a ``Histogram``
    (``observe``) and/or a ``WorkPhases`` accumulator (``add(phase)``).
    Exceptions propagate (the interval is still recorded).
    """

    __slots__ = ("histogram", "phases", "phase", "t0", "elapsed")

    def __init__(self, histogram=None, phases: Optional[WorkPhases] = None,
                 phase: Optional[str] = None):
        self.histogram = histogram
        self.phases = phases
        self.phase = phase
        self.t0 = 0.0
        self.elapsed = 0.0

    def __enter__(self) -> "time_block":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.elapsed = time.perf_counter() - self.t0
        if self.histogram is not None:
            self.histogram.observe(self.elapsed)
        if self.phases is not None and self.phase is not None:
            self.phases.add(self.phase, self.elapsed)
