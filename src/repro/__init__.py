"""repro — Hybrid LSH (Pham, 2016) as a first-class feature of a
multi-pod JAX training/serving framework."""
__version__ = "0.1.0"
