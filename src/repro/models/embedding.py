"""Vocab-sharded embedding, chunked cross-entropy, greedy sampling.

Large-vocab rules (nemotron 256k, gemma3 262k, llama4 202k):
  * the (V, D) tables are sharded over the 'model' axis on V;
  * logits are NEVER materialized as (B, S, V): the loss runs over seq
    chunks inside a scan, each chunk computing LOCAL (B, C, V/m) logits
    and reducing with a log-sum-exp psum over the vocab shards;
  * decode samples greedily from local argmaxes + a pmax/pmin merge.

Without a mesh (unit tests) every function falls back to dense ops.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.common import dense_init


def init_table(key, vocab: int, d: int, dtype=jnp.bfloat16):
    return dense_init(key, (vocab, d), 1, dtype=dtype)


# ----------------------------------------------------------------- embed
def embed(table: jax.Array, ids: jax.Array, par) -> jax.Array:
    """table: (V, D) model-sharded on V; ids: (B, S) -> (B, S, D)."""
    if not (par is not None and par.active):
        return table[ids]
    mesh = par.mesh
    ma = par.model_axis
    v_loc = table.shape[0] // par.n_model

    def local(tab, ids_):
        off = jax.lax.axis_index(ma) * v_loc
        lid = ids_ - off
        ok = (lid >= 0) & (lid < v_loc)
        emb = tab[jnp.clip(lid, 0, v_loc - 1)]
        emb = jnp.where(ok[..., None], emb, 0)
        return jax.lax.psum(emb, ma)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(ma, None), P(par.batch(), None)),
                   out_specs=P(par.batch(), None, None),
                   check_rep=False)
    return fn(table, ids)


# ------------------------------------------------------------------ loss
def softmax_xent(head: jax.Array, h: jax.Array, labels: jax.Array, par,
                 chunk: int = 2048) -> jax.Array:
    """Mean CE of h @ head.T vs labels, seq-chunked, vocab-shard-aware.

    h: (B, S, D); labels: (B, S) with -1 = ignore.  Returns scalar f32.
    """
    b, s, d = h.shape
    c = min(chunk, s)
    nc = s // c
    assert s % c == 0, (s, c)

    def chunk_loss(hc, lc):
        """hc: (B, C, D); lc: (B, C) -> (sum_loss, count)."""
        if par is not None and par.active:
            mesh, ma = par.mesh, par.model_axis
            v_loc = head.shape[0] // par.n_model

            def local(hd_, hc_, lc_):
                off = jax.lax.axis_index(ma) * v_loc
                logits = jnp.einsum("bcd,vd->bcv", hc_.astype(jnp.float32),
                                    hd_.astype(jnp.float32))
                # Global max via all_gather (differentiable, unlike
                # pmax) + stop_gradient: the max shift cancels
                # analytically in d(logsumexp).
                m_loc = jnp.max(logits, axis=-1)
                m = jax.lax.stop_gradient(jnp.max(
                    jax.lax.all_gather(m_loc, ma), axis=0))
                se = jax.lax.psum(
                    jnp.sum(jnp.exp(logits - m[..., None]), axis=-1), ma)
                lid = lc_ - off
                ok = (lid >= 0) & (lid < v_loc)
                lab = jnp.take_along_axis(
                    logits, jnp.clip(lid, 0, v_loc - 1)[..., None],
                    axis=-1)[..., 0]
                lab = jax.lax.psum(jnp.where(ok, lab, 0.0), ma)
                return jnp.log(se) + m - lab                   # (B, C)

            fn = shard_map(
                local, mesh=mesh,
                in_specs=(P(ma, None), P(par.batch(), None, None),
                          P(par.batch(), None)),
                out_specs=P(par.batch(), None),
                check_rep=False)
            nll = fn(head, hc, lc)
        else:
            logits = jnp.einsum("bcd,vd->bcv", hc.astype(jnp.float32),
                                head.astype(jnp.float32))
            nll = (jax.nn.logsumexp(logits, axis=-1)
                   - jnp.take_along_axis(
                       logits, jnp.clip(lc, 0, None)[..., None],
                       axis=-1)[..., 0])
        valid = lc >= 0
        return (jnp.sum(jnp.where(valid, nll, 0.0)),
                jnp.sum(valid.astype(jnp.float32)))

    chunk_loss = jax.checkpoint(chunk_loss)  # recompute logits in bwd

    def body(acc, inp):
        hc, lc = inp
        sl, cnt = chunk_loss(hc, lc)
        return (acc[0] + sl, acc[1] + cnt), None

    hs = h.reshape(b, nc, c, d).swapaxes(0, 1)
    ls = labels.reshape(b, nc, c).swapaxes(0, 1)
    (total, count), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                     (hs, ls))
    return total / jnp.maximum(count, 1.0)


# --------------------------------------------------------------- decode
def greedy_sample(head: jax.Array, h_last: jax.Array, par) -> jax.Array:
    """argmax_v (h_last @ head.T).  h_last: (B, D) -> (B,) int32."""
    if not (par is not None and par.active):
        logits = h_last.astype(jnp.float32) @ head.astype(jnp.float32).T
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    mesh, ma = par.mesh, par.model_axis
    v_loc = head.shape[0] // par.n_model

    def local(hd_, hl_):
        off = jax.lax.axis_index(ma) * v_loc
        logits = hl_.astype(jnp.float32) @ hd_.astype(jnp.float32).T
        loc_max = jnp.max(logits, axis=-1)
        loc_arg = jnp.argmax(logits, axis=-1).astype(jnp.int32) + off
        g_max = jax.lax.pmax(loc_max, ma)
        cand = jnp.where(loc_max >= g_max, loc_arg, jnp.int32(2**30))
        return jax.lax.pmin(cand, ma)

    bspec = par.batch()
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(ma, None), P(bspec, None)),
                   out_specs=P(bspec),
                   check_rep=False)
    return fn(head, h_last)
