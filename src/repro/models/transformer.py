"""Model assembly: block-pattern transformer/SSM/MoE/hybrid LMs.

A model is ``len(pattern)`` heterogeneous layers compiled inline,
``lax.scan``'d over ``n_repeats`` stacked weight slices, plus an
unstacked ``tail`` — one code path covers all 10 assigned archs (see
configs/base.py).  Three entry points:

  forward_train(params, batch, cfg, par)  -> (loss, metrics)
  prefill(params, batch, cfg, par, cache_len) -> (h_last, caches, lengths)
  decode_step(params, caches, token, lengths, cfg, par, memory)
      -> (h_last, caches)

Decode keeps the stacked caches in the loop carry and updates them with
dynamic_update_index (in-place under donation), so cache memory is not
doubled by scan ys buffers — this matters at 32k/500k contexts.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, CROSS, MAMBA1, MAMBA2, MOE, SHARED_ATTN,
                                SWA, ArchConfig)
from repro.models import attention as attn_lib
from repro.models import embedding as emb_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.common import (dense_init, mlp_apply, mlp_init, mlp_specs,
                                 rmsnorm, rmsnorm_init)
from repro.models.parallel import ParallelConfig

# ===================================================================== init

def _init_layer(key, kind: str, cfg: ArchConfig):
    d, dt = cfg.d_model, cfg.param_dtype
    ks = jax.random.split(key, 6)
    if kind == SHARED_ATTN:
        return {"marker": jnp.zeros((1,), dt)}  # weights live in "shared"
    out = {"norm1": rmsnorm_init(d, dt)}
    if kind in (ATTN, SWA, MOE, CROSS):
        out["attn"] = attn_lib.init_attn(ks[0], d, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.hd, dt)
        out["norm2"] = rmsnorm_init(d, dt)
        if kind == MOE:
            out["moe"] = moe_lib.init_moe(ks[1], d, cfg.d_ff,
                                          cfg.moe.num_experts, dt)
        else:
            out["mlp"] = mlp_init(ks[1], d, cfg.d_ff, dt)
        if kind == CROSS:
            out["normx"] = rmsnorm_init(d, dt)
            out["xattn"] = attn_lib.init_attn(ks[2], d, cfg.n_heads,
                                              cfg.n_kv_heads, cfg.hd, dt)
    elif kind == MAMBA1:
        s = cfg.ssm
        out["mixer"] = ssm_lib.init_mamba1(ks[0], d, s.d_state, s.expand,
                                           s.d_conv, s.dt_rank, dt)
    elif kind == MAMBA2:
        s = cfg.ssm
        out["mixer"] = ssm_lib.init_mamba2(ks[0], d, s.d_state, s.expand,
                                           s.d_conv, s.head_dim, dt)
    else:
        raise ValueError(kind)
    return out


def _layer_specs(kind: str, cfg: ArchConfig, par: ParallelConfig,
                 stacked: bool = True):
    st = (None,) if stacked else ()
    if kind == SHARED_ATTN:
        return {"marker": st}
    out = {"norm1": st}
    if kind in (ATTN, SWA, MOE, CROSS):
        out["attn"] = attn_lib.attn_specs(par, stacked)
        out["norm2"] = st
        if kind == MOE:
            out["moe"] = moe_lib.moe_specs(par, stacked)
        else:
            out["mlp"] = mlp_specs(par, stacked)
        if kind == CROSS:
            out["normx"] = st
            out["xattn"] = attn_lib.attn_specs(par, stacked)
    elif kind == MAMBA1:
        out["mixer"] = ssm_lib.mamba1_specs(par, stacked)
    elif kind == MAMBA2:
        out["mixer"] = ssm_lib.mamba2_specs(par, stacked)
    return out


def init_params(cfg: ArchConfig, key: jax.Array) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    d, dt = cfg.d_model, cfg.param_dtype
    r = cfg.n_repeats

    blocks = []
    for i, kind in enumerate(cfg.pattern):
        ks = jax.random.split(jax.random.fold_in(keys[0], i), r)
        blocks.append(jax.vmap(lambda k: _init_layer(k, kind, cfg))(ks))
    tail = [_init_layer(jax.random.fold_in(keys[1], i), kind, cfg)
            for i, kind in enumerate(cfg.tail)]

    params: Dict[str, Any] = {
        "embed": emb_lib.init_table(keys[2], cfg.vocab, d, dt),
        "blocks": tuple(blocks),
        "tail": tuple(tail),
        "final_norm": rmsnorm_init(d, dt),
        "lm_head": emb_lib.init_table(keys[3], cfg.vocab, d, dt),
    }
    if SHARED_ATTN in cfg.pattern + cfg.tail:
        params["shared"] = {
            "norm1": rmsnorm_init(d, dt),
            "attn": attn_lib.init_attn(keys[4], d, cfg.n_heads,
                                       cfg.n_kv_heads, cfg.hd, dt),
            "norm2": rmsnorm_init(d, dt),
            "mlp": mlp_init(keys[5], d, cfg.d_ff, dt),
        }
    if cfg.encoder_layers:
        ks = jax.random.split(keys[6], cfg.encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: _init_layer(k, ATTN, cfg))(ks),
            "final_norm": rmsnorm_init(d, dt),
        }
    if cfg.num_image_tokens:
        params["img_proj"] = dense_init(keys[7], (d, d), 0, dtype=dt)
    return params


def param_specs(cfg: ArchConfig, par: ParallelConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "embed": par.w_vocab(),
        "blocks": tuple(_layer_specs(k, cfg, par, True)
                        for k in cfg.pattern),
        "tail": tuple(_layer_specs(k, cfg, par, False) for k in cfg.tail),
        "final_norm": (),
        "lm_head": par.w_vocab(),
    }
    if SHARED_ATTN in cfg.pattern + cfg.tail:
        specs["shared"] = {
            "norm1": (), "attn": attn_lib.attn_specs(par, False),
            "norm2": (), "mlp": mlp_specs(par, False),
        }
    if cfg.encoder_layers:
        specs["encoder"] = {"blocks": _layer_specs(ATTN, cfg, par, True),
                            "final_norm": ()}
    if cfg.num_image_tokens:
        specs["img_proj"] = (par.fsdp_axis(),
                             par.model_axis if par.active else None)
    return specs


# ============================================================ train/forward

def _attn_kwargs(cfg: ArchConfig, par: ParallelConfig):
    return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
                rope_theta=cfg.rope_theta, chunk_q=par.attn_chunk_q,
                chunk_k=par.attn_chunk_k, remat_qchunk=par.attn_remat,
                probs_bf16=par.attn_probs_bf16, par=par)


def _apply_layer_train(kind: str, lp, h, positions, cfg, par, memory,
                       shared, causal: bool = True):
    eps = cfg.norm_eps
    aux = jnp.float32(0)
    p = shared if kind == SHARED_ATTN else lp
    if kind in (ATTN, SWA, MOE, CROSS, SHARED_ATTN):
        window = cfg.sliding_window if kind == SWA else 0
        a = attn_lib.self_attention(
            p["attn"], rmsnorm(h, p["norm1"], eps), positions,
            causal=causal, window=window, **_attn_kwargs(cfg, par))
        h = h + a
        if kind == CROSS:
            x = attn_lib.self_attention(
                lp["xattn"], rmsnorm(h, lp["normx"], eps), positions,
                causal=False, memory=memory, **_attn_kwargs(cfg, par))
            h = h + x
        h2 = rmsnorm(h, p["norm2"], eps)
        if kind == MOE:
            mo, aux = moe_lib.moe_apply(
                lp["moe"], h2, top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor, act=cfg.mlp_act, par=par)
            h = h + mo
        else:
            h = h + mlp_apply(p["mlp"], h2, cfg.mlp_act)
    elif kind == MAMBA1:
        s = cfg.ssm
        y = ssm_lib.mamba1_block(
            lp["mixer"], rmsnorm(h, lp["norm1"], eps), d_state=s.d_state,
            chunk=s.chunk, dt_rank=s.dt_rank or -(-cfg.d_model // 16),
            remat=par.ssm_remat)
        h = h + y
    elif kind == MAMBA2:
        s = cfg.ssm
        y = ssm_lib.mamba2_block(
            lp["mixer"], rmsnorm(h, lp["norm1"], eps), d_state=s.d_state,
            head_dim=s.head_dim, chunk=s.chunk, norm_eps=eps,
            remat=par.ssm_remat)
        h = h + y
    else:
        raise ValueError(kind)
    return h, aux


def _encode(params, frames, cfg, par):
    """Whisper-style bidirectional encoder over stub frame embeddings."""
    h = frames.astype(cfg.param_dtype)
    pos = jnp.arange(h.shape[1], dtype=jnp.int32)

    def body(hh, bp):
        hh, _ = _apply_layer_train(ATTN, bp, hh, pos, cfg, par, None, None,
                                   causal=False)
        return par.shard_activations(hh), None

    if par.remat == "block":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["encoder"]["blocks"])
    return rmsnorm(h, params["encoder"]["final_norm"], cfg.norm_eps)


def _memory(params, batch, cfg, par):
    if cfg.encoder_layers:
        return _encode(params, batch["frames"], cfg, par)
    if cfg.num_image_tokens:
        img = batch["image_embeds"].astype(cfg.param_dtype)
        return par.shard_activations(img @ params["img_proj"])
    return None


def _backbone(params, h, positions, cfg, par, memory):
    """Scan the block pattern + tail. h: (B, S, D) -> (h, aux_sum)."""
    shared = params.get("shared")

    def body(hh, bps):
        aux = jnp.float32(0)
        for i, kind in enumerate(cfg.pattern):
            hh, a = _apply_layer_train(kind, bps[i], hh, positions, cfg,
                                       par, memory, shared)
            aux += a
        return par.shard_activations(hh), aux

    if par.remat == "block":
        body = jax.checkpoint(body)
    h, auxs = jax.lax.scan(body, h, params["blocks"])
    aux = jnp.sum(auxs)
    for i, kind in enumerate(cfg.tail):
        h, a = _apply_layer_train(kind, params["tail"][i], h, positions,
                                  cfg, par, memory, shared)
        aux += a
    return par.shard_activations(h), aux


def forward_train(params, batch, cfg: ArchConfig, par: ParallelConfig):
    """batch: tokens (B,S), labels (B,S) [+frames/image_embeds]."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = emb_lib.embed(params["embed"], tokens, par)
    h = par.shard_activations(h)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    memory = _memory(params, batch, cfg, par)
    h, aux = _backbone(params, h, positions, cfg, par, memory)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    loss = emb_lib.softmax_xent(params["lm_head"], h, batch["labels"], par,
                                chunk=par.logits_chunk)
    total = loss + 0.01 * aux
    return total, {"ce_loss": loss, "aux_loss": aux}


def forward_embed(params, batch, cfg: ArchConfig, par: ParallelConfig):
    """Mean-pooled final-hidden embedding (the retrieval encoder path).

    Returns (B, D) f32, L2-normalized — the vectors the Hybrid LSH
    index stores/queries in serve.retrieval.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = emb_lib.embed(params["embed"], tokens, par)
    h = par.shard_activations(h)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    memory = _memory(params, batch, cfg, par)
    h, _ = _backbone(params, h, positions, cfg, par, memory)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    emb = jnp.mean(h.astype(jnp.float32), axis=1)
    return emb / jnp.maximum(jnp.linalg.norm(emb, axis=-1, keepdims=True),
                             1e-9)


# =============================================================== caches

def _cache_for(kind: str, cfg: ArchConfig, b: int, cache_len: int,
               memory_len: int, stacked_r: int, par: ParallelConfig):
    """ShapeDtype template of one pattern position's decode cache."""
    dt = cfg.param_dtype
    hkv, hd = cfg.n_kv_heads, cfg.hd

    def z(*shape, dtype=dt):
        lead = (stacked_r,) if stacked_r else ()
        return jnp.zeros(lead + shape, dtype)

    if kind in (ATTN, MOE, SHARED_ATTN, CROSS):
        c = {"k": z(b, cache_len, hkv, hd), "v": z(b, cache_len, hkv, hd)}
        if kind == CROSS:
            c["mem_k"] = z(b, memory_len, hkv, hd)
            c["mem_v"] = z(b, memory_len, hkv, hd)
        return c
    if kind == SWA:
        w = min(cfg.sliding_window, cache_len)
        return {"k": z(b, w, hkv, hd), "v": z(b, w, hkv, hd)}
    s = cfg.ssm
    di = s.expand * cfg.d_model
    conv_ch = di if kind == MAMBA1 else di + 2 * s.d_state
    nh = di // s.head_dim
    ssm_state = ((b, di, s.d_state) if kind == MAMBA1
                 else (b, nh, s.head_dim, s.d_state))
    return {"conv": z(b, s.d_conv - 1, conv_ch),
            "ssm": z(*ssm_state, dtype=jnp.float32)}


def init_caches(cfg: ArchConfig, b: int, cache_len: int,
                par: ParallelConfig, memory_len: int = 0):
    r = cfg.n_repeats
    return {
        "blocks": tuple(_cache_for(k, cfg, b, cache_len, memory_len, r, par)
                        for k in cfg.pattern),
        "tail": tuple(_cache_for(k, cfg, b, cache_len, memory_len, 0, par)
                      for k in cfg.tail),
    }


def cache_specs(cfg: ArchConfig, par: ParallelConfig):
    """PartitionSpec pytree matching init_caches output."""
    if not par.active:
        return jax.tree_util.tree_map(lambda _: (), init_specs_placeholder())

    batch = par.batch()
    seqax = par.decode_seq_shard or None

    def spec_for(kind, stacked):
        st = (None,) if stacked else ()
        if kind in (ATTN, MOE, SHARED_ATTN, CROSS):
            if par.decode_kv_head_shard:
                kv = st + (batch, None, par.model_axis, None)
            else:
                kv = st + (batch, seqax, None, None)
            c = {"k": kv, "v": kv}
            if kind == CROSS:
                c["mem_k"] = st + (batch, None, None, None)
                c["mem_v"] = st + (batch, None, None, None)
            return c
        if kind == SWA:
            kv = st + (batch, None, None, None)
            return {"k": kv, "v": kv}
        ma = par.model_axis
        if kind == MAMBA1:
            return {"conv": st + (batch, None, ma),
                    "ssm": st + (batch, ma, None)}
        return {"conv": st + (batch, None, ma),
                "ssm": st + (batch, ma, None, None)}

    return {
        "blocks": tuple(spec_for(k, True) for k in cfg.pattern),
        "tail": tuple(spec_for(k, False) for k in cfg.tail),
    }


def init_specs_placeholder():
    return {"blocks": (), "tail": ()}


# ============================================================== prefill

def _prefill_layer(kind, lp, h, positions, cfg, par, memory, shared,
                   cache_len):
    """Apply layer and emit its decode cache."""
    eps = cfg.norm_eps
    b, s, _ = h.shape
    p = shared if kind == SHARED_ATTN else lp
    cache = {}
    if kind in (ATTN, SWA, MOE, CROSS, SHARED_ATTN):
        window = cfg.sliding_window if kind == SWA else 0
        a, k, v = attn_lib.self_attention(
            p["attn"], rmsnorm(h, p["norm1"], eps), positions,
            causal=True, window=window, return_kv=True,
            **_attn_kwargs(cfg, par))
        h = h + a
        if kind == SWA:
            w = min(cfg.sliding_window, cache_len)
            kw, vw = k[:, s - w:], v[:, s - w:]
            slots = (positions[0, s - w:] % w)
            ck = jnp.zeros((b, w) + k.shape[2:], k.dtype)
            cache = {"k": ck.at[:, slots].set(kw),
                     "v": ck.at[:, slots].set(vw)}
        else:
            ck = jnp.zeros((b, cache_len) + k.shape[2:], k.dtype)
            cache = {"k": jax.lax.dynamic_update_slice(
                         ck, k, (0, 0, 0, 0)),
                     "v": jax.lax.dynamic_update_slice(
                         ck, v, (0, 0, 0, 0))}
        if kind == CROSS:
            x, mk, mv = attn_lib.self_attention(
                lp["xattn"], rmsnorm(h, lp["normx"], eps), positions,
                causal=False, memory=memory, return_kv=True,
                **_attn_kwargs(cfg, par))
            h = h + x
            cache["mem_k"], cache["mem_v"] = mk, mv
        h2 = rmsnorm(h, p["norm2"], eps)
        if kind == MOE:
            mo, _ = moe_lib.moe_apply(
                lp["moe"], h2, top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor, act=cfg.mlp_act, par=par)
            h = h + mo
        else:
            h = h + mlp_apply(p["mlp"], h2, cfg.mlp_act)
    elif kind in (MAMBA1, MAMBA2):
        s_ = cfg.ssm
        if kind == MAMBA1:
            y, state = ssm_lib.mamba1_block(
                lp["mixer"], rmsnorm(h, lp["norm1"], eps),
                d_state=s_.d_state, chunk=s_.chunk,
                dt_rank=s_.dt_rank or -(-cfg.d_model // 16),
                return_state=True)
        else:
            y, state = ssm_lib.mamba2_block(
                lp["mixer"], rmsnorm(h, lp["norm1"], eps),
                d_state=s_.d_state, head_dim=s_.head_dim, chunk=s_.chunk,
                norm_eps=eps, return_state=True)
        h = h + y
        cache = state
    return h, cache


def prefill(params, batch, cfg: ArchConfig, par: ParallelConfig,
            cache_len: int):
    """Process the prompt, build decode caches.

    Returns (h_last (B, D), caches, lengths (B,)).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = emb_lib.embed(params["embed"], tokens, par)
    h = par.shard_activations(h)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    memory = _memory(params, batch, cfg, par)
    shared = params.get("shared")

    def body(hh, bps):
        caches = []
        for i, kind in enumerate(cfg.pattern):
            hh, c = _prefill_layer(kind, bps[i], hh, positions, cfg, par,
                                   memory, shared, cache_len)
            caches.append(c)
        return par.shard_activations(hh), tuple(caches)

    h, block_caches = jax.lax.scan(body, h, params["blocks"])
    tail_caches = []
    for i, kind in enumerate(cfg.tail):
        h, c = _prefill_layer(kind, params["tail"][i], h, positions, cfg,
                              par, memory, shared, cache_len)
        tail_caches.append(c)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    lengths = jnp.full((b,), s, jnp.int32)
    return (h[:, -1], {"blocks": block_caches, "tail": tuple(tail_caches)},
            lengths)


# =============================================================== decode

def _decode_layer(kind, lp, h, cache, lengths, cfg, par, shared):
    eps = cfg.norm_eps
    p = shared if kind == SHARED_ATTN else lp
    if kind in (ATTN, SWA, MOE, CROSS, SHARED_ATTN):
        window = cfg.sliding_window if kind == SWA else 0
        out, new_sa = attn_lib.decode_self_attention(
            p["attn"], rmsnorm(h, p["norm1"], eps), cache, lengths,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
            rope_theta=cfg.rope_theta, par=par,
            seq_axes=() if kind == SWA else par.decode_seq_shard,
            window=window)
        h = h + out
        new_cache = dict(cache)
        new_cache.update(new_sa)
        if kind == CROSS:
            x = attn_lib.decode_cross_attention(
                lp["xattn"], rmsnorm(h, lp["normx"], eps),
                {"k": cache["mem_k"], "v": cache["mem_v"]},
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd)
            h = h + x
        h2 = rmsnorm(h, p["norm2"], eps)
        if kind == MOE:
            mo, _ = moe_lib.moe_apply(
                lp["moe"], h2[:, None], top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor, act=cfg.mlp_act, par=par)
            h = h + mo[:, 0]
        else:
            h = h + mlp_apply(p["mlp"], h2, cfg.mlp_act)
        return h, new_cache
    s_ = cfg.ssm
    if kind == MAMBA1:
        y, st = ssm_lib.mamba1_decode(
            lp["mixer"], rmsnorm(h, lp["norm1"], eps), cache,
            d_state=s_.d_state,
            dt_rank=s_.dt_rank or -(-cfg.d_model // 16))
    else:
        y, st = ssm_lib.mamba2_decode(
            lp["mixer"], rmsnorm(h, lp["norm1"], eps), cache,
            d_state=s_.d_state, head_dim=s_.head_dim, norm_eps=eps)
    return h + y, st


def decode_step(params, caches, token: jax.Array, lengths: jax.Array,
                cfg: ArchConfig, par: ParallelConfig):
    """One token for the whole batch.  token: (B,) -> (h_last, caches)."""
    h = emb_lib.embed(params["embed"], token[:, None], par)[:, 0]
    shared = params.get("shared")
    r = cfg.n_repeats

    def body(i, carry):
        h, bc = carry
        take = functools.partial(jax.lax.dynamic_index_in_dim, index=i,
                                 axis=0, keepdims=False)
        new_caches = []
        for pos, kind in enumerate(cfg.pattern):
            lp = jax.tree_util.tree_map(take, params["blocks"][pos])
            cache = jax.tree_util.tree_map(take, bc[pos])
            h, nc = _decode_layer(kind, lp, h, cache, lengths, cfg, par,
                                  shared)
            new_caches.append(nc)
        put = lambda full, new: jax.lax.dynamic_update_index_in_dim(
            full, new.astype(full.dtype), i, 0)
        bc = tuple(jax.tree_util.tree_map(put, bc[pos], new_caches[pos])
                   for pos in range(len(cfg.pattern)))
        return (h, bc)

    h, block_caches = jax.lax.fori_loop(
        0, r, body, (h, tuple(caches["blocks"])))

    tail_caches = []
    for i, kind in enumerate(cfg.tail):
        h, nc = _decode_layer(kind, params["tail"][i], h,
                              caches["tail"][i], lengths, cfg, par, shared)
        tail_caches.append(nc)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return h, {"blocks": block_caches, "tail": tuple(tail_caches)}
