"""Attention for the model zoo.

Train/prefill: blockwise ("flash-style") attention as a pure-JAX
online-softmax scan over KV chunks — O(S * chunk) activation memory so
the 32k prefill and 4k train cells have credible memory_analysis, and
the remat story stays simple.  Supports causal, sliding-window and
cross attention with GQA grouping.

Decode: single-token attention against a KV cache.  At scale the cache
seq dim is sharded (over 'model', and also 'data' when global_batch=1);
``flash_decode`` is a shard_map that computes local partial softmax
(m, l, o) per shard and merges with a log-sum-exp psum — flash-decoding
mapped onto jax.lax collectives.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.common import apply_rope, dense_init

_NEG = -1e30


# ----------------------------------------------------------------- params
def init_attn(key, d_model: int, n_heads: int, n_kv: int, hd: int,
              dtype=jnp.bfloat16):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d_model, n_heads * hd), 0, dtype=dtype),
        "wk": dense_init(kk, (d_model, n_kv * hd), 0, dtype=dtype),
        "wv": dense_init(kv, (d_model, n_kv * hd), 0, dtype=dtype),
        "wo": dense_init(ko, (n_heads * hd, d_model), 0, dtype=dtype),
    }


def attn_specs(par, stacked: bool = True):
    return {"wq": par.w_col(stacked), "wk": par.w_col(stacked),
            "wv": par.w_col(stacked), "wo": par.w_row(stacked)}


# ------------------------------------------------------------- blockwise
def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        q_pos: jax.Array, k_pos: jax.Array, *,
                        causal: bool, window: int = 0,
                        chunk_q: int = 512, chunk_k: int = 512,
                        remat_qchunk: bool = False,
                        probs_bf16: bool = False) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, Hkv, hd) -> (B, Sq, H, hd).

    Online softmax over KV chunks; masked chunks still execute (see
    EXPERIMENTS §Perf for the chunk-skipping optimization).

    remat_qchunk: recompute the per-q-chunk KV scan in the backward
    pass instead of saving the stacked (nk, B, Hkv, G, cq, ck) softmax
    intermediates — the flash-attention trade (EXPERIMENTS §Perf i1).
    probs_bf16: run the p @ v matmul with bf16 probabilities (m/l stats
    stay f32) — halves the dominant S^2 HBM traffic (§Perf i2).
    """
    b, sq, h, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv

    def _divisor_chunk(s, c):
        for d in range(min(c, s), 0, -1):
            if s % d == 0:
                return d
        return 1

    cq = _divisor_chunk(sq, chunk_q)
    ck = _divisor_chunk(sk, chunk_k)
    nq, nk = sq // cq, sk // ck
    scale = hd ** -0.5

    qg = q.reshape(b, nq, cq, hkv, g, hd).transpose(1, 0, 3, 4, 2, 5)
    # (nq, B, Hkv, G, cq, hd)
    kc = k.reshape(b, nk, ck, hkv, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, ck, hkv, hd).transpose(1, 0, 3, 2, 4)
    qp = q_pos.reshape(nq, cq)
    kp = k_pos.reshape(nk, ck)

    def q_chunk(args):
        qc, qpos = args                                 # (B,Hkv,G,cq,hd)
        m0 = jnp.full((b, hkv, g, cq), _NEG, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, hd), jnp.float32)

        def kv_step(carry, inp):
            m, l, acc = carry
            kk, vv, kpos = inp                          # (B,Hkv,ck,hd)
            s = jnp.einsum("bngqh,bnkh->bngqk", qc, kk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(mask[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            if probs_bf16:
                p = p.astype(jnp.bfloat16)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bngqk,bnkh->bngqh", p, vv,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kc, vc, kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out                                       # (B,Hkv,G,cq,hd)

    if remat_qchunk:
        q_chunk = jax.checkpoint(q_chunk)
    out = jax.lax.map(q_chunk, (qg, qp))                 # (nq,B,Hkv,G,cq,hd)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def self_attention(params, x: jax.Array, positions: jax.Array, *,
                   n_heads: int, n_kv: int, hd: int, rope_theta: float,
                   causal: bool = True, window: int = 0,
                   chunk_q: int = 512, chunk_k: int = 512,
                   memory: Optional[jax.Array] = None,
                   memory_pos: Optional[jax.Array] = None,
                   return_kv: bool = False,
                   remat_qchunk: bool = False,
                   probs_bf16: bool = False,
                   par=None):
    """Full block: project -> rope -> blockwise attention -> out-proj.

    With ``memory`` set, k/v come from it (cross attention, no rope).
    With ``return_kv``, also returns the (post-rope) k, v for KV caches.
    """
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, n_heads, hd)
    src = x if memory is None else memory
    sk = src.shape[1]
    k = (src @ params["wk"]).reshape(b, sk, n_kv, hd)
    v = (src @ params["wv"]).reshape(b, sk, n_kv, hd)
    if memory is None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
        k_pos = positions[0] if positions.ndim > 1 else positions
    else:
        k_pos = (memory_pos if memory_pos is not None
                 else jnp.arange(sk, dtype=jnp.int32))
    q_pos = positions[0] if positions.ndim > 1 else positions
    if par is not None and par.active and par.attn_head_shard:
        # One explicit seq->head reshard per layer; without this the
        # partitioner re-derives shardings per chunk of the scan and
        # emits per-chunk all-to-alls (measured in §Perf i4).  Q shards
        # over query heads; K/V are pinned REPLICATED over the model
        # axis — with GQA, n_kv is often below the model-axis size and
        # letting the partitioner "shard" them produced repeated
        # replicate-repartition cycles (§Perf i5).  GQA K/V are small
        # (one gather of (B,S,n_kv,hd) per layer).
        q = par.shard(q, par.batch(), None, par.model_axis, None)
        k = par.shard(k, par.batch(), None, None, None)
        v = par.shard(v, par.batch(), None, None, None)
    out = blockwise_attention(q, k, v, q_pos, k_pos, causal=causal,
                              window=window, chunk_q=chunk_q,
                              chunk_k=chunk_k, remat_qchunk=remat_qchunk,
                              probs_bf16=probs_bf16)
    out = out.reshape(b, s, n_heads * hd)
    if par is not None and par.active and par.attn_head_shard:
        out = par.shard(out, par.batch(), None, par.model_axis)
    out = out @ params["wo"]
    if return_kv:
        return out, k, v
    return out


# --------------------------------------------------------------- decode
def _plain_decode(q, k_cache, v_cache, lengths, seq_offset=0):
    """q: (B, Hkv, G, hd); caches (B, S, Hkv, hd); lengths (B,) tokens valid."""
    b, s, hkv, hd = k_cache.shape
    scale = hd ** -0.5
    s_ = jnp.einsum("bngh,bsnh->bngs", q, k_cache,
                    preferred_element_type=jnp.float32) * scale
    pos = seq_offset + jnp.arange(s, dtype=jnp.int32)
    valid = pos[None, :] < lengths[:, None]              # (B, S)
    s_ = jnp.where(valid[:, None, None, :], s_, _NEG)
    m = jnp.max(s_, axis=-1)
    p = jnp.exp(s_ - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bngs,bsnh->bngh", p, v_cache,
                   preferred_element_type=jnp.float32)
    return m, l, o


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 lengths: jax.Array, par, *,
                 seq_axes: Tuple[str, ...] = ()) -> jax.Array:
    """Single-token attention vs a (possibly seq-sharded) KV cache.

    q: (B, H, hd); caches: (B, S, Hkv, hd); lengths: (B,).
    seq_axes: mesh axes sharding the cache's S dim.  Partial softmax per
    shard, log-sum-exp merge via pmax/psum (flash-decoding on ICI).
    """
    b, h, hd = q.shape
    hkv = k_cache.shape[2]
    qg = q.reshape(b, hkv, h // hkv, hd)

    if not (par is not None and par.active and seq_axes):
        m, l, o = _plain_decode(qg, k_cache, v_cache, lengths)
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(b, h, hd).astype(q.dtype)

    mesh = par.mesh
    n_shards = par.axis_size(seq_axes)
    s_loc = k_cache.shape[1] // n_shards
    batch_axes = tuple(a for a in par.batch_axes_
                       if a not in seq_axes) if b > 1 else ()

    def local(qg_, kc, vc, ln):
        rank = jnp.int32(0)
        for a in seq_axes:
            rank = rank * mesh.shape[a] + jax.lax.axis_index(a)
        m, l, o = _plain_decode(qg_, kc, vc, ln, seq_offset=rank * s_loc)
        mg = jax.lax.pmax(m, seq_axes)
        corr = jnp.exp(m - mg)
        lg = jax.lax.psum(l * corr, seq_axes)
        og = jax.lax.psum(o * corr[..., None], seq_axes)
        return og / jnp.maximum(lg, 1e-30)[..., None]

    bspec = batch_axes if batch_axes else None
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec), P(bspec, seq_axes), P(bspec, seq_axes),
                  P(bspec)),
        out_specs=P(bspec),
        check_rep=False)
    out = fn(qg, k_cache, v_cache, lengths)
    return out.reshape(b, h, hd).astype(q.dtype)


def decode_self_attention(params, x_tok: jax.Array, cache: dict,
                          lengths: jax.Array, *, n_heads: int, n_kv: int,
                          hd: int, rope_theta: float, par=None,
                          seq_axes: Tuple[str, ...] = (),
                          window: int = 0) -> Tuple[jax.Array, dict]:
    """One decode step.  x_tok: (B, D); cache: {"k","v"}: (B, S, Hkv, hd).

    Returns (out (B, D), updated cache).  With ``window`` the cache is a
    ring buffer of size window (slot = position % window).
    """
    b, _ = x_tok.shape
    q = (x_tok @ params["wq"]).reshape(b, 1, n_heads, hd)
    k = (x_tok @ params["wk"]).reshape(b, 1, n_kv, hd)
    v = (x_tok @ params["wv"]).reshape(b, 1, n_kv, hd)
    q = apply_rope(q, lengths[:, None], rope_theta)[:, 0]
    k = apply_rope(k, lengths[:, None], rope_theta)[:, 0]
    v = v[:, 0]

    s_cache = cache["k"].shape[1]
    slot = (lengths % s_cache) if window else lengths
    bidx = jnp.arange(b)
    k_cache = cache["k"].at[bidx, slot].set(k.astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, slot].set(v.astype(cache["v"].dtype))

    if window:
        # Ring buffer: every stored slot is within the window by
        # construction; valid slots are min(lengths+1, window).
        eff_len = jnp.minimum(lengths + 1, window)
        m, l, o = _plain_decode(q.reshape(b, n_kv, n_heads // n_kv, hd),
                                k_cache, v_cache, eff_len)
        out = (o / jnp.maximum(l, 1e-30)[..., None]).reshape(b, n_heads * hd)
    elif par is not None and par.active and par.decode_kv_head_shard:
        # KV-head-sharded decode: heads are independent, so no LSE
        # merge collective at all — each model rank attends over its
        # own head group with the FULL sequence (§Perf gemma3 decode).
        kvspec = (par.batch(), None, par.model_axis, None)
        k_cache = par.shard(k_cache, *kvspec)
        v_cache = par.shard(v_cache, *kvspec)
        qg = par.shard(q.reshape(b, n_kv, n_heads // n_kv, hd),
                       par.batch(), par.model_axis, None, None)
        m, l, o = _plain_decode(qg, k_cache, v_cache, lengths + 1)
        out = (o / jnp.maximum(l, 1e-30)[..., None]).reshape(b, n_heads * hd)
    else:
        out = flash_decode(q, k_cache, v_cache, lengths + 1, par,
                           seq_axes=seq_axes).reshape(b, n_heads * hd)
    out = out.astype(x_tok.dtype) @ params["wo"]
    return out, {"k": k_cache, "v": v_cache}


def decode_cross_attention(params, x_tok: jax.Array, memory_kv: dict,
                           *, n_heads: int, n_kv: int, hd: int) -> jax.Array:
    """Cross attention at decode: static precomputed memory K/V."""
    b, _ = x_tok.shape
    q = (x_tok @ params["wq"]).reshape(b, n_kv, n_heads // n_kv, hd)
    mlen = memory_kv["k"].shape[1]
    lengths = jnp.full((b,), mlen, jnp.int32)
    m, l, o = _plain_decode(q, memory_kv["k"], memory_kv["v"], lengths)
    out = (o / jnp.maximum(l, 1e-30)[..., None]).reshape(b, n_heads * hd)
    return out.astype(x_tok.dtype) @ params["wo"]
