"""Parallelism context threaded through the model zoo.

Model code never hardcodes a mesh: it receives a ``ParallelConfig`` and
calls ``shard()`` / ``pspec()`` helpers which no-op on a single device
(smoke tests) and emit sharding constraints / shard_map specs under the
production mesh.  Axis roles:

  data axes   ('pod', 'data') or ('data',)  — batch / fsdp axis
  model axis  'model'                        — tensor/expert parallel

Weight layout is FSDP + TP: 2-D weights are P(fsdp_axis, 'model') with
'model' on the contracted-out ("parallel") dim; stacked block weights
prepend None.  Activations are P(data_axes, 'model', None) between
blocks when ``seq_shard`` (Megatron-style sequence parallelism) is on.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ParallelConfig", "P"]


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    mesh: Optional[Mesh] = None
    data_axes: Tuple[str, ...] = ("data",)
    # batch_axes defaults to data_axes; set to () for global_batch too
    # small to shard (long_500k decode) while keeping fsdp on data_axes.
    batch_axes: Optional[Tuple[str, ...]] = None
    model_axis: str = "model"
    seq_shard: bool = True        # sequence-parallel activations
    fsdp: bool = True             # shard weight dim 0 over data axes
    remat: str = "block"          # none | block
    logits_chunk: int = 2048      # seq chunk for the CE loss
    attn_chunk_q: int = 512
    attn_chunk_k: int = 512
    decode_seq_shard: Tuple[str, ...] = ()  # axes sharding the KV seq dim
    grad_compression: bool = False
    # --- perf-iteration knobs (see EXPERIMENTS.md §Perf) ---
    attn_remat: bool = False      # rematerialize attention q-chunks in
    #                               bwd instead of saving stacked probs
    attn_probs_bf16: bool = False  # cast softmax probs to bf16 for p@v
    moe_local_dispatch: bool = False  # shard_map per-shard MoE sort
    attn_head_shard: bool = False  # pin q/k/v to head-sharding so the
    #                                seq<->head reshard happens once per
    #                                layer, not per chunk (§Perf i4)
    ssm_remat: bool = False       # recompute SSM chunk scans in bwd
    #                               (the attn_remat analogue for mamba)
    decode_kv_head_shard: bool = False  # shard decode KV caches by KV
    #                                head instead of seq: heads are
    #                                independent, so no LSE psum merge
    #                                is needed (requires n_kv % model
    #                                axis == 0; gemma3 decode §Perf)

    # ------------------------------------------------------------------
    @property
    def active(self) -> bool:
        return self.mesh is not None

    def axis_size(self, names: Sequence[str]) -> int:
        if not self.active:
            return 1
        s = 1
        for n in names:
            s *= self.mesh.shape[n]
        return s

    @property
    def n_data(self) -> int:
        return self.axis_size(self.data_axes)

    @property
    def n_model(self) -> int:
        return self.axis_size([self.model_axis])

    # ------------------------------------------------------------------
    def shard(self, x: jax.Array, *spec) -> jax.Array:
        """with_sharding_constraint if a mesh is active, else identity."""
        if not self.active:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    @property
    def batch_axes_(self) -> Tuple[str, ...]:
        return self.data_axes if self.batch_axes is None else self.batch_axes

    def batch(self):
        """Spec entry for a global-batch dimension."""
        return (self.batch_axes_ or None) if self.active else None

    def seq(self):
        """Spec entry for the sequence dim of inter-block activations."""
        return self.model_axis if (self.active and self.seq_shard) else None

    def fsdp_axis(self):
        return self.data_axes if (self.active and self.fsdp) else None

    def shard_activations(self, h: jax.Array) -> jax.Array:
        """(B, S, D) inter-block activation layout."""
        return self.shard(h, self.batch(), self.seq(), None)

    # Weight specs -----------------------------------------------------
    def w_col(self, stacked: bool = True):
        """(…, D, F) with F model-parallel (e.g. q/k/v/up projections)."""
        base = (self.fsdp_axis(), self.model_axis if self.active else None)
        return ((None,) if stacked else ()) + base

    def w_row(self, stacked: bool = True):
        """(…, F, D) with F model-parallel (e.g. out/down projections)."""
        base = (self.model_axis if self.active else None, self.fsdp_axis())
        return ((None,) if stacked else ()) + base

    def w_vocab(self, stacked: bool = False):
        """(V, D) embedding/lm_head — vocab-sharded over model axis."""
        base = (self.model_axis if self.active else None, self.fsdp_axis())
        return ((None,) if stacked else ()) + base

    def w_replicated(self, stacked: bool = True):
        return ((None,) if stacked else ())

    def put(self, x: jax.Array, *spec) -> jax.Array:
        return self.shard(x, *spec)


def spec_bytes(x) -> int:
    return x.size * x.dtype.itemsize
