"""Model zoo: block-pattern assembly covering all 10 assigned archs."""
from repro.models.parallel import ParallelConfig
from repro.models.transformer import (cache_specs, decode_step, forward_train,
                                      init_caches, init_params, param_specs,
                                      prefill)

__all__ = ["ParallelConfig", "cache_specs", "decode_step", "forward_train",
           "init_caches", "init_params", "param_specs", "prefill"]
