"""Mixture-of-Experts MLP with sort-based (gather/scatter) dispatch.

No (tokens, experts, capacity) one-hot tensors — at llama4 scale that
would be ~5e9 elements.  Instead: top-k routing -> argsort by expert ->
position-in-expert via running counts -> capacity clamp -> scatter into
an (E, C, D) buffer -> stacked-expert einsum -> unsort + weighted
combine.  Expert weights are sharded over the 'model' axis (expert
parallelism); the scatter/gather around the expert einsum induces XLA
all-to-alls between the token (data) and expert (model) shardings.

Tokens routed beyond capacity are dropped (standard capacity-factor
semantics); the router softmax keeps their probability mass out of the
combine, so the layer degrades gracefully.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def init_moe(key, d_model: int, d_ff: int, num_experts: int,
             dtype=jnp.bfloat16):
    kr, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, (d_model, num_experts), 0,
                             dtype=jnp.float32),
        "wi": dense_init(k1, (num_experts, d_model, d_ff), 1, dtype=dtype),
        "wg": dense_init(k2, (num_experts, d_model, d_ff), 1, dtype=dtype),
        "wo": dense_init(k3, (num_experts, d_ff, d_model), 1, dtype=dtype),
    }


def moe_specs(par, stacked: bool = True):
    st = (None,) if stacked else ()
    ma = par.model_axis if par.active else None
    fa = par.fsdp_axis()
    return {"router": st + (None, None),
            "wi": st + (ma, fa, None),
            "wg": st + (ma, fa, None),
            "wo": st + (ma, fa, None)}


def moe_apply(params, x: jax.Array, *, top_k: int, capacity_factor: float,
              act: str = "silu", par=None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    if par is not None and par.active and par.moe_local_dispatch \
            and x.shape[0] * x.shape[1] >= par.axis_size(par.batch_axes_):
        return _moe_apply_local(params, x, top_k=top_k,
                                capacity_factor=capacity_factor, act=act,
                                par=par)
    b, s, d = x.shape
    e = params["router"].shape[1]
    t = b * s
    xt = x.reshape(t, d)

    logits = xt.astype(jnp.float32) @ params["router"]       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, top_k)               # (T, K)

    # Load-balancing aux loss (Switch-style): E * sum_e f_e * p_e.
    density = jnp.mean(jax.nn.one_hot(expert[:, 0], e, dtype=jnp.float32),
                       axis=0)
    aux = e * jnp.sum(density * jnp.mean(probs, axis=0))

    flat_expert = expert.reshape(-1)                         # (T*K,)
    flat_gate = gate.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)

    capacity = int(capacity_factor * t * top_k / e) or 1
    order = jnp.argsort(flat_expert)                         # stable
    se, sg, stok = (flat_expert[order], flat_gate[order], flat_tok[order])
    # Position within expert group: index - start offset of that expert.
    counts = jnp.bincount(se, length=e)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * top_k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos < capacity

    buf = jnp.zeros((e, capacity, d), x.dtype)
    buf = buf.at[jnp.where(keep, se, 0),
                 jnp.where(keep, pos, 0)].add(
        jnp.where(keep[:, None], xt[stok], 0).astype(x.dtype))
    if par is not None and par.active:
        # Expert-parallel layout: all-to-all from token(data)- to
        # expert(model)-sharding happens at this boundary.
        buf = par.shard(buf, par.model_axis, None, None)

    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    g = jnp.einsum("ecd,edf->ecf", buf, params["wg"])
    if act == "silu":
        h = jax.nn.silu(g) * h
    else:
        h = jnp.square(jax.nn.relu(g)) * h
    y = jnp.einsum("ecf,efd->ecd", h, params["wo"])          # (E, C, D)

    expert_out = y[jnp.where(keep, se, 0), jnp.where(keep, pos, 0)]
    expert_out = jnp.where(keep[:, None], expert_out, 0)
    contrib = expert_out.astype(jnp.float32) * sg[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[stok].add(contrib)
    return out.reshape(b, s, d).astype(x.dtype), aux


def _moe_apply_local(params, x: jax.Array, *, top_k: int,
                     capacity_factor: float, act: str, par):
    """Per-data-shard dispatch (§Perf iteration: kill the global sort).

    The baseline path argsorts the GLOBAL (tokens x top_k) assignment
    array, which XLA partitions into a distributed sort — enormous
    collective traffic at 1M+ tokens.  Here each data shard sorts only
    its local tokens inside a shard_map (zero collectives), and the
    only cross-device movement left is the intended expert-parallel
    all-to-all of the (E, C, D) dispatch buffers.
    """
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e = params["router"].shape[1]
    mesh = par.mesh
    taxes = par.batch_axes_ or None
    n_shards = par.axis_size(par.batch_axes_)
    x = par.shard(x, par.batch(), None, None)
    xt = x.reshape(b * s, d)
    t_loc = (b * s) // n_shards
    cap = max(1, int(capacity_factor * t_loc * top_k / e))
    router = params["router"]

    def dispatch(xt_loc, router_):
        logits = xt_loc.astype(jnp.float32) @ router_
        probs = jax.nn.softmax(logits, axis=-1)
        gate, expert = jax.lax.top_k(probs, top_k)
        density = jnp.mean(jax.nn.one_hot(expert[:, 0], e,
                                          dtype=jnp.float32), axis=0)
        aux = e * jnp.sum(density * jnp.mean(probs, axis=0))
        if taxes:
            aux = jax.lax.pmean(aux, taxes)
        fe = expert.reshape(-1)
        fg = gate.reshape(-1)
        ft = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), top_k)
        order = jnp.argsort(fe)
        se, sg, st = fe[order], fg[order], ft[order]
        counts = jnp.bincount(se, length=e)
        starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                  jnp.cumsum(counts)[:-1]])
        pos = (jnp.arange(t_loc * top_k, dtype=jnp.int32)
               - starts[se].astype(jnp.int32))
        keep = pos < cap
        buf = jnp.zeros((e, cap, d), x.dtype)
        buf = buf.at[jnp.where(keep, se, 0),
                     jnp.where(keep, pos, 0)].add(
            jnp.where(keep[:, None], xt_loc[st], 0).astype(x.dtype))
        return buf, se, sg, st, pos, keep, aux

    dis = shard_map(
        dispatch, mesh=mesh,
        in_specs=(P(taxes, None), P(None, None)),
        out_specs=(P(None, taxes, None), P(taxes), P(taxes), P(taxes),
                   P(taxes), P(taxes), P()),
        check_rep=False)
    buf, se, sg, st, pos, keep, aux = dis(xt, router)

    # Expert-parallel einsum: the only collective is the E<->C all-to-all.
    buf = par.shard(buf, par.model_axis, None, None)
    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    g = jnp.einsum("ecd,edf->ecf", buf, params["wg"])
    if act == "silu":
        h = jax.nn.silu(g) * h
    else:
        h = jnp.square(jax.nn.relu(g)) * h
    y = jnp.einsum("ecf,efd->ecd", h, params["wo"])
    y = par.shard(y, None, par.batch(), None)

    def combine(y_loc, se_, sg_, st_, pos_, keep_):
        out_ = y_loc[jnp.where(keep_, se_, 0), jnp.where(keep_, pos_, 0)]
        out_ = jnp.where(keep_[:, None], out_, 0)
        contrib = out_.astype(jnp.float32) * sg_[:, None]
        return jnp.zeros((t_loc, d), jnp.float32).at[st_].add(contrib)

    comb = shard_map(
        combine, mesh=mesh,
        in_specs=(P(None, taxes, None), P(taxes), P(taxes), P(taxes),
                  P(taxes), P(taxes)),
        out_specs=P(taxes, None),
        check_rep=False)
    out = comb(y, se, sg, st, pos, keep)
    return out.reshape(b, s, d).astype(x.dtype), aux
