"""Mamba-1 and Mamba-2 (SSD) blocks, TPU-native.

No (B, S, d_inner, d_state) materialization: sequences are processed in
chunks with a ``lax.scan`` carrying the (B, d_inner, d_state) state.

  * Mamba-1: within-chunk ``lax.associative_scan`` over the diagonal
    recurrence h_t = exp(dt_t*A) h_{t-1} + dt_t B_t x_t (log-depth,
    numerically safe — no exp of positive cumsums).
  * Mamba-2: the SSD matmul form — intra-chunk decay-masked C B^T
    "attention" (MXU) + inter-chunk scalar-decay state recurrence.

Decode is the O(1) single-step recurrence; the state is the whole
"KV cache" (this is why the SSM/hybrid archs run the long_500k cell).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rmsnorm


# --------------------------------------------------------------- params
def init_mamba1(key, d_model: int, d_state: int, expand: int, d_conv: int,
                dt_rank: int, dtype=jnp.bfloat16):
    di = expand * d_model
    dtr = dt_rank or -(-d_model // 16)
    ks = jax.random.split(key, 8)
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * di), 0, dtype=dtype),
        "conv_w": dense_init(ks[1], (d_conv, di), 0, dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * d_state), 0, dtype=dtype),
        "dt_proj": dense_init(ks[3], (dtr, di), 0, dtype=dtype),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus ~ 0.01
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d_model), 0, dtype=dtype),
    }


def mamba1_specs(par, stacked: bool = True):
    st = (None,) if stacked else ()
    ma = par.model_axis if par.active else None
    fa = par.fsdp_axis()
    return {"in_proj": st + (fa, ma), "conv_w": st + (None, ma),
            "conv_b": st + (ma,), "x_proj": st + (ma, None),
            "dt_proj": st + (None, ma), "dt_bias": st + (ma,),
            "A_log": st + (ma, None), "D": st + (ma,),
            "out_proj": st + (ma, fa)}


def init_mamba2(key, d_model: int, d_state: int, expand: int, d_conv: int,
                head_dim: int, dtype=jnp.bfloat16):
    di = expand * d_model
    nh = di // head_dim
    ks = jax.random.split(key, 6)
    d_in = 2 * di + 2 * d_state + nh
    return {
        "in_proj": dense_init(ks[0], (d_model, d_in), 0, dtype=dtype),
        "conv_w": dense_init(ks[1], (d_conv, di + 2 * d_state), 0,
                             dtype=dtype),
        "conv_b": jnp.zeros((di + 2 * d_state,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -4.6, jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], (di, d_model), 0, dtype=dtype),
    }


def mamba2_specs(par, stacked: bool = True):
    st = (None,) if stacked else ()
    ma = par.model_axis if par.active else None
    fa = par.fsdp_axis()
    return {"in_proj": st + (fa, ma), "conv_w": st + (None, ma),
            "conv_b": st + (ma,), "A_log": st + (ma,),
            "dt_bias": st + (ma,), "D": st + (ma,),
            "gate_norm": st + (ma,), "out_proj": st + (ma, fa)}


# ----------------------------------------------------------------- conv
def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via kernel-size shifts. x: (B, S, C)."""
    k = w.shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi.astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def conv_step(x_new: jax.Array, conv_state: jax.Array, w: jax.Array,
              b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. x_new: (B, C); conv_state: (B, k-1, C)."""
    full = jnp.concatenate([conv_state, x_new[:, None]], axis=1)  # (B,k,C)
    y = jnp.einsum("bkc,kc->bc", full.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    return y.astype(x_new.dtype), full[:, 1:]


def _divisor_chunk(s: int, c: int) -> int:
    """Largest divisor of s that is <= c (chunked scans need s % k == 0)."""
    for d in range(min(c, s), 0, -1):
        if s % d == 0:
            return d
    return 1


# -------------------------------------------------------------- mamba-1
def mamba1_scan(xb, dt, bmat, cmat, a_neg, h0, chunk: int,
                remat: bool = False):
    """Chunked selective scan.

    xb, dt: (B, S, di); bmat, cmat: (B, S, N); a_neg: (di, N) (negative);
    h0: (B, di, N).  Returns (y (B, S, di), h_final).
    """
    b, s, di = xb.shape
    n = bmat.shape[-1]
    k = _divisor_chunk(s, chunk)
    nc = s // k

    def to_chunks(t):
        return t.reshape(b, nc, k, *t.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(xb.astype(jnp.float32)), to_chunks(dt.astype(jnp.float32)),
          to_chunks(bmat.astype(jnp.float32)),
          to_chunks(cmat.astype(jnp.float32)))

    def step(h, inp):
        xk, dtk, bk, ck = inp                     # (B,K,di) / (B,K,N)
        da = dtk[..., None] * a_neg               # (B,K,di,N)
        decay = jnp.exp(da)
        u = (dtk * xk)[..., None] * bk[:, :, None, :]
        u = u.at[:, 0].add(decay[:, 0] * h)

        def comb(lt, rt):
            al, bl = lt
            ar, br = rt
            return al * ar, ar * bl + br

        _, hs = jax.lax.associative_scan(comb, (decay, u), axis=1)
        y = jnp.einsum("bkdn,bkn->bkd", hs, ck)
        return hs[:, -1], y

    if remat:
        step = jax.checkpoint(step)
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    return y, h_final


def mamba1_block(params, x: jax.Array, *, d_state: int, chunk: int,
                 dt_rank: int, return_state: bool = False,
                 remat: bool = False):
    """Full Mamba-1 mixer. x: (B, S, D) -> (B, S, D).

    With ``return_state`` also returns the decode state
    {"conv": (B, k-1, di) pre-conv inputs, "ssm": (B, di, N)}.
    """
    b, s, _ = x.shape
    di = params["D"].shape[0]
    dtr = dt_rank
    xz = x @ params["in_proj"]
    xb_raw, z = jnp.split(xz, 2, axis=-1)
    xb = jax.nn.silu(causal_conv(xb_raw, params["conv_w"], params["conv_b"]))
    proj = xb @ params["x_proj"]                  # (B,S,dtr+2N)
    dt_low = proj[..., :dtr]
    bmat = proj[..., dtr:dtr + d_state].astype(jnp.float32)
    cmat = proj[..., dtr + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt_low @ params["dt_proj"]).astype(jnp.float32) + params["dt_bias"])
    a_neg = -jnp.exp(params["A_log"])
    h0 = jnp.zeros((b, di, d_state), jnp.float32)
    y, h_final = mamba1_scan(xb, dt, bmat, cmat, a_neg, h0, chunk,
                             remat=remat)
    y = y + params["D"] * xb.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["out_proj"]
    if return_state:
        k = params["conv_w"].shape[0]
        state = {"conv": xb_raw[:, s - (k - 1):], "ssm": h_final}
        return out, state
    return out


def mamba1_decode(params, x_tok: jax.Array, state: dict, *, d_state: int,
                  dt_rank: int) -> Tuple[jax.Array, dict]:
    """One step. x_tok: (B, D); state: {"conv": (B,k-1,di), "ssm": (B,di,N)}."""
    xz = x_tok @ params["in_proj"]
    xb, z = jnp.split(xz, 2, axis=-1)
    xb, conv_state = conv_step(xb, state["conv"], params["conv_w"],
                               params["conv_b"])
    xb = jax.nn.silu(xb)
    proj = xb @ params["x_proj"]
    dtr = dt_rank
    dt = jax.nn.softplus(
        (proj[..., :dtr] @ params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"])                                  # (B, di)
    bm = proj[..., dtr:dtr + d_state].astype(jnp.float32)     # (B, N)
    cm = proj[..., dtr + d_state:].astype(jnp.float32)
    a_neg = -jnp.exp(params["A_log"])                         # (di, N)
    h = state["ssm"]
    h = h * jnp.exp(dt[..., None] * a_neg) \
        + (dt * xb.astype(jnp.float32))[..., None] * bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, cm) + params["D"] * xb.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_tok.dtype)
    return y @ params["out_proj"], {"conv": conv_state, "ssm": h}


# -------------------------------------------------------------- mamba-2
def ssd_scan(x, dt, bmat, cmat, a_neg, h0, chunk: int,
             remat: bool = False):
    """SSD chunked scan (Mamba-2).

    x: (B, S, nh, P); dt: (B, S, nh); bmat/cmat: (B, S, N);
    a_neg: (nh,); h0: (B, nh, P, N).  Returns (y, h_final).
    """
    b, s, nh, p = x.shape
    n = bmat.shape[-1]
    k = _divisor_chunk(s, chunk)
    nc = s // k

    def to_chunks(t):
        return t.reshape(b, nc, k, *t.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(x.astype(jnp.float32)), to_chunks(dt.astype(jnp.float32)),
          to_chunks(bmat.astype(jnp.float32)),
          to_chunks(cmat.astype(jnp.float32)))

    tri = jnp.tril(jnp.ones((k, k), bool))

    def step(h, inp):
        xk, dtk, bk, ck = inp                      # (B,K,nh,P),(B,K,nh),(B,K,N)
        da = dtk * a_neg                           # (B,K,nh)
        cum = jnp.cumsum(da, axis=1)               # (B,K,nh)
        # Intra-chunk: decay-masked CB^T "attention".
        cb = jnp.einsum("btn,bsn->bts", ck, bk)    # (B,K,K)
        diff = cum[:, :, None, :] - cum[:, None, :, :]   # (B,K,K,nh)
        w = cb[..., None] * jnp.exp(jnp.where(tri[None, ..., None], diff, 0.0))
        w = jnp.where(tri[None, ..., None], w, 0.0)
        xdt = xk * dtk[..., None]                  # (B,K,nh,P)
        y_intra = jnp.einsum("btsh,bshp->bthp", w, xdt)
        # Inter-chunk: contribution of the carried state.
        y_inter = jnp.einsum("btn,bhpn,bth->bthp", ck, h, jnp.exp(cum))
        # State update.
        rem = jnp.exp(cum[:, -1:, :] - cum)        # (B,K,nh)
        h_new = h * jnp.exp(cum[:, -1])[:, :, None, None] \
            + jnp.einsum("bshp,bsn,bsh->bhpn", xdt, bk, rem)
        return h_new, y_intra + y_inter

    if remat:
        step = jax.checkpoint(step)
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    y = ys.swapaxes(0, 1).reshape(b, s, nh, p)
    return y, h_final


def mamba2_block(params, x: jax.Array, *, d_state: int, head_dim: int,
                 chunk: int, norm_eps: float = 1e-5,
                 return_state: bool = False, remat: bool = False):
    """Full Mamba-2 mixer. x: (B, S, D) -> (B, S, D)."""
    b, s, _ = x.shape
    nh = params["A_log"].shape[0]
    di = nh * head_dim
    proj = x @ params["in_proj"]
    z = proj[..., :di]
    xbc_raw = proj[..., di:di + di + 2 * d_state]
    dt_raw = proj[..., -nh:]
    xbc = jax.nn.silu(causal_conv(xbc_raw, params["conv_w"],
                                  params["conv_b"]))
    xb = xbc[..., :di].reshape(b, s, nh, head_dim)
    bmat = xbc[..., di:di + d_state].astype(jnp.float32)
    cmat = xbc[..., di + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a_neg = -jnp.exp(params["A_log"])
    h0 = jnp.zeros((b, nh, head_dim, d_state), jnp.float32)
    y, h_final = ssd_scan(xb, dt, bmat, cmat, a_neg, h0, chunk,
                          remat=remat)
    y = y + params["D"][:, None] * xb.astype(jnp.float32)
    y = y.reshape(b, s, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)),
                params["gate_norm"], norm_eps).astype(x.dtype)
    out = y @ params["out_proj"]
    if return_state:
        k = params["conv_w"].shape[0]
        state = {"conv": xbc_raw[:, s - (k - 1):], "ssm": h_final}
        return out, state
    return out


def mamba2_decode(params, x_tok: jax.Array, state: dict, *, d_state: int,
                  head_dim: int, norm_eps: float = 1e-5):
    """One step. state: {"conv": (B,k-1,di+2N), "ssm": (B,nh,P,N)}."""
    nh = params["A_log"].shape[0]
    di = nh * head_dim
    b = x_tok.shape[0]
    proj = x_tok @ params["in_proj"]
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * d_state]
    dt_raw = proj[..., -nh:]
    xbc, conv_state = conv_step(xbc, state["conv"], params["conv_w"],
                                params["conv_b"])
    xbc = jax.nn.silu(xbc)
    xb = xbc[..., :di].reshape(b, nh, head_dim).astype(jnp.float32)
    bm = xbc[..., di:di + d_state].astype(jnp.float32)
    cm = xbc[..., di + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a_neg = -jnp.exp(params["A_log"])
    h = state["ssm"] * jnp.exp(dt * a_neg)[..., None, None] \
        + jnp.einsum("bhp,bn,bh->bhpn", xb, bm, dt)
    y = jnp.einsum("bhpn,bn->bhp", h, cm) + params["D"][:, None] * xb
    y = y.reshape(b, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)),
                params["gate_norm"], norm_eps).astype(x_tok.dtype)
    return y @ params["out_proj"], {"conv": conv_state, "ssm": h}
