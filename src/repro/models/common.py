"""Shared building blocks: norms, RoPE, MLPs, initializers."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, in_axis: int = 0, scale: float = 1.0,
               dtype=jnp.bfloat16):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis]
    std = scale / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def rmsnorm_init(d: int, dtype=jnp.bfloat16):
    return jnp.ones((d,), dtype)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * w


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n_heads, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- MLP
def mlp_init(key, d: int, f: int, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wi": dense_init(k1, (d, f), 0, dtype=dtype),
            "wg": dense_init(k2, (d, f), 0, dtype=dtype),
            "wo": dense_init(k3, (f, d), 0, dtype=dtype)}


def mlp_apply(params, x: jax.Array, act: str = "silu") -> jax.Array:
    h = x @ params["wi"]
    g = x @ params["wg"]
    if act == "silu":
        h = jax.nn.silu(g) * h
    elif act == "relu2":           # squared ReLU (nemotron-4)
        h = jnp.square(jax.nn.relu(g)) * h
    else:
        raise ValueError(act)
    return h @ params["wo"]


def mlp_specs(par, stacked: bool = True):
    return {"wi": par.w_col(stacked), "wg": par.w_col(stacked),
            "wo": par.w_row(stacked)}
