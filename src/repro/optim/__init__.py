from repro.optim.adamw import AdamWConfig, init as adamw_init, update as adamw_update
from repro.optim.clipping import clip_by_global_norm, global_norm
from repro.optim.schedule import warmup_cosine

__all__ = ["AdamWConfig", "adamw_init", "adamw_update",
           "clip_by_global_norm", "global_norm", "warmup_cosine"]
