"""Int8 error-feedback gradient compression for cross-pod all-reduce.

At 512+ chips the slow links are the cross-pod ones; we compress the
pod-axis gradient reduction to int8 with per-tensor dynamic scale and
error feedback (residual carried to the next step), a standard
distributed-optimization trick (1-bit Adam / EF-SGD family).

``compressed_psum_tree`` is the raw collective (call inside shard_map
with the reduction axis manual); ``apply_ef`` wraps quantize->psum->
dequantize with the EF residual state.  Correctness is validated in
tests/test_distributed.py on an 8-device subprocess mesh.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jax.Array, axes) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 with a pmax-shared scale."""
    xf = x.astype(jnp.float32)
    local_amax = jnp.max(jnp.abs(xf))
    amax = jax.lax.pmax(local_amax, axes)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(x: jax.Array, axes, n_shards: int) -> jax.Array:
    """Mean over ``axes`` of x, int8 on the wire. Call inside shard_map."""
    q, scale = _quantize(x, axes)
    s = jax.lax.psum(q.astype(jnp.int32), axes)
    return (s.astype(jnp.float32) * scale / n_shards).astype(x.dtype)


def apply_ef(grads, ef_state, axes, n_shards: int):
    """Error-feedback compressed mean-reduction over ``axes``.

    grads/ef_state: matching pytrees (ef f32).  Returns (reduced_grads,
    new_ef_state).  The residual (g + e) - dequant(q) stays local.
    """
    def reduced(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = _quantize(corrected, axes)
        s = jax.lax.psum(q.astype(jnp.int32), axes)
        return (s.astype(jnp.float32) * scale / n_shards).astype(g.dtype)

    def residual(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = _quantize(corrected, axes)
        return corrected - q.astype(jnp.float32) * scale

    red = jax.tree_util.tree_map(reduced, grads, ef_state)
    ef = jax.tree_util.tree_map(residual, grads, ef_state)
    return red, ef


def init_ef(params) -> Dict[str, Any]:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
