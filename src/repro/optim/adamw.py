"""AdamW with bf16 params / f32 moments, built from scratch (no optax).

``update`` is pure and jit-safe; moments are stored in f32 regardless of
parameter dtype (mixed-precision training convention).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def update(grads, opt_state, params, lr, cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_opt_state)."""
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    tmap = jax.tree_util.tree_map
    new_m = tmap(lambda g, m: cfg.b1 * m + (1 - cfg.b1) * g.astype(
        jnp.float32), grads, opt_state["m"])
    new_v = tmap(lambda g, v: cfg.b2 * v + (1 - cfg.b2) * jnp.square(
        g.astype(jnp.float32)), grads, opt_state["v"])

    def upd(p, m, v):
        pf = p.astype(jnp.float32)
        pf = pf - lr * ((m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
                        + cfg.weight_decay * pf)
        return pf.astype(p.dtype)

    new_params = tmap(upd, params, new_m, new_v)
    return new_params, {"m": new_m, "v": new_v, "step": step}
