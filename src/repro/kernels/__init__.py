"""Pallas TPU kernels for the paper's compute hot spots (distance scans,
hashing, HLL merge) with jnp oracles in ref.py and wrappers in ops.py."""
