"""Pallas TPU kernel for packed Hamming distances (bit-sampling LSH path).

XOR + SWAR popcount on the VPU over uint32 words.  The word axis W is
small (2 words for the paper's 64-bit MNIST fingerprints), so one
``(TQ, TN, W)`` broadcast tile fits easily in VMEM
(128 * 128 * 8 words * 4 B = 512 KiB at the default tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_U = jnp.uint32


def _popcount(v):
    v = v - ((v >> 1) & np.uint32(0x55555555))
    v = (v & np.uint32(0x33333333)) + ((v >> 2) & np.uint32(0x33333333))
    v = (v + (v >> 4)) & np.uint32(0x0F0F0F0F)
    return ((v * np.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _kernel(q_ref, x_ref, out_ref):
    x = q_ref[...][:, None, :] ^ x_ref[...][None, :, :]
    out_ref[...] = jnp.sum(_popcount(x), axis=-1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("tq", "tn", "interpret"))
def hamming_pallas(qc: jax.Array, xc: jax.Array, *, tq: int = 128,
                   tn: int = 128, interpret: bool = False) -> jax.Array:
    """(Q, W) x (N, W) packed uint32 codes -> (Q, N) int32 distances."""
    nq, w = qc.shape
    nn = xc.shape[0]
    assert nq % tq == 0 and nn % tn == 0, (qc.shape, xc.shape)
    grid = (nq // tq, nn // tn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, w), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tq, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, nn), jnp.int32),
        interpret=interpret,
    )(qc.astype(_U), xc.astype(_U))
