"""Public jit'd wrappers around the Pallas kernels.

Handles (a) padding inputs to tile multiples and slicing outputs back,
(b) backend dispatch: on TPU -> compiled Pallas kernels, elsewhere ->
the pure-jnp oracles in ``ref.py`` (Pallas ``interpret=True`` is for
correctness tests, not speed).  Callers may force a backend with
``impl=`` ("pallas", "pallas_interpret", "ref", None = auto).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import distances as _dist
from repro.kernels import fused_scan as _fs
from repro.kernels import hamming as _ham
from repro.kernels import hll_merge as _hllm
from repro.kernels import ref as _ref
from repro.kernels import simhash as _sim

__all__ = ["pairwise_dist", "hamming_dist", "simhash_fingerprint",
           "hll_merge_estimate", "pad_to", "metric_radius_transform",
           "fused_linear_scan", "fused_lsh_scan", "resolve_impl"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: Optional[str]) -> str:
    if impl is not None:
        return impl
    return "pallas" if _on_tpu() else "ref"


def resolve_impl(impl: Optional[str] = None) -> str:
    """The backend an ``impl=`` override actually dispatches to (public:
    the tracer labels per-route kernel timings with this)."""
    return _resolve(impl)


def pad_to(x: jax.Array, mult: int, axis: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def metric_radius_transform(metric: str, r: float) -> float:
    """Map a user radius to the raw-kernel comparison value.

    The L2 kernels return *squared* distances, so the threshold is r^2;
    other metrics are identity.
    """
    return r * r if metric == "l2" else r


def pairwise_dist(q: jax.Array, x: jax.Array, metric: str,
                  impl: Optional[str] = None) -> jax.Array:
    """(Q, d) x (N, d) -> (Q, N) float32 distances.

    NOTE: metric "l2" returns SQUARED L2 (compare against r^2 via
    ``metric_radius_transform``) — avoids a full-matrix sqrt on the scan.
    """
    impl = _resolve(impl)
    if impl == "ref":
        if metric == "l2":
            return _ref.pairwise_sql2(q, x)
        if metric == "l1":
            return _ref.pairwise_l1(q, x)
        if metric == "cosine":
            return _ref.pairwise_cosine(q, x)
        raise ValueError(metric)

    interpret = impl == "pallas_interpret"
    nq, nn = q.shape[0], x.shape[0]
    if metric == "cosine":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)

    if metric in ("l2", "cosine"):
        tq, tn, td = _dist.DEFAULT_TQ, _dist.DEFAULT_TN, _dist.DEFAULT_TD
        tq, tn, td = min(tq, 128 if interpret else tq), \
            min(tn, 128 if interpret else tn), min(td, 128 if interpret else td)
        qp = pad_to(pad_to(q, tq, 0), td, 1)
        xp = pad_to(pad_to(x, tn, 0), td, 1)
        qn = jnp.sum(qp.astype(jnp.float32) ** 2, axis=-1)
        xn = jnp.sum(xp.astype(jnp.float32) ** 2, axis=-1)
        out = _dist.pairwise_dot_pallas(
            qp, xp, qn, xn, mode="l2" if metric == "l2" else "cosine",
            tq=tq, tn=tn, td=td, interpret=interpret)
        out = out[:nq, :nn]
        return jnp.maximum(out, 0.0) if metric == "l2" else out
    if metric == "l1":
        tq = tn = td = 128
        qp = pad_to(pad_to(q, tq, 0), td, 1)
        xp = pad_to(pad_to(x, tn, 0), td, 1)
        return _dist.pairwise_l1_pallas(qp, xp, tq=tq, tn=tn, td=td,
                                        interpret=interpret)[:nq, :nn]
    raise ValueError(metric)


def hamming_dist(qc: jax.Array, xc: jax.Array,
                 impl: Optional[str] = None) -> jax.Array:
    """(Q, W) x (N, W) packed uint32 -> (Q, N) int32 Hamming distances."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.hamming(qc, xc)
    interpret = impl == "pallas_interpret"
    nq, nn = qc.shape[0], xc.shape[0]
    tq = tn = 128
    qp = pad_to(qc, tq, 0)
    xp = pad_to(xc, tn, 0)
    return _ham.hamming_pallas(qp, xp, tq=tq, tn=tn,
                               interpret=interpret)[:nq, :nn]


def pad_projection(r: jax.Array, L: int, k: int) -> jax.Array:
    """(d, L*k) projection -> (d, L*words*32) zero-padded per table."""
    d = r.shape[0]
    words = (k + 31) // 32
    r = r.reshape(d, L, k)
    r = jnp.pad(r, ((0, 0), (0, 0), (0, words * 32 - k)))
    return r.reshape(d, L * words * 32)


def simhash_fingerprint(x: jax.Array, r: jax.Array, L: int, k: int,
                        impl: Optional[str] = None) -> jax.Array:
    """(N, d) points, (d, L*k) projections -> (N, L, ceil(k/32)) u32."""
    impl = _resolve(impl)
    words = (k + 31) // 32
    rp = pad_projection(r, L, k)
    if impl == "ref":
        return _ref.simhash_fingerprint(x, rp, L, words)
    interpret = impl == "pallas_interpret"
    n = x.shape[0]
    tn = 128
    xp = pad_to(x, tn, 0)
    return _sim.simhash_pallas(xp, rp, L=L, words=words, tn=tn,
                               interpret=interpret)[:n]


def fused_linear_scan(q: jax.Array, x: jax.Array, r, metric: str,
                      impl: Optional[str] = None):
    """Fused linear-route scan: distance + threshold + report mask +
    candidate ids in ONE kernel pass over (Q, N) tiles.

    q: (Q, d) queries ((Q, W) packed u32 codes for hamming); x: (N, d)
    corpus ((N, W) for hamming); r: report radius (traced OK).
    Returns (ids (Q, N) i32, dists (Q, N) f32, mask (Q, N) bool) —
    identical to the composed ``pairwise_dist`` -> compare ->
    broadcast-ids pipeline, without materializing the intermediates.
    """
    impl = _resolve(impl)
    thresh = metric_radius_transform(metric, r)
    if impl == "ref":
        return _ref.fused_linear_scan(q, x, thresh, metric)
    interpret = impl == "pallas_interpret"
    t = jnp.full((1, 1), thresh, jnp.float32)
    nq, nn = q.shape[0], x.shape[0]
    sl = lambda a: a[:nq, :nn]
    if metric == "hamming":
        tq = tn = 128
        d_i, m, i = _fs.linear_scan_hamming_pallas(
            t, pad_to(q, tq, 0), pad_to(x, tn, 0), tq=tq, tn=tn,
            interpret=interpret)
        return sl(i), sl(d_i).astype(jnp.float32), sl(m).astype(bool)
    if metric == "cosine":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    if metric in ("l2", "cosine"):
        tq, tn, td = _dist.DEFAULT_TQ, _dist.DEFAULT_TN, _dist.DEFAULT_TD
        tq, tn, td = min(tq, 128 if interpret else tq), \
            min(tn, 128 if interpret else tn), min(td, 128 if interpret else td)
        qp = pad_to(pad_to(q, tq, 0), td, 1)
        xp = pad_to(pad_to(x, tn, 0), td, 1)
        qn = jnp.sum(qp.astype(jnp.float32) ** 2, axis=-1)
        xn = jnp.sum(xp.astype(jnp.float32) ** 2, axis=-1)
        dd, m, i = _fs.linear_scan_dot_pallas(
            t, qp, xp, qn, xn, mode="l2" if metric == "l2" else "cosine",
            tq=tq, tn=tn, td=td, interpret=interpret)
        return sl(i), sl(dd), sl(m).astype(bool)
    if metric == "l1":
        tq = tn = td = 128
        qp = pad_to(pad_to(q, tq, 0), td, 1)
        xp = pad_to(pad_to(x, tn, 0), td, 1)
        dd, m, i = _fs.linear_scan_l1_pallas(t, qp, xp, tq=tq, tn=tn, td=td,
                                             interpret=interpret)
        return sl(i), sl(dd), sl(m).astype(bool)
    raise ValueError(metric)


def fused_lsh_scan(x: jax.Array, ids_sorted: jax.Array, q: jax.Array, r,
                   metric: str, impl: Optional[str] = None):
    """Fused LSH-route candidate verification: sorted-run dedup + row
    gather + rowwise distance + threshold in ONE kernel pass over the
    (Q, C) candidate tiles — the composed ``dedupe_sorted`` ->
    ``x[ids]`` -> ``rowwise_dist`` -> compare chain without the
    (Q, C, d) gathered-rows materialization.

    x: (n, d) corpus ((n, W) packed u32 for hamming); ids_sorted:
    (Q, C) *sorted* candidate ids with sentinel = n (the int32 sort is
    the caller's — it is the cheap d-independent stage); q: (Q, d).
    Returns (ids (Q, C) i32, dists (Q, C) f32, mask (Q, C) bool) with
    duplicates, sentinels, and out-of-radius rows masked.
    """
    impl = _resolve(impl)
    thresh = metric_radius_transform(metric, r)
    n = x.shape[0]
    prev = jnp.concatenate(
        [jnp.full(ids_sorted.shape[:-1] + (1,), -1, ids_sorted.dtype),
         ids_sorted[..., :-1]], axis=-1)
    if impl == "ref":
        return _ref.fused_lsh_scan(x, ids_sorted, prev, q, thresh, metric)
    interpret = impl == "pallas_interpret"
    t = jnp.full((1, 1), thresh, jnp.float32)
    nq, c = ids_sorted.shape
    tq, tc = _fs.LSH_TQ, _fs.LSH_TC
    sent = jnp.int32(n)
    ids_p = pad_to(pad_to(ids_sorted.astype(jnp.int32), tq, 0, value=sent),
                   tc, 1, value=sent)
    prev_p = pad_to(pad_to(prev.astype(jnp.int32), tq, 0, value=sent),
                    tc, 1, value=sent)
    # corpus rows 8-aligned, lanes 128-aligned (zeros: norms unaffected,
    # XOR-popcount unaffected; gathers are clipped to the real n rows)
    xp = pad_to(pad_to(x, 8, 0), 128, 1)
    qp = pad_to(pad_to(q, tq, 0), 128, 1)
    dd, m = _fs.lsh_scan_pallas(t, xp, qp, ids_p, prev_p, metric=metric,
                                n=n, tq=tq, tc=tc, interpret=interpret)
    return ids_sorted, dd[:nq, :c], m[:nq, :c].astype(bool)


def hll_merge_estimate(regs: jax.Array,
                       impl: Optional[str] = None) -> jax.Array:
    """(Q, L, m) uint8 registers -> (Q,) float32 candSize estimates."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.hll_merge_estimate(regs)
    interpret = impl == "pallas_interpret"
    q = regs.shape[0]
    tq = 8 if interpret else 64
    rp = pad_to(regs, tq, 0)
    return _hllm.hll_merge_estimate_pallas(rp, tq=tq,
                                           interpret=interpret)[:q]
