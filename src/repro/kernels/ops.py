"""Public jit'd wrappers around the Pallas kernels.

Handles (a) padding inputs to tile multiples and slicing outputs back,
(b) backend dispatch: on TPU -> compiled Pallas kernels, elsewhere ->
the pure-jnp oracles in ``ref.py`` (Pallas ``interpret=True`` is for
correctness tests, not speed).  Callers may force a backend with
``impl=`` ("pallas", "pallas_interpret", "ref", None = auto).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import distances as _dist
from repro.kernels import hamming as _ham
from repro.kernels import hll_merge as _hllm
from repro.kernels import ref as _ref
from repro.kernels import simhash as _sim

__all__ = ["pairwise_dist", "hamming_dist", "simhash_fingerprint",
           "hll_merge_estimate", "pad_to", "metric_radius_transform"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: Optional[str]) -> str:
    if impl is not None:
        return impl
    return "pallas" if _on_tpu() else "ref"


def pad_to(x: jax.Array, mult: int, axis: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def metric_radius_transform(metric: str, r: float) -> float:
    """Map a user radius to the raw-kernel comparison value.

    The L2 kernels return *squared* distances, so the threshold is r^2;
    other metrics are identity.
    """
    return r * r if metric == "l2" else r


def pairwise_dist(q: jax.Array, x: jax.Array, metric: str,
                  impl: Optional[str] = None) -> jax.Array:
    """(Q, d) x (N, d) -> (Q, N) float32 distances.

    NOTE: metric "l2" returns SQUARED L2 (compare against r^2 via
    ``metric_radius_transform``) — avoids a full-matrix sqrt on the scan.
    """
    impl = _resolve(impl)
    if impl == "ref":
        if metric == "l2":
            return _ref.pairwise_sql2(q, x)
        if metric == "l1":
            return _ref.pairwise_l1(q, x)
        if metric == "cosine":
            return _ref.pairwise_cosine(q, x)
        raise ValueError(metric)

    interpret = impl == "pallas_interpret"
    nq, nn = q.shape[0], x.shape[0]
    if metric == "cosine":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)

    if metric in ("l2", "cosine"):
        tq, tn, td = _dist.DEFAULT_TQ, _dist.DEFAULT_TN, _dist.DEFAULT_TD
        tq, tn, td = min(tq, 128 if interpret else tq), \
            min(tn, 128 if interpret else tn), min(td, 128 if interpret else td)
        qp = pad_to(pad_to(q, tq, 0), td, 1)
        xp = pad_to(pad_to(x, tn, 0), td, 1)
        qn = jnp.sum(qp.astype(jnp.float32) ** 2, axis=-1)
        xn = jnp.sum(xp.astype(jnp.float32) ** 2, axis=-1)
        out = _dist.pairwise_dot_pallas(
            qp, xp, qn, xn, mode="l2" if metric == "l2" else "cosine",
            tq=tq, tn=tn, td=td, interpret=interpret)
        out = out[:nq, :nn]
        return jnp.maximum(out, 0.0) if metric == "l2" else out
    if metric == "l1":
        tq = tn = td = 128
        qp = pad_to(pad_to(q, tq, 0), td, 1)
        xp = pad_to(pad_to(x, tn, 0), td, 1)
        return _dist.pairwise_l1_pallas(qp, xp, tq=tq, tn=tn, td=td,
                                        interpret=interpret)[:nq, :nn]
    raise ValueError(metric)


def hamming_dist(qc: jax.Array, xc: jax.Array,
                 impl: Optional[str] = None) -> jax.Array:
    """(Q, W) x (N, W) packed uint32 -> (Q, N) int32 Hamming distances."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.hamming(qc, xc)
    interpret = impl == "pallas_interpret"
    nq, nn = qc.shape[0], xc.shape[0]
    tq = tn = 128
    qp = pad_to(qc, tq, 0)
    xp = pad_to(xc, tn, 0)
    return _ham.hamming_pallas(qp, xp, tq=tq, tn=tn,
                               interpret=interpret)[:nq, :nn]


def pad_projection(r: jax.Array, L: int, k: int) -> jax.Array:
    """(d, L*k) projection -> (d, L*words*32) zero-padded per table."""
    d = r.shape[0]
    words = (k + 31) // 32
    r = r.reshape(d, L, k)
    r = jnp.pad(r, ((0, 0), (0, 0), (0, words * 32 - k)))
    return r.reshape(d, L * words * 32)


def simhash_fingerprint(x: jax.Array, r: jax.Array, L: int, k: int,
                        impl: Optional[str] = None) -> jax.Array:
    """(N, d) points, (d, L*k) projections -> (N, L, ceil(k/32)) u32."""
    impl = _resolve(impl)
    words = (k + 31) // 32
    rp = pad_projection(r, L, k)
    if impl == "ref":
        return _ref.simhash_fingerprint(x, rp, L, words)
    interpret = impl == "pallas_interpret"
    n = x.shape[0]
    tn = 128
    xp = pad_to(x, tn, 0)
    return _sim.simhash_pallas(xp, rp, L=L, words=words, tn=tn,
                               interpret=interpret)[:n]


def hll_merge_estimate(regs: jax.Array,
                       impl: Optional[str] = None) -> jax.Array:
    """(Q, L, m) uint8 registers -> (Q,) float32 candSize estimates."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.hll_merge_estimate(regs)
    interpret = impl == "pallas_interpret"
    q = regs.shape[0]
    tq = 8 if interpret else 64
    rp = pad_to(regs, tq, 0)
    return _hllm.hll_merge_estimate_pallas(rp, tq=tq,
                                           interpret=interpret)[:q]
