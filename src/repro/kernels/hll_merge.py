"""Pallas TPU kernel for the O(m*L) HLL merge + estimate (Algorithm 2,
line 2) — the step the paper adds on the query path.

Per query: max-merge the (L, m) gathered registers, then the HLL
estimator with small/large-range corrections.  Entirely VPU work on a
``(TQ, L, m)`` tile (64 * 64 * 128 * 4 B = 2 MiB at defaults); memory
bound, but fusing merge+estimate avoids a round trip of the merged
registers through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _alpha(m: int) -> float:
    if m <= 16:
        return 0.673
    if m <= 32:
        return 0.697
    if m <= 64:
        return 0.709
    return 0.7213 / (1.0 + 1.079 / m)


def _kernel(regs_ref, out_ref, *, m: int):
    regs = regs_ref[...].astype(jnp.int32)              # (TQ, L, m)
    merged = jnp.max(regs, axis=1)                      # (TQ, m)
    rf = merged.astype(jnp.float32)
    raw = _alpha(m) * m * m / jnp.sum(jnp.exp2(-rf), axis=-1)
    zeros = jnp.sum((merged == 0).astype(jnp.float32), axis=-1)
    small = m * jnp.log(m / jnp.maximum(zeros, 1e-9))
    est = jnp.where((raw <= 2.5 * m) & (zeros > 0), small, raw)
    two32 = jnp.float32(2.0**32)
    est = jnp.where(est > two32 / 30.0, -two32 * jnp.log1p(-est / two32), est)
    out_ref[...] = est


@functools.partial(jax.jit, static_argnames=("tq", "interpret"))
def hll_merge_estimate_pallas(regs: jax.Array, *, tq: int = 64,
                              interpret: bool = False) -> jax.Array:
    """(Q, L, m) uint8 registers -> (Q,) float32 candSize estimates."""
    q, L, m = regs.shape
    assert q % tq == 0, regs.shape
    grid = (q // tq,)
    return pl.pallas_call(
        functools.partial(_kernel, m=m),
        grid=grid,
        in_specs=[pl.BlockSpec((tq, L, m), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tq,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.float32),
        interpret=interpret,
    )(regs)
