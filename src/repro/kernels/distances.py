"""Pallas TPU kernels for blocked pairwise distances (the linear-scan and
candidate-verification hot spot — step S3 of the paper's cost model).

Design (TPU-native, not a CUDA port):
  * The L2/cosine scans are decomposed so the inner loop is a
    ``(TQ, TD) @ (TD, TN)`` matmul that runs on the MXU; norms are
    precomputed (O(N·d), done once per database) and added on the first
    d-block only.
  * Tiles are 128-aligned (MXU/VREG lanes); the d (contraction) axis is
    blocked so the working set ``TQ*TD + TN*TD + TQ*TN`` floats stays
    well inside VMEM (default tiles: 256*256*3*4B = 768 KiB).
  * L1 has no matmul form; its kernel broadcasts a ``(TQ, TN, TD)``
    tile on the VPU and accumulates over d-blocks.

Grid is (Q/TQ, N/TN, D/TD) with the contraction axis innermost; the
output block for (i, j) is revisited across k, initialized at k == 0.
Inputs must be pre-padded to tile multiples (ops.py does this).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TQ = 256
DEFAULT_TN = 256
DEFAULT_TD = 256


def _dot_kernel(q_ref, x_ref, qn_ref, xn_ref, out_ref, *, mode: str):
    """out[i,j] (+)= norms - 2 q.x  (l2)  |  1 - q.x (cosine, normalized)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        if mode == "l2":
            out_ref[...] = qn_ref[...][:, None] + xn_ref[...][None, :]
        else:  # cosine: inputs pre-normalized, distance = 1 - dot
            out_ref[...] = jnp.ones_like(out_ref)

    acc = jnp.dot(q_ref[...], x_ref[...].T,
                  preferred_element_type=jnp.float32)
    scale = 2.0 if mode == "l2" else 1.0
    out_ref[...] = out_ref[...] - scale * acc


def _l1_kernel(q_ref, x_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    diff = jnp.abs(q_ref[...][:, None, :] - x_ref[...][None, :, :])
    out_ref[...] = out_ref[...] + jnp.sum(diff, axis=-1)


@functools.partial(jax.jit, static_argnames=("mode", "tq", "tn", "td",
                                             "interpret"))
def pairwise_dot_pallas(q: jax.Array, x: jax.Array, qn: jax.Array,
                        xn: jax.Array, *, mode: str = "l2",
                        tq: int = DEFAULT_TQ, tn: int = DEFAULT_TN,
                        td: int = DEFAULT_TD,
                        interpret: bool = False) -> jax.Array:
    """Blocked (Q, d) x (N, d) -> (Q, N) squared-L2 or cosine distances.

    Shapes must already be padded: Q % tq == N % tn == d % td == 0.
    ``qn``/``xn`` are squared norms (ignored for cosine but still tiled).
    """
    nq, d = q.shape
    nn = x.shape[0]
    assert nq % tq == 0 and nn % tn == 0 and d % td == 0, (q.shape, x.shape)
    grid = (nq // tq, nn // tn, d // td)
    return pl.pallas_call(
        functools.partial(_dot_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, td), lambda i, j, k: (i, k)),
            pl.BlockSpec((tn, td), lambda i, j, k: (j, k)),
            pl.BlockSpec((tq,), lambda i, j, k: (i,)),
            pl.BlockSpec((tn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((tq, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, nn), jnp.float32),
        interpret=interpret,
    )(q.astype(jnp.float32), x.astype(jnp.float32),
      qn.astype(jnp.float32), xn.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("tq", "tn", "td", "interpret"))
def pairwise_l1_pallas(q: jax.Array, x: jax.Array, *, tq: int = 128,
                       tn: int = 128, td: int = 128,
                       interpret: bool = False) -> jax.Array:
    """Blocked (Q, d) x (N, d) -> (Q, N) L1 distances (VPU broadcast)."""
    nq, d = q.shape
    nn = x.shape[0]
    assert nq % tq == 0 and nn % tn == 0 and d % td == 0, (q.shape, x.shape)
    grid = (nq // tq, nn // tn, d // td)
    return pl.pallas_call(
        _l1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, td), lambda i, j, k: (i, k)),
            pl.BlockSpec((tn, td), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tq, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq, nn), jnp.float32),
        interpret=interpret,
    )(q.astype(jnp.float32), x.astype(jnp.float32))
