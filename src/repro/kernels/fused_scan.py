"""Fused Pallas kernels for the two query routes (the candidate scan).

The composed hot path runs distance, threshold, report-mask (and, on
the LSH route, row gather + dedup) as separate XLA kernels, writing the
full ``(Q, N)`` / ``(Q, C, d)`` intermediates to HBM between stages.
These kernels fuse each route into one ``pallas_call`` that streams
candidate blocks through VMEM once — the blockwise Q_CHUNK/K_CHUNK
pattern: fixed-size tiles, online accumulate over the contraction axis,
no full intermediate materialization.

Linear route (``linear_scan_*_pallas``): grid ``(Q/tq, N/tn[, d/td])``
with the contraction axis innermost.  The distance tile accumulates in
the revisited output block (init at ``k == 0``, exactly like
``distances.py``); on the *last* d-block the epilogue applies the L2
clamp, compares against the threshold, and writes the report mask and
the candidate-id tile in place — the separate compare/broadcast-ids
passes of the composed path never touch HBM.

LSH route (``lsh_scan_pallas``): grid ``(Q/tq, C/tc)`` over the sorted
candidate-id tiles.  Per tile the kernel masks duplicate runs and
sentinels (``ids != prev & ids < n`` — the sorted-run half of
``dedupe_sorted``; the (Q, C) int32 sort itself stays an XLA op, it is
the d-independent cheap part), gathers each candidate row from the
resident corpus block by dynamic slice into a VMEM scratch, and runs
the rowwise distance + threshold on the gathered tile.  The composed
path's ``(Q, C, d)`` gathered-rows buffer — the dominant HBM traffic of
the route — is never materialized; only the ``(Q, C)`` distances and
mask leave the kernel.

Memory spaces: candidate ids ride twice — an SMEM copy feeding the
scalar dynamic-slice gathers and a VMEM copy for the vectorized dedup
compare.  The corpus block is resident (constant index map), so a
segment's rows must fit VMEM (~16 MB/core); the LSM stack bounds
segment size, and ``ops.py`` falls back to the jnp oracle elsewhere.

Thresholds arrive as (1, 1) SMEM scalars (they are traced values — the
radius is a runtime argument).  Masks are written as int8 (TPU-tileable)
and cast to bool by the ``ops`` wrappers; sentinel semantics (internal
sentinel = n) match ``core.search`` bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Linear-route tiles mirror distances.py (MXU-aligned); the LSH route
# tiles the candidate axis at one VREG row of lanes per query row.
DEFAULT_TQ = 256
DEFAULT_TN = 256
DEFAULT_TD = 256
LSH_TQ = 8
LSH_TC = 128


def _popcount_u32(v):
    """SWAR popcount (same as ref.popcount_u32, VPU-friendly)."""
    v = v.astype(jnp.uint32)
    v = v - ((v >> 1) & np.uint32(0x55555555))
    v = (v & np.uint32(0x33333333)) + ((v >> 2) & np.uint32(0x33333333))
    v = (v + (v >> 4)) & np.uint32(0x0F0F0F0F)
    return ((v * np.uint32(0x01010101)) >> 24).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Linear route: distance + threshold + report mask + candidate ids
# ---------------------------------------------------------------------------
def _linear_dot_kernel(t_ref, q_ref, x_ref, qn_ref, xn_ref,
                       dist_ref, mask_ref, ids_ref, *, mode, nk, tn):
    """Accumulate norms - 2 q.x (l2) | 1 - q.x (cosine) over d-blocks;
    epilogue on the last block: clamp, threshold, ids."""
    k = pl.program_id(2)
    j = pl.program_id(1)     # read outside @pl.when (interpret-mode rule)

    @pl.when(k == 0)
    def _init():
        if mode == "l2":
            dist_ref[...] = qn_ref[...][:, None] + xn_ref[...][None, :]
        else:   # cosine: inputs pre-normalized, distance = 1 - dot
            dist_ref[...] = jnp.ones_like(dist_ref)

    acc = jnp.dot(q_ref[...], x_ref[...].T,
                  preferred_element_type=jnp.float32)
    scale = 2.0 if mode == "l2" else 1.0
    dist_ref[...] = dist_ref[...] - scale * acc

    @pl.when(k == nk - 1)
    def _report():
        d = dist_ref[...]
        if mode == "l2":
            d = jnp.maximum(d, 0.0)
            dist_ref[...] = d
        mask_ref[...] = (d <= t_ref[0, 0]).astype(jnp.int8)
        ids_ref[...] = (jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
                        + j * tn)


def _linear_l1_kernel(t_ref, q_ref, x_ref, dist_ref, mask_ref, ids_ref,
                      *, nk, tn):
    k = pl.program_id(2)
    j = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        dist_ref[...] = jnp.zeros_like(dist_ref)

    diff = jnp.abs(q_ref[...][:, None, :] - x_ref[...][None, :, :])
    dist_ref[...] = dist_ref[...] + jnp.sum(diff, axis=-1)

    @pl.when(k == nk - 1)
    def _report():
        d = dist_ref[...]
        mask_ref[...] = (d <= t_ref[0, 0]).astype(jnp.int8)
        ids_ref[...] = (jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
                        + j * tn)


def _linear_hamming_kernel(t_ref, q_ref, x_ref, dist_ref, mask_ref, ids_ref,
                           *, tn):
    """Packed-code XOR + popcount, single shot per (i, j) tile (the code
    width is not blocked — W words fit a tile)."""
    x = q_ref[...][:, None, :] ^ x_ref[...][None, :, :]
    d = jnp.sum(_popcount_u32(x), axis=-1, dtype=jnp.int32)
    dist_ref[...] = d
    mask_ref[...] = (d.astype(jnp.float32) <= t_ref[0, 0]).astype(jnp.int8)
    j = pl.program_id(1)
    ids_ref[...] = (jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
                    + j * tn)


def _linear_out(nq, nn, tq, tn, dist_dtype):
    specs = [pl.BlockSpec((tq, tn), lambda i, j, k: (i, j))] * 3
    shapes = [jax.ShapeDtypeStruct((nq, nn), dist_dtype),
              jax.ShapeDtypeStruct((nq, nn), jnp.int8),
              jax.ShapeDtypeStruct((nq, nn), jnp.int32)]
    return specs, shapes


@functools.partial(jax.jit, static_argnames=("mode", "tq", "tn", "td",
                                             "interpret"))
def linear_scan_dot_pallas(thresh: jax.Array, q: jax.Array, x: jax.Array,
                           qn: jax.Array, xn: jax.Array, *, mode: str = "l2",
                           tq: int = DEFAULT_TQ, tn: int = DEFAULT_TN,
                           td: int = DEFAULT_TD, interpret: bool = False):
    """Fused (Q, d) x (N, d) -> (dists f32, mask i8, ids i32), all (Q, N).

    Shapes pre-padded (ops.py): Q % tq == N % tn == d % td == 0;
    ``thresh`` is a (1, 1) f32 scalar (r^2 for l2).
    """
    nq, d = q.shape
    nn = x.shape[0]
    assert nq % tq == 0 and nn % tn == 0 and d % td == 0, (q.shape, x.shape)
    grid = (nq // tq, nn // tn, d // td)
    out_specs, out_shape = _linear_out(nq, nn, tq, tn, jnp.float32)
    return pl.pallas_call(
        functools.partial(_linear_dot_kernel, mode=mode, nk=grid[2], tn=tn),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((tq, td), lambda i, j, k: (i, k)),
            pl.BlockSpec((tn, td), lambda i, j, k: (j, k)),
            pl.BlockSpec((tq,), lambda i, j, k: (i,)),
            pl.BlockSpec((tn,), lambda i, j, k: (j,)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(thresh.astype(jnp.float32), q.astype(jnp.float32),
      x.astype(jnp.float32), qn.astype(jnp.float32), xn.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("tq", "tn", "td", "interpret"))
def linear_scan_l1_pallas(thresh: jax.Array, q: jax.Array, x: jax.Array, *,
                          tq: int = 128, tn: int = 128, td: int = 128,
                          interpret: bool = False):
    """Fused L1 scan -> (dists f32, mask i8, ids i32), all (Q, N)."""
    nq, d = q.shape
    nn = x.shape[0]
    assert nq % tq == 0 and nn % tn == 0 and d % td == 0, (q.shape, x.shape)
    grid = (nq // tq, nn // tn, d // td)
    out_specs, out_shape = _linear_out(nq, nn, tq, tn, jnp.float32)
    return pl.pallas_call(
        functools.partial(_linear_l1_kernel, nk=grid[2], tn=tn),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((tq, td), lambda i, j, k: (i, k)),
            pl.BlockSpec((tn, td), lambda i, j, k: (j, k)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(thresh.astype(jnp.float32), q.astype(jnp.float32),
      x.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("tq", "tn", "interpret"))
def linear_scan_hamming_pallas(thresh: jax.Array, qc: jax.Array,
                               xc: jax.Array, *, tq: int = 128,
                               tn: int = 128, interpret: bool = False):
    """Fused packed-code Hamming scan -> (dists i32, mask i8, ids i32)."""
    nq, w = qc.shape
    nn = xc.shape[0]
    assert nq % tq == 0 and nn % tn == 0, (qc.shape, xc.shape)
    grid = (nq // tq, nn // tn, 1)
    out_specs, out_shape = _linear_out(nq, nn, tq, tn, jnp.int32)
    return pl.pallas_call(
        functools.partial(_linear_hamming_kernel, tn=tn),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((tq, w), lambda i, j, k: (i, 0)),
            pl.BlockSpec((tn, w), lambda i, j, k: (j, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(thresh.astype(jnp.float32), qc.astype(jnp.uint32),
      xc.astype(jnp.uint32))


# ---------------------------------------------------------------------------
# LSH route: sorted-run dedup + row gather + rowwise distance + threshold
# ---------------------------------------------------------------------------
def _lsh_kernel(t_ref, ids_sm, x_ref, q_ref, ids_ref, prev_ref,
                dist_ref, mask_ref, scratch, *, metric, n, tq, tc):
    """One (tq, tc) candidate tile: dedup-mask, gather, verify.

    ``ids_sm`` is the SMEM copy of the sorted candidate tile (scalar
    reads drive the dynamic-slice row gathers); ``ids_ref``/``prev_ref``
    are the VMEM copies for the vectorized run-boundary compare.  The
    rowwise math is kept expression-identical to ``ref.rowwise_dist``.
    """
    ids_v = ids_ref[...]
    uniq = (ids_v != prev_ref[...]) & (ids_v < n)        # sorted-run dedup
    thresh = t_ref[0, 0]
    for qi in range(tq):     # static unroll: stores at static row offsets

        def gather(c, carry):
            idx = jnp.clip(ids_sm[qi, c], 0, n - 1)
            scratch[pl.ds(c, 1), :] = x_ref[pl.ds(idx, 1), :]
            return carry

        jax.lax.fori_loop(0, tc, gather, 0)
        rows = scratch[...]                              # (tc, d) in VMEM
        if metric == "hamming":
            qv = q_ref[qi, :].astype(jnp.uint32)
            dist = jnp.sum(_popcount_u32(rows ^ qv[None, :]),
                           axis=-1).astype(jnp.float32)
        else:
            qv = q_ref[qi, :]
            if metric == "l2":
                diff = rows - qv[None, :]
                dist = jnp.sum(diff * diff, axis=-1)
            elif metric == "l1":
                dist = jnp.sum(jnp.abs(rows - qv[None, :]), axis=-1)
            else:   # cosine (pad columns are zero: norms unaffected)
                rn = rows / jnp.maximum(
                    jnp.sqrt(jnp.sum(rows * rows, -1, keepdims=True)), 1e-12)
                qn = qv / jnp.maximum(jnp.sqrt(jnp.sum(qv * qv)), 1e-12)
                dist = 1.0 - jnp.sum(rn * qn[None, :], axis=-1)
        dist_ref[qi, :] = dist
        mask_ref[qi, :] = (uniq[qi] & (dist <= thresh)).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("metric", "n", "tq", "tc",
                                             "interpret"))
def lsh_scan_pallas(thresh: jax.Array, x: jax.Array, q: jax.Array,
                    ids: jax.Array, prev: jax.Array, *, metric: str, n: int,
                    tq: int = LSH_TQ, tc: int = LSH_TC,
                    interpret: bool = False):
    """Fused LSH-route verification -> (dists f32, mask i8), both (Q, C).

    x: (n_pad, d_pad) resident corpus block (rows >= n are pad; never
    gathered — ids are clipped to n - 1); q: (Q, d_pad); ids/prev:
    (Q, C) sorted candidate ids and their left-shift (prev[0] = -1),
    sentinel = ``n``.  Q % tq == C % tc == 0 (ops.py pads; sentinel
    padding makes padded slots self-masking).
    """
    nq, c = ids.shape
    assert nq % tq == 0 and c % tc == 0, (ids.shape, tq, tc)
    assert q.shape[1] == x.shape[1], (q.shape, x.shape)
    grid = (nq // tq, c // tc)
    dtype = jnp.uint32 if metric == "hamming" else jnp.float32
    return pl.pallas_call(
        functools.partial(_lsh_kernel, metric=metric, n=n, tq=tq, tc=tc),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # thresh
            pl.BlockSpec((tq, tc), lambda i, j: (i, j),
                         memory_space=pltpu.SMEM),                 # ids tile
            pl.BlockSpec(x.shape, lambda i, j: (0, 0)),            # corpus
            pl.BlockSpec((tq, q.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((tq, tc), lambda i, j: (i, j)),
            pl.BlockSpec((tq, tc), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((tq, tc), lambda i, j: (i, j)),
            pl.BlockSpec((tq, tc), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, c), jnp.float32),
            jax.ShapeDtypeStruct((nq, c), jnp.int8),
        ],
        scratch_shapes=[pltpu.VMEM((tc, x.shape[1]), dtype)],
        interpret=interpret,
    )(thresh.astype(jnp.float32), ids.astype(jnp.int32), x.astype(dtype),
      q.astype(dtype), ids.astype(jnp.int32), prev.astype(jnp.int32))
