"""Pallas TPU kernel for SimHash fingerprinting (hash step S1).

Projection is a ``(TN, d) @ (d, L*W*32)`` MXU matmul; sign extraction
and bit packing (dot with 2^j) run on the VPU.  The projection matrix is
replicated into VMEM across grid steps (d and L*k are small for LSH use:
d <= ~1k, L*k <= ~2k  ->  <= ~8 MiB f32).

The projection matrix is pre-padded by ops.py to ``(d, L * W * 32)``
with zero columns beyond each table's true k bits; zero projections
yield 0-bits, matching families._pack_bits and ref.simhash_fingerprint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_U = jnp.uint32


def _kernel(x_ref, r_ref, out_ref, *, L: int, words: int):
    proj = jnp.dot(x_ref[...], r_ref[...],
                   preferred_element_type=jnp.float32)   # (TN, L*W*32)
    tn = proj.shape[0]
    bits = (proj > 0).reshape(tn, L, words, 32).astype(_U)
    powers = jnp.asarray(np.uint32(1), _U) << jnp.arange(32, dtype=_U)
    out_ref[...] = jnp.sum(bits * powers, axis=-1, dtype=_U)


@functools.partial(jax.jit, static_argnames=("L", "words", "tn", "interpret"))
def simhash_pallas(x: jax.Array, r_padded: jax.Array, *, L: int, words: int,
                   tn: int = 256, interpret: bool = False) -> jax.Array:
    """(N, d) x (d, L*words*32) -> packed fingerprints (N, L, words) u32."""
    n, d = x.shape
    assert n % tn == 0, x.shape
    assert r_padded.shape == (d, L * words * 32), r_padded.shape
    grid = (n // tn,)
    return pl.pallas_call(
        functools.partial(_kernel, L=L, words=words),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, d), lambda i: (i, 0)),
            pl.BlockSpec((d, L * words * 32), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tn, L, words), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, L, words), _U),
        interpret=interpret,
    )(x.astype(jnp.float32), r_padded.astype(jnp.float32))
