"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references (``assert_allclose`` targets in
tests) AND the CPU execution path: on the CPU container the ops layer
dispatches here, while on TPU it dispatches to the Pallas kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_UINT = jnp.uint32


def popcount_u32(v: jax.Array) -> jax.Array:
    """Classic SWAR popcount for uint32."""
    v = v.astype(_UINT)
    v = v - ((v >> 1) & np.uint32(0x55555555))
    v = (v & np.uint32(0x33333333)) + ((v >> 2) & np.uint32(0x33333333))
    v = (v + (v >> 4)) & np.uint32(0x0F0F0F0F)
    return ((v * np.uint32(0x01010101)) >> 24).astype(jnp.int32)


def pairwise_sql2(q: jax.Array, x: jax.Array) -> jax.Array:
    """Squared L2 distances, (Q, d) x (N, d) -> (Q, N) float32.

    MXU-friendly decomposition ||q||^2 - 2<q,x> + ||x||^2 (this is the
    exact form the Pallas kernel tiles).
    """
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qn = jnp.sum(q * q, axis=-1)
    xn = jnp.sum(x * x, axis=-1)
    d = qn[:, None] + xn[None, :] - 2.0 * (q @ x.T)
    return jnp.maximum(d, 0.0)


def pairwise_l1(q: jax.Array, x: jax.Array) -> jax.Array:
    """L1 distances, (Q, d) x (N, d) -> (Q, N) float32."""
    return jnp.sum(jnp.abs(q.astype(jnp.float32)[:, None, :]
                           - x.astype(jnp.float32)[None, :, :]), axis=-1)


def pairwise_cosine(q: jax.Array, x: jax.Array) -> jax.Array:
    """Cosine distances 1 - cos(q, x), (Q, d) x (N, d) -> (Q, N)."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    return 1.0 - qn @ xn.T


def rowwise_dist(rows: jax.Array, q: jax.Array, metric: str) -> jax.Array:
    """rows: (..., C, d) candidates vs q: (..., d) -> (..., C) distances.

    The candidate-verification math (gather-bound, plain VPU ops; L2
    returns squared distance, consistent with pairwise_sql2).  This is
    the expression the fused LSH-route kernel replicates per tile —
    ``core.search.rowwise_dist`` delegates here.
    """
    if metric == "hamming":
        x = rows.astype(_UINT) ^ q[..., None, :].astype(_UINT)
        return jnp.sum(popcount_u32(x), axis=-1).astype(jnp.float32)
    rows = rows.astype(jnp.float32)
    q = q.astype(jnp.float32)[..., None, :]
    if metric == "l2":
        d = rows - q
        return jnp.sum(d * d, axis=-1)
    if metric == "l1":
        return jnp.sum(jnp.abs(rows - q), axis=-1)
    if metric == "cosine":
        rn = rows / jnp.maximum(
            jnp.linalg.norm(rows, axis=-1, keepdims=True), 1e-12)
        qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True),
                             1e-12)
        return 1.0 - jnp.sum(rn * qn, axis=-1)
    raise ValueError(metric)


def fused_linear_scan(q: jax.Array, x: jax.Array, thresh,
                      metric: str):
    """Oracle for the fused linear-route scan: the composed pipeline
    (pairwise distance -> threshold -> broadcast ids) as plain jnp.
    Returns (ids, dists, mask), each (Q, N); ``thresh`` is already
    radius-transformed (r^2 for l2)."""
    if metric == "hamming":
        dists = hamming(q, x).astype(jnp.float32)
    elif metric == "l2":
        dists = pairwise_sql2(q, x)
    elif metric == "l1":
        dists = pairwise_l1(q, x)
    elif metric == "cosine":
        dists = pairwise_cosine(q, x)
    else:
        raise ValueError(metric)
    mask = dists <= thresh
    ids = jnp.broadcast_to(jnp.arange(x.shape[0], dtype=jnp.int32),
                           dists.shape)
    return ids, dists, mask


def fused_lsh_scan(x: jax.Array, ids_sorted: jax.Array, prev: jax.Array,
                   q: jax.Array, thresh, metric: str):
    """Oracle for the fused LSH-route scan: sorted-run dedup -> row
    gather -> rowwise distance -> threshold, as plain jnp.

    ids_sorted: (Q, C) sorted candidate ids with sentinel = x.shape[0];
    prev: ids_sorted shifted right one slot (prev[..., 0] = -1), so
    ``ids != prev`` marks run starts — identical to
    ``core.search.dedupe_sorted``'s first-occurrence mask on sorted
    input.  Returns (ids_sorted, dists, mask), each (Q, C).
    """
    n = x.shape[0]
    uniq = (ids_sorted != prev) & (ids_sorted < n)
    rows = x[jnp.clip(ids_sorted, 0, n - 1)]             # (Q, C, d)
    dists = rowwise_dist(rows, q, metric)
    mask = uniq & (dists <= thresh)
    return ids_sorted, dists, mask


def hamming(qc: jax.Array, xc: jax.Array) -> jax.Array:
    """Hamming distances over packed codes, (Q, W) x (N, W) -> (Q, N) i32."""
    x = qc.astype(_UINT)[:, None, :] ^ xc.astype(_UINT)[None, :, :]
    return jnp.sum(popcount_u32(x), axis=-1, dtype=jnp.int32)


def simhash_fingerprint(x: jax.Array, r_padded: jax.Array, L: int,
                        words: int) -> jax.Array:
    """SimHash fingerprints, (N, d) x (d, L*words*32) -> (N, L, words) u32.

    ``r_padded`` has zero columns beyond the family's true k bits per
    table (zero projection -> bit 0, matching families._pack_bits).
    """
    proj = x.astype(jnp.float32) @ r_padded.astype(jnp.float32)
    bits = (proj > 0).reshape(x.shape[0], L, words, 32).astype(_UINT)
    powers = jnp.asarray(np.uint32(1), _UINT) << jnp.arange(32, dtype=_UINT)
    return jnp.sum(bits * powers, axis=-1, dtype=_UINT)


def hll_merge_estimate(regs: jax.Array) -> jax.Array:
    """Merge (Q, L, m) registers over L and estimate cardinality -> (Q,).

    Must match repro.core.hll exactly (merge + estimator with
    small/large-range corrections).
    """
    from repro.core import hll as hll_lib
    merged = hll_lib.merge_registers(regs.astype(jnp.int32), axis=1)
    return hll_lib.estimate_cardinality(merged, int(regs.shape[-1]))
