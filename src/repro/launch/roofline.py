"""Roofline terms from a compiled dry-run artifact (TPU v5e model).

The SPMD-partitioned module is per-device, so cost_analysis FLOPs/bytes
and HLO tensor shapes are already per-chip quantities:

  compute term    = flops_per_chip / peak_flops
  memory term     = bytes_per_chip / hbm_bw
  collective term = wire_bytes_per_chip / link_bw

wire bytes come from parsing the optimized HLO for collective ops and
summing result-tensor bytes with a per-op wire factor (all-reduce moves
~2x its payload ring-wise; gather/scatter/permute ~1x).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e-like hardware model (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_LINK_BW = 50e9              # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

# one result tensor:  bf16[16,512,128]{...}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# op line:  %name = <shape or tuple> opcode(
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s*"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-type wire bytes (per device) from optimized HLO."""
    out = {c: 0.0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_txt, op = m.group(1), m.group(2)
        out[op] += _shape_bytes(shape_txt) * _WIRE_FACTOR[op]
        counts[op] += 1
    out["_counts"] = counts
    return out


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    bytes_per_chip: float
    wire_bytes_per_chip: float
    model_flops_per_chip: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS: remat/masking/redundancy waste."""
        return self.model_flops_per_chip / max(self.flops_per_chip, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / achievable step time: the score."""
        model_t = self.model_flops_per_chip / PEAK_FLOPS_BF16
        return model_t / max(self.bound_s, 1e-30)


def terms_from_cost(cost: Dict[str, float], wire_bytes: float,
                    model_flops_global: float, chips: int) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=byts / HBM_BW,
        collective_s=wire_bytes / ICI_LINK_BW,
        flops_per_chip=flops,
        bytes_per_chip=byts,
        wire_bytes_per_chip=wire_bytes,
        model_flops_per_chip=model_flops_global / chips,
    )


def linear_scan_traffic(nq: int, n: int, d: int,
                        dtype_bytes: int = 4) -> Dict[str, float]:
    """Analytic HBM bytes for one linear-route scan, composed vs fused.

    Both variants must read the inputs (q, x) and write the reporting
    buffers (dists f32, mask i8, ids i32).  The composed pipeline
    additionally writes the (Q, N) distance matrix and reads it back
    for the threshold compare — the traffic the fused kernel deletes.
    """
    inputs = (nq * d + n * d) * dtype_bytes
    outputs = nq * n * (4 + 1 + 4)
    intermediate = nq * n * (4 + 4)         # dist write + compare re-read
    return {"fused_bytes": float(inputs + outputs),
            "composed_bytes": float(inputs + outputs + intermediate)}


def lsh_scan_traffic(nq: int, c: int, d: int,
                     dtype_bytes: int = 4) -> Dict[str, float]:
    """Analytic HBM bytes for one LSH-route verification, composed vs
    fused, over (Q, C) candidates of d-dim rows.

    Both variants read the candidate ids (sorted + prev) and the corpus
    rows they reference, and write the (Q, C) dists + mask.  The
    composed pipeline materializes the gathered (Q, C, d) rows — one
    write plus one re-read for the rowwise distance — which is the
    dominant traffic of the route and what the fused kernel deletes.
    """
    ids = nq * c * 4 * 2
    gather_read = nq * c * d * dtype_bytes
    outputs = nq * c * (4 + 1)
    intermediate = nq * c * d * dtype_bytes * 2   # rows write + re-read
    return {"fused_bytes": float(ids + gather_read + outputs),
            "composed_bytes": float(ids + gather_read + outputs
                                    + intermediate)}


def scan_memory_seconds(n_bytes: float) -> float:
    """Memory-roofline seconds for ``n_bytes`` of HBM traffic."""
    return float(n_bytes) / HBM_BW


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs per step (global).

    train: 6 * N_active * tokens;  prefill: 2 * N_active * tokens;
    decode: 2 * N_active * global_batch (one token each).
    """
    n = cfg.num_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch
